"""End-to-end driver (deliverable): serve a small hybrid model with batched
requests through the full two-cluster PrfaaS-PD deployment — routing by the
SAME ``core.router.Router`` the cluster simulator uses, real prefill on the
"PrfaaS cluster", byte-accurate KV transfer over a simulated Ethernet link
(layer-wise pipelined), continuous-batching decode on the "PD cluster",
prefix-cache hits on follow-up turns.  (For N regions, int8 KV on the wire,
and simulator cross-validation, see ``python -m repro.launch.serve``.)

    PYTHONPATH=src python examples/serve_cross_dc.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving import CrossDCDeployment, DeploymentConfig, Request

ARCH = "kimi-linear-1t"          # the paper's case-study family

cfg = get_smoke_config(ARCH)
model = Model(cfg, use_kernels=False)
params = model.init(jax.random.PRNGKey(0))
dep = CrossDCDeployment(
    model, params,
    DeploymentConfig(threshold=64,       # offload prefills > 64 new tokens
                     link_gbps=0.05,     # deliberately skinny inter-DC link
                     decode_slots=8, capacity=512, block_tokens=16))

rng = np.random.default_rng(0)
print(f"serving {ARCH} (smoke scale): threshold=64 tok, link=0.05 Gbps\n")

# --- turn 1: a mixed batch of short and long prompts -----------------------
prompts = {i: rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
           for i, L in enumerate([24, 48, 150, 230, 90, 300])}
reqs = [Request(rid=i, tokens=p, max_new_tokens=12)
        for i, p in prompts.items()]
out = dep.submit_batch(reqs)
print("turn 1 (cold caches):")
for r in reqs:
    print(f"  req {r.rid}: len={len(r.tokens):4d} -> {r.route:7s} "
          f"cached={r.cached_tokens:4d} kv={r.kv_bytes:8d}B "
          f"prefill={r.prefill_s*1e3:7.1f}ms transfer={r.transfer_s*1e3:7.1f}ms")

# --- turn 2: agentic follow-ups (same prefix + new tokens) ------------------
follow = []
for i, p in list(prompts.items())[:4]:
    grown = np.concatenate([p, rng.integers(0, cfg.vocab_size, (40,))
                            .astype(np.int32)])
    follow.append(Request(rid=100 + i, tokens=grown, max_new_tokens=8))
dep.submit_batch(follow)
print("\nturn 2 (incremental prefills after prefix-cache hits):")
for r in follow:
    print(f"  req {r.rid}: len={len(r.tokens):4d} -> {r.route:7s} "
          f"cached={r.cached_tokens:4d} (incremental "
          f"{len(r.tokens)-r.cached_tokens})")

m = dep.metrics()
print(f"\nsummary: {m['requests']} requests, {m['offloaded']} offloaded, "
      f"mean TTFT {m['ttft_mean_s']*1e3:.1f} ms, "
      f"cross-DC KV {m['kv_bytes_total']} bytes, "
      f"hit rates {m['cache_hit_rate']}")
# the deployment's inter-DC links and routing policy are the same code the
# cluster simulator runs (core.transfer.LinkTopology + core.router.Router):
# concurrent KV flows in a prefill batch contend on the exact fair-share
# solver, and per-home thresholds adapt from each region's own congestion
print(f"link: {dep.link.sent_bytes:.0f} bytes on the wire, "
      f"busy {dep.link.busy_time*1e3:.1f} ms (virtual), "
      f"thresholds {m['thresholds']}")

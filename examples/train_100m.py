"""Train a hybrid LM (KDA:MLA 3:1 — the paper's architecture family) with
the full production training stack: AdamW, remat, gradient accumulation,
async atomic checkpointing, straggler detection, crash-resume.

Two scales:
  * --scale 8m   (default) — CPU-feasible demo (~60 steps, loss visibly
    drops in a few minutes on this container);
  * --scale 100m — the real recipe (~100M params, a few hundred steps);
    sized for accelerators, runs unchanged there via the same entry point.

    PYTHONPATH=src python examples/train_100m.py
    PYTHONPATH=src python examples/train_100m.py --scale 100m --steps 300
"""
import argparse
import shutil

import jax

from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                LinearSpec, ModelConfig)
from repro.models import Model
from repro.training import (AdamWConfig, DataConfig, SyntheticLM,
                            TrainConfig, TrainLoop, init_opt_state)


def hybrid_lm(scale: str) -> ModelConfig:
    if scale == "100m":
        d, dk, heads, dff, vocab, reps = 512, 64, 8, 2048, 8192, 3
    else:                                    # ~8M (1-core friendly)
        d, dk, heads, dff, vocab, reps = 256, 32, 4, 1024, 4096, 2
    kda = LinearSpec(kind="kda", heads=heads, key_dim=dk, value_dim=dk,
                     conv_kernel=4)
    mla = AttentionSpec(kind="mla", q_heads=heads, kv_heads=heads,
                        head_dim=dk, mla_kv_rank=2 * dk, mla_rope_dim=dk // 2)
    ffn = FFNSpec(kind="dense", d_ff=dff, activation="swiglu")
    return ModelConfig(
        name=f"hybrid-{scale}", family="hybrid", d_model=d, vocab_size=vocab,
        groups=(GroupSpec(blocks=(BlockSpec(kda, ffn), BlockSpec(kda, ffn),
                                  BlockSpec(kda, ffn), BlockSpec(mla, ffn)),
                          repeats=reps),),
        tie_embeddings=True, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="8m", choices=["8m", "100m"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    args = ap.parse_args()
    steps = args.steps or (60 if args.scale == "8m" else 300)
    batch = args.batch or (4 if args.scale == "8m" else 32)
    seq = args.seq or (128 if args.scale == "8m" else 1024)

    cfg = hybrid_lm(args.scale)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{cfg.n_layers} layers (KDA:MLA 3:1), "
          f"{steps} steps x {batch}x{seq} tokens")
    model = Model(cfg, use_kernels=False, remat=True)
    params = model.init(jax.random.PRNGKey(0))

    ckpt = f"/tmp/repro_{cfg.name}_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)
    stragglers = []
    tc = TrainConfig(
        microbatches=2, remat=True,
        adamw=AdamWConfig(lr=1e-3, warmup_steps=max(5, steps // 15),
                          total_steps=steps),
        checkpoint_every=max(20, steps // 4), checkpoint_dir=ckpt)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch))
    loop = TrainLoop(model, tc, data,
                     on_straggler=lambda s, r: stragglers.append((s, r)))
    _, _, hist = loop.run(params, init_opt_state(params, tc), steps)

    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
          f"{len(hist)} steps "
          f"({sum(h['time_s'] for h in hist)/len(hist)*1e3:.0f} ms/step)")
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3, "training failed"
    print(f"straggler flags: {len(stragglers)}; checkpoints in {ckpt}")


if __name__ == "__main__":
    main()

"""Quickstart: the PrfaaS idea in 60 seconds.

1. Build a hybrid-attention model (the paper's KDA:MLA 3:1 family) and a
   dense baseline; show the S_kv asymmetry that makes cross-DC KV plausible.
2. Feed the paper's measured profile into the throughput model (Eqs. 1-8),
   grid-search (t, N_p/N_d), and reproduce Table 6.
3. Run one real prefill -> ship the KVCache -> decode from it, verifying
   the shipped bytes match the S_kv accounting.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import (SystemConfig, ThroughputModel, Workload,
                        kv_throughput, paper_h20_profile,
                        paper_h200_profile)
from repro.models import Model, prepare_decode_caches
from repro.models.kvcache import cache_num_bytes

print("=" * 72)
print("1. Why hybrid models change the PD deployment boundary (paper §2.2)")
print("=" * 72)
hybrid = get_config("kimi-linear-1t")      # the paper's case-study family
dense = get_config("mistral-nemo-12b")
for l in (8192, 32768, 131072):
    print(f"  S_kv({l//1024:>4}K): hybrid-1T = "
          f"{hybrid.kv_cache_bytes(l)/2**20:8.1f} MiB   "
          f"dense-12B = {dense.kv_cache_bytes(l)/2**20:8.1f} MiB")
phi = kv_throughput(paper_h200_profile(), 32768) * 8 / 1e9
print(f"  1T hybrid KV throughput @32K: {phi:.1f} Gbps -> commodity Ethernet")

print()
print("=" * 72)
print("2. Throughput model + grid search reproduces Table 6 (paper §4)")
print("=" * 72)
w = Workload()
tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
sc, lam, _ = tm.grid_search(n_prfaas=4, n_pd_total=8, b_out=100e9 / 8)
tm_h = ThroughputModel(None, paper_h20_profile(), w)
_, lam_h, _ = tm_h.grid_search(0, 12, 0)
print(f"  optimal: t={sc.threshold/1000:.1f}K tokens (paper 19.4K), "
      f"N_p/N_d={sc.n_p}/{sc.n_d} (paper 3/5)")
print(f"  PrfaaS-PD {lam:.2f} req/s vs homogeneous {lam_h:.2f} req/s "
      f"-> {lam/lam_h:.2f}x (paper 1.54x)")
print(f"  egress {tm.egress_load(sc)*8/1e9:.1f} Gbps of 100 Gbps "
      f"(paper ~13)")

print()
print("=" * 72)
print("3. Real prefill -> KV transfer -> decode (smoke-scale model)")
print("=" * 72)
cfg = get_smoke_config("kimi-linear-1t")
model = Model(cfg, use_kernels=False)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 64)), jnp.int32)
logits, caches = model.prefill(params, {"tokens": toks})
nbytes = cache_num_bytes(caches)
print(f"  prefill produced {nbytes} KV bytes "
      f"(would take {nbytes*8/1e9*1000:.2f} ms on a 1 Gbps inter-DC link)")
dc = prepare_decode_caches(cfg, caches, capacity=96)
tok = jnp.argmax(logits, -1).astype(jnp.int32)
out = [int(tok[0])]
lengths = jnp.full((1,), 64, jnp.int32)
for i in range(8):
    lg, dc = model.decode_step(params, tok, dc, lengths + i)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    out.append(int(tok[0]))
print(f"  decoded from shipped cache: {out}")
print("done.")

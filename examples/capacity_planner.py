"""Capacity planner: use the paper's throughput model + simulator to size a
PrfaaS-PD deployment for YOUR traffic — the operator-facing workflow the
paper's §3.4/§4 enables.

Sweeps PrfaaS cluster size and link bandwidth, reports achievable req/s,
optimal threshold, and egress demand; validates the chosen point under
bursty traffic with the discrete-event simulator; then splits the PD fleet
into three regional clusters (skewed traffic shares, thinner links to the
smaller regions) and re-validates over the multi-cluster ``LinkTopology``
with the regionalized control plane on: per-home routing thresholds,
per-region autoscaling, and session roaming over the PD<->PD mesh.

Finally, reads the scenario engine's cost-per-million-requests frontier
(``BENCH_scenario_grid.json``, produced by ``python -m
benchmarks.scenario_grid``) and recommends, per workload family, the
cheapest fleet meeting a target SLO attainment.

    PYTHONPATH=src python examples/capacity_planner.py
"""
import json
import os

from repro.core import (PrfaasSimulator, SimConfig, SystemConfig,
                        ThroughputModel, Workload, paper_h20_profile,
                        paper_h200_profile)

w = Workload()
tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)

print("PrfaaS-PD capacity plan (PD cluster fixed at 8 instances)")
print(f"{'N_prfaas':>9} {'link':>9} {'t*':>8} {'Np/Nd':>6} {'req/s':>7} "
      f"{'egress':>9} {'vs_none':>8}")
_, base, _ = ThroughputModel(None, paper_h20_profile(), w).grid_search(0, 8, 0)
best = None
for n_prfaas in (0, 2, 4, 8):
    for gbps in (10, 100, 400):
        if n_prfaas == 0 and gbps > 10:
            continue
        sc, lam, _ = tm.grid_search(n_prfaas, 8, gbps * 1e9 / 8) \
            if n_prfaas else ThroughputModel(
                None, paper_h20_profile(), w).grid_search(0, 8, 0)
        egress = tm.egress_load(sc) * 8 / 1e9 if n_prfaas else 0.0
        print(f"{n_prfaas:>9} {gbps:>7}Gb {sc.threshold/1000:>7.1f}K "
              f"{sc.n_p}/{sc.n_d:>4} {lam:>7.2f} {egress:>8.1f}Gb "
              f"{lam/base:>7.2f}x")
        if best is None or lam > best[1]:
            best = (sc, lam, gbps)

sc, lam, gbps = best
print(f"\nvalidating best plan under bursty traffic "
      f"(burst_factor=1.6, link fluctuation 20%):")
wb = Workload(burst_factor=1.6, burst_period_s=120.0, session_prob=0.3)
sim = PrfaasSimulator(tm, sc, wb, SimConfig(
    arrival_rate=0.85 * lam, sim_time=600, dt=0.05, seed=0,
    link_gbps=gbps, link_fluctuation=0.2, autoscale=True))
m = sim.run()
print(f"  sustained {m['throughput_rps']:.2f} req/s "
      f"(offered {0.85*lam:.2f}), TTFT p90 {m['ttft_p90']:.2f}s, "
      f"egress {m['egress_gbps']:.1f} Gbps, "
      f"router adjustments {m['router_adjustments']}, "
      f"threshold now {m['threshold']/1000:.1f}K")

# --- regional build-out: three PD clusters over a star topology -------------
shares = (0.5, 0.3, 0.2)
region_gbps = (100.0, 50.0, 25.0)             # thinner links to small regions


def share_split(total, shares, min_per=1):
    """Allocate instances ~proportional to regional traffic, >=1 each
    (a region with zero prefill instances models to zero capacity: its
    short requests have nowhere to run)."""
    alloc = [max(min_per, round(total * s)) for s in shares]
    alloc[0] += total - sum(alloc)            # rounding drift -> hot region
    return tuple(alloc)


sc_r, lam_r, _ = tm.grid_search(4, 12, 100e9 / 8)
sc3 = SystemConfig(sc_r.n_prfaas, sc_r.n_p, sc_r.n_d, sc_r.b_out,
                   sc_r.threshold,
                   n_p_clusters=share_split(sc_r.n_p, shares),
                   n_d_clusters=share_split(sc_r.n_d, shares))
lam3 = tm.lambda_max(sc3, pd_shares=list(shares))
print(f"\nregional build-out: 12 PD instances as 3 clusters "
      f"(shares {shares}, links {region_gbps} Gbps):")
print(f"  Np/Nd per region {sc3.n_p_clusters}/{sc3.n_d_clusters}; "
      f"modeled capacity {lam3:.2f} req/s "
      f"(vs {lam_r:.2f} pooled; regional split costs "
      f"{(1 - lam3/lam_r)*100:.0f}%)")
sim3 = PrfaasSimulator(tm, sc3, wb, SimConfig(
    arrival_rate=0.85 * lam3, sim_time=600, dt=0.05, seed=0,
    link_fluctuation=0.2, pd_clusters=3, pd_shares=shares,
    pd_link_gbps=region_gbps, pd_mesh_gbps=10.0,
    autoscale=True, roam_prob=0.1))       # regionalized control plane ON
m3 = sim3.run()
print(f"  sustained {m3['throughput_rps']:.2f} req/s, "
      f"TTFT p90 {m3['ttft_p90']:.2f}s, egress {m3['egress_gbps']:.1f} Gbps")
for name, c in m3["clusters"].items():
    print(f"    {name}: {c['throughput_rps']:.2f} req/s, "
          f"TTFT p90 {c['ttft_p90']:.2f}s, t {c['threshold']/1000:.1f}K, "
          f"cache-hit {c['cache_hit_frac']*100:.0f}%, "
          f"P<->D conversions {c['conversions']}")
for pair, s in m3["links"].items():
    if s["sent_bytes"]:
        kind = "mesh" if "prfaas" not in pair else "star"
        print(f"    {kind} link {pair}: "
              f"{s['sent_bytes']*8/1e9/600:.2f} Gbps avg "
              f"of {s['capacity_gbps']:.0f} Gbps")
# planner-side check at the state the sim actually converged to: the
# autoscalers' final per-region (n_p, n_d) plus the per-home thresholds
names = sorted(m3["thresholds"])
n_p_f = tuple(sim3.autoscalers[n].system.n_p for n in names)
n_d_f = tuple(sim3.autoscalers[n].system.n_d for n in names)
sc3_final = SystemConfig(sc_r.n_prfaas, sum(n_p_f), sum(n_d_f), sc_r.b_out,
                         sc_r.threshold,
                         n_p_clusters=n_p_f, n_d_clusters=n_d_f)
lam3_t = tm.lambda_max(sc3_final, pd_shares=list(shares),
                       thresholds=[m3["thresholds"][n] for n in names])
print(f"  modeled capacity at the converged allocation "
      f"{n_p_f}/{n_d_f} + per-home thresholds: {lam3_t:.2f} req/s")

# --- scenario-engine frontier: what does the SLO actually cost? ------------
# The scenario engine (benchmarks/scenario_grid.py) sweeps workload family
# x topology x policy x fleet size through the vectorized simulator and
# keeps the Pareto-optimal (cost-per-1M-requests, SLO attainment) points.
# The planner walks that frontier: cheapest fleet meeting the target.
TARGET_ATTAINMENT = 0.9
_bench = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      os.pardir, "BENCH_scenario_grid.json")
if not os.path.exists(_bench):
    print(f"\n(no BENCH_scenario_grid.json next to the repo root — run "
          f"`PYTHONPATH=src python -m benchmarks.scenario_grid` for the "
          f"cost/SLO frontier)")
else:
    with open(_bench) as f:
        _grid = json.load(f)
    frontier = _grid.get("frontier", {})
    slo = _grid.get("slo_ttft_s", 0.0)
    print(f"\ncost/SLO frontier by workload family "
          f"(TTFT SLO {slo:.0f}s, target attainment "
          f">={TARGET_ATTAINMENT:.0%}):")
    for fam, pts in frontier.items():
        curve = " -> ".join(f"${p['cost_per_mreq']:.0f}@"
                            f"{p['slo_attainment']:.2f}" for p in pts)
        print(f"  {fam}: {curve}")
        ok = [p for p in pts if p["slo_attainment"] >= TARGET_ATTAINMENT]
        if ok:
            p = ok[0]                 # frontier is sorted by cost
            print(f"    -> cheapest meeting target: "
                  f"{p['size']:.2f}x fleet, {p['pd_clusters']} region(s), "
                  f"{p['policy']} policy: ${p['cost_per_mreq']:.0f}/Mreq "
                  f"(attains {p['slo_attainment']:.1%}, "
                  f"p99 {p['ttft_p99_s']:.1f}s)")
        else:
            p = pts[-1]
            print(f"    -> NO swept fleet meets {TARGET_ATTAINMENT:.0%}; "
                  f"best is {p['size']:.2f}x/{p['policy']} at "
                  f"{p['slo_attainment']:.1%} — provision beyond "
                  f"{max(pt['size'] for pt in pts):.2f}x or relax the SLO")

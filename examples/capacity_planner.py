"""Capacity planner: use the paper's throughput model + simulator to size a
PrfaaS-PD deployment for YOUR traffic — the operator-facing workflow the
paper's §3.4/§4 enables.

Sweeps PrfaaS cluster size and link bandwidth, reports achievable req/s,
optimal threshold, and egress demand; then validates the chosen point under
bursty traffic with the discrete-event simulator.

    PYTHONPATH=src python examples/capacity_planner.py
"""
from repro.core import (PrfaasSimulator, SimConfig, ThroughputModel,
                        Workload, paper_h20_profile, paper_h200_profile)

w = Workload()
tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)

print("PrfaaS-PD capacity plan (PD cluster fixed at 8 instances)")
print(f"{'N_prfaas':>9} {'link':>9} {'t*':>8} {'Np/Nd':>6} {'req/s':>7} "
      f"{'egress':>9} {'vs_none':>8}")
_, base, _ = ThroughputModel(None, paper_h20_profile(), w).grid_search(0, 8, 0)
best = None
for n_prfaas in (0, 2, 4, 8):
    for gbps in (10, 100, 400):
        if n_prfaas == 0 and gbps > 10:
            continue
        sc, lam, _ = tm.grid_search(n_prfaas, 8, gbps * 1e9 / 8) \
            if n_prfaas else ThroughputModel(
                None, paper_h20_profile(), w).grid_search(0, 8, 0)
        egress = tm.egress_load(sc) * 8 / 1e9 if n_prfaas else 0.0
        print(f"{n_prfaas:>9} {gbps:>7}Gb {sc.threshold/1000:>7.1f}K "
              f"{sc.n_p}/{sc.n_d:>4} {lam:>7.2f} {egress:>8.1f}Gb "
              f"{lam/base:>7.2f}x")
        if best is None or lam > best[1]:
            best = (sc, lam, gbps)

sc, lam, gbps = best
print(f"\nvalidating best plan under bursty traffic "
      f"(burst_factor=1.6, link fluctuation 20%):")
wb = Workload(burst_factor=1.6, burst_period_s=120.0, session_prob=0.3)
sim = PrfaasSimulator(tm, sc, wb, SimConfig(
    arrival_rate=0.85 * lam, sim_time=600, dt=0.05, seed=0,
    link_gbps=gbps, link_fluctuation=0.2, autoscale=True))
m = sim.run()
print(f"  sustained {m['throughput_rps']:.2f} req/s "
      f"(offered {0.85*lam:.2f}), TTFT p90 {m['ttft_p90']:.2f}s, "
      f"egress {m['egress_gbps']:.1f} Gbps, "
      f"router adjustments {m['router_adjustments']}, "
      f"threshold now {m['threshold']/1000:.1f}K")

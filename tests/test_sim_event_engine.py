"""Event-driven simulator core: exact link solver, tick-engine equivalence,
MMPP mean preservation, cross-cache byte accounting, sim cache semantics."""
import itertools
import math
from collections import deque

import numpy as np
import pytest

from repro.core import (PD, PRFAAS, EventPool, Link, PrfaasSimulator,
                        Request, SimConfig, SystemConfig, ThroughputModel,
                        Workload, mmpp_rate, paper_h20_profile,
                        paper_h200_profile)
from repro.core.sim_cache import SimPrefixCache


@pytest.fixture(scope="module")
def setup():
    w = Workload()
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, rate, _ = tm.grid_search(4, 8, 100e9 / 8)
    return tm, sc, rate, w


# --------------------------------------------------------------------------
# exact progressive-filling link
# --------------------------------------------------------------------------
class TestExactLink:
    def test_single_flow_completion_exact(self):
        link = Link(8e9)                         # 1 GB/s
        f = link.submit(2e9, 0.0)
        link.run_until_idle()
        assert f.done_time == pytest.approx(2.0, abs=1e-9)

    def test_processor_sharing_two_flows_exact(self):
        link = Link(8e9)
        a = link.submit(0.5e9, 0.0)              # drains first
        b = link.submit(1.5e9, 0.0)
        link.run_until_idle()
        # share 0.5 GB/s each -> a done at 1.0; b then alone: 1.0 GB left
        # at full rate -> done at 2.0
        assert a.done_time == pytest.approx(1.0, abs=1e-9)
        assert b.done_time == pytest.approx(2.0, abs=1e-9)

    def test_paced_ramp_flow_caps_at_release_rate(self):
        link = Link(8e9)
        # R releases 1 GB linearly over [0, 2] (0.5 GB/s); E is eager 0.6 GB.
        # Progressive filling: R paced at 0.5, E gets the other 0.5
        # -> E done at 1.2; R stays paced -> done exactly at ramp end 2.0.
        r = link.submit(1e9, 0.0, ramp_end=2.0)
        e = link.submit(0.6e9, 0.0)
        link.run_until_idle()
        assert e.done_time == pytest.approx(1.2, abs=1e-9)
        assert r.done_time == pytest.approx(2.0, abs=1e-9)

    def test_backlogged_ramp_drains_after_ramp_end(self):
        link = Link(8e9)
        # 2 GB released over [0, 1] (2 GB/s) on a 1 GB/s link: 1 GB sent by
        # ramp end, remaining 1 GB backlog drains by t=2 exactly.
        f = link.submit(2e9, 0.0, ramp_end=1.0)
        link.run_until_idle()
        assert f.done_time == pytest.approx(2.0, abs=1e-9)

    def test_conservation_under_events(self):
        link = Link(8e9)
        for i in range(5):
            link.submit(5e8, 0.1 * i, ramp_end=0.1 * i + 0.3)
        link.advance(1.5)
        assert link.sent_bytes <= 1e9 * 1.5 * 1.0001

    def test_event_and_tick_links_agree(self):
        done_e, done_t = [], []
        le = Link(8e9)
        le.submit(1e9, 0.0, ramp_end=2.0,
                  on_done=lambda t: done_e.append(t))
        le.run_until_idle()
        lt = Link(8e9)
        from repro.core.transfer import layerwise_release
        lt.submit(1e9, 0.0, release=layerwise_release(0.0, 2.0, 1e9, 256),
                  on_done=lambda t: done_t.append(t))
        for i in range(400):
            lt.tick(i * 0.01, 0.01)
        assert done_e and done_t
        assert abs(done_e[0] - done_t[0]) < 0.05

    def test_future_start_flow_transfers_nothing_early(self):
        """A flow submitted ahead of the link clock (deployment virtual
        batches) must not move bytes before its start_time."""
        link = Link(8e9)                         # 1 GB/s
        f = link.submit(125e6, 10.0, ramp_end=10.0)   # eager, starts at t=10
        link.advance(5.0)
        assert f.sent == 0.0 and link.sent_bytes == 0.0
        link.run_until_idle()
        assert f.done_time == pytest.approx(10.125, abs=1e-9)

    def test_drops_signal_decays(self):
        link = Link(1e9)
        for _ in range(10):
            link.submit(1e9, 0.0)
        for i in range(100):
            link.tick(i * 0.05, 0.05)
        congested = link.congestion_signal()["drops"]
        assert congested > 0
        link.flows.clear()
        for i in range(2000):
            link.tick(5 + i * 0.05, 0.05)
        assert link.congestion_signal()["drops"] < 0.05 * congested
        assert link.drops_total >= congested      # cumulative still recorded


# --------------------------------------------------------------------------
# MMPP arrival modulation: mean rate preserved for any burst factor
# --------------------------------------------------------------------------
class TestMmppMeanPreserved:
    @pytest.mark.parametrize("bf", [1.5, 3.0])
    def test_rate_integral_matches_base(self, bf):
        base, period = 2.0, 60.0
        ts = np.linspace(0, period, 120_001)[:-1]
        mean = np.mean([mmpp_rate(base, bf, period, t) for t in ts])
        assert mean == pytest.approx(base, rel=1e-3)

    @pytest.mark.parametrize("bf", [1.5, 3.0])
    def test_generated_trace_preserves_offered_load(self, setup, bf):
        tm, sc, rate, _ = setup
        w = Workload(burst_factor=bf)
        sim = PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=2.0, sim_time=3000.0, seed=5))
        n = len(sim._generate_arrivals())
        assert n / 3000.0 == pytest.approx(2.0, rel=0.05)

    def test_seed_bug_would_have_inflated(self):
        """bf=3 with the seed's clamped low phase gave 1.5x the mean."""
        base, period = 2.0, 60.0
        ts = np.linspace(0, period, 120_001)[:-1]
        seed_mean = np.mean([base * (3.0 if (t % period) < period / 2
                                     else max(0.0, 2.0 - 3.0))
                             for t in ts])
        assert seed_mean == pytest.approx(1.5 * base, rel=1e-3)


# --------------------------------------------------------------------------
# event engine vs legacy tick engine (same arrival trace)
# --------------------------------------------------------------------------
class TestEngineEquivalence:
    def _both(self, tm, sc, w, rate, **kw):
        out = {}
        for engine in ("tick", "event"):
            sim = PrfaasSimulator(tm, sc, w, SimConfig(
                arrival_rate=rate, sim_time=360, dt=0.02, seed=11,
                engine=engine, **kw))
            out[engine] = sim.run()
        return out["tick"], out["event"]

    def test_poisson_scenario_within_5pct(self, setup):
        tm, sc, rate, w = setup
        t, e = self._both(tm, sc, w, 0.85 * rate)
        assert e["throughput_rps"] == pytest.approx(t["throughput_rps"],
                                                    rel=0.05)
        assert e["ttft_mean"] == pytest.approx(t["ttft_mean"], rel=0.05)
        assert e["ttft_p90"] == pytest.approx(t["ttft_p90"], rel=0.05)
        assert e["offload_frac"] == pytest.approx(t["offload_frac"],
                                                  rel=0.05)
        assert e["egress_gbps"] == pytest.approx(t["egress_gbps"], rel=0.05)

    def test_bursty_scenario_within_5pct(self, setup):
        tm, sc, rate, _ = setup
        w = Workload(burst_factor=1.5)
        t, e = self._both(tm, sc, w, 0.8 * rate)
        assert e["throughput_rps"] == pytest.approx(t["throughput_rps"],
                                                    rel=0.05)
        assert e["ttft_mean"] == pytest.approx(t["ttft_mean"], rel=0.05)
        assert e["ttft_p90"] == pytest.approx(t["ttft_p90"], rel=0.05)

    def test_block_boundary_admission_within_5pct(self, setup):
        """decode_block_tokens > 0 quantizes decode admission to the block
        grid (mirroring the serving RegionScheduler); both engines snap to
        the same absolute boundaries so equivalence must survive."""
        tm, sc, rate, w = setup
        t, e = self._both(tm, sc, w, 0.85 * rate, decode_block_tokens=8)
        assert e["throughput_rps"] == pytest.approx(t["throughput_rps"],
                                                    rel=0.05)
        assert e["ttft_mean"] == pytest.approx(t["ttft_mean"], rel=0.05)
        assert e["ttft_p90"] == pytest.approx(t["ttft_p90"], rel=0.05)

    def test_block_boundary_math(self, setup):
        """Boundary snap rounds up to the block grid (exact multiples stay
        put) and decode service time rounds up to whole blocks; the
        default ``decode_block_tokens=0`` keeps both exact, preserving the
        golden trace byte for byte."""
        tm, sc, rate, w = setup
        sim = PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=1.0, engine="event", decode_block_tokens=8))
        bs = 8 * w.t_decode
        assert sim._block_boundary(0.0) == pytest.approx(0.0, abs=1e-12)
        assert sim._block_boundary(bs) == pytest.approx(bs, abs=1e-12)
        assert sim._block_boundary(0.3 * bs) == pytest.approx(bs, abs=1e-12)
        assert sim._block_boundary(2.5 * bs) == pytest.approx(3 * bs,
                                                             abs=1e-12)
        # output_len rounded up to a multiple of 8 tokens
        blocks = -(-w.output_len // 8)
        assert sim._decode_service_time() == pytest.approx(
            blocks * 8 * w.t_decode, rel=1e-12)
        exact = PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=1.0, engine="event"))
        assert exact._block_boundary(0.1234) == 0.1234
        assert exact._decode_service_time() == pytest.approx(
            w.output_len * w.t_decode, rel=1e-12)

    def test_unknown_engine_rejected(self, setup):
        tm, sc, rate, w = setup
        sim = PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=1.0, sim_time=10, engine="fluid"))
        with pytest.raises(ValueError):
            sim.run()


# --------------------------------------------------------------------------
# cross-cache transfer bytes now hit the link (seed bug #1)
# --------------------------------------------------------------------------
def _event_ready(sim, sc, w):
    """Initialize just enough event-engine state to drive arrivals."""
    sim.prfaas_pool = EventPool(sc.n_prfaas)
    sim.pdp_pool = EventPool(sc.n_p)
    sim.decode_pool = EventPool(sc.n_d * w.bs_max)
    sim._decode_time = w.output_len * w.t_decode
    sim._heap = []
    sim._seq = itertools.count()
    sim._link_wake = math.inf
    sim._ready_seen = set()
    return sim


class TestCrossCacheBytes:
    def test_event_engine_charges_cross_cache_flow(self, setup):
        tm, sc, rate, w = setup
        sim = _event_ready(PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=1.0, engine="event")), sc, w)
        # session 0's 38400-token prefix cached at PrfaaS; the follow-up has
        # only 1600 incremental tokens -> routes to PD with a cross transfer
        sim.kv.clusters[PRFAAS].insert(0, 600)
        req = Request(0, 0.0, 40_000, 0)
        sim._ev_arrival(req, 0.0)
        d = req.decision
        assert d.target == PD and d.cross_cache_transfer
        assert d.cache_cluster == PRFAAS
        assert len(sim.link.flows) == 1
        flow = next(iter(sim.link.flows.values()))
        assert flow.total_bytes == pytest.approx(sim._cross_cache_bytes(d))
        assert flow.total_bytes > 1e6            # real KV, not a placeholder
        sim.link.run_until_idle()
        assert sim.link.sent_bytes == pytest.approx(flow.total_bytes)
        # decode admission waited for the cross flow
        assert req.flows_pending == 0
        assert req.transfer_done == pytest.approx(flow.done_time)

    def test_tick_engine_charges_cross_cache_flow(self, setup):
        tm, sc, rate, w = setup
        sim = PrfaasSimulator(tm, sc, w, SimConfig(arrival_rate=1.0,
                                                   engine="tick"))
        sim._inflight = []
        sim.kv.clusters[PRFAAS].insert(0, 600)
        req = Request(0, 0.0, 40_000, 0)
        cluster, st = sim._route(req)
        assert cluster == PD and req.decision.cross_cache_transfer
        sim._on_prefill_start(PD)(req, 0.0, st)
        assert len(sim.link.flows) == 1 and req.flows_pending == 1

    def test_fast_cross_flow_defers_decode_until_prefill(self, setup):
        """A cross-cache copy can drain long before prefill finishes; decode
        admission must wait for PREFILL_DONE, not fire with a future
        timestamp (which corrupted pool time integration)."""
        tm, sc, rate, w = setup
        sim = _event_ready(PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=1.0, engine="event")), sc, w)
        sim.kv.clusters[PRFAAS].insert(0, 600)
        req = Request(0, 0.0, 40_000, 0)
        sim._ev_arrival(req, 0.0)
        sim.link.run_until_idle()                # copy drains fast
        assert req.flows_pending == 0
        assert req.transfer_done < req.prefill_done
        assert req.rid not in sim._ready_seen    # NOT admitted early
        assert sim.decode_pool.busy == 0
        sim._maybe_ready(req, req.prefill_done)  # PREFILL_DONE path
        assert req.rid in sim._ready_seen and sim.decode_pool.busy == 1
        assert req.decode_start == pytest.approx(req.prefill_done)

    def test_sessions_produce_cross_transfers_end_to_end(self, setup):
        tm, sc, rate, _ = setup
        w = Workload(session_prob=0.6)
        sim = PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=0.6 * rate, sim_time=300, seed=3,
            pool_blocks=2_000_000, engine="event"))
        m = sim.run()
        assert sim.router.cross_transfers > 0
        assert m["egress_gbps"] > 0


# --------------------------------------------------------------------------
# simulator prefix cache (chain-level metadata twin of HybridPrefixCache)
# --------------------------------------------------------------------------
class TestSimPrefixCache:
    def test_snapshot_exactness_semantics(self):
        c = SimPrefixCache(1024, 64)
        c.insert(7, 10)                          # 10 blocks cached
        # extension reuses the full cached prefix
        assert c.match(7, 12) == 10 * 64
        # shorter query: blocks cover it but no snapshot at 5 -> miss
        # (paper §3.2: request-level states reusable only at exact length)
        assert c.match(7, 5) == 0
        # exact length hit
        assert c.match(7, 10) == 10 * 64
        assert c.match(8, 10) == 0               # different chain

    def test_growing_session_snapshots(self):
        c = SimPrefixCache(4096, 64)
        c.insert(1, 10)
        c.insert(1, 20)
        assert c.match(1, 25) == 20 * 64
        assert c.match(1, 15) == 10 * 64         # snapshot at 10 <= covered
        assert c.match(1, 9) == 0

    def test_lru_eviction_of_whole_chains(self):
        c = SimPrefixCache(100, 64)
        c.insert(1, 40)
        c.insert(2, 40)
        c.insert(3, 40)                          # evicts chain 1 (and 2)
        assert c.pool.used <= 100
        assert c.pool.stats["evicted"] > 0
        assert c.match(1, 40) == 0
        assert c.match(3, 40) == 40 * 64

    def test_oversized_insert_fails_cleanly(self):
        c = SimPrefixCache(16, 64)
        assert c.insert(1, 64) == 0
        assert c.pool.stats["alloc_fail"] == 1


# --------------------------------------------------------------------------
# live-session window: explicit, counted eviction (was a silent
# deque(maxlen=512) that dropped live sessions under high arrival rates)
# --------------------------------------------------------------------------
class TestOpenSessionWindow:
    def _sim(self, setup, **kw):
        tm, sc, rate, _ = setup
        w = Workload(session_prob=0.5)
        kw.setdefault("sim_time", 200.0)
        return PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=8.0, seed=9, **kw))

    def test_eviction_explicit_and_counted(self, setup):
        """Overflowing the window evicts oldest-first and COUNTS it — the
        old deque(maxlen=512) silently discarded live sessions, so reuse
        draws only ever saw the most recent 512."""
        sim = self._sim(setup, max_open_sessions=64)
        sim._generate_arrivals()
        assert len(sim._open_sessions) == 64
        assert sim.session_evictions > 0
        # conservation: every session ever opened is either still in the
        # window or was explicitly evicted
        assert sim.session_evictions \
            == sim._next_session - len(sim._open_sessions)

    def test_large_window_never_evicts(self, setup):
        sim = self._sim(setup, max_open_sessions=1_000_000)
        sim._generate_arrivals()
        assert sim.session_evictions == 0
        assert len(sim._open_sessions) == sim._next_session

    def test_default_window_matches_legacy_maxlen(self, setup):
        """The default window (512, oldest-first) reproduces the legacy
        deque(maxlen=512) trajectory bit-for-bit: same RNG stream, same
        session ids/lengths — only the eviction is now observable."""
        sim = self._sim(setup)
        trace = sim._generate_arrivals()
        assert sim.sim.max_open_sessions == 512
        assert len(sim._open_sessions) == 512
        assert sim.session_evictions > 0
        legacy = self._sim(setup)
        legacy._open_sessions = deque(maxlen=512)     # seed behavior
        legacy_trace = legacy._generate_arrivals()
        assert [(r.session, r.total_len, r.home) for r in trace] \
            == [(r.session, r.total_len, r.home) for r in legacy_trace]

    def test_metrics_expose_window_counters(self, setup):
        sim = self._sim(setup, max_open_sessions=64, sim_time=30.0)
        m = sim.run()
        assert m["session_evictions"] == sim.session_evictions
        assert m["open_sessions"] == len(sim._open_sessions) <= 64

    def test_invalid_window_rejected(self, setup):
        tm, sc, _, w = setup
        with pytest.raises(ValueError, match="max_open_sessions"):
            PrfaasSimulator(tm, sc, w, SimConfig(
                arrival_rate=1.0, max_open_sessions=0))
        with pytest.raises(ValueError, match="roam_prob"):
            PrfaasSimulator(tm, sc, w, SimConfig(
                arrival_rate=1.0, roam_prob=1.5))


# --------------------------------------------------------------------------
# event pool
# --------------------------------------------------------------------------
class TestEventPool:
    def test_fifo_and_capacity(self):
        p = EventPool(2)
        assert p.submit("a", 0.0) and p.submit("b", 0.0)
        assert not p.submit("c", 0.0)
        assert p.release(1.0) == "c"
        assert p.release(2.0) is None
        assert p.utilization(2.0) > 0

    def test_capacity_increase_starts_queued(self):
        p = EventPool(1)
        p.submit("a", 0.0)
        p.submit("b", 0.0)
        p.submit("c", 0.0)
        started = p.set_capacity(3, 1.0)
        assert started == ["b", "c"]

"""Sharding rules (pure-function tests on AbstractMesh) + roofline parser +
cost-fit algebra (no compiles)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.costfit import basis_row
from repro.analysis.roofline import collective_bytes
from repro.distributed.sharding import _with_fsdp, abstract_mesh, param_pspec

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class TestParamShardingRules:
    def test_ffn_tp(self):
        assert param_pspec("groups/0/stacked/b0/ffn/w1", (56, 6144, 16384),
                           MESH) == P(None, None, "model")
        assert param_pspec("groups/0/stacked/b0/ffn/w2", (56, 16384, 6144),
                           MESH) == P(None, "model", None)

    def test_moe_expert_dff_tp(self):
        # (R, E, d, f): shard f — works for 8 experts on a 16-way axis
        assert param_pspec("groups/0/stacked/b0/ffn/w1",
                           (56, 8, 6144, 16384), MESH) \
            == P(None, None, None, "model")
        assert param_pspec("groups/0/stacked/b0/ffn/w2",
                           (56, 8, 16384, 6144), MESH) \
            == P(None, None, "model", None)

    def test_attention_projections(self):
        assert param_pspec("groups/0/stacked/b0/mixer/wq/w", (56, 6144, 6144),
                           MESH) == P(None, None, "model")
        assert param_pspec("groups/0/stacked/b0/mixer/wo/w", (56, 6144, 6144),
                           MESH) == P(None, "model", None)

    def test_indivisible_replicates(self):
        # kv proj output 1024 = 8 heads x 128: divisible; 8 x 80 = 640 not
        assert param_pspec("g/mixer/wk/w", (24, 2560, 640), MESH) \
            == P(None, None, "model") if 640 % 16 == 0 else True
        assert param_pspec("g/mixer/wk/w", (24, 2560, 200), MESH) \
            == P(None, None, None)

    def test_norms_replicated(self):
        assert param_pspec("groups/0/stacked/b0/ln1", (56, 6144), MESH) \
            == P(None, None)

    def test_embed_vocab_sharded(self):
        assert param_pspec("embed", (32768, 6144), MESH) == P("model", None)
        assert param_pspec("unembed", (6144, 32768), MESH) \
            == P(None, "model")

    def test_fsdp_adds_data_axis(self):
        spec = param_pspec("groups/0/stacked/b0/ffn/w1", (56, 6144, 16384),
                           MESH, fsdp=True)
        assert "data" in spec and "model" in spec

    def test_fsdp_skips_small(self):
        spec = _with_fsdp(P(None), (8,), MESH)
        assert spec == P(None)


class TestRooflineParser:
    HLO = """
  %ag = bf16[2048,512]{1,0} all-gather(%p0), replica_groups={...}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%add
  %rs.1 = bf16[64,128]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%a, %b)
  %cp = u32[8]{0} collective-permute(%c), source_target_pairs={{0,1}}
  %fusion.1 = bf16[999,999]{1,0} fusion(%q), kind=kLoop
"""

    def test_collective_bytes(self):
        out = collective_bytes(self.HLO)
        assert out["all-gather"] == 2048 * 512 * 2
        assert out["all-reduce"] == 1024 * 4
        assert out["reduce-scatter"] == 64 * 128 * 2
        assert out["all-to-all"] == 2 * 16 * 16 * 4
        assert out["collective-permute"] == 8 * 4
        assert out["count"] == 5
        assert out["total"] == sum(out[k] for k in (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"))

    def test_non_collectives_ignored(self):
        assert collective_bytes("%f = bf16[4,4]{1,0} fusion(%x)")["total"] == 0


class TestCostFitAlgebra:
    def test_exact_recovery_of_planted_polynomial(self):
        """lstsq over the probe basis recovers a planted cost model exactly
        and extrapolates to full scale."""
        rng = np.random.default_rng(0)
        true = rng.uniform(1, 10, size=len(basis_row("train", 1, 1, (1,), 1)))

        def F(B, S, r, mb):
            return float(np.dot(true, basis_row("train", B, S, r, mb)))

        plan = [(16, s, (r,), m) for s in (2048, 4096, 8192)
                for r in (1, 2) for m in (1,)]
        plan += [(32, 2048, (1,), 1), (32, 2048, (2,), 1),
                 (32, 2048, (1,), 2), (32, 4096, (2,), 2)]
        A = np.stack([basis_row("train", *p) for p in plan])
        y = np.array([F(*p) for p in plan])
        scale = np.maximum(np.abs(A).max(0), 1e-12)
        c, *_ = np.linalg.lstsq(A / scale, y, rcond=None)
        c = c / scale
        # extrapolate far outside the probe grid
        got = float(np.dot(c, basis_row("train", 256, 32768, (56,), 16)))
        want = F(256, 32768, (56,), 16)
        assert abs(got / want - 1) < 1e-6

    def test_mesh_fn_no_device_state(self):
        """Importing mesh.py must not initialize jax devices (the dry-run
        sets XLA_FLAGS first)."""
        import importlib

        import repro.launch.mesh as m
        importlib.reload(m)
        assert callable(m.make_production_mesh)


class TestKVByteAccounting:
    def test_incremental_bytes(self):
        from repro.configs import get_config
        from repro.models.kvcache import kv_bytes, kv_bytes_incremental
        cfg = get_config("kimi-linear-1t")
        full = kv_bytes(cfg, 32768)
        inc = kv_bytes_incremental(cfg, 16384, 32768)
        assert inc < full
        # incremental transfer still resends the O(1) linear state
        state = sum(b.mixer.state_bytes() for *_, b in cfg.iter_blocks()
                    if not hasattr(b.mixer, "q_heads"))
        assert inc == pytest.approx(full - kv_bytes(cfg, 16384) + state)

    def test_paper_table5_calibration(self):
        """kimi-linear-1t proxy S_kv matches the paper's Table 5 within 2%."""
        from repro.configs import get_config
        cfg = get_config("kimi-linear-1t")
        paper = {1024: 190.8, 8192: 308.9, 32768: 701.3, 131072: 2316.3}
        for l, mib in paper.items():
            ours = cfg.kv_cache_bytes(l) / 2**20
            assert abs(ours / mib - 1) < 0.02, (l, ours, mib)

"""Benchmark-harness smoke test: ``python -m benchmarks.run --smoke`` must
finish clean AND under a wall-time budget so benchmark drift (correctness
or cost) fails tier-1 instead of rotting silently.

Runs in a temporary working directory so the harness's BENCH_*.json
artifacts never clobber the checked-in full-run results.  Marked ``slow``
(it compiles JAX kernels and runs every simulator scenario once).
"""
import json
import os
import subprocess
import sys
import time

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SMOKE_WALL_BUDGET_S = 900.0        # full --smoke harness must fit in this


@pytest.mark.slow
def test_bench_smoke_runs_clean(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (ROOT, os.path.join(ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p)
    t0 = time.perf_counter()
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke"],
        cwd=tmp_path, env=env, capture_output=True, text=True, timeout=1200)
    wall = time.perf_counter() - t0
    assert res.returncode == 0, (
        f"bench smoke failed\n--- stdout ---\n{res.stdout[-4000:]}"
        f"\n--- stderr ---\n{res.stderr[-4000:]}")
    assert "# all benchmarks complete" in res.stdout
    assert "# FAILED" not in res.stdout
    assert wall < SMOKE_WALL_BUDGET_S, (
        f"--smoke harness took {wall:.0f}s (budget "
        f"{SMOKE_WALL_BUDGET_S:.0f}s): a benchmark got slow")
    # every artifact records the wall time of the module that wrote it
    for name in ("BENCH_scenario_grid.json", "BENCH_sim_engine.json",
                 "BENCH_kernel.json", "BENCH_engine.json"):
        art = json.loads((tmp_path / name).read_text())
        assert 0.0 <= art["bench_wall_s"] < SMOKE_WALL_BUDGET_S, name
    # the harness actually produced its simulator artifacts
    assert (tmp_path / "BENCH_scenario_grid.json").exists()
    # vectorized engine comparison (PR 9): the seed-swept equivalence must
    # hold at 5% on every headline metric and the SoA scale point must
    # actually run at scale (requests >> what the event engine could do
    # in the same wall time)
    se = json.loads((tmp_path / "BENCH_sim_engine.json").read_text())
    assert se["vector"]["wall_s"] > 0
    for metric, stats in se["seed_sweep"]["vector"].items():
        assert stats["max"] <= 0.05, (metric, stats)
    assert se["vector_scale"]["requests"] >= 10_000
    assert se["vector_scale"]["completed"] > 0
    assert se["vector_scale"]["req_per_wall_s"] > 1000
    # scenario engine (PR 9): trace-driven sweep emits a cost/attainment
    # frontier for every workload family, and the stressor grid carries
    # SLO + per-link drop telemetry
    grid = json.loads((tmp_path / "BENCH_scenario_grid.json").read_text())
    assert grid["scenarios"]["n_points"] > 0
    for fam in ("diurnal", "flash_crowd", "conversation"):
        front = grid["frontier"][fam]
        assert front, fam
        costs = [p["cost_per_mreq"] for p in front]
        atts = [p["slo_attainment"] for p in front]
        assert costs == sorted(costs)              # Pareto: cost up...
        assert atts == sorted(atts)                # ...only if att up
    for p in grid["points"]:
        assert "ttft_p99_s" in p and "slo_attainment" in p
        for pair, s in p["links"].items():
            assert set(s) == {"gb", "drops"}, pair
    # ... and the measured-kernel calibration + serving hot-path artifacts
    assert (tmp_path / "BENCH_kernel.json").exists()
    assert (tmp_path / "BENCH_engine.json").exists()
    # continuous-batching telemetry: the region scheduler must beat the
    # PR 5 alternating loop on slot occupancy, with a recompile-free hot
    # path after warmup
    eng = json.loads((tmp_path / "BENCH_engine.json").read_text())
    assert "occupancy_at_16_slots" in eng
    assert "occupancy_alternating_baseline" in eng
    occ = eng["occupancy"]
    assert occ["occupancy_continuous"] > occ["occupancy_alternating"]
    assert occ["recompiles_after_warmup"] == 0
    # paged KV telemetry (PR 7): prefix hits must measurably skip
    # cached-prefix prefill, prefix pages must stay device-resident after
    # the drain, and both the admission scatter and the block-table decode
    # must run recompile-free after warmup
    assert "paged_token_savings_at_50pct_hits" in eng
    assert "paged_resident_kv_bytes" in eng
    paged = eng["paged"]
    assert paged["admission"]["admit_recompiles_after_warmup"] == 0
    assert paged["prefix"]["decode_recompiles"] == 0
    assert paged["prefix"]["token_savings_frac"] > 0.2
    assert paged["prefix"]["tokens_prefilled"] < \
        paged["prefix"]["tokens_submitted"]
    assert paged["prefix"]["resident_kv_bytes"] > 0
    # int8 wire admission (PR 8): the dequantize-in-scatter program variant
    # must also be recompile-free after warmup_admission
    assert paged["admission"]["wire_admit_recompiles_after_warmup"] == 0
    assert paged["admission"]["wire_admit_us"] > 0
    # speculative decode (PR 10): the bench point must exist, accept more
    # than one token per verify dispatch, and beat the plain k=0 path at
    # 16 slots on the continuous scheduler (token-identical by assertion
    # inside the bench itself)
    assert "spec_decode_speedup_at_16_slots" in eng
    assert "accepted_tokens_per_dispatch" in eng
    spec = eng["speculative"]
    assert spec["accepted_tokens_per_dispatch"] > 1.0
    assert spec["best_k"] >= 2
    assert spec["sweep"][f"k{spec['best_k']}"]["tok_s"] >= \
        spec["sweep"]["k0"]["tok_s"]
    for key, point in spec["sweep"].items():
        if key != "k0":
            assert point["verify_compiles"] == 1, (key, point)
    # fused serving-path kernels (PR 8) land interpret-mode sweep points
    ker = json.loads((tmp_path / "BENCH_kernel.json").read_text())
    pts = ker["interpret_points"]
    for key in ("gla_fused_us", "delta_fused_us", "quantize_fused_us",
                "paged_prefill_us"):
        assert pts.get(key, 0) > 0, f"missing kernel bench point {key}"

"""Chunked (gated) delta-rule Pallas kernel vs sequential oracle.

Also unit-tests the Neumann-product unit-lower-triangular inverse that makes
the WY transform MXU-friendly (DESIGN.md §3).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.delta import _neumann_unit_lower_inverse, delta_chunked

pytestmark = pytest.mark.slow      # JAX compiles dominate; -m "not slow" skips

RNG = np.random.default_rng(2)


def mk(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def inputs(B, H, S, dk, dv, gated=True):
    q, k, v = mk(B, H, S, dk), mk(B, H, S, dk), mk(B, H, S, dv)
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    beta = jnp.asarray(RNG.uniform(0.1, 1.0, (B, H, S)).astype(np.float32))
    la = (-0.1 * jnp.abs(mk(B, H, S))) if gated else jnp.zeros((B, H, S))
    return q, k, v, la, beta


@pytest.mark.parametrize("n", [4, 16, 64, 128])
def test_neumann_inverse(n):
    """Inverse in the delta rule's actual regime: N = diag(beta) *
    (K K^T . strict-lower-decay) with L2-normalized keys and beta in (0,1]
    — the operator is a contraction there (random N(0,1) triangles have
    exponentially large inverses and are NOT the kernel's input domain)."""
    k = RNG.standard_normal((n, 32)).astype(np.float32)
    k /= np.linalg.norm(k, axis=-1, keepdims=True)
    beta = RNG.uniform(0.1, 1.0, (n, 1)).astype(np.float32)
    L = jnp.asarray(beta * np.tril(k @ k.T, -1))
    inv = _neumann_unit_lower_inverse(L, n)
    want = np.linalg.inv(np.eye(n) + np.asarray(L, np.float64))
    np.testing.assert_allclose(np.asarray(inv), want, atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("B,H,S,dk,dv,chunk", [
    (1, 2, 128, 32, 32, 64),
    (2, 3, 130, 32, 48, 64),
    (1, 1, 96, 16, 16, 32),
])
@pytest.mark.parametrize("gated", [True, False])
def test_delta_matches_oracle(B, H, S, dk, dv, chunk, gated):
    q, k, v, la, beta = inputs(B, H, S, dk, dv, gated)
    o, st = delta_chunked(q, k, v, la, beta, chunk=chunk, interpret=True)
    o2, st2 = ref.delta_ref(q, k, v, la, beta)
    np.testing.assert_allclose(o, o2, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(st, st2, atol=1e-4, rtol=1e-3)


def test_delta_state_continuation():
    B, H, S, d = 1, 2, 128, 32
    q, k, v, la, beta = inputs(B, H, S, d, d)
    o_full, st_full = delta_chunked(q, k, v, la, beta, chunk=32,
                                    interpret=True)
    h = S // 2
    o1, st1 = delta_chunked(q[:, :, :h], k[:, :, :h], v[:, :, :h],
                            la[:, :, :h], beta[:, :, :h], chunk=32,
                            interpret=True)
    o2, st2 = delta_chunked(q[:, :, h:], k[:, :, h:], v[:, :, h:],
                            la[:, :, h:], beta[:, :, h:],
                            initial_state=st1, chunk=32, interpret=True)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 2), o_full,
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(st2, st_full, atol=2e-4, rtol=2e-3)


def test_delta_memorizes_associations():
    """Functional check: with beta=1, no decay, normalized distinct keys,
    the delta state stores exact k->v associations (the delta rule's
    defining property — what makes KDA expressive)."""
    B, H, S, d = 1, 1, 8, 32
    k = jnp.asarray(np.linalg.qr(RNG.standard_normal((d, d)))[0][:S]
                    .astype(np.float32))[None, None]   # orthonormal keys
    v = mk(B, H, S, 16)
    q = k
    beta = jnp.ones((B, H, S))
    la = jnp.zeros((B, H, S))
    o, st = delta_chunked(q, k, v, la, beta, chunk=8, interpret=True)
    # querying with k_i after step i returns exactly v_i
    np.testing.assert_allclose(o[:, :, -1],
                               v[:, :, -1], atol=1e-4, rtol=1e-4)
    recall = jnp.einsum("bhsk,bhkv->bhsv", k, st)
    np.testing.assert_allclose(recall, v, atol=1e-4, rtol=1e-4)


def test_delta_step_matches_scan():
    from repro.kernels.ops import delta_step
    B, H, d = 2, 2, 16
    q, k, v, la, beta = inputs(B, H, 6, d, d)
    state = jnp.zeros((B, H, d, d))
    outs = []
    for t in range(6):
        o, state = delta_step(q[:, :, t], k[:, :, t], v[:, :, t],
                              la[:, :, t], beta[:, :, t], state)
        outs.append(o)
    o_ref, st_ref = ref.delta_ref(q, k, v, la, beta)
    np.testing.assert_allclose(jnp.stack(outs, 2), o_ref, atol=1e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(state, st_ref, atol=1e-5, rtol=1e-4)


# --------------------------------------------------------------------------
# Fused padded-batch variant: masking happens in-VMEM inside the kernel
# --------------------------------------------------------------------------


def test_delta_fused_equals_premasked_plain():
    """In-VMEM masking (decay -> 1, k/beta -> 0) == jnp.where pre-masking,
    bit for bit."""
    from repro.kernels.delta import delta_chunked_fused
    from repro.kernels.ops import _mask_padded
    B, H, S, dk, dv, chunk = 2, 2, 128, 32, 32, 32
    q, k, v, la, beta = inputs(B, H, S, dk, dv)
    lengths = jnp.asarray([S, 83], jnp.int32)
    o, st = delta_chunked_fused(q, k, v, la, beta, lengths, chunk=chunk,
                                interpret=True)
    la_m, k_m, beta_m = _mask_padded(lengths, S, la, k, beta)
    o2, st2 = delta_chunked(q, k_m, v, la_m, beta_m, chunk=chunk,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(st), np.asarray(st2))


@pytest.mark.parametrize("gated", [True, False])
def test_delta_fused_matches_truncated_ref(gated):
    from repro.kernels.delta import delta_chunked_fused
    B, H, S, dk, dv, chunk = 2, 2, 128, 32, 32, 32
    q, k, v, la, beta = inputs(B, H, S, dk, dv, gated)
    lengths = [128, 71]
    o, st = delta_chunked_fused(q, k, v, la, beta,
                                jnp.asarray(lengths, jnp.int32), chunk=chunk,
                                interpret=True)
    for b, L in enumerate(lengths):
        sl = slice(b, b + 1)
        o2, st2 = ref.delta_ref(q[sl, :, :L], k[sl, :, :L], v[sl, :, :L],
                                la[sl, :, :L], beta[sl, :, :L])
        np.testing.assert_allclose(o[sl, :, :L], o2, atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(st[sl], st2, atol=1e-4, rtol=1e-3)


def test_ops_delta_lengths_dispatch_and_grad():
    from repro.kernels import ops
    B, H, S, dk, dv = 2, 2, 64, 16, 16
    q, k, v, la, beta = inputs(B, H, S, dk, dv)
    lengths = jnp.asarray([64, 45], jnp.int32)

    def loss(q, k, v, la, beta):
        o, st = ops.delta(q, k, v, la, beta, lengths=lengths, chunk=16)
        return jnp.sum(o ** 2) + jnp.sum(st ** 2)

    want = loss(q, k, v, la, beta)
    gw = jax.grad(loss, argnums=(0, 1, 2, 3, 4))(q, k, v, la, beta)
    ops.FORCE_KERNEL_ON_CPU = True
    try:
        got = loss(q, k, v, la, beta)
        gk = jax.grad(loss, argnums=(0, 1, 2, 3, 4))(q, k, v, la, beta)
    finally:
        ops.FORCE_KERNEL_ON_CPU = False
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)
    for a, b in zip(gk, gw):
        assert bool(jnp.all(jnp.isfinite(a)))
        np.testing.assert_allclose(a, b, atol=1e-3, rtol=1e-3)

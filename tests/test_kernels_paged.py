"""Paged (block-table) flash-decode kernel vs pure-jnp oracle.

The oracle gathers pages through the table and runs the dense decode oracle,
so these tests simultaneously pin (a) kernel == oracle and (b) paged oracle
== dense oracle on the equivalent dense cache.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.paged_decode_attn import paged_decode_attention

pytestmark = pytest.mark.slow      # JAX compiles dominate; -m "not slow" skips

RNG = np.random.default_rng(7)


def mk(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


def mk_tables(B, N, P):
    """Random permutation-style tables: distinct physical pages per request."""
    t = np.stack([RNG.choice(P, size=N, replace=False) for _ in range(B)])
    return jnp.asarray(t.astype(np.int32))


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,Hq,Hkv,T,N,D", [
    (1, 4, 4, 16, 8, 64),      # MHA
    (3, 8, 2, 16, 5, 64),      # GQA
    (2, 8, 1, 32, 4, 128),     # MQA, bigger pages
    (2, 4, 4, 8, 7, 32),       # small pages
])
def test_paged_decode_matches_oracle(B, Hq, Hkv, T, N, D):
    P = 2 * N * B + 1
    k_pages, v_pages = mk(Hkv, P, T, D), mk(Hkv, P, T, D)
    tables = mk_tables(B, N, P)
    lengths = jnp.asarray(RNG.integers(1, N * T + 1, size=B), jnp.int32)
    q = mk(B, Hq, D)
    out = paged_decode_attention(q, k_pages, v_pages, tables, lengths,
                                 interpret=True)
    want = ref.paged_decode_attention_ref(q, k_pages, v_pages, tables,
                                          lengths)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_paged_matches_dense_ref_through_table():
    """Gathered pages == contiguous dense cache, bit-for-bit through the ref,
    close through the kernel."""
    B, Hq, Hkv, T, N, D = 2, 4, 2, 16, 6, 64
    S = N * T
    dense_k, dense_v = mk(B, Hkv, S, D), mk(B, Hkv, S, D)
    P = B * N + 3
    k_pages = jnp.zeros((Hkv, P, T, D), jnp.float32)
    v_pages = jnp.zeros((Hkv, P, T, D), jnp.float32)
    # scatter the dense cache into scrambled physical pages
    perm = RNG.permutation(B * N)
    tables = jnp.asarray(perm.reshape(B, N).astype(np.int32)) + 3
    for b in range(B):
        for j in range(N):
            pid = int(tables[b, j])
            k_pages = k_pages.at[:, pid].set(dense_k[b, :, j * T:(j + 1) * T])
            v_pages = v_pages.at[:, pid].set(dense_v[b, :, j * T:(j + 1) * T])
    lengths = jnp.asarray([S - 5, 37], jnp.int32)
    q = mk(B, Hq, D)
    want = ref.decode_attention_ref(q, dense_k, dense_v, lengths)
    got_ref = ref.paged_decode_attention_ref(q, k_pages, v_pages, tables,
                                             lengths)
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want))
    got_kernel = paged_decode_attention(q, k_pages, v_pages, tables, lengths,
                                        interpret=True)
    np.testing.assert_allclose(got_kernel, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [16, 48])
def test_paged_sliding_window(window):
    B, Hq, Hkv, T, N, D = 2, 4, 2, 16, 4, 32
    P = B * N + 1
    k_pages, v_pages = mk(Hkv, P, T, D), mk(Hkv, P, T, D)
    tables = mk_tables(B, N, P)
    lengths = jnp.asarray([N * T, 19], jnp.int32)
    q = mk(B, Hq, D)
    out = paged_decode_attention(q, k_pages, v_pages, tables, lengths,
                                 window=window, interpret=True)
    want = ref.paged_decode_attention_ref(q, k_pages, v_pages, tables,
                                          lengths, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_paged_dk_neq_dv_and_scale():
    """MLA-absorbed shape: dk = rank+rope > dv = rank, explicit scale."""
    B, Hq, Hkv, T, N = 2, 8, 1, 16, 3
    Dk, Dv = 96, 64
    P = B * N + 2
    k_pages, v_pages = mk(Hkv, P, T, Dk), mk(Hkv, P, T, Dv)
    tables = mk_tables(B, N, P)
    lengths = jnp.asarray([N * T - 1, 17], jnp.int32)
    q = mk(B, Hq, Dk)
    out = paged_decode_attention(q, k_pages, v_pages, tables, lengths,
                                 scale=Dk ** -0.5, interpret=True)
    want = ref.paged_decode_attention_ref(q, k_pages, v_pages, tables,
                                          lengths, scale=Dk ** -0.5)
    assert out.shape == (B, Hq, Dv)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_dtypes(dtype):
    B, Hq, Hkv, T, N, D = 1, 4, 2, 16, 4, 64
    P = N + 1
    k_pages = mk(Hkv, P, T, D).astype(dtype)
    v_pages = mk(Hkv, P, T, D).astype(dtype)
    tables = mk_tables(B, N, P)
    lengths = jnp.asarray([N * T - 7], jnp.int32)
    q = mk(B, Hq, D).astype(dtype)
    out = paged_decode_attention(q, k_pages, v_pages, tables, lengths,
                                 interpret=True)
    want = ref.paged_decode_attention_ref(q, k_pages, v_pages, tables,
                                          lengths)
    assert out.dtype == dtype
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), atol=tol(dtype),
                               rtol=tol(dtype))


def test_ops_dispatch_paged():
    """ops.paged_decode_attention: ref on CPU, kernel when forced."""
    B, Hq, Hkv, T, N, D = 2, 4, 2, 16, 4, 32
    P = B * N + 1
    k_pages, v_pages = mk(Hkv, P, T, D), mk(Hkv, P, T, D)
    tables = mk_tables(B, N, P)
    lengths = jnp.asarray([N * T, 21], jnp.int32)
    q = mk(B, Hq, D)
    want = ref.paged_decode_attention_ref(q, k_pages, v_pages, tables,
                                          lengths)
    got = ops.paged_decode_attention(q, k_pages, v_pages, tables, lengths)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    ops.FORCE_KERNEL_ON_CPU = True
    try:
        got_k = ops.paged_decode_attention(q, k_pages, v_pages, tables,
                                           lengths)
    finally:
        ops.FORCE_KERNEL_ON_CPU = False
    np.testing.assert_allclose(got_k, want, atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# Paged-prefill kernel: suffix chunks attend over block tables directly
# --------------------------------------------------------------------------
from repro.kernels.paged_prefill_attn import paged_prefill_attention


@pytest.mark.parametrize("B,Hq,Hkv,T,N,C,Ssuf,D", [
    (1, 4, 4, 16, 4, 16, 16, 64),     # MHA, chunk == suffix
    (2, 8, 2, 16, 3, 32, 48, 64),     # GQA, prior suffix rows before chunk
    (1, 8, 1, 32, 2, 16, 64, 128),    # MQA, long accumulated suffix
    (2, 4, 2, 8, 5, 8, 24, 32),       # small pages
])
def test_paged_prefill_matches_oracle(B, Hq, Hkv, T, N, C, Ssuf, D):
    P = 2 * N * B + 1
    k_pages, v_pages = mk(Hkv, P, T, D), mk(Hkv, P, T, D)
    tables = mk_tables(B, N, P)
    k_suf, v_suf = mk(B, Hkv, Ssuf, D), mk(B, Hkv, Ssuf, D)
    q = mk(B, Hq, C, D)
    out = paged_prefill_attention(q, k_pages, v_pages, tables, k_suf, v_suf,
                                  interpret=True)
    want = ref.paged_prefill_attention_ref(q, k_pages, v_pages, tables,
                                           k_suf, v_suf)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_paged_prefill_ref_equals_dense_flash():
    """The paged-prefill oracle == dense flash over [gathered pages|suffix]
    with the chunk's true position offset — the exact operand the dense
    suffix path used to build, so paged == dense is pinned bit-for-bit."""
    B, Hq, Hkv, T, N, C, Ssuf, D = 2, 4, 2, 16, 4, 16, 32, 32
    P = B * N + 2
    k_pages, v_pages = mk(Hkv, P, T, D), mk(Hkv, P, T, D)
    tables = mk_tables(B, N, P)
    k_suf, v_suf = mk(B, Hkv, Ssuf, D), mk(B, Hkv, Ssuf, D)
    q = mk(B, Hq, C, D)
    gk = jnp.transpose(k_pages[:, tables], (1, 0, 2, 3, 4)).reshape(
        B, Hkv, N * T, D)
    gv = jnp.transpose(v_pages[:, tables], (1, 0, 2, 3, 4)).reshape(
        B, Hkv, N * T, D)
    dense = ref.flash_attention_ref(
        q, jnp.concatenate([gk, k_suf], 2), jnp.concatenate([gv, v_suf], 2),
        causal=True, q_offset=N * T + Ssuf - C)
    got = ref.paged_prefill_attention_ref(q, k_pages, v_pages, tables,
                                          k_suf, v_suf)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))
    got_k = paged_prefill_attention(q, k_pages, v_pages, tables, k_suf,
                                    v_suf, interpret=True)
    np.testing.assert_allclose(got_k, dense, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_prefill_dtypes(dtype):
    B, Hq, Hkv, T, N, C, Ssuf, D = 1, 4, 2, 16, 3, 16, 16, 64
    P = N + 2
    k_pages = mk(Hkv, P, T, D).astype(dtype)
    v_pages = mk(Hkv, P, T, D).astype(dtype)
    tables = mk_tables(B, N, P)
    k_suf = mk(B, Hkv, Ssuf, D).astype(dtype)
    v_suf = mk(B, Hkv, Ssuf, D).astype(dtype)
    q = mk(B, Hq, C, D).astype(dtype)
    out = paged_prefill_attention(q, k_pages, v_pages, tables, k_suf, v_suf,
                                  interpret=True)
    want = ref.paged_prefill_attention_ref(q, k_pages, v_pages, tables,
                                           k_suf, v_suf)
    assert out.dtype == dtype
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), atol=tol(dtype),
                               rtol=tol(dtype))


def test_ops_dispatch_paged_prefill():
    """ops.paged_prefill_attention: ref on CPU, interpret kernel when
    FORCE_KERNEL_ON_CPU — same routing contract as every other kernel."""
    B, Hq, Hkv, T, N, C, Ssuf, D = 2, 4, 2, 16, 3, 16, 32, 32
    P = B * N + 1
    k_pages, v_pages = mk(Hkv, P, T, D), mk(Hkv, P, T, D)
    tables = mk_tables(B, N, P)
    k_suf, v_suf = mk(B, Hkv, Ssuf, D), mk(B, Hkv, Ssuf, D)
    q = mk(B, Hq, C, D)
    want = ref.paged_prefill_attention_ref(q, k_pages, v_pages, tables,
                                           k_suf, v_suf)
    got = ops.paged_prefill_attention(q, k_pages, v_pages, tables, k_suf,
                                      v_suf)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    ops.FORCE_KERNEL_ON_CPU = True
    try:
        got_k = ops.paged_prefill_attention(q, k_pages, v_pages, tables,
                                            k_suf, v_suf)
    finally:
        ops.FORCE_KERNEL_ON_CPU = False
    np.testing.assert_allclose(got_k, want, atol=2e-5, rtol=2e-5)

"""Multi-cluster LinkTopology + metrics-correctness bugfix sweep (PR 2).

Covers: two-cluster LinkTopology == single Link (pair-level exact and
simulator-level bit-for-bit via the golden trace), per-pair byte
conservation, 3-PD-cluster tick/event equivalence, horizon-filtered
throughput, warmup-consistent egress, post-resize pool utilization, the
lambda_max dead branch removal, per-instance config isolation, and the
sub-epsilon drain-boundary livelock fix in the exact link solver."""
import json
import math
import os

import numpy as np
import pytest

from repro.core import (PD, PRFAAS, EventPool, Link, LinkTopology,
                        PrfaasSimulator, Request, Router, SimConfig,
                        SystemConfig, ThroughputModel, Workload,
                        paper_h20_profile, paper_h200_profile, split_even,
                        star_pairs)
from repro.core.autoscaler import Autoscaler

GOLDEN_PATH = os.path.join(os.path.dirname(__file__),
                           "golden_twocluster_trace.json")


@pytest.fixture(scope="module")
def setup():
    w = Workload()
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, rate, _ = tm.grid_search(4, 8, 100e9 / 8)
    return tm, sc, rate, w


def _sc3(sc, k=3):
    return SystemConfig(sc.n_prfaas, sc.n_p, sc.n_d, sc.b_out, sc.threshold,
                        n_p_clusters=tuple(split_even(sc.n_p, k)),
                        n_d_clusters=tuple(split_even(sc.n_d, k)))


# --------------------------------------------------------------------------
# two-cluster LinkTopology == single Link, exactly
# --------------------------------------------------------------------------
class TestTwoClusterEquivalence:
    def test_pair_link_matches_bare_link_exactly(self):
        """Identical seed + flow schedule -> identical completion times,
        byte counters, and congestion telemetry (fluctuation on)."""
        done_l, done_t = [], []
        bare = Link(8e9, fluctuation=0.2, seed=3)
        topo = LinkTopology.build([PRFAAS, PD], [(PRFAAS, PD)], [8.0],
                                  fluctuation=[0.2], seed=3)
        for i in range(4):
            bare.submit(5e8, 0.2 * i, ramp_end=0.2 * i + 0.5,
                        on_done=lambda t: done_l.append(t))
            topo.submit(PRFAAS, PD, 5e8, 0.2 * i, ramp_end=0.2 * i + 0.5,
                        on_done=lambda t: done_t.append(t))
        for t in (0.3, 0.9, 1.7, 4.0, 9.0):
            bare.advance(t)
            topo.advance(t)
        assert done_l == done_t and len(done_l) == 4
        assert topo.sent_bytes == bare.sent_bytes
        assert topo.pair_signal(PRFAAS, PD) == bare.congestion_signal()
        assert topo.aggregate_signal() == bare.congestion_signal()

    def test_golden_trace_bit_for_bit(self):
        """The refactored simulator (internally a LinkTopology) reproduces
        the pre-topology single-Link per-request trajectories exactly on
        the same seed, for BOTH engines.  The trace was regenerated after
        the PR 3 regionalization with ``roam_prob=0.0, autoscale=False``
        pinned — per-request trajectories came out byte-identical, proving
        the regional control plane is RNG- and trajectory-neutral when
        disabled.  sent_bytes keeps a 1e-8 relative tolerance (legacy of
        the sub-epsilon livelock fix's ~1e-10 byte correction)."""
        import sys
        sys.path.insert(0, os.path.dirname(__file__))
        from golden_trace_gen import run_engine
        golden = json.load(open(GOLDEN_PATH))
        for engine in ("event", "tick"):
            new = run_engine(engine)
            g = golden[engine]
            assert new["n_requests"] == g["n_requests"]
            assert new["sent_bytes"] == pytest.approx(g["sent_bytes"],
                                                      rel=1e-8)
            for rn, rg in zip(new["requests"], g["requests"]):
                assert rn == rg


# --------------------------------------------------------------------------
# topology invariants
# --------------------------------------------------------------------------
class TestTopologyInvariants:
    def _topo3(self, fluct=0.0):
        pds = ["pd0", "pd1", "pd2"]
        pairs = star_pairs(PRFAAS, pds, mesh=True)
        return LinkTopology.build([PRFAAS] + pds, pairs,
                                  [8.0] * len(pairs),
                                  fluctuation=fluct, seed=1), pairs

    def test_per_pair_byte_conservation(self):
        topo, pairs = self._topo3()
        sizes = {p: 1e8 * (i + 1) for i, p in enumerate(pairs)}
        for (a, b), nbytes in sizes.items():
            topo.submit(a, b, nbytes, 0.0)
        topo.run_until_idle()
        stats = topo.pair_stats()
        # every byte lands on the pair it was charged to, and the totals add
        for (a, b), nbytes in sizes.items():
            key = f"{min(a,b)}|{max(a,b)}"
            assert stats[key]["sent_bytes"] == pytest.approx(nbytes)
        assert topo.sent_bytes == pytest.approx(sum(sizes.values()))

    def test_capacity_bound_per_pair(self):
        topo, pairs = self._topo3()
        for a, b in pairs:
            topo.submit(a, b, 5e9, 0.0)
        topo.advance(1.5)
        for s in topo.pair_stats().values():
            assert s["sent_bytes"] <= 1e9 * 1.5 * 1.0001   # 8 Gbps = 1 GB/s

    def test_links_are_independent(self):
        """Saturating one pair leaves the others idle (no shared capacity)."""
        topo, _ = self._topo3()
        topo.submit(PRFAAS, "pd0", 10e9, 0.0)
        topo.advance(5.0)                      # >> 1 s telemetry constant
        sig_busy = topo.pair_signal(PRFAAS, "pd0")
        sig_idle = topo.pair_signal(PRFAAS, "pd1")
        assert sig_busy["util"] > 0.9 and sig_idle["util"] == 0.0
        assert topo.dest_signal("pd0")["util"] == sig_busy["util"]

    def test_unknown_pair_raises(self):
        pds = ["pd0", "pd1"]
        topo = LinkTopology.build([PRFAAS] + pds,
                                  star_pairs(PRFAAS, pds), [8.0, 8.0])
        assert not topo.has_link("pd0", "pd1")      # star: no PD mesh
        with pytest.raises(KeyError):
            topo.link("pd0", "pd1")


# --------------------------------------------------------------------------
# 3-PD-cluster simulation: end-to-end + engine equivalence
# --------------------------------------------------------------------------
class TestThreeClusterSim:
    def _run(self, tm, sc3, w, rate, engine, **kw):
        sim = PrfaasSimulator(tm, sc3, w, SimConfig(
            arrival_rate=rate, sim_time=360, dt=0.02, seed=11, engine=engine,
            pd_clusters=3, pd_shares=(0.5, 0.3, 0.2),
            pd_link_gbps=(100.0, 50.0, 25.0), pd_mesh_gbps=10.0, **kw))
        return sim, sim.run()

    def test_event_runs_end_to_end_with_per_pair_links(self, setup):
        tm, sc, rate, w = setup
        sim, m = self._run(tm, _sc3(sc), w, 0.7 * rate, "event")
        assert m["completed"] > 50
        # every region decodes its own share of traffic
        shares = {"pd0": 0.5, "pd1": 0.3, "pd2": 0.2}
        for name, s in shares.items():
            frac = m["clusters"][name]["completed"] / m["completed"]
            assert frac == pytest.approx(s, abs=0.1)
        # offloaded prefills land on the right star link
        links = m["links"]
        assert links["pd0|prfaas"]["sent_bytes"] > \
            links["pd2|prfaas"]["sent_bytes"] > 0
        assert sum(l["sent_bytes"] for l in links.values()) \
            == pytest.approx(sim.topology.sent_bytes)

    def test_tick_event_equivalence_3pd(self, setup):
        tm, sc, rate, w = setup
        _, mt = self._run(tm, _sc3(sc), w, 0.7 * rate, "tick")
        _, me = self._run(tm, _sc3(sc), w, 0.7 * rate, "event")
        assert me["throughput_rps"] == pytest.approx(mt["throughput_rps"],
                                                     rel=0.05)
        assert me["ttft_mean"] == pytest.approx(mt["ttft_mean"], rel=0.05)
        assert me["egress_gbps"] == pytest.approx(mt["egress_gbps"],
                                                  rel=0.05)

    def test_cross_cache_charged_to_home_pair(self, setup):
        """A follow-up whose prefix is cached at PrfaaS routes home with a
        cross-cache copy on the home<->PrfaaS pair link only."""
        tm, sc, rate, w = setup
        sim = PrfaasSimulator(tm, _sc3(sc), w, SimConfig(
            arrival_rate=1.0, engine="event", pd_clusters=3,
            pd_mesh_gbps=10.0))
        # initialize event state without running the full loop
        import itertools as it
        sim.prfaas_pool = EventPool(sc.n_prfaas)
        for name, (n_p_c, n_d_c) in zip(sim._pd_names, sim._per_cluster):
            sim.pdp_pools[name] = EventPool(n_p_c)
            sim.decode_pools[name] = EventPool(n_d_c * w.bs_max)
        sim._decode_time = w.output_len * w.t_decode
        sim._heap, sim._seq = [], it.count()
        sim._link_wake = math.inf
        sim._ready_seen = set()
        sim.kv.clusters[PRFAAS].insert(0, 600)
        req = Request(0, 0.0, 40_000, 0, home="pd1")
        sim._ev_arrival(req, 0.0)
        d = req.decision
        assert d.target == "pd1" and d.cross_cache_transfer
        assert d.cache_cluster == PRFAAS and d.home == "pd1"
        flows_on = {pair: len(l.flows)
                    for pair, l in sim.topology.links.items()}
        assert flows_on[("pd1", PRFAAS)] == 1
        assert sum(flows_on.values()) == 1

    def test_autoscale_accepted_for_multicluster(self, setup):
        """PR 3: per-region autoscaling replaced the old hard ValueError —
        one Autoscaler per PD cluster over its region-local instances."""
        tm, sc, _, w = setup
        sim = PrfaasSimulator(tm, _sc3(sc), w, SimConfig(
            arrival_rate=1.0, pd_clusters=3, autoscale=True))
        assert set(sim.autoscalers) == {"pd0", "pd1", "pd2"}
        assert sim.autoscaler is sim.autoscalers["pd0"]
        for name, a in sim.autoscalers.items():
            assert a.home == name
            n_p_c, n_d_c = dict(zip(sim._pd_names, sim._per_cluster))[name]
            assert (a.system.n_p, a.system.n_d) == (n_p_c, n_d_c)


# --------------------------------------------------------------------------
# regionalized control plane (PR 3): per-home thresholds, session roaming
# over the PD mesh, per-region autoscaling
# --------------------------------------------------------------------------
class TestRegionalControlPlane:
    def test_burst_confined_to_one_home_raises_only_its_threshold(self, setup):
        """Acceptance: congestion on ONE region's star link moves ONLY that
        home's offload threshold; it relaxes alone once the burst drains."""
        tm, sc, _, w = setup
        sim = PrfaasSimulator(tm, _sc3(sc), w, SimConfig(
            arrival_rate=1.0, engine="event", pd_clusters=3,
            pd_mesh_gbps=10.0))
        base = {n: sim.router.threshold_for(n) for n in sim._pd_names}
        # burst confined to pd2: saturate its star pair link only
        sim.topology.submit(PRFAAS, "pd2", 6e10, 0.0)
        sim.topology.advance(4.0)
        sim._observe_regions()
        assert sim.router.threshold_for("pd2") > base["pd2"]
        assert sim.router.threshold_for("pd0") == base["pd0"]
        assert sim.router.threshold_for("pd1") == base["pd1"]
        # drain + idle long past the telemetry time constant -> pd2 relaxes
        sim.topology.run_until_idle()
        sim.topology.advance(sim.topology.link(PRFAAS, "pd2").now + 30.0)
        for _ in range(8):
            sim._observe_regions()
        assert sim.router.threshold_for("pd2") \
            == pytest.approx(base["pd2"], rel=0.05)
        # per-request routing uses the per-home threshold
        m = sim.metrics()
        assert m["thresholds"]["pd2"] == sim.router.threshold_for("pd2")

    def test_roaming_charges_mesh_pair_links(self, setup):
        """Acceptance: pd_clusters=3 with roam_prob>0 puts nonzero bytes on
        at least one PD<->PD mesh pair link (cross-region cache copies)."""
        tm, sc, rate, _ = setup
        w = Workload(session_prob=0.6)
        sim = PrfaasSimulator(tm, _sc3(sc), w, SimConfig(
            arrival_rate=0.5 * rate, sim_time=300, seed=7, engine="event",
            pd_clusters=3, pd_mesh_gbps=10.0, roam_prob=0.4,
            pool_blocks=2_000_000))
        m = sim.run()
        mesh = {pair: s["sent_bytes"] for pair, s in m["links"].items()
                if PRFAAS not in pair}
        assert len(mesh) == 3                      # full pd mesh exists
        assert sum(mesh.values()) > 0
        assert sim.router.cross_transfers > 0

    def test_no_roaming_keeps_mesh_cold(self, setup):
        """roam_prob=0 pins sessions to their home: the mesh carries no
        bytes (the pre-roaming behavior, also pinned by the golden trace)."""
        tm, sc, rate, _ = setup
        w = Workload(session_prob=0.6)
        sim = PrfaasSimulator(tm, _sc3(sc), w, SimConfig(
            arrival_rate=0.5 * rate, sim_time=200, seed=7, engine="event",
            pd_clusters=3, pd_mesh_gbps=10.0, roam_prob=0.0,
            pool_blocks=2_000_000))
        m = sim.run()
        mesh = [s["sent_bytes"] for pair, s in m["links"].items()
                if PRFAAS not in pair]
        assert sum(mesh) == 0

    def test_regional_autoscale_converts_only_starved_region(self, setup):
        """A prefill-starved region converts D->P alone; balanced regions
        keep their allocation (queue evidence gates per region), and only
        the starved home's threshold is re-anchored."""
        tm, _, _, w = setup
        sc = SystemConfig(4, 5, 7, 100e9 / 8, 19_400.0,
                          n_p_clusters=(1, 2, 2), n_d_clusters=(3, 2, 2))
        sim = PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=2.5, sim_time=600, seed=6, engine="event",
            pd_clusters=3, pd_shares=(0.5, 0.25, 0.25), autoscale=True))
        for a in sim.autoscalers.values():
            a.cfg.period_s = 60.0
        m = sim.run()
        assert sim.autoscalers["pd0"].conversions, \
            "starved region never rebalanced"
        _, n_p0, n_d0 = sim.autoscalers["pd0"].conversions[-1]
        assert n_p0 > 1                                  # D -> P in pd0
        assert not sim.autoscalers["pd1"].conversions
        assert not sim.autoscalers["pd2"].conversions
        # pools resized region-locally; conversion re-anchored pd0's t only
        assert sim.pdp_pools["pd0"].capacity == n_p0
        assert sim.pdp_pools["pd1"].capacity == 2
        assert m["thresholds"]["pd1"] == pytest.approx(19_400.0)
        assert m["clusters"]["pd0"]["conversions"] == \
            len(sim.autoscalers["pd0"].conversions)

    def test_tick_event_equivalence_roaming(self, setup):
        """Engine equivalence (5%) holds with roaming + mesh traffic on."""
        tm, sc, rate, _ = setup
        w = Workload(session_prob=0.4)
        out = {}
        for engine in ("tick", "event"):
            sim = PrfaasSimulator(tm, _sc3(sc), w, SimConfig(
                arrival_rate=0.7 * rate, sim_time=360, dt=0.02, seed=11,
                engine=engine, pd_clusters=3, pd_shares=(0.5, 0.3, 0.2),
                pd_mesh_gbps=10.0, roam_prob=0.3, pool_blocks=2_000_000))
            out[engine] = sim.run()
        t, e = out["tick"], out["event"]
        assert e["throughput_rps"] == pytest.approx(t["throughput_rps"],
                                                    rel=0.05)
        assert e["ttft_mean"] == pytest.approx(t["ttft_mean"], rel=0.05)
        assert e["egress_gbps"] == pytest.approx(t["egress_gbps"], rel=0.05)

    @pytest.mark.slow
    def test_tick_event_equivalence_regional_autoscale(self, setup):
        """Engine equivalence (5%) holds with per-region autoscaling on;
        metrics cover the steady state after the control transient."""
        tm, _, _, w = setup
        sc = SystemConfig(4, 5, 7, 100e9 / 8, 19_400.0,
                          n_p_clusters=(1, 2, 2), n_d_clusters=(3, 2, 2))
        out, conv = {}, {}
        for engine in ("tick", "event"):
            sim = PrfaasSimulator(tm, sc, w, SimConfig(
                arrival_rate=2.5, sim_time=900, dt=0.05, seed=6,
                warmup_frac=0.25, engine=engine, pd_clusters=3,
                pd_shares=(0.5, 0.25, 0.25), autoscale=True))
            for a in sim.autoscalers.values():
                a.cfg.period_s = 60.0
            out[engine] = sim.run()
            conv[engine] = {n: a.conversions
                            for n, a in sim.autoscalers.items()}
            assert conv[engine]["pd0"]
        assert conv["tick"] == conv["event"]     # identical control decisions
        t, e = out["tick"], out["event"]
        assert e["throughput_rps"] == pytest.approx(t["throughput_rps"],
                                                    rel=0.05)
        assert e["ttft_mean"] == pytest.approx(t["ttft_mean"], rel=0.05)
        assert e["egress_gbps"] == pytest.approx(t["egress_gbps"], rel=0.05)

    def test_lambda_max_per_region_thresholds(self, setup):
        """Planner-side regional awareness: uniform per-region thresholds
        reproduce the scalar case; raising only a hot region's t matches
        the simulator's per-home control direction (less offload there)."""
        tm, sc, _, _ = setup
        sc3 = _sc3(sc, 3)
        t = sc.threshold
        uniform = tm.lambda_max(sc3, thresholds=[t, t, t])
        assert uniform == pytest.approx(tm.lambda_max(sc3))
        # one congested region raises its bar alone; capacity stays finite
        # and the planner's answer moves continuously
        bumped = tm.lambda_max(sc3, thresholds=[t, t, 1.35 * t])
        assert 0 < bumped
        assert bumped == pytest.approx(uniform, rel=0.5)
        with pytest.raises(ValueError):
            tm.lambda_max(sc3, thresholds=[t, t])          # wrong length
        with pytest.raises(ValueError):
            tm.lambda_max(sc, thresholds=[t])   # scalar config, no regions


# --------------------------------------------------------------------------
# satellite: horizon-filtered throughput
# --------------------------------------------------------------------------
class TestHorizonFilteredMetrics:
    def _sim(self, setup, **kw):
        tm, sc, _, w = setup
        return PrfaasSimulator(tm, sc, w, SimConfig(arrival_rate=1.0, **kw))

    def test_decode_past_horizon_not_counted(self, setup):
        sim = self._sim(setup, sim_time=100.0, warmup_frac=0.1)
        for rid, done in ((0, 50.0), (1, 99.9), (2, 130.0), (3, -1.0)):
            r = Request(rid, 20.0, 1000, rid)
            r.first_token, r.done = done - 1.0, done
            sim.all_requests.append(r)
        m = sim.metrics()
        # only the two decodes finishing inside the horizon count
        assert m["completed"] == 2
        assert m["throughput_rps"] == pytest.approx(2 / 90.0)

    def test_warmup_arrivals_still_excluded(self, setup):
        sim = self._sim(setup, sim_time=100.0, warmup_frac=0.1)
        r = Request(0, 5.0, 1000, 0)          # arrives during warmup
        r.first_token, r.done = 40.0, 50.0
        sim.all_requests.append(r)
        assert sim.metrics()["completed"] == 0

    def test_end_to_end_no_tail_inflation(self, setup):
        """Near saturation the unfiltered count included decodes finishing
        after the horizon; the filtered throughput can never exceed what
        the horizon actually absorbed."""
        tm, sc, rate, w = setup
        sim = PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=2.0 * rate, sim_time=240, seed=1))
        m = sim.run()
        horizon_ok = [r for r in sim.all_requests
                      if 0 <= r.done <= 240 and r.arrival >= 24.0]
        assert m["completed"] == len(horizon_ok)
        assert all(r.done <= 240.0 for r in horizon_ok)


# --------------------------------------------------------------------------
# satellite: warmup-consistent egress
# --------------------------------------------------------------------------
class TestEgressWindow:
    def test_event_and_tick_snapshot_warmup_bytes(self, setup):
        tm, sc, rate, w = setup
        for engine in ("event", "tick"):
            sim = PrfaasSimulator(tm, sc, w, SimConfig(
                arrival_rate=0.8 * rate, sim_time=200, dt=0.02, seed=2,
                warmup_frac=0.25, engine=engine))
            m = sim.run()
            assert sim._egress_t0 > 0          # warmup traffic existed
            expect = (sim.topology.sent_bytes - sim._egress_t0) \
                * 8 / 1e9 / (200 * 0.75)
            assert m["egress_gbps"] == pytest.approx(expect)

    def test_warmup_only_traffic_reports_zero(self, setup):
        """All bytes sent during warmup -> egress over the measurement
        window must be ~0 (the old code averaged them over the horizon)."""
        tm, sc, _, w = setup
        sim = PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=1.0, sim_time=100.0, warmup_frac=0.5))
        sim._egress_t0 = 7.5e9
        sim.link.sent_bytes = 7.5e9            # nothing after t0
        assert sim.metrics()["egress_gbps"] == pytest.approx(0.0)


# --------------------------------------------------------------------------
# satellite: utilization after a capacity resize
# --------------------------------------------------------------------------
class TestPoolUtilizationResize:
    def test_resize_does_not_rewrite_history(self):
        p = EventPool(1)
        assert p.submit("a", 0.0)              # busy 1/1 over [0, 10]
        p.release(10.0)
        p.set_capacity(4, 10.0)                # idle 0/4 over [10, 20]
        # busy_time=10; capacity-time = 10*1 + 10*4 = 50 -> 0.2 (the old
        # elapsed * current_capacity denominator gave 10/80 = 0.125)
        assert p.utilization(20.0) == pytest.approx(0.2)

    def test_unresized_pool_unchanged(self):
        p = EventPool(2)
        p.submit("a", 0.0)
        p.release(5.0)
        assert p.utilization(10.0) == pytest.approx(5.0 / 20.0)

    def test_downsize_keeps_epoch_weights(self):
        p = EventPool(4)
        for x in "abcd":
            p.submit(x, 0.0)                   # 4/4 busy over [0, 10]
        for _ in range(4):
            p.release(10.0)
        p.set_capacity(1, 10.0)                # 0/1 over [10, 30]
        assert p.utilization(30.0) == pytest.approx(40.0 / (40.0 + 20.0))


# --------------------------------------------------------------------------
# satellite: throughput-model + shared-config fixes
# --------------------------------------------------------------------------
class TestModelAndConfigFixes:
    def test_lambda_max_zero_when_no_local_prefill_needed(self, setup):
        tm, sc, _, w = setup
        sc0 = SystemConfig(0, 0, 8, 0.0, math.inf)   # no prefill anywhere
        assert tm.lambda_max(sc0) == 0.0             # theta_pdp == 0 path

    def test_per_cluster_uniform_matches_aggregate(self, setup):
        tm, sc, _, _ = setup
        sc3 = _sc3(sc, 2)                            # n_p, n_d split evenly
        if sc.n_p % 2 == 0 and sc.n_d % 2 == 0:
            assert tm.lambda_max(sc3) == pytest.approx(tm.lambda_max(sc))

    def test_skewed_shares_bind_on_smallest_region(self, setup):
        tm, sc, _, _ = setup
        sc3 = _sc3(sc, 3)
        uniform = tm.lambda_max(sc3)
        skewed = tm.lambda_max(sc3, pd_shares=[0.7, 0.2, 0.1])
        assert skewed <= uniform + 1e-9      # hot region saturates first

    def test_shares_normalized_and_length_checked(self, setup):
        tm, sc, _, _ = setup
        sc3 = _sc3(sc, 3)
        # raw weights == fractions after normalization
        assert tm.lambda_max(sc3, pd_shares=[50, 30, 20]) \
            == pytest.approx(tm.lambda_max(sc3, pd_shares=[0.5, 0.3, 0.2]))
        with pytest.raises(ValueError):
            tm.lambda_max(sc3, pd_shares=[0.5, 0.5])     # wrong length
        with pytest.raises(ValueError):
            tm.lambda_max(sc3, pd_shares=[1.0, 0.5, -0.5])

    def test_no_prfaas_profile_zeroes_multicluster_capacity(self):
        """n_prfaas > 0 with no PrfaaS profile means the offloaded fraction
        has nowhere to run: the per-cluster branch must return 0.0 exactly
        like the single-cluster path (theta_prfaas == 0)."""
        w = Workload()
        tm_none = ThroughputModel(None, paper_h20_profile(), w)
        sc1 = SystemConfig(4, 4, 4, 1e9, 19_400.0)
        sc2 = SystemConfig(4, 4, 4, 1e9, 19_400.0,
                           n_p_clusters=(2, 2), n_d_clusters=(2, 2))
        assert tm_none.lambda_max(sc1) == 0.0
        assert tm_none.lambda_max(sc2) == 0.0
        # threshold=inf offloads nothing: capacity is PD-only and positive
        sc_inf = SystemConfig(4, 4, 4, 1e9, math.inf,
                              n_p_clusters=(2, 2), n_d_clusters=(2, 2))
        assert tm_none.lambda_max(sc_inf) > 0

    def test_per_cluster_tuples_validated(self):
        with pytest.raises(ValueError):
            SystemConfig(4, 4, 4, 1e9, 1000.0,
                         n_p_clusters=(2, 1), n_d_clusters=(2, 2))

    def test_router_and_autoscaler_cfgs_not_shared(self, setup):
        tm, sc, _, _ = setup
        r1, r2 = Router(tm, sc), Router(tm, sc)
        r1.cfg.util_high = 0.123
        assert r2.cfg.util_high != 0.123
        a1, a2 = Autoscaler(tm, r1, sc), Autoscaler(tm, r2, sc)
        a1.cfg.period_s = 7.0
        assert a2.cfg.period_s != 7.0


# --------------------------------------------------------------------------
# exact-link livelock fix (sub-epsilon drain boundary)
# --------------------------------------------------------------------------
class TestLinkLivelockFix:
    def test_drain_boundary_inside_epsilon_completes(self):
        """A drain time within _EPS_T of the clock used to be uncrossable:
        advance() refused the zero-length step and next_event() re-announced
        the same boundary forever.  It must now resolve in O(1) steps."""
        link = Link(8e9)                       # 1 GB/s
        done = []
        link.submit(1e9, 0.0, on_done=lambda t: done.append(t))
        link.advance(1.0 - 5e-10)              # residual: 0.5 bytes
        for _ in range(3):                     # bounded, not while-flows
            nxt = link.next_event()
            if not math.isfinite(nxt):
                break
            link.advance(nxt)
        assert done and done[0] == pytest.approx(1.0, abs=1e-8)
        assert link.sent_bytes == pytest.approx(1e9)
        assert not link.flows

    def test_run_until_idle_terminates_on_residual(self):
        link = Link(8e9)
        link.submit(2e9, 0.0)
        link.advance(2.0 - 8e-10)
        t = link.run_until_idle(max_time=10.0)
        assert not link.flows and t == pytest.approx(2.0, abs=1e-8)

"""Throughput model (Eqs. 1-8), router, autoscaler, workload moments."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (PD, PRFAAS, Autoscaler, Router, RouterConfig,
                        StageTelemetry, SystemConfig, ThroughputModel,
                        Workload, kv_throughput, paper_h20_profile,
                        paper_h200_profile)
from repro.core.workload import LogNormalLengths


@pytest.fixture(scope="module")
def tm():
    return ThroughputModel(paper_h200_profile(), paper_h20_profile(),
                           Workload())


class TestWorkloadMoments:
    def test_mean_matches_paper(self):
        w = LogNormalLengths()
        assert 26_000 < w.mean() < 28_500          # paper: ~27K

    def test_moments_match_monte_carlo(self):
        w = LogNormalLengths()
        x = w.sample(np.random.default_rng(0), 400_000)
        for t in (2000.0, 19_400.0, 60_000.0):
            assert abs(w.p_gt(t) - (x > t).mean()) < 0.01
            assert abs(w.mean_above(t) / x[x > t].mean() - 1) < 0.03
            assert abs(w.mean_below(t) / x[x <= t].mean() - 1) < 0.03

    @settings(max_examples=60, deadline=None)
    @given(st.floats(200, 120_000), st.floats(200, 120_000))
    def test_p_gt_monotone(self, a, b):
        w = LogNormalLengths()
        lo, hi = min(a, b), max(a, b)
        assert w.p_gt(lo) >= w.p_gt(hi) - 1e-12

    @settings(max_examples=60, deadline=None)
    @given(st.floats(300, 100_000))
    def test_law_of_total_expectation(self, t):
        w = LogNormalLengths()
        p = w.p_gt(t)
        total = p * w.mean_above(t) + (1 - p) * w.mean_below(t)
        assert abs(total / w.mean() - 1) < 1e-6


class TestThroughputModel:
    def test_reproduces_paper_table6(self, tm):
        """The faithful-reproduction gate: Table 6 within a few %."""
        sc, rate, _ = tm.grid_search(4, 8, 100e9 / 8)
        assert (sc.n_prfaas, sc.n_p, sc.n_d) == (4, 3, 5)
        assert abs(sc.threshold - 19_400) / 19_400 < 0.05
        assert abs(rate - 3.24) / 3.24 < 0.03
        hom = ThroughputModel(None, paper_h20_profile(), Workload())
        sc_h, rate_h, _ = hom.grid_search(0, 12, 0)
        assert (sc_h.n_p, sc_h.n_d) == (9, 3)
        assert abs(rate_h - 2.11) / 2.11 < 0.03
        naive = SystemConfig(4, 0, 8, 100e9 / 8, 0.0)
        rate_n = tm.lambda_max(naive)
        assert abs(rate_n - 2.45) / 2.45 < 0.03
        assert 1.45 < rate / rate_h < 1.62          # paper: 1.54x
        assert 1.10 < rate_n / rate_h < 1.25        # paper: 1.16x

    def test_egress_within_link(self, tm):
        sc, rate, _ = tm.grid_search(4, 8, 100e9 / 8)
        gbps = tm.egress_load(sc) * 8 / 1e9
        assert 10 < gbps < 16                        # paper: ~13 Gbps
        assert gbps < 100                            # within the link

    def test_eq7_balance_at_optimum(self, tm):
        sc, _, _ = tm.grid_search(4, 8, 100e9 / 8)
        eq7, _ = tm.balance_residuals(sc)
        p = tm.workload.lengths.p_gt(sc.threshold)
        rel = abs(eq7) / (tm.theta_prfaas(sc) / p)
        assert rel < 0.1                              # stages co-saturate

    def test_bandwidth_clips_prfaas(self, tm):
        """Eq. 3: shrinking B_out must eventually bind Θ_prfaas."""
        sc = SystemConfig(4, 3, 5, 1e9 / 8, 19_400.0)   # 1 Gbps
        sc_big = SystemConfig(4, 3, 5, 1e12, 19_400.0)
        assert tm.theta_prfaas(sc) < tm.theta_prfaas(sc_big)

    @settings(max_examples=40, deadline=None)
    @given(st.floats(1000, 100_000), st.integers(1, 7))
    def test_lambda_bounded_by_decode(self, t, n_p):
        tm_l = ThroughputModel(paper_h200_profile(), paper_h20_profile(),
                               Workload())
        sc = SystemConfig(4, n_p, 8 - n_p, 100e9 / 8, t)
        assert tm_l.lambda_max(sc) <= tm_l.theta_pdd(sc) + 1e-9

    def test_kv_wire_compression_lifts_bandwidth_bound(self, tm):
        """Beyond-paper: int8 wire KV doubles the egress ceiling; only
        matters when Θ_prfaas is bandwidth-clipped."""
        _, lam_plain, _ = tm.grid_search(8, 8, 10e9 / 8)
        _, lam_comp, _ = tm.grid_search(8, 8, 10e9 / 8,
                                        kv_wire_compression=2.0)
        assert lam_comp > lam_plain * 1.2
        # compute-bound regime (paper's 100 Gbps): no change
        _, a, _ = tm.grid_search(4, 8, 100e9 / 8)
        _, b, _ = tm.grid_search(4, 8, 100e9 / 8, kv_wire_compression=2.0)
        assert b == pytest.approx(a, rel=1e-6)

    def test_kv_throughput_drops_with_length(self):
        """§3.4.2: T_prefill grows faster than S_kv -> Φ_kv falls (hybrid)."""
        prof = paper_h200_profile()
        assert kv_throughput(prof, 131072) < kv_throughput(prof, 8192)


class TestRouter:
    def make(self, tm, t=19_400.0):
        sc = SystemConfig(4, 3, 5, 100e9 / 8, t)
        return Router(tm, sc, RouterConfig())

    def test_threshold_routing(self, tm):
        r = self.make(tm)
        assert r.route(40_000, {PD: 0, PRFAAS: 0}).target == PRFAAS
        assert r.route(5_000, {PD: 0, PRFAAS: 0}).target == PD

    def test_cache_aware_scarce(self, tm):
        """Bandwidth scarce: clusters evaluated independently."""
        r = self.make(tm)
        sig = {"util": 0.95}
        # long request whose PD-side cache makes it short locally
        d = r.route(40_000, {PD: 30_000, PRFAAS: 0}, sig)
        assert d.target == PD and d.cached_tokens == 30_000
        assert not d.cross_cache_transfer

    def test_cache_aware_abundant_cross_transfer(self, tm):
        """Bandwidth abundant: best cache anywhere + cross-cluster copy."""
        r = self.make(tm)
        sig = {"util": 0.05}
        d = r.route(40_000, {PD: 0, PRFAAS: 36_000}, sig)
        assert d.target == PD                 # incr 4K <= t
        assert d.cache_cluster == PRFAAS and d.cross_cache_transfer

    def test_congestion_raises_threshold(self, tm):
        r = self.make(tm)
        t0 = r.threshold
        r.observe_congestion({"util": 0.99, "queue_bytes": 5e9})
        assert r.threshold > t0
        for _ in range(50):
            r.observe_congestion({"util": 0.1, "queue_bytes": 0.0})
        assert r.threshold == pytest.approx(t0, rel=0.05)

    @settings(max_examples=60, deadline=None)
    @given(st.integers(128, 131072), st.integers(0, 131072),
           st.integers(0, 131072), st.floats(0, 1))
    def test_incremental_nonnegative(self, total, mpd, mprfaas, util):
        tm_l = ThroughputModel(paper_h200_profile(), paper_h20_profile(),
                               Workload())
        r = self.make(tm_l)
        d = r.route(total, {PD: min(mpd, total), PRFAAS: min(mprfaas, total)},
                    {"util": util})
        assert d.incremental >= 0
        assert d.cached_tokens + d.incremental >= total


class TestAutoscaler:
    def test_converts_roles_on_imbalance(self, tm):
        sc = SystemConfig(4, 6, 2, 100e9 / 8, 19_400.0)  # decode-starved
        r = Router(tm, sc)
        a = Autoscaler(tm, r, sc)
        a._last_eval = -1e9
        new = a.maybe_rebalance(1000.0, StageTelemetry(prefill_queue=0,
                                                       decode_queue=50))
        assert new is not None and new.n_d == 3 and new.n_p == 5

    def test_respects_period(self, tm):
        sc = SystemConfig(4, 6, 2, 100e9 / 8, 19_400.0)
        r = Router(tm, sc)
        a = Autoscaler(tm, r, sc)
        a._last_eval = 900.0
        assert a.maybe_rebalance(1000.0, StageTelemetry(0, 50)) is None

    def test_cache_hits_boost_producer_over_window(self, tm):
        """Session-aware loop: a hot prefix cache means cached tokens cost
        no prefill compute, so the effective producer rate rises and a
        P->D conversion fires where raw rates alone would not.  The hit
        fraction is windowed per evaluation (cumulative counter diffs),
        not a lifetime average."""
        sc = SystemConfig(4, 2, 6, 100e9 / 8, 19_400.0)
        # raw producer (~2.7) << consumer*1.25 (~5.9): no conversion cold
        r = Router(tm, sc)
        a = Autoscaler(tm, r, sc)
        a._last_eval = -1e9
        cold = StageTelemetry(prefill_queue=0, decode_queue=50,
                              cached_tokens=0, routed_tokens=10_000)
        assert a.maybe_rebalance(1000.0, cold) is None
        # window 2: lifetime frac is only 0.3 (4.5K/15K) but the LAST
        # window is 90% cached (4.5K of 5K) -> producer/0.1 -> P -> D
        hot = StageTelemetry(prefill_queue=0, decode_queue=50,
                             cached_tokens=4_500, routed_tokens=15_000)
        new = a.maybe_rebalance(2000.0, hot)
        assert new is not None and new.n_p == 1 and new.n_d == 7

    def test_lifetime_frac_alone_would_not_convert(self, tm):
        """Control for the window test: the same cumulative counters fed
        as a single lifetime observation (0.3 hit frac) stay balanced."""
        sc = SystemConfig(4, 2, 6, 100e9 / 8, 19_400.0)
        r = Router(tm, sc)
        a = Autoscaler(tm, r, sc)
        a._last_eval = -1e9
        tel = StageTelemetry(prefill_queue=0, decode_queue=50,
                             cache_hit_frac=0.3)
        assert a.maybe_rebalance(1000.0, tel) is None

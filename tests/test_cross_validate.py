"""Policy/actual cross-validation: the live multi-region deployment and the
discrete-event simulator share ONE control plane (``core.router.Router``
over a ``core.transfer.LinkTopology``), so a live run's arrival trace
replayed through ``PrfaasSimulator`` must reproduce its routing decisions —
exactly when congestion feedback is frozen, within tolerance when live.

Also pins the wire-compression byte property on the LIVE path: every pair
link's sent bytes equal the measured quantized cache bytes the deployment
put on it (the deployment-side extension of the PR 3 simulator property
harness).
"""
import numpy as np
import pytest

from repro.core import PRFAAS

pytestmark = pytest.mark.live      # jits real (smoke) models


def _run(freeze: bool, k: int = 3, compression: bool = True, seed: int = 0,
         requests: int = 12):
    from repro.launch.serve import build_parser, run_serve

    argv = ["--arch", "kimi-linear-1t", "--smoke",
            "--requests", str(requests), "--batches", "3",
            "--pd-clusters", str(k), "--threshold", "64",
            "--link-gbps", "10.0", "--pd-mesh-gbps", "10.0",
            "--seed", str(seed), "--cross-validate"]
    if compression:
        argv.append("--wire-compression")
    if freeze:
        argv.append("--freeze-thresholds")
    return run_serve(build_parser().parse_args(argv))


class TestCrossValidation:
    def test_frozen_thresholds_routes_agree_exactly(self):
        """Deterministic seed + frozen congestion feedback: the simulator
        replay matches the live run on EVERY request's route."""
        rep = _run(freeze=True)
        cv = rep["cross_validate"]
        assert cv["requests"] == 12
        assert cv["route_agreement"] == 1.0, cv["mismatches"]
        # both sides really did offload some and keep some local
        dec = rep["deployment"]["router_decisions"]
        assert dec.get(PRFAAS, 0) > 0
        assert sum(dec.values()) - dec.get(PRFAAS, 0) > 0
        # frozen means frozen: no threshold moved on either side
        assert set(cv["thresholds"]["live"].values()) == {64.0}
        assert set(cv["thresholds"]["sim"].values()) == {64.0}

    def test_live_feedback_within_tolerance(self):
        """With the short-term loops running on both sides (telemetry
        timing differs between wall clock and event clock), routing still
        agrees on at least 90% of requests."""
        rep = _run(freeze=False)
        assert rep["cross_validate"]["route_agreement"] >= 0.9

    def test_two_cluster_legacy_shape(self):
        """k=1 is the classic two-cluster deployment: same control plane,
        legacy 'pd' naming, exact agreement."""
        rep = _run(freeze=True, k=1, compression=False)
        cv = rep["cross_validate"]
        assert cv["route_agreement"] == 1.0, cv["mismatches"]
        assert list(cv["thresholds"]["live"]) == ["pd"]


class TestLiveWireBytes:
    @pytest.fixture(scope="class")
    def served(self):
        rep = _run(freeze=True)
        return rep, rep.pop("_requests")

    def test_pair_links_carry_measured_quantized_bytes(self, served):
        """Acceptance property: with compression on, the bytes each pair
        link reports sending equal the measured quantized cache bytes (plus
        cross-cache copies) the routing decisions charged to that pair."""
        rep, reqs = served
        charged: dict = {}

        def _charge(a, b, nbytes):
            key = f"{min(a, b)}|{max(a, b)}"
            charged[key] = charged.get(key, 0.0) + nbytes

        for r in reqs:
            d = r.decision
            assert d is not None
            if d.target == PRFAAS:
                _charge(PRFAAS, r.home, float(r.kv_bytes))
            if d.cross_cache_transfer and d.cached_tokens:
                _charge(d.cache_cluster, d.target, r.cross_kv_bytes)
        for pair, stats in rep["deployment"]["links"].items():
            assert stats["sent_bytes"] == pytest.approx(
                charged.get(pair, 0.0), rel=1e-6, abs=1.0), pair

    def test_quantized_bytes_beat_raw_and_ratio_is_measured(self, served):
        rep, reqs = served
        offloaded = [r for r in reqs if r.route == PRFAAS]
        assert offloaded
        for r in offloaded:
            assert 0 < r.kv_bytes < r.kv_bytes_raw
        ratio = rep["deployment"]["wire_compression"]
        assert ratio == pytest.approx(
            sum(r.kv_bytes_raw for r in offloaded)
            / sum(r.kv_bytes for r in offloaded))
        assert 1.5 < ratio < 4.5          # f32 smoke K/V -> int8

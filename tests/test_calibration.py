"""Measured-kernel calibration: curve fit, profile behavior, JSON roundtrip
(the kernel_bench -> calibrate -> CalibratedProfile -> Router flow)."""
import json

import numpy as np
import pytest

from repro.analysis.calibrate import (calibrated_profile,
                                      calibration_from_points,
                                      calibration_to_json, fit_mfu_curve,
                                      load_calibration)
from repro.configs import get_config
from repro.core.hardware import (CHIPS, AnalyticProfile, CalibratedProfile,
                                 Calibration)


def _curve(l, mfu_max, l_half):
    return mfu_max * l / (l + l_half)


class TestFit:
    def test_recovers_synthetic_curve(self):
        lens = [128, 256, 512, 1024, 4096]
        mfus = [_curve(l, 0.55, 900.0) for l in lens]
        mfu_max, l_half = fit_mfu_curve(lens, mfus)
        assert mfu_max == pytest.approx(0.55, rel=1e-3)
        assert l_half == pytest.approx(900.0, rel=1e-2)

    def test_noisy_fit_stays_sane(self):
        rng = np.random.default_rng(0)
        lens = [64, 128, 256, 512, 1024]
        mfus = [_curve(l, 0.4, 300.0) * float(rng.uniform(0.8, 1.25))
                for l in lens]
        mfu_max, l_half = fit_mfu_curve(lens, mfus)
        assert 0.0 < mfu_max <= 1.0
        assert l_half >= 0.0

    def test_needs_two_points(self):
        with pytest.raises(ValueError):
            fit_mfu_curve([128], [0.3])


class TestCalibratedProfile:
    def _calib(self):
        lens = [128, 512, 2048]
        pts = [(l, _curve(l, 0.5, 500.0)) for l in lens]
        return calibration_from_points(pts, peak_flops=100e9, mem_bw=20e9)

    def test_mfu_interpolates_measured_points(self):
        calib = self._calib()
        prof = calibrated_profile(get_config("qwen2.5-3b"), calib)
        for l, m in calib.points:
            assert prof.mfu(l) == pytest.approx(m, rel=1e-6)
        # outside the sweep: fitted saturation curve
        assert prof.mfu(1 << 20) == pytest.approx(calib.mfu_max, rel=0.05)

    def test_t_prefill_uses_measured_peak(self):
        cfg = get_config("qwen2.5-3b")
        calib = self._calib()
        slow = calibrated_profile(cfg, calib)
        fast = CalibratedProfile(
            cfg, Calibration(peak_flops=calib.peak_flops * 10,
                             mem_bw=calib.mem_bw * 10,
                             mfu_max=calib.mfu_max, l_half=calib.l_half,
                             points=calib.points))
        l = 512
        assert slow.t_prefill(l) == pytest.approx(10 * fast.t_prefill(l),
                                                  rel=1e-6)
        # S_kv is model-side and must not depend on the machine
        h200 = AnalyticProfile(cfg, CHIPS["h200"], 8)
        assert slow.s_kv(l) == h200.s_kv(l)

    def test_json_roundtrip(self, tmp_path):
        calib = self._calib()
        path = tmp_path / "BENCH_kernel.json"
        path.write_text(json.dumps(
            {"machine": {}, "calibration": calibration_to_json(calib)}))
        back = load_calibration(str(path))
        assert back == calib
        # bare-dict form also loads
        path2 = tmp_path / "bare.json"
        path2.write_text(json.dumps(calibration_to_json(calib)))
        assert load_calibration(str(path2)) == calib

"""Chunked GLA Pallas kernel vs sequential-scan oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.gla import gla_chunked

pytestmark = pytest.mark.slow      # JAX compiles dominate; -m "not slow" skips

RNG = np.random.default_rng(1)


def mk(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


def decay(B, H, S, strength=1.0):
    return jnp.asarray(
        -strength * np.abs(RNG.standard_normal((B, H, S))).astype(np.float32))


@pytest.mark.parametrize("B,H,S,dk,dv,chunk", [
    (1, 2, 128, 32, 32, 64),
    (2, 3, 130, 32, 48, 64),     # ragged + dk != dv
    (1, 1, 64, 16, 16, 16),
    (2, 2, 96, 64, 64, 32),
])
def test_gla_matches_oracle(B, H, S, dk, dv, chunk):
    q, k, v = mk(B, H, S, dk), mk(B, H, S, dk), mk(B, H, S, dv)
    la = decay(B, H, S)
    o, st = gla_chunked(q, k, v, la, chunk=chunk, interpret=True)
    o2, st2 = ref.gla_ref(q, k, v, la)
    np.testing.assert_allclose(o, o2, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(st, st2, atol=5e-4, rtol=5e-4)


def test_gla_strong_decay_stable():
    """Strong decay (a -> 0) must not produce inf/nan (the exp-of-
    differences formulation keeps every factor <= 1)."""
    B, H, S, d = 1, 2, 128, 32
    q, k, v = mk(B, H, S, d), mk(B, H, S, d), mk(B, H, S, d)
    la = jnp.full((B, H, S), -25.0)          # a ~ 1e-11 per step
    o, st = gla_chunked(q, k, v, la, chunk=32, interpret=True)
    assert bool(jnp.all(jnp.isfinite(o))) and bool(jnp.all(jnp.isfinite(st)))
    o2, _ = ref.gla_ref(q, k, v, la)
    np.testing.assert_allclose(o, o2, atol=5e-4, rtol=5e-4)


def test_gla_state_continuation():
    """Two half-sequence calls with state handoff == one full call."""
    B, H, S, d = 1, 2, 128, 32
    q, k, v = mk(B, H, S, d), mk(B, H, S, d), mk(B, H, S, d)
    la = decay(B, H, S)
    o_full, st_full = gla_chunked(q, k, v, la, chunk=32, interpret=True)
    h = S // 2
    o1, st1 = gla_chunked(q[:, :, :h], k[:, :, :h], v[:, :, :h],
                          la[:, :, :h], chunk=32, interpret=True)
    o2, st2 = gla_chunked(q[:, :, h:], k[:, :, h:], v[:, :, h:],
                          la[:, :, h:], initial_state=st1, chunk=32,
                          interpret=True)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 2), o_full,
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(st2, st_full, atol=5e-4, rtol=5e-4)


def test_gla_no_decay_is_linear_attention():
    """log_a = 0 degenerates to plain (Lightning-style) linear attention."""
    B, H, S, d = 1, 2, 64, 16
    q, k, v = mk(B, H, S, d), mk(B, H, S, d), mk(B, H, S, d)
    la = jnp.zeros((B, H, S))
    o, _ = gla_chunked(q, k, v, la, chunk=16, interpret=True)
    # cumulative-sum reference
    kv = jnp.cumsum(jnp.einsum("bhsk,bhsv->bhskv", q * 0 + k, v), axis=2)
    want = jnp.einsum("bhsk,bhskv->bhsv", q, kv)
    np.testing.assert_allclose(o, want, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gla_dtypes(dtype):
    B, H, S, d = 1, 2, 64, 32
    q, k, v = (mk(B, H, S, d).astype(dtype) for _ in range(3))
    la = decay(B, H, S, 0.2)
    o, st = gla_chunked(q, k, v, la, chunk=32, interpret=True)
    o2, st2 = ref.gla_ref(q, k, v, la)
    assert o.dtype == dtype
    atol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(o.astype(np.float32),
                               o2.astype(np.float32), atol=atol, rtol=atol)


def test_gla_step_matches_scan():
    from repro.kernels.ops import gla_step
    B, H, d = 2, 2, 16
    state = jnp.zeros((B, H, d, d))
    outs = []
    q = mk(B, H, 5, d)
    k = mk(B, H, 5, d)
    v = mk(B, H, 5, d)
    la = decay(B, H, 5)
    for t in range(5):
        o, state = gla_step(q[:, :, t], k[:, :, t], v[:, :, t], la[:, :, t],
                            state)
        outs.append(o)
    o_ref, st_ref = ref.gla_ref(q, k, v, la)
    np.testing.assert_allclose(jnp.stack(outs, 2), o_ref, atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(state, st_ref, atol=1e-5, rtol=1e-5)

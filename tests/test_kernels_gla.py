"""Chunked GLA Pallas kernel vs sequential-scan oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.gla import gla_chunked

pytestmark = pytest.mark.slow      # JAX compiles dominate; -m "not slow" skips

RNG = np.random.default_rng(1)


def mk(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


def decay(B, H, S, strength=1.0):
    return jnp.asarray(
        -strength * np.abs(RNG.standard_normal((B, H, S))).astype(np.float32))


@pytest.mark.parametrize("B,H,S,dk,dv,chunk", [
    (1, 2, 128, 32, 32, 64),
    (2, 3, 130, 32, 48, 64),     # ragged + dk != dv
    (1, 1, 64, 16, 16, 16),
    (2, 2, 96, 64, 64, 32),
])
def test_gla_matches_oracle(B, H, S, dk, dv, chunk):
    q, k, v = mk(B, H, S, dk), mk(B, H, S, dk), mk(B, H, S, dv)
    la = decay(B, H, S)
    o, st = gla_chunked(q, k, v, la, chunk=chunk, interpret=True)
    o2, st2 = ref.gla_ref(q, k, v, la)
    np.testing.assert_allclose(o, o2, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(st, st2, atol=5e-4, rtol=5e-4)


def test_gla_strong_decay_stable():
    """Strong decay (a -> 0) must not produce inf/nan (the exp-of-
    differences formulation keeps every factor <= 1)."""
    B, H, S, d = 1, 2, 128, 32
    q, k, v = mk(B, H, S, d), mk(B, H, S, d), mk(B, H, S, d)
    la = jnp.full((B, H, S), -25.0)          # a ~ 1e-11 per step
    o, st = gla_chunked(q, k, v, la, chunk=32, interpret=True)
    assert bool(jnp.all(jnp.isfinite(o))) and bool(jnp.all(jnp.isfinite(st)))
    o2, _ = ref.gla_ref(q, k, v, la)
    np.testing.assert_allclose(o, o2, atol=5e-4, rtol=5e-4)


def test_gla_state_continuation():
    """Two half-sequence calls with state handoff == one full call."""
    B, H, S, d = 1, 2, 128, 32
    q, k, v = mk(B, H, S, d), mk(B, H, S, d), mk(B, H, S, d)
    la = decay(B, H, S)
    o_full, st_full = gla_chunked(q, k, v, la, chunk=32, interpret=True)
    h = S // 2
    o1, st1 = gla_chunked(q[:, :, :h], k[:, :, :h], v[:, :, :h],
                          la[:, :, :h], chunk=32, interpret=True)
    o2, st2 = gla_chunked(q[:, :, h:], k[:, :, h:], v[:, :, h:],
                          la[:, :, h:], initial_state=st1, chunk=32,
                          interpret=True)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 2), o_full,
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(st2, st_full, atol=5e-4, rtol=5e-4)


def test_gla_no_decay_is_linear_attention():
    """log_a = 0 degenerates to plain (Lightning-style) linear attention."""
    B, H, S, d = 1, 2, 64, 16
    q, k, v = mk(B, H, S, d), mk(B, H, S, d), mk(B, H, S, d)
    la = jnp.zeros((B, H, S))
    o, _ = gla_chunked(q, k, v, la, chunk=16, interpret=True)
    # cumulative-sum reference
    kv = jnp.cumsum(jnp.einsum("bhsk,bhsv->bhskv", q * 0 + k, v), axis=2)
    want = jnp.einsum("bhsk,bhskv->bhsv", q, kv)
    np.testing.assert_allclose(o, want, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gla_dtypes(dtype):
    B, H, S, d = 1, 2, 64, 32
    q, k, v = (mk(B, H, S, d).astype(dtype) for _ in range(3))
    la = decay(B, H, S, 0.2)
    o, st = gla_chunked(q, k, v, la, chunk=32, interpret=True)
    o2, st2 = ref.gla_ref(q, k, v, la)
    assert o.dtype == dtype
    atol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(o.astype(np.float32),
                               o2.astype(np.float32), atol=atol, rtol=atol)


def test_gla_step_matches_scan():
    from repro.kernels.ops import gla_step
    B, H, d = 2, 2, 16
    state = jnp.zeros((B, H, d, d))
    outs = []
    q = mk(B, H, 5, d)
    k = mk(B, H, 5, d)
    v = mk(B, H, 5, d)
    la = decay(B, H, 5)
    for t in range(5):
        o, state = gla_step(q[:, :, t], k[:, :, t], v[:, :, t], la[:, :, t],
                            state)
        outs.append(o)
    o_ref, st_ref = ref.gla_ref(q, k, v, la)
    np.testing.assert_allclose(jnp.stack(outs, 2), o_ref, atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(state, st_ref, atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# Fused padded-batch variant: masking happens in-VMEM inside the kernel
# --------------------------------------------------------------------------


def test_gla_fused_equals_premasked_plain():
    """In-VMEM masking == jnp.where pre-masking, bit for bit: both paths run
    the identical chunk step on identical operands."""
    from repro.kernels.gla import gla_chunked_fused
    from repro.kernels.ops import _mask_padded
    B, H, S, d, chunk = 2, 2, 128, 32, 32
    q, k, v = mk(B, H, S, d), mk(B, H, S, d), mk(B, H, S, d)
    la = decay(B, H, S)
    lengths = jnp.asarray([S, 77], jnp.int32)
    o, st = gla_chunked_fused(q, k, v, la, lengths, chunk=chunk,
                              interpret=True)
    la_m, k_m = _mask_padded(lengths, S, la, k)
    o2, st2 = gla_chunked(q, k_m, v, la_m, chunk=chunk, interpret=True)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(st), np.asarray(st2))


def test_gla_fused_matches_truncated_ref():
    """Valid rows and final state of a right-padded batch == running the
    oracle on each row's true-length slice."""
    from repro.kernels.gla import gla_chunked_fused
    B, H, S, d, chunk = 2, 2, 128, 32, 32
    q, k, v = mk(B, H, S, d), mk(B, H, S, d), mk(B, H, S, d)
    la = decay(B, H, S)
    lengths = [128, 77]
    o, st = gla_chunked_fused(q, k, v, la, jnp.asarray(lengths, jnp.int32),
                              chunk=chunk, interpret=True)
    for b, L in enumerate(lengths):
        sl = slice(b, b + 1)
        o2, st2 = ref.gla_ref(q[sl, :, :L], k[sl, :, :L], v[sl, :, :L],
                              la[sl, :, :L])
        np.testing.assert_allclose(o[sl, :, :L], o2, atol=5e-4, rtol=5e-4)
        np.testing.assert_allclose(st[sl], st2, atol=5e-4, rtol=5e-4)


def test_ops_gla_lengths_dispatch_and_grad():
    """ops.gla(lengths=...): the CPU jnp path and the forced-kernel path
    agree forwards AND backwards (the kernel's vjp is the masked oracle)."""
    from repro.kernels import ops
    B, H, S, d = 2, 2, 64, 16
    q, k, v = mk(B, H, S, d), mk(B, H, S, d), mk(B, H, S, d)
    la = decay(B, H, S, 0.3)
    lengths = jnp.asarray([64, 39], jnp.int32)

    def loss(q, k, v, la):
        o, st = ops.gla(q, k, v, la, lengths=lengths, chunk=16)
        return jnp.sum(o ** 2) + jnp.sum(st ** 2)

    want = loss(q, k, v, la)
    gw = jax.grad(loss, argnums=(0, 1, 2, 3))(q, k, v, la)
    ops.FORCE_KERNEL_ON_CPU = True
    try:
        got = loss(q, k, v, la)
        gk = jax.grad(loss, argnums=(0, 1, 2, 3))(q, k, v, la)
    finally:
        ops.FORCE_KERNEL_ON_CPU = False
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)
    for a, b in zip(gk, gw):
        assert bool(jnp.all(jnp.isfinite(a)))
        np.testing.assert_allclose(a, b, atol=1e-3, rtol=1e-3)

"""Block pool + hybrid prefix cache: unit + hypothesis property tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.blockpool import PREFIX, TRANSFER, BlockPool
from repro.core.prefix_cache import HybridPrefixCache, token_block_hashes


def make_cache(blocks=256, bt=4, full=True, linear=True):
    pool = BlockPool(blocks, block_tokens=bt, block_bytes=1024)
    return HybridPrefixCache(pool, 0, 512, has_full_attn=full,
                             has_linear=linear)


class TestBlockPool:
    def test_alloc_free_cycle(self):
        p = BlockPool(8, 4)
        a = p.allocate(4)
        assert len(a) == 4 and p.free_blocks == 4
        p.release(a)
        assert p.free_blocks == 8       # unpopulated -> truly freed
        p.check_invariants()

    def test_transfer_blocks_discarded_on_release(self):
        """Paper Fig.4: transfer-cache blocks die when the wire finishes."""
        p = BlockPool(8, 4)
        t = p.allocate(3, TRANSFER)
        p.mark_populated(t)
        p.release(t)
        assert p.free_blocks == 8
        assert all(b not in p._blocks for b in t)

    def test_prefix_blocks_cached_then_evictable(self):
        p = BlockPool(4, 4)
        a = p.allocate(4, PREFIX)
        p.mark_populated(a)
        p.release(a)                     # rc=0 but cached (LRU)
        assert p.free_blocks == 4        # evictable counts as free
        b = p.allocate(4)                # forces eviction of all 4
        assert len(b) == 4
        assert p.stats["evicted"] == 4
        p.check_invariants()

    def test_overallocate_fails_cleanly(self):
        p = BlockPool(4, 4)
        a = p.allocate(3)
        assert p.allocate(2) is None
        assert p.stats["alloc_fail"] == 1
        p.release(a)

    def test_exhausted_all_refheld_alloc_fails(self):
        """With every block ref-counted (live requests), allocation must
        fail cleanly — nothing is evictable — and succeed again once refs
        drop to the LRU."""
        p = BlockPool(6, 4)
        a = p.allocate(6)
        p.mark_populated(a)
        p.retain(a)                      # rc=2: pinned by a second user
        assert p.allocate(1) is None
        assert p.stats["alloc_fail"] == 1
        assert p.stats["evicted"] == 0   # eviction never touches ref-held
        p.release(a)
        assert p.allocate(1) is None     # rc=1: still pinned
        p.release(a)                     # rc=0: populated -> LRU
        assert p.allocate(1) is not None
        assert p.stats["evicted"] == 1
        p.check_invariants()

    def test_lru_never_reclaims_refheld_or_unpopulated(self):
        """Eviction may only take rc=0 populated prefix blocks: ref-held
        blocks never enter the LRU, and unpopulated blocks free outright
        instead of lingering as (garbage) cache."""
        p = BlockPool(4, 4)
        held = p.allocate(2)             # rc=1 for the whole test
        cached = p.allocate(2)
        p.mark_populated(cached)
        p.release(cached)                # rc=0 + populated -> LRU
        got = p.allocate(2)              # free list empty: must evict
        assert set(got) == set(cached)   # ...exactly the LRU pair
        assert p.stats["evicted"] == 2
        for bid in held:
            assert p.get(bid).ref_count == 1
        p.release(got)                   # unpopulated at rc=0
        assert all(bid not in p._lru for bid in got)
        assert p.stats["freed"] == 2     # freed, not cached
        p.release(held)
        p.check_invariants()

    def test_transfer_discard_on_complete_even_if_retained(self):
        """Transfer blocks die the moment their last reference drops —
        populated or not, retained mid-flight or not — and never reach the
        LRU (paper Fig. 4: the transfer cache is not reusable)."""
        p = BlockPool(8, 4)
        t = p.allocate(3, TRANSFER)
        p.retain(t)                      # e.g. sender + receiver views
        p.mark_populated(t)
        p.release(t)                     # transfer completes on one side
        assert all(b in p._blocks for b in t)
        p.release(t)                     # last ref: discard, not cache
        assert all(b not in p._blocks for b in t)
        assert len(p._lru) == 0
        assert p.free_blocks == 8
        assert p.stats["freed"] == 3 and p.stats["evicted"] == 0
        p.check_invariants()

    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["alloc", "release", "retain"]),
                              st.integers(1, 5)), max_size=60))
    def test_invariants_under_random_ops(self, ops):
        """ref+cached+free == total after any op sequence; no negative rc."""
        p = BlockPool(16, 4)
        live = []
        for op, n in ops:
            if op == "alloc":
                got = p.allocate(n, PREFIX if n % 2 else TRANSFER)
                if got:
                    if n % 2:
                        p.mark_populated(got)
                    live.append(got)
            elif op == "release" and live:
                p.release(live.pop())
            elif op == "retain" and live:
                p.retain(live[-1])
                p.release(live[-1])
            p.check_invariants()


class TestHybridPrefixCache:
    def test_insert_then_match(self):
        c = make_cache()
        toks = list(range(40))
        assert c.match(toks) == 0
        c.insert(toks)
        assert c.match(toks) == 40       # 10 blocks of 4
        # shorter prefix: full-attn blocks cover it but the linear snapshot
        # exists only at 40 -> hybrid resumable length is 0 (paper §3.2:
        # request-level states reusable only at exact cached length)
        assert c.match(toks[:23]) == 0

    def test_hybrid_requires_both_groups(self):
        """Linear states are request-level: reusable only at their exact
        snapshot length (paper §3.2)."""
        c = make_cache()
        c.insert(list(range(40)))
        # extension of the cached prefix: snapshot at 40 + blocks [0,40)
        assert c.match(list(range(40)) + [99, 98]) == 40
        # shorter prefix: full-attn blocks cover it, but no linear snapshot
        assert c.match(list(range(20))) == 0

    def test_attention_only_partial_match(self):
        c = make_cache(linear=False)
        c.insert(list(range(40)))
        assert c.match(list(range(20))) == 20    # block-level partial hit

    def test_linear_only_exact_match(self):
        c = make_cache(full=False)
        c.insert(list(range(40)))
        assert c.match(list(range(40)) + [7]) == 40
        # snapshots exist only at insert lengths -> shorter prefixes miss
        assert c.match(list(range(36))) == 0
        assert c.match(list(range(28))) == 0

    def test_divergent_suffix_no_match(self):
        c = make_cache()
        c.insert(list(range(40)))
        other = list(range(40))
        other[2] = 999                    # first block differs
        assert c.match(other) == 0

    @settings(max_examples=50, deadline=None)
    @given(st.integers(4, 120), st.integers(0, 119))
    def test_match_never_exceeds_prefix(self, n, cut):
        """Property: match length <= common block-aligned prefix length."""
        c = make_cache(blocks=1024)
        toks = list(np.random.default_rng(0).integers(0, 50, n))
        c.insert(toks)
        cut = min(cut, n)
        query = toks[:cut] + [777]
        m = c.match(query)
        assert m <= cut
        assert m % c.block_tokens == 0

    def test_eviction_under_pressure_keeps_working(self):
        c = make_cache(blocks=16)        # tiny pool
        for i in range(20):
            c.insert(list(range(i * 100, i * 100 + 32)))
        # no crash; pool invariants hold; most old entries evicted
        c.pool.check_invariants()

    def test_transfer_alloc_release(self):
        c = make_cache()
        t = c.allocate_transfer(10)       # 3 blocks of 4 tokens
        assert len(t) == 3
        before = c.pool.free_blocks
        c.release_transfer(t)
        assert c.pool.free_blocks == before + 3


def test_token_block_hashes_chain():
    h1 = token_block_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4)
    h2 = token_block_hashes([1, 2, 3, 4, 9, 9, 9, 9], 4)
    assert h1[0] == h2[0] and h1[1] != h2[1]
    assert len(token_block_hashes([1, 2, 3], 4)) == 0

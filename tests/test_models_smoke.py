"""Per-arch smoke tests (assignment requirement): REDUCED same-family
configs, one forward/train step on CPU, asserting output shapes + no NaNs.
Plus the serving invariant: decode-from-shipped-cache == prefill logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_BUILDERS, get_config, get_smoke_config
from repro.models import Model, prepare_decode_caches

pytestmark = pytest.mark.slow      # JAX compiles dominate; -m "not slow" skips

ARCHS = list(ARCH_BUILDERS)
RNG = np.random.default_rng(7)


def make_batch(cfg, B, S, with_labels=True):
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (B, S + (1 if with_labels else 0))),
        jnp.int32)}
    if cfg.num_image_patches:
        batch["patches"] = jnp.asarray(
            RNG.standard_normal((B, cfg.num_image_patches, cfg.d_model))
            .astype(np.float32))
    if cfg.encoder_groups is not None:
        batch["frames"] = jnp.asarray(
            RNG.standard_normal((B, S, cfg.encoder_input_dim))
            .astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_is_exact(arch):
    cfg = get_config(arch)
    # exact dims from the assignment table
    assert cfg.param_count() > 0
    assert cfg.n_layers >= 12


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B=2, S=32)
    (loss, metrics), grads = jax.value_and_grad(
        model.train_loss, has_aux=True)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: degenerate grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_shapes(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    logits, caches = model.prefill(params, make_batch(cfg, B, S,
                                                      with_labels=False))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert len(caches["groups"]) == len(cfg.groups)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    """The PrfaaS invariant: KV produced by (remote) prefill, placed into
    decode buffers, must reproduce the prefill distribution exactly."""
    cfg = get_smoke_config(arch)
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 33
    batch = make_batch(cfg, B, S, with_labels=False)
    toks = batch["tokens"]
    full_logits, _ = model.prefill(params, batch)
    pre = dict(batch)
    pre["tokens"] = toks[:, :S - 1]
    _, caches = model.prefill(params, pre)
    total0 = (S - 1) + (cfg.num_image_patches or 0)
    dc = prepare_decode_caches(cfg, caches, capacity=total0 + 8)
    lengths = jnp.full((B,), total0, jnp.int32)
    dec_logits, dc2 = model.decode_step(params, toks[:, S - 1], dc, lengths)
    err = float(jnp.max(jnp.abs(jax.nn.log_softmax(full_logits)
                                - jax.nn.log_softmax(dec_logits))))
    assert err < 5e-4, f"{arch}: decode/prefill mismatch {err}"
    # second step stays finite
    nxt = jnp.argmax(dec_logits, -1).astype(jnp.int32)
    lg3, _ = model.decode_step(params, nxt, dc2, lengths + 1)
    assert bool(jnp.all(jnp.isfinite(lg3)))


def test_swa_ring_buffer_beyond_window():
    """Decode past the SWA window: ring buffer must equal full prefill."""
    import dataclasses
    cfg = get_smoke_config("h2o-danube-1.8b")   # window 64 after reduce
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 1, 97                                 # beyond the 64 window
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits, _ = model.prefill(params, {"tokens": toks})
    _, caches = model.prefill(params, {"tokens": toks[:, :S - 1]})
    dc = prepare_decode_caches(cfg, caches, capacity=S + 8)
    lg, _ = model.decode_step(params, toks[:, S - 1],
                              dc, jnp.full((B,), S - 1, jnp.int32))
    err = float(jnp.max(jnp.abs(jax.nn.log_softmax(full_logits)
                                - jax.nn.log_softmax(lg))))
    assert err < 5e-4, f"ring-buffer mismatch {err}"


def test_kv_bytes_asymmetry():
    """The paper's core premise: hybrid/SSM S_kv grows ~O(1) in length,
    dense-attention S_kv grows linearly."""
    xl = get_config("xlstm-350m")
    nemo = get_config("mistral-nemo-12b")
    g_xl = xl.kv_cache_bytes(131072) / max(1, xl.kv_cache_bytes(1024))
    g_nm = nemo.kv_cache_bytes(131072) / max(1, nemo.kv_cache_bytes(1024))
    assert g_xl < 1.5, "bounded-state arch must have ~flat S_kv"
    assert g_nm > 100, "dense arch S_kv must grow ~linearly"


def test_long_context_skips_match_assignment():
    from repro.configs import SHAPES, all_configs, cells
    runnable = list(cells(all_configs()))
    long_archs = {a for a, s in runnable if s == "long_500k"}
    assert long_archs == {"mixtral-8x22b", "h2o-danube-1.8b", "zamba2-1.2b",
                          "xlstm-350m"}
    # 10 archs x 4 shapes - 6 skipped long_500k cells
    assert len(runnable) == 34


def test_kv_wire_quantization_roundtrip():
    """int8 wire format: K/V leaves compress ~2x and dequantize within
    int8 tolerance; fp32 recurrent states pass through untouched."""
    import jax.numpy as jnp
    from repro.models.kvcache import (cache_num_bytes,
                                      dequantize_cache_from_wire,
                                      quantize_cache_for_wire)
    caches = {"groups": [{"b0": {
        "k": jnp.asarray(RNG.standard_normal((2, 1, 16, 2, 8)),
                         jnp.bfloat16),
        "v": jnp.asarray(RNG.standard_normal((2, 1, 16, 2, 8)),
                         jnp.bfloat16)},
        "b1": {"state": jnp.ones((2, 1, 4, 8), jnp.float32)}}]}
    before = cache_num_bytes(caches)
    wire, wire_bytes = quantize_cache_for_wire(caches)
    assert wire_bytes < 0.7 * before
    back = dequantize_cache_from_wire(wire)
    err = float(jnp.max(jnp.abs(
        back["groups"][0]["b0"]["k"].astype(jnp.float32)
        - caches["groups"][0]["b0"]["k"].astype(jnp.float32))))
    assert err < 0.1
    assert back["groups"][0]["b1"]["state"].dtype == jnp.float32

"""Continuous region-scheduler integration tests.

The load-bearing property: under greedy decoding the continuously-batched
``RegionScheduler`` emits EXACTLY the token sequences the PR 5 alternating
loop produced — bucket/chunk padding is exact per request and decode slots
are independent, so admission timing and batch composition must not change
a single token.  Plus the starvation guard (a ready request never waits a
block boundary while free slots exist) and sampling determinism under a
fixed seed.

Marked ``live`` (full scheduler loops on jitted smoke models) so the fast
lane (``-m "not slow and not live"``) stays quick.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving.api import Request
from repro.serving.engine import (DecodeEngine, PrefillEngine,
                                  RegionScheduler, trim_request_cache)

pytestmark = pytest.mark.live

SLOTS, CAPACITY, BLOCK = 4, 384, 8
MAX_BUCKET = 64

# full-attention (SWA window straddles chunk boundaries) + linear-state
ARCHS = ["h2o-danube-1.8b", "xlstm-350m"]


@pytest.fixture(scope="module", params=ARCHS)
def arch(request):
    cfg = get_smoke_config(request.param)
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk_requests(cfg, lens, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        (L,)).astype(np.int32),
                    max_new_tokens=b)
            for i, (L, b) in enumerate(zip(lens, budgets))]


def _engines(model, params, **dec_kw):
    peng = PrefillEngine(model, params, min_bucket=32, max_bucket=MAX_BUCKET)
    dec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                       **dec_kw)
    return peng, dec


def _alternating(model, params, reqs):
    """The PR 5 regime: ONE bucketed prefill call for the whole batch, then
    admit waves draining all active streams between."""
    peng, dec = _engines(model, params)
    lengths = np.array([len(r.tokens) for r in reqs], np.int32)
    toks = np.zeros((len(reqs), int(lengths.max())), np.int32)
    for i, r in enumerate(reqs):
        toks[i, :len(r.tokens)] = r.tokens
    first, caches, _ = peng.prefill(toks, lengths)
    pending = [(r, int(first[i]),
                trim_request_cache(caches, i, int(lengths[i])),
                int(lengths[i])) for i, r in enumerate(reqs)]
    while pending:
        n = dec.admit_many(pending)
        pending = pending[n:]
        dec.run_until_drained()
    dec.run_until_drained()
    return {rid: resp.output_tokens for rid, resp in dec.outputs.items()}


def _continuous(model, params, reqs, max_prefill_batch=3):
    peng, dec = _engines(model, params)
    sched = RegionScheduler(peng, dec, max_prefill_batch=max_prefill_batch)
    for r in reqs:
        sched.submit(r)
    sched.run()
    return {rid: resp.output_tokens for rid, resp in dec.outputs.items()}, \
        sched, dec


class TestTokenIdentity:
    def test_scheduler_matches_alternating_loop(self, arch):
        """Greedy, fixed seed, mixed buckets + one past-max-bucket prompt
        (chunk-interleaved), more requests than slots (several admit
        waves): per-request token sequences must be identical."""
        cfg, model, params = arch
        lens = [24, 40, 150, 33, 90, 16, 60]      # 150 > MAX_BUCKET*bucket
        budgets = [7, 12, 5, 9, 3, 8, 10]
        reqs = _mk_requests(cfg, lens, budgets, seed=2)
        want = _alternating(model, params, reqs)
        got, sched, dec = _continuous(model, params, reqs)
        assert sorted(got) == sorted(want) == list(range(len(reqs)))
        for rid in want:
            assert got[rid] == want[rid], f"rid {rid} diverged"
        assert all(r.finished for r in dec.outputs.values())
        assert dec.truncations == 0

    def test_chunk_interleaving_happened(self, arch):
        """The long prompt must actually run as an interleaved unit, not
        block the loop: decode blocks fire between its chunks."""
        cfg, model, params = arch
        reqs = _mk_requests(cfg, [16, 20, 150, 24], [20, 20, 4, 20], seed=5)
        got, sched, dec = _continuous(model, params, reqs, max_prefill_batch=2)
        assert all(resp.finished for resp in dec.outputs.values())
        # the chunked prompt needed ceil(150/64)=3 ticks of prefill; decode
        # was already active during them (short units finished first)
        assert sched.boundaries > 3
        # first token comes from prefill; every budgeted token decoded
        assert dec.tokens_out == sum(r.max_new_tokens for r in reqs)
        for r in reqs:
            assert len(dec.outputs[r.rid].output_tokens) == \
                r.max_new_tokens + 1


class TestStarvation:
    def test_no_ready_request_waits_with_free_slots(self, arch):
        cfg, model, params = arch
        lens = [16, 20, 24, 30, 40, 50, 18, 22, 26, 34]
        budgets = [3, 9, 5, 12, 4, 7, 15, 6, 8, 10]
        reqs = _mk_requests(cfg, lens, budgets, seed=7)
        got, sched, dec = _continuous(model, params, reqs)
        assert sorted(got) == list(range(len(reqs)))
        assert all(r.finished for r in dec.outputs.values())
        # the guard: FIFO admission runs at EVERY block boundary, so a
        # request only ever waits while all slots are occupied
        assert sched.starved_boundaries == 0
        stats = sched.stats()
        assert stats["starved_boundaries"] == 0
        assert stats["occupancy"] > 0 and stats["goodput_tok_s"] > 0


class TestSampling:
    def _decode(self, model, params, reqs, **dec_kw):
        peng = PrefillEngine(model, params, min_bucket=32)
        dec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                           **dec_kw)
        lengths = np.array([len(r.tokens) for r in reqs], np.int32)
        toks = np.zeros((len(reqs), int(lengths.max())), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.tokens)] = r.tokens
        first, caches, _ = peng.prefill(toks, lengths)
        dec.admit_many([(r, int(first[i]),
                         trim_request_cache(caches, i, int(lengths[i])),
                         int(lengths[i])) for i, r in enumerate(reqs)])
        dec.run_until_drained()
        return {rid: resp.output_tokens for rid, resp in dec.outputs.items()}

    def test_fixed_seed_is_deterministic(self, arch):
        cfg, model, params = arch
        reqs = _mk_requests(cfg, [24, 40, 33], [12, 12, 12], seed=3)
        kw = dict(temperature=0.8, top_k=5, seed=123)
        assert self._decode(model, params, reqs, **kw) \
            == self._decode(model, params, reqs, **kw)

    def test_seed_changes_samples(self, arch):
        cfg, model, params = arch
        reqs = _mk_requests(cfg, [24, 40, 33], [16, 16, 16], seed=3)
        a = self._decode(model, params, reqs, temperature=1.5, seed=123)
        b = self._decode(model, params, reqs, temperature=1.5, seed=124)
        assert a != b

    def test_top_k_one_is_greedy(self, arch):
        """top_k=1 renormalizes over the argmax alone: identical tokens to
        the greedy (temperature=0) engine."""
        cfg, model, params = arch
        reqs = _mk_requests(cfg, [24, 40, 33], [10, 10, 10], seed=4)
        greedy = self._decode(model, params, reqs)
        topk1 = self._decode(model, params, reqs, temperature=1.0, top_k=1,
                             seed=99)
        assert greedy == topk1

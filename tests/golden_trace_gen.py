"""Regenerate tests/golden_twocluster_trace.json.

The golden trace pins the two-cluster simulator's per-request trajectories
(raw event times, which are independent of how ``metrics()`` post-processes
them) so the multi-cluster ``LinkTopology`` refactor can be verified to
reproduce the single-``Link`` code path bit-for-bit on the same seed.

The regionalized control plane (PR 3) is pinned the same way: the scenario
explicitly sets ``roam_prob=0.0`` and ``autoscale=False``, so per-home
thresholds, session roaming, and per-region autoscaling must all be
RNG-stream- and trajectory-neutral when disabled — regenerating this file
after the regionalization produced a byte-identical trace.

    PYTHONPATH=src python tests/golden_trace_gen.py
"""
import json
import os

from repro.core import (PrfaasSimulator, SimConfig, ThroughputModel,
                        Workload, paper_h20_profile, paper_h200_profile)

GOLDEN_PATH = os.path.join(os.path.dirname(__file__),
                           "golden_twocluster_trace.json")
N_REQS = 48


def scenario():
    w = Workload(session_prob=0.3, burst_factor=1.5)
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, lam, _ = tm.grid_search(4, 8, 100e9 / 8)
    return tm, sc, w, lam


def run_engine(engine: str) -> dict:
    tm, sc, w, lam = scenario()
    sim = PrfaasSimulator(tm, sc, w, SimConfig(
        arrival_rate=0.8 * lam, sim_time=120.0, dt=0.02, seed=42,
        link_gbps=25.0, link_fluctuation=0.15, engine=engine,
        roam_prob=0.0, autoscale=False))    # regional control loops OFF
    sim.run()
    reqs = []
    for r in sim.all_requests[:N_REQS]:
        reqs.append({
            "rid": r.rid, "arrival": r.arrival, "total_len": r.total_len,
            "session": r.session, "target": r.decision.target,
            "cached": r.decision.cached_tokens,
            "cross": r.decision.cross_cache_transfer,
            "prefill_start": r.prefill_start, "prefill_done": r.prefill_done,
            "transfer_done": r.transfer_done, "decode_start": r.decode_start,
            "done": r.done,
        })
    return {"n_requests": len(sim.all_requests),
            "sent_bytes": sim.link.sent_bytes, "requests": reqs}


def main():
    out = {engine: run_engine(engine) for engine in ("event", "tick")}
    with open(GOLDEN_PATH, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH}: "
          + ", ".join(f"{e}: n={v['n_requests']} sent={v['sent_bytes']:.0f}B"
                      for e, v in out.items()))


if __name__ == "__main__":
    main()

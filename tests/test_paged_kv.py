"""Paged device KV end-to-end tests (PR 7 tentpole).

The load-bearing property: with greedy decoding, the paged engine — pool
pages + block tables from prefix hit through decode — emits EXACTLY the
token sequences the dense per-slot layout produces, across full-attn, MLA,
SWA, and hybrid-linear archs.  On top of identity:

  * a prefix-hit request resumes from pinned pool pages and prefills ONLY
    the uncached suffix, reproducing the full-prefill tokens while
    ``PrefillEngine.tokens_prefilled`` counts only the suffix;
  * the pool conserves pages: after the scheduler drains,
    ``allocated == freed + evicted + resident`` and nothing is ref-held.

Marked ``live`` (full scheduler loops on jitted smoke models).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import AttentionSpec
from repro.core.blockpool import BlockPool
from repro.core.prefix_cache import HybridPrefixCache
from repro.core.router import PRFAAS
from repro.models import Model, paged_layout
from repro.serving.api import PagePin, Request
from repro.serving.deployment import CrossDCDeployment, DeploymentConfig
from repro.serving.engine import (DecodeEngine, PrefillEngine,
                                  RegionScheduler)

pytestmark = pytest.mark.live

SLOTS, CAPACITY, BLOCK = 4, 384, 8
MAX_BUCKET = 64
PAGE = 16

# one arch per decode-cache family: full-attn, MLA + linear, SWA, hybrid
ARCHS = ["mistral-nemo-12b", "kimi-linear-1t", "h2o-danube-1.8b",
         "zamba2-1.2b"]


@pytest.fixture(scope="module", params=ARCHS)
def arch(request):
    cfg = get_smoke_config(request.param)
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk_requests(cfg, lens, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        (L,)).astype(np.int32),
                    max_new_tokens=b)
            for i, (L, b) in enumerate(zip(lens, budgets))]


def _cache_flags(cfg):
    """(has_full_attn, has_linear) for the device prefix cache: seq pages
    exist iff some full/MLA layer does; exact-length snapshots are needed
    iff the arch carries SWA rings or recurrent state."""
    lay = paged_layout(cfg, CAPACITY, PAGE, 1)
    has_state = any(not isinstance(b.mixer, AttentionSpec)
                    for g in cfg.groups for b in g.blocks)
    return lay.seq_cols > 0, (lay.ring_cols > 0 or has_state)


def _run(model, params, reqs, *, paged, pool=None, cache=None):
    peng = PrefillEngine(model, params, min_bucket=32, max_bucket=MAX_BUCKET)
    dec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                       paged=paged, pool=pool, page_tokens=PAGE)
    if cache is not None:
        dec.on_admit = lambda req, L, ids, snap: cache.insert_device(
            [int(t) for t in req.tokens], ids, snap)
    sched = RegionScheduler(peng, dec, max_prefill_batch=3)
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert not sched.has_work
    return ({rid: r.output_tokens for rid, r in dec.outputs.items()},
            peng, dec)


class TestTokenIdentity:
    def test_paged_matches_dense(self, arch):
        """Greedy token streams through the scheduler are identical between
        the dense and paged layouts (mixed lengths, slot churn, a chunked
        prompt past max_bucket)."""
        cfg, model, params = arch
        lens = [24, 40, 70, 16, 33, 64]
        budgets = [12, 20, 9, 16, 11, 7]
        dense, _, _ = _run(model, params,
                           _mk_requests(cfg, lens, budgets), paged=False)
        paged, _, dec = _run(model, params,
                             _mk_requests(cfg, lens, budgets), paged=True)
        assert paged == dense
        dec.pool.check_invariants()

    def test_pool_conserves_pages(self, arch):
        """After the paged run drains: nothing ref-held, and
        allocated == freed + evicted + resident."""
        cfg, model, params = arch
        pool = BlockPool(SLOTS * CAPACITY // PAGE, PAGE)
        _run(model, params, _mk_requests(cfg, [24, 40, 33], [10, 8, 12]),
             paged=True, pool=pool)
        s = pool.stats
        assert s["allocated"] > 0
        assert s["allocated"] == s["freed"] + s["evicted"] + pool.resident
        # no registration in this run -> every page came back
        assert pool.resident == 0
        pool.check_invariants()


class TestPrefixHitSuffixOnly:
    def test_suffix_prefill_reproduces_full_prefill(self, arch):
        """Request B shares a page-aligned 64-token prefix with a retired
        request A.  B resumes from A's registered pool pages: only the
        suffix is prefilled, and B's tokens equal a fresh dense run's."""
        cfg, model, params = arch
        has_full, has_linear = _cache_flags(cfg)
        pool = BlockPool(SLOTS * CAPACITY // PAGE, PAGE, 1)
        cache = HybridPrefixCache(pool, 0, 1, has_full_attn=has_full,
                                  has_linear=has_linear)

        rng = np.random.default_rng(3)
        prefix = rng.integers(0, cfg.vocab_size, (64,)).astype(np.int32)
        suffix = rng.integers(0, cfg.vocab_size, (41,)).astype(np.int32)
        req_a = Request(rid=0, tokens=prefix, max_new_tokens=6)

        peng = PrefillEngine(model, params, min_bucket=32,
                             max_bucket=MAX_BUCKET)
        dec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                           paged=True, pool=pool, page_tokens=PAGE)
        dec.on_admit = lambda req, L, ids, snap: cache.insert_device(
            [int(t) for t in req.tokens], ids, snap)
        sched = RegionScheduler(peng, dec, max_prefill_batch=3)
        sched.submit(req_a)
        sched.run()

        tokens_b = np.concatenate([prefix, suffix])
        c, ids, snap = cache.match_resume([int(t) for t in tokens_b])
        assert c == 64, "page-aligned prefix must be device-resumable"
        pool.retain(ids)
        req_b = Request(rid=1, tokens=tokens_b, max_new_tokens=12,
                        device_pin=PagePin(c, ids, snap))
        before = peng.tokens_prefilled
        sched.submit(req_b)
        sched.run()
        suffix_cost = peng.tokens_prefilled - before
        assert suffix_cost == len(tokens_b) - c, \
            "prefix hit must prefill only the uncached suffix"

        dense_out, _, _ = _run(model, params,
                               [Request(rid=1, tokens=tokens_b.copy(),
                                        max_new_tokens=12)], paged=False)
        assert dec.outputs[1].output_tokens == dense_out[1]

        # pins came back when B retired; registered prefix pages stay
        # LRU-resident, everything else freed
        pool.check_invariants()
        s = pool.stats
        assert s["allocated"] == s["freed"] + s["evicted"] + pool.resident


class TestPagedDeployment:
    """``DeploymentConfig(paged_kv=True)`` end-to-end: the region pool is
    shared by the decode engine and the prefix cache, ``_route`` pins
    device-resident prefixes, and metrics expose pool/kv-manager state."""

    @pytest.fixture(scope="class")
    def dep_model(self):
        cfg = get_smoke_config("mistral-nemo-12b")
        model = Model(cfg, use_kernels=False)
        return cfg, model, model.init(jax.random.PRNGKey(0))

    def _dcfg(self, **kw):
        return DeploymentConfig(threshold=4096, decode_slots=SLOTS,
                                capacity=CAPACITY, decode_block_size=BLOCK,
                                min_prefill_bucket=32, max_prefill_bucket=64,
                                block_tokens=PAGE, pool_blocks=96, **kw)

    def test_paged_deployment_matches_dense_and_resumes(self, dep_model):
        cfg, model, params = dep_model
        rng = np.random.default_rng(11)
        prefix = rng.integers(0, cfg.vocab_size, (64,)).astype(np.int32)
        suffix = rng.integers(0, cfg.vocab_size, (30,)).astype(np.int32)
        tok_b = np.concatenate([prefix, suffix])

        dep_d = CrossDCDeployment(model, params, self._dcfg())
        out_d = dep_d.submit_batch([Request(rid=1, tokens=tok_b.copy(),
                                            max_new_tokens=6)])

        dep_p = CrossDCDeployment(model, params, self._dcfg(paged_kv=True))
        dep_p.submit_batch([Request(rid=0, tokens=prefix.copy(),
                                    max_new_tokens=4)])
        before = dep_p.pd_prefill.tokens_prefilled
        rb = Request(rid=1, tokens=tok_b.copy(), max_new_tokens=6)
        out_p = dep_p.submit_batch([rb])

        # _route pinned the registered prefix; only the suffix ran
        assert rb.device_pin is not None and rb.device_pin.cached_len == 64
        assert dep_p.pd_prefill.tokens_prefilled - before == len(tok_b) - 64
        assert out_p[1].output_tokens == out_d[1].output_tokens

        m = dep_p.metrics()
        region = m["clusters"][dep_p.pd_names[0]]
        assert region["cache_hit_rate"] > 0
        assert region["resident_kv_bytes"] > 0
        assert region["page_fail_retires"] == 0
        pool_stats = region["pool"]
        assert pool_stats["allocated"] == (pool_stats["freed"]
                                           + pool_stats["evicted"]
                                           + pool_stats["resident"])
        assert m["paged_kv"] is True
        assert set(m["kv_manager"]) == {"rebalanced", "cross_transfers",
                                        "clusters"}
        dep_p.decoders[dep_p.pd_names[0]].pool.check_invariants()


@pytest.fixture(scope="module")
def one_arch():
    """Single full-attn arch for boundary/churn tests: the properties under
    test live in the pool/prefix-cache layer and are arch-independent."""
    cfg = get_smoke_config("mistral-nemo-12b")
    model = Model(cfg, use_kernels=False)
    return cfg, model, model.init(jax.random.PRNGKey(0))


class TestPrefixBoundary:
    """Pin ``match_resume`` at page boundaries: a hit landing on exactly
    k*page_tokens must still leave the final prompt token to recompute
    (its logits seed generation), and +-1 around the boundary must round
    to the right page count — all while reproducing dense tokens."""

    @pytest.mark.parametrize("delta,want_c", [(-1, 48), (0, 48), (1, 64)])
    def test_resume_at_page_boundary(self, one_arch, delta, want_c):
        cfg, model, params = one_arch
        pool = BlockPool(SLOTS * CAPACITY // PAGE, PAGE, 1)
        cache = HybridPrefixCache(pool, 0, 1, has_full_attn=True,
                                  has_linear=False)
        rng = np.random.default_rng(21)
        prefix = rng.integers(0, cfg.vocab_size, (64,)).astype(np.int32)
        extra = rng.integers(0, cfg.vocab_size, (1,)).astype(np.int32)

        peng = PrefillEngine(model, params, min_bucket=32,
                             max_bucket=MAX_BUCKET)
        dec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                           paged=True, pool=pool, page_tokens=PAGE)
        dec.on_admit = lambda req, L, ids, snap: cache.insert_device(
            [int(t) for t in req.tokens], ids, snap)
        sched = RegionScheduler(peng, dec, max_prefill_batch=3)
        sched.submit(Request(rid=0, tokens=prefix, max_new_tokens=5))
        sched.run()

        tokens_b = (prefix[:64 + delta] if delta <= 0
                    else np.concatenate([prefix, extra]))
        L = len(tokens_b)
        c, ids, snap = cache.match_resume([int(t) for t in tokens_b])
        assert c == want_c, (delta, c)
        assert c < L, "resume must leave >= 1 token to recompute"
        assert len(ids) == c // PAGE
        pool.retain(ids)
        before = peng.tokens_prefilled
        sched.submit(Request(rid=1, tokens=tokens_b, max_new_tokens=9,
                             device_pin=PagePin(c, ids, snap)))
        sched.run()
        assert peng.tokens_prefilled - before == L - c

        dense_out, _, _ = _run(model, params,
                               [Request(rid=1, tokens=tokens_b.copy(),
                                        max_new_tokens=9)], paged=False)
        assert dec.outputs[1].output_tokens == dense_out[1]
        pool.check_invariants()


class TestPoolConservationChurn:
    """Property: ``allocated == freed + evicted + resident`` survives
    interleaved suffix-resume admissions, mid-block retires (odd budgets),
    and pool-exhaustion truncations on ONE shared pool."""

    def test_interleaved_churn_with_exhaustion(self, one_arch):
        cfg, model, params = one_arch
        pool = BlockPool(20, PAGE, 1)          # deliberately tight: 320 tok
        cache = HybridPrefixCache(pool, 0, 1, has_full_attn=True,
                                  has_linear=False)
        peng = PrefillEngine(model, params, min_bucket=32,
                             max_bucket=MAX_BUCKET)
        dec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                           paged=True, pool=pool, page_tokens=PAGE)
        dec.on_admit = lambda req, L, ids, snap: cache.insert_device(
            [int(t) for t in req.tokens], ids, snap)
        sched = RegionScheduler(peng, dec, max_prefill_batch=4)

        rng = np.random.default_rng(5)
        prefix = rng.integers(0, cfg.vocab_size, (32,)).astype(np.int32)
        for i in range(4):                      # concurrent growth > pool
            tail = rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(6, 14)),)).astype(np.int32)
            sched.submit(Request(rid=i, tokens=np.concatenate([prefix, tail]),
                                 max_new_tokens=int(rng.integers(41, 55))))
        sched.run()
        assert dec.page_fail_retires > 0, \
            "churn must actually exhaust the pool"

        for i in range(3):                      # suffix-resume wave
            tail = rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(6, 14)),)).astype(np.int32)
            toks = np.concatenate([prefix, tail])
            c, ids, snap = cache.match_resume([int(t) for t in toks])
            if c:
                pool.retain(ids)
            sched.submit(Request(
                rid=10 + i, tokens=toks,
                max_new_tokens=int(rng.integers(9, 19)),
                device_pin=PagePin(c, ids, snap) if c else None))
        sched.run()
        assert not sched.has_work
        assert len(dec.outputs) == 7            # every request produced

        pool.check_invariants()
        s = pool.stats
        assert s["allocated"] == s["freed"] + s["evicted"] + pool.resident


class TestWireAdmission:
    """paged_kv + wire_compression: offloaded prefills admit their int8
    wire pytree directly — dequantization fuses into the page scatter —
    and the tokens are bit-identical to eager dequantize-then-admit."""

    def _wcfg(self):
        return DeploymentConfig(threshold=8, decode_slots=SLOTS,
                                capacity=CAPACITY, decode_block_size=BLOCK,
                                min_prefill_bucket=32, max_prefill_bucket=64,
                                block_tokens=PAGE, pool_blocks=96,
                                paged_kv=True, wire_compression=True)

    def _reqs(self, cfg):
        rng = np.random.default_rng(17)
        return [Request(rid=i,
                        tokens=rng.integers(0, cfg.vocab_size,
                                            (L,)).astype(np.int32),
                        max_new_tokens=b)
                for i, (L, b) in enumerate([(40, 9), (70, 6)])]

    def test_fused_dequant_scatter_matches_eager(self, one_arch):
        cfg, model, params = one_arch
        dep_w = CrossDCDeployment(model, params, self._wcfg())
        assert all(d.wire_admission for d in dep_w.decoders.values())
        out_w = dep_w.submit_batch(self._reqs(cfg))

        dep_e = CrossDCDeployment(model, params, self._wcfg())
        for d in dep_e.decoders.values():
            d.wire_admission = False            # force eager dequantize
        out_e = dep_e.submit_batch(self._reqs(cfg))

        for r in dep_w.completed:
            assert r.route == PRFAAS            # threshold=8: all offload
        assert {k: v.output_tokens for k, v in out_w.items()} \
            == {k: v.output_tokens for k, v in out_e.items()}
        assert dep_w.measured_compression() > 1.5

    def test_measured_compression_seeded_at_construction(self, one_arch):
        """Regression: with wire_compression on, the reported ratio must
        reflect the int8 wire format BEFORE any quantized flow ships —
        seeded from a one-page dry-run quantization — not report 1.0."""
        cfg, model, params = one_arch
        dep = CrossDCDeployment(model, params, self._wcfg())
        assert dep._wire_quant == 0              # no flows yet
        assert dep.measured_compression() > 1.5
        plain = CrossDCDeployment(
            model, params,
            DeploymentConfig(threshold=8, decode_slots=SLOTS,
                             capacity=CAPACITY, decode_block_size=BLOCK,
                             min_prefill_bucket=32, max_prefill_bucket=64,
                             block_tokens=PAGE, pool_blocks=96))
        assert plain.measured_compression() == 1.0

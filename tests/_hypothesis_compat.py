"""Fallback for the ``hypothesis`` property-testing API.

The test-suite's property tests use a small subset of hypothesis
(``given`` / ``settings`` / ``strategies as st``).  When hypothesis is
installed (see requirements-dev.txt) this module re-exports it unchanged;
otherwise it provides a deterministic fixed-corpus stand-in so the suite
still *collects and runs* everywhere: each ``@given`` test is executed over
a seeded pseudo-random example corpus (boundary values first), which keeps
the property checks meaningful even if far less adversarial than real
shrinking-based hypothesis runs.
"""
from __future__ import annotations

try:                                    # real hypothesis when available
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # deterministic fallback corpus
    import functools
    import itertools

    import numpy as np

    HAVE_HYPOTHESIS = False
    _N_EXAMPLES = 25

    class HealthCheck:                  # pragma: no cover - placeholder
        all = staticmethod(lambda: [])
        too_slow = data_too_large = filter_too_much = None

    class _Strategy:
        """Generates one example per draw from a shared seeded rng; the
        first draws hit the boundary examples."""

        def __init__(self, fn, boundaries=()):
            self._fn = fn
            self._boundaries = list(boundaries)
            self._count = 0

        def example_with(self, rng):
            i = self._count
            self._count += 1
            if i < len(self._boundaries):
                return self._boundaries[i]
            return self._fn(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 31):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                boundaries=[min_value, max_value])

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            span = max_value - min_value
            return _Strategy(
                lambda rng: float(min_value + span * rng.random()),
                boundaries=[min_value, max_value])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)),
                             boundaries=[False, True])

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))],
                             boundaries=seq[:1])

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rng: tuple(s.example_with(rng)
                                               for s in strategies))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def gen(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example_with(rng) for _ in range(n)]
            return _Strategy(gen, boundaries=[[]] if min_size == 0 else [])

    st = _Strategies()

    def settings(*_a, **kw):
        """Accepts (and mostly ignores) hypothesis settings; honours
        ``max_examples`` as an upper bound on the fallback corpus size."""
        max_examples = kw.get("max_examples")

        def deco(fn):
            if max_examples is not None:
                fn._compat_max_examples = min(max_examples, _N_EXAMPLES)
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            import inspect

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_compat_max_examples", None) \
                    or getattr(wrapper, "_compat_max_examples", None) \
                    or _N_EXAMPLES
                rng = np.random.default_rng(0)
                for _ in range(n):
                    ex = [s.example_with(rng) for s in strategies]
                    fn(*args, *ex, **kwargs)

            # hide the strategy-filled trailing params from pytest's
            # fixture resolution (functools.wraps exposes them otherwise)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())[:-len(strategies)]
            wrapper.__signature__ = sig.replace(parameters=params)
            wrapper.__dict__.pop("__wrapped__", None)
            return wrapper
        return deco

"""Scenario-grid cost accounting + speculative/TBT simulator plumbing.

Fast, jit-free tests for the PR 10 satellites:

  * ``_fleet_cost_hr`` time-INTEGRATES each autoscaler's piecewise-constant
    (n_p, n_d) trajectory over its conversion epochs — charging the final
    allocation for the whole horizon under-bills runs that scaled down and
    over-bills runs that scaled up (the old bug);
  * ``SimConfig.spec_accept_rate`` scales the decode slot hold time by
    1 / (1 + rate) so ``--cross-validate`` can price speculation, and
    rate = 0 keeps the golden pre-spec path exact;
  * both simulator engines emit TBT percentiles + SLO attainment.
"""
import math
from types import SimpleNamespace

import pytest

from benchmarks.scenario_grid import PRICE_HR, _fleet_cost_hr
from repro.core import (PrfaasSimulator, SimConfig, SystemConfig,
                        ThroughputModel, Workload, paper_h20_profile,
                        paper_h200_profile)

HORIZON = 3600.0


def _sc(n_p, n_d, n_prfaas=1):
    return SimpleNamespace(n_prfaas=n_prfaas, n_p=n_p, n_d=n_d)


def _cost(n_p, n_d):
    return n_p * PRICE_HR["prefill"] + n_d * PRICE_HR["decode"]


class TestFleetCostIntegration:
    def test_fixed_point_charges_configured_allocation(self):
        sim = SimpleNamespace(autoscalers={})
        got = _fleet_cost_hr(sim, _sc(4, 4), HORIZON)
        assert got == pytest.approx(PRICE_HR["prfaas"] + _cost(4, 4))

    def test_midpoint_conversion_integrates_both_segments(self):
        """One P->D conversion at horizon/2: the run must be billed the
        time-weighted mean of the two allocations — strictly between
        final-forever and initial-forever."""
        a = SimpleNamespace(initial=(4, 4),
                            conversions=[(HORIZON / 2, 3, 5)])
        sim = SimpleNamespace(autoscalers={"pd": a})
        got = _fleet_cost_hr(sim, _sc(4, 4), HORIZON)
        base = PRICE_HR["prfaas"]
        initial_forever = base + _cost(4, 4)           # 70 + 392
        final_forever = base + _cost(3, 5)             # 70 + 350
        expected = base + (_cost(4, 4) + _cost(3, 5)) / 2.0
        assert got == pytest.approx(expected)          # 70 + 371
        assert final_forever < got < initial_forever

    def test_no_conversions_bills_initial_allocation(self):
        """An autoscaler that never fired bills its initial allocation for
        the whole horizon (the old final-allocation code agreed here only
        by accident)."""
        a = SimpleNamespace(initial=(4, 4), conversions=[])
        sim = SimpleNamespace(autoscalers={"pd": a})
        got = _fleet_cost_hr(sim, _sc(4, 4), HORIZON)
        assert got == pytest.approx(PRICE_HR["prfaas"] + _cost(4, 4))

    def test_late_scale_down_bills_mostly_initial(self):
        """Conversion at 90% of the horizon: the integrated bill sits 90%
        of the way toward the initial allocation, not at the final one."""
        a = SimpleNamespace(initial=(4, 4),
                            conversions=[(0.9 * HORIZON, 3, 5)])
        sim = SimpleNamespace(autoscalers={"pd": a})
        got = _fleet_cost_hr(sim, _sc(4, 4), HORIZON)
        expected = (PRICE_HR["prfaas"]
                    + 0.9 * _cost(4, 4) + 0.1 * _cost(3, 5))
        assert got == pytest.approx(expected)

    def test_multi_region_sums_per_autoscaler_trajectories(self):
        a1 = SimpleNamespace(initial=(2, 2),
                             conversions=[(HORIZON / 4, 1, 3)])
        a2 = SimpleNamespace(initial=(3, 1), conversions=[])
        sim = SimpleNamespace(autoscalers={"pd0": a1, "pd1": a2})
        got = _fleet_cost_hr(sim, _sc(5, 3), HORIZON)
        expected = (PRICE_HR["prfaas"]
                    + 0.25 * _cost(2, 2) + 0.75 * _cost(1, 3)
                    + _cost(3, 1))
        assert got == pytest.approx(expected)


class TestSpecAcceptRateServiceTime:
    def _stub(self, rate, output_len=64, t_decode=0.01, block=0):
        return SimpleNamespace(
            w=SimpleNamespace(output_len=output_len, t_decode=t_decode),
            sim=SimpleNamespace(decode_block_tokens=block,
                                spec_accept_rate=rate))

    def test_rate_zero_is_exact_pre_spec_path(self):
        plain = PrfaasSimulator._decode_service_time(self._stub(0.0))
        assert plain == 64 * 0.01          # bitwise: no division applied

    def test_rate_scales_hold_time_harmonically(self):
        """accept_rate r => (1 + r) tokens per dispatch: the slot hold
        time shrinks by exactly 1 / (1 + r)."""
        plain = PrfaasSimulator._decode_service_time(self._stub(0.0))
        for r in (0.5, 0.73, 1.0, 2.0):
            spec = PrfaasSimulator._decode_service_time(self._stub(r))
            assert spec == pytest.approx(plain / (1.0 + r))

    def test_block_rounding_applies_before_spec_scaling(self):
        got = PrfaasSimulator._decode_service_time(
            self._stub(1.0, output_len=60, block=16))
        assert got == pytest.approx(64 * 0.01 / 2.0)

    def test_config_default_off(self):
        assert SimConfig(arrival_rate=1.0).spec_accept_rate == 0.0
        assert SimConfig(arrival_rate=1.0).tbt_slo_s == 0.0


TBT_KEYS = ("tbt_mean", "tbt_p50", "tbt_p90", "tbt_p99", "tbt_slo_s",
            "tbt_attainment")


class TestDeploymentTbtStats:
    """`CrossDCDeployment._tbt_stats` (the live-side aggregation) without
    spinning up a deployment."""

    def test_percentiles_and_attainment(self):
        from repro.serving.deployment import CrossDCDeployment
        tbt = [0.01, 0.02, 0.03, 0.04, 0.10]
        s = CrossDCDeployment._tbt_stats(tbt, 0.05)
        assert s["tbt_p50_s"] <= s["tbt_p90_s"] <= s["tbt_p99_s"]
        assert s["tbt_mean_s"] == pytest.approx(sum(tbt) / len(tbt))
        assert s["tbt_slo_s"] == 0.05
        assert s["tbt_attainment"] == pytest.approx(0.8)   # 4 of 5 under

    def test_empty_and_unset_slo_report_full_attainment(self):
        from repro.serving.deployment import CrossDCDeployment
        assert CrossDCDeployment._tbt_stats([], 0.05)["tbt_attainment"] == 1.0
        assert CrossDCDeployment._tbt_stats([0.2], 0.0)["tbt_attainment"] == 1.0


@pytest.fixture(scope="module")
def tm_sc():
    w = Workload()
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, rate, _ = tm.grid_search(4, 8, 100e9 / 8)
    return tm, sc, rate, w


class TestSimulatorTbtMetrics:
    @pytest.mark.parametrize("engine", ["event", "vector"])
    def test_tbt_keys_and_attainment(self, tm_sc, engine):
        tm, sc, rate, w = tm_sc
        cfg = SimConfig(arrival_rate=0.6 * rate, sim_time=300.0,
                        seed=3, engine=engine, tbt_slo_s=1.0)
        m = PrfaasSimulator(tm, sc, w, cfg).run()
        for key in TBT_KEYS:
            assert key in m, key
        assert m["completed"] > 0
        assert m["tbt_mean"] > 0.0
        assert m["tbt_p50"] <= m["tbt_p90"] <= m["tbt_p99"]
        assert m["tbt_slo_s"] == 1.0
        assert 0.0 <= m["tbt_attainment"] <= 1.0
        # a generous SLO must be attainable; unset SLO reports 1.0
        cfg2 = SimConfig(arrival_rate=0.6 * rate, sim_time=300.0,
                         seed=3, engine=engine)
        m2 = PrfaasSimulator(tm, sc, w, cfg2).run()
        assert m2["tbt_slo_s"] == 0.0
        assert m2["tbt_attainment"] == 1.0

    @pytest.mark.parametrize("engine", ["event", "vector"])
    def test_spec_accept_rate_raises_throughput(self, tm_sc, engine):
        """At a decode-bound operating point, pricing speculation into the
        replay (accept_rate 1.0 halves slot hold time) must not LOWER
        completed throughput, and must shrink mean TBT."""
        tm, sc, rate, w = tm_sc
        base = dict(arrival_rate=0.6 * rate, sim_time=300.0, seed=3,
                    engine=engine)
        m0 = PrfaasSimulator(tm, sc, w, SimConfig(**base)).run()
        m1 = PrfaasSimulator(
            tm, sc, w, SimConfig(**base, spec_accept_rate=1.0)).run()
        assert m1["completed"] >= m0["completed"]
        assert m1["tbt_mean"] < m0["tbt_mean"]

"""int8 KV wire compression + S_kv byte-accounting correctness.

Fast (non-jit) coverage of the quantized wire format, the measured
compression ratio the throughput model/simulator charge, and the
``kv_bytes_incremental`` mixer-type predicate; the ``live``-marked tests
exercise the same paths on REAL prefill caches from a jitted smoke model.
"""
import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                LinearSpec, ModelConfig)
from repro.core import SystemConfig, ThroughputModel, Workload
from repro.core.hardware import paper_h20_profile, paper_h200_profile
from repro.models.kvcache import (cache_num_bytes, dequantize_cache_from_wire,
                                  kv_bytes, kv_bytes_incremental,
                                  linear_state_bytes, quantize_cache_for_wire,
                                  wire_compression_ratio)

RNG = np.random.default_rng(0)


def _mixed_config() -> ModelConfig:
    """One full-attn + one MLA + one linear block (the hybrid worst case for
    mixer-type classification)."""
    ffn = FFNSpec(kind="dense", d_ff=64)
    blocks = (
        BlockSpec(mixer=AttentionSpec(kind="full", q_heads=4, kv_heads=2,
                                      head_dim=16), ffn=ffn),
        BlockSpec(mixer=AttentionSpec(kind="mla", q_heads=4, kv_heads=4,
                                      head_dim=16, mla_kv_rank=32,
                                      mla_rope_dim=16), ffn=ffn),
        BlockSpec(mixer=LinearSpec(kind="gla", heads=2, key_dim=16,
                                   value_dim=16), ffn=ffn),
    )
    return ModelConfig(name="mixed-test", family="hybrid", d_model=64,
                       vocab_size=256, groups=(GroupSpec(blocks, repeats=2),))


class TestIncrementalBytes:
    def test_mixed_config_identity(self):
        """full-attn + MLA + linear mix: incremental bytes are exactly
        S_kv(total) - S_kv(cached) plus ONE linear-state resend."""
        cfg = _mixed_config()
        state = linear_state_bytes(cfg)
        # 2 repeats x 1 linear block contribute state; attention/MLA do not
        assert state == 2 * LinearSpec(kind="gla", heads=2, key_dim=16,
                                       value_dim=16).state_bytes()
        inc = kv_bytes_incremental(cfg, 128, 512)
        assert inc == kv_bytes(cfg, 512) - kv_bytes(cfg, 128) + state
        # cold start: no prior cache, no state resend
        assert kv_bytes_incremental(cfg, 0, 512) == kv_bytes(cfg, 512)

    def test_explicit_predicate_not_duck_typing(self):
        """A linear mixer that HAPPENS to carry a ``q_heads`` attribute must
        still be classified by spec type (the old ``hasattr`` duck-typing
        silently dropped its state resend)."""

        class QHeadedLinear(LinearSpec):
            q_heads = 4                      # red herring attribute

        weird = QHeadedLinear(kind="gla", heads=2, key_dim=16, value_dim=16)
        cfg = _mixed_config()
        cfg = dataclasses.replace(cfg, groups=(GroupSpec(
            (BlockSpec(mixer=weird, ffn=FFNSpec(kind="dense", d_ff=64)),),
            repeats=1),))
        assert hasattr(weird, "q_heads")     # the trap is armed
        assert linear_state_bytes(cfg) == weird.state_bytes()
        inc = kv_bytes_incremental(cfg, 64, 128)
        assert inc == kv_bytes(cfg, 128) - kv_bytes(cfg, 64) \
            + weird.state_bytes()

    def test_unknown_mixer_rejected(self):
        class Mystery:
            pass

        cfg = _mixed_config()
        cfg = dataclasses.replace(cfg, groups=(GroupSpec(
            (BlockSpec(mixer=Mystery(), ffn=FFNSpec(kind="dense", d_ff=64)),),
            repeats=1),))
        with pytest.raises(TypeError, match="unknown mixer"):
            linear_state_bytes(cfg)


class TestWireQuantization:
    def _fake_cache(self, dtype):
        return {"groups": [{
            "b0": {"k": jnp.asarray(RNG.standard_normal((2, 1, 16, 2, 8)),
                                    dtype),
                   "v": jnp.asarray(RNG.standard_normal((2, 1, 16, 2, 8)),
                                    dtype)},
            "b1": {"state": jnp.ones((2, 1, 4, 8), jnp.float32)}}]}

    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    def test_roundtrip_restores_dtype_within_scale(self, dtype):
        caches = self._fake_cache(dtype)
        wire, _ = quantize_cache_for_wire(caches)
        back = dequantize_cache_from_wire(wire)
        k0 = back["groups"][0]["b0"]["k"]
        assert k0.dtype == dtype
        orig = caches["groups"][0]["b0"]["k"].astype(jnp.float32)
        err = float(jnp.max(jnp.abs(k0.astype(jnp.float32) - orig)))
        # per-tensor symmetric int8: error <= scale (0.5 quantization + the
        # scale's own storage rounding in the original dtype)
        scale = float(jnp.max(jnp.abs(orig))) / 127.0
        assert err <= scale * 1.01 + 1e-7
        # recurrent fp32 state ships untouched
        assert back["groups"][0]["b1"]["state"].dtype == jnp.float32

    @pytest.mark.parametrize("dtype,lo", [(jnp.bfloat16, 1.5),
                                          (jnp.float32, 2.5)])
    def test_measured_ratio_matches_charged_bytes(self, dtype, lo):
        """The measured quantized bytes and the ratio the throughput model /
        simulator charge are two views of the same number:
        wire_bytes == raw_bytes / wire_compression_ratio exactly."""
        caches = self._fake_cache(dtype)
        raw = cache_num_bytes(caches)
        _, wire_bytes = quantize_cache_for_wire(caches)
        ratio = wire_compression_ratio(caches)
        assert wire_bytes == pytest.approx(raw / ratio)
        assert ratio > lo                 # 2-byte K/V -> ~2x, 4-byte -> ~4x

    def test_throughput_model_charges_measured_ratio(self):
        """In the egress-bound regime Θ_prfaas scales EXACTLY with the
        wire-compression ratio it is given."""
        w = Workload()
        tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
        base = SystemConfig(4, 4, 4, 1e8, 8192.0)      # skinny egress
        comp = dataclasses.replace(base, kv_wire_compression=2.37)
        t0, t1 = tm.theta_prfaas(base), tm.theta_prfaas(comp)
        assert t1 == pytest.approx(t0 * 2.37)
        assert tm.egress_load(comp, rate=1.0) == pytest.approx(
            tm.egress_load(base, rate=1.0) / 2.37)

    def test_compression_below_one_rejected_by_simulator(self):
        from repro.core import PrfaasSimulator, SimConfig
        w = Workload()
        tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
        sc = SystemConfig(4, 4, 4, 100e9 / 8, 8192.0,
                          kv_wire_compression=0.5)
        with pytest.raises(ValueError, match="kv_wire_compression"):
            PrfaasSimulator(tm, sc, w, SimConfig(arrival_rate=1.0))


@pytest.mark.live
class TestRealPrefillCaches:
    """Same properties on REAL caches from a jitted smoke model."""

    @pytest.fixture(scope="class")
    def prefill_caches(self):
        import jax

        from repro.configs import get_smoke_config
        from repro.models import Model

        cfg = get_smoke_config("kimi-linear-1t")
        model = Model(cfg, use_kernels=False)
        params = model.init(jax.random.PRNGKey(0))
        toks = np.asarray(
            RNG.integers(0, cfg.vocab_size, (2, 96)), np.int32)
        _, caches = jax.jit(model.prefill)(params,
                                           {"tokens": jnp.asarray(toks)})
        return cfg, caches

    def test_roundtrip_error_bounded(self, prefill_caches):
        import jax

        _, caches = prefill_caches
        wire, _ = quantize_cache_for_wire(caches)
        back = dequantize_cache_from_wire(wire)
        flat_w = jax.tree_util.tree_flatten_with_path(caches)[0]
        flat_b = jax.tree.leaves(back)
        quantized = 0
        for (path, orig), deq in zip(flat_w, flat_b):
            name = jax.tree_util.keystr(path)
            if not any(k in name for k in ("'k'", "'v'", "'ckv'", "'kpe'")):
                np.testing.assert_array_equal(np.asarray(orig),
                                              np.asarray(deq))
                continue
            quantized += 1
            o = np.asarray(orig, np.float32)
            d = np.asarray(deq, np.float32)
            scale = np.abs(o).max() / 127.0
            assert np.abs(o - d).max() <= scale * 1.01 + 1e-7, name
        assert quantized > 0              # the model really has K/V leaves

    def test_measured_bytes_match_charged_ratio(self, prefill_caches):
        _, caches = prefill_caches
        raw = cache_num_bytes(caches)
        wire, wire_bytes = quantize_cache_for_wire(caches)
        ratio = wire_compression_ratio(caches)
        assert wire_bytes < raw
        assert wire_bytes == pytest.approx(raw / ratio)
        assert 1.0 < ratio < 4.5
        # feeding the measured ratio into the analytic model charges the
        # same bytes the quantized pytree actually occupies
        assert raw / ratio == pytest.approx(cache_num_bytes(wire))

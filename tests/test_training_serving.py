"""Training loop (checkpoint/restart, fault injection, compression) and the
live two-cluster serving deployment."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.distributed.collectives import (compress_grads_with_feedback,
                                           dequantize_int8, quantize_int8)
from repro.models import Model
from repro.serving import CrossDCDeployment, DeploymentConfig, Request
from repro.training import (AdamWConfig, DataConfig, SyntheticLM,
                            TrainConfig, TrainLoop, init_opt_state,
                            make_train_step)

pytestmark = pytest.mark.slow      # JAX compiles dominate; -m "not slow" skips


@pytest.fixture()
def tiny(tmp_path):
    cfg = get_smoke_config("qwen2.5-3b")
    model = Model(cfg, use_kernels=False, remat=True)
    params = model.init(jax.random.PRNGKey(0))
    tc = TrainConfig(microbatches=2, checkpoint_every=4,
                     checkpoint_dir=str(tmp_path / "ckpt"),
                     adamw=AdamWConfig(lr=1e-3, warmup_steps=4,
                                       total_steps=50))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=48,
                                  global_batch=8, seed=0))
    return cfg, model, params, tc, data


class TestTraining:
    def test_loss_decreases(self, tiny):
        cfg, model, params, tc, data = tiny
        loop = TrainLoop(model, tc, data)
        _, _, hist = loop.run(params, init_opt_state(params, tc), 10)
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_crash_and_resume_exact(self, tiny):
        """Fault tolerance: injected failure at step 6; restart resumes from
        the step-4 checkpoint and reaches the same final loss as an
        uninterrupted run (deterministic data + optimizer)."""
        cfg, model, params, tc, data = tiny
        ref_loop = TrainLoop(model, tc, data)
        p0 = model.init(jax.random.PRNGKey(0))
        _, _, ref_hist = ref_loop.run(p0, init_opt_state(p0, tc), 8)
        shutil.rmtree(tc.checkpoint_dir, ignore_errors=True)

        crash = TrainLoop(model, tc, data, fail_at_step=6)
        p1 = model.init(jax.random.PRNGKey(0))
        with pytest.raises(RuntimeError, match="injected node failure"):
            crash.run(p1, init_opt_state(p1, tc), 8)
        resumed = TrainLoop(model, tc, data)
        p2 = model.init(jax.random.PRNGKey(0))
        _, _, hist2 = resumed.run(p2, init_opt_state(p2, tc), 8)
        assert hist2[0]["step"] == 4                # resumed from checkpoint
        assert hist2[-1]["loss"] == pytest.approx(ref_hist[-1]["loss"],
                                                  rel=1e-4)

    def test_straggler_hook_fires(self, tiny):
        cfg, model, params, tc, data = tiny
        flagged = []
        import dataclasses
        tc2 = dataclasses.replace(tc, straggler_factor=0.0001,
                                  checkpoint_dir=tc.checkpoint_dir + "2")
        loop = TrainLoop(model, tc2, data,
                         on_straggler=lambda s, r: flagged.append(s))
        loop.run(params, init_opt_state(params, tc2), 4)
        assert flagged                                # every step "slow"

    def test_checkpoint_mesh_agnostic_restore(self, tiny, tmp_path):
        from repro.training.checkpoint import CheckpointManager
        cfg, model, params, tc, data = tiny
        mgr = CheckpointManager(str(tmp_path / "m"), keep=2)
        tree = {"params": params, "x": jnp.arange(8)}
        mgr.save(3, tree, "data=16xmodel=16", blocking=True)
        restored, manifest = mgr.restore(tree)
        assert manifest["step"] == 3
        flat0 = jax.tree.leaves(tree)
        flat1 = jax.tree.leaves(restored)
        for a, b in zip(flat0, flat1):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_retention_policy(self, tiny, tmp_path):
        from repro.training.checkpoint import CheckpointManager
        mgr = CheckpointManager(str(tmp_path / "r"), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"x": jnp.ones(3)}, blocking=True)
        assert mgr.all_steps() == [3, 4]


class TestGradCompression:
    def test_int8_roundtrip_bounded_error(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                        jnp.float32)
        q, s = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, s) - x)
        assert float(err.max()) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_preserves_signal(self):
        """g_quantized + residual == g + old_residual (nothing is lost)."""
        g = {"w": jnp.asarray(np.random.default_rng(1)
                              .standard_normal((64,)), jnp.float32)}
        r = {"w": jnp.zeros((64,), jnp.float32)}
        gq, r2 = compress_grads_with_feedback(g, r)
        np.testing.assert_allclose(gq["w"] + r2["w"], g["w"], atol=1e-5)


class TestServingDeployment:
    def test_end_to_end_generation_and_routing(self):
        cfg = get_smoke_config("kimi-linear-1t")
        model = Model(cfg, use_kernels=False)
        params = model.init(jax.random.PRNGKey(0))
        dep = CrossDCDeployment(model, params,
                                DeploymentConfig(threshold=48, capacity=256,
                                                 decode_slots=4,
                                                 link_gbps=0.01))
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, tokens=rng.integers(
            0, cfg.vocab_size, (L,)).astype(np.int32), max_new_tokens=4)
            for i, L in enumerate([16, 100])]
        out = dep.submit_batch(reqs)
        assert all(r.finished for r in out.values())
        assert reqs[0].route == "pd" and reqs[1].route == "prfaas"
        assert reqs[1].kv_bytes > reqs[0].kv_bytes
        assert reqs[1].transfer_s > 0 and reqs[0].transfer_s == 0

    def test_prefix_cache_reduces_offload(self):
        cfg = get_smoke_config("qwen2.5-3b")
        model = Model(cfg, use_kernels=False)
        params = model.init(jax.random.PRNGKey(0))
        dep = CrossDCDeployment(model, params,
                                DeploymentConfig(threshold=48, capacity=256,
                                                 decode_slots=2))
        rng = np.random.default_rng(1)
        toks = rng.integers(0, cfg.vocab_size, (100,)).astype(np.int32)
        dep.submit_batch([Request(rid=0, tokens=toks, max_new_tokens=2)])
        assert dep.completed[0].route == "prfaas"
        # same prompt again: prfaas cache hit -> incremental 0 -> but router
        # evaluates PD's cache (scarce default); extended prompt hits too
        dep.submit_batch([Request(rid=1, tokens=toks, max_new_tokens=2)])
        assert dep.caches["prfaas"].hit_rate() > 0

"""Lowerable chunked/banded paths (what the dry-run compiles) vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models import chunked_attention as chk

pytestmark = pytest.mark.slow      # JAX compiles dominate; -m "not slow" skips

RNG = np.random.default_rng(4)


def mk(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


@pytest.mark.parametrize("causal", [True, False])
def test_flash_chunked(causal):
    q, k, v = mk(2, 4, 320, 32), mk(2, 2, 320, 32), mk(2, 2, 320, 32)
    out = chk.flash_chunked(q, k, v, causal=causal, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_mea_attention_and_grad():
    q, k, v = mk(1, 4, 256, 32), mk(1, 2, 256, 32), mk(1, 2, 256, 32)
    out = chk.mea_attention(q, k, v, causal=True, block_q=64)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)
    g = jax.grad(lambda q: jnp.sum(
        chk.mea_attention(q, k, v, causal=True, block_q=64) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(
        ref.flash_attention_ref(q, k, v, causal=True) ** 2))(q)
    np.testing.assert_allclose(g, g2, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("window,S", [(64, 320), (100, 512)])
def test_swa_banded(window, S):
    q, k, v = mk(1, 4, S, 32), mk(1, 2, S, 32), mk(1, 2, S, 32)
    out = chk.swa_banded(q, k, v, window=window, block_q=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_gla_chunked_jnp_vs_oracle():
    B, H, S, d = 2, 2, 200, 32
    q, k, v = mk(B, H, S, d), mk(B, H, S, d), mk(B, H, S, d)
    la = -0.3 * jnp.abs(mk(B, H, S))
    s0 = jnp.zeros((B, H, d, d))
    o, st = chk.gla_chunked_jnp(q, k, v, la, s0, chunk=64)
    o2, st2 = ref.gla_ref(q, k, v, la, s0)
    np.testing.assert_allclose(o, o2, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(st, st2, atol=5e-4, rtol=5e-4)


def test_delta_chunked_jnp_vs_oracle():
    B, H, S, d = 2, 2, 200, 32
    q, k, v = mk(B, H, S, d), mk(B, H, S, d), mk(B, H, S, d)
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    la = -0.2 * jnp.abs(mk(B, H, S))
    beta = jnp.asarray(RNG.uniform(0.1, 1, (B, H, S)).astype(np.float32))
    s0 = jnp.zeros((B, H, d, d))
    o, st = chk.delta_chunked_jnp(q, k, v, la, beta, s0, chunk=64)
    o2, st2 = ref.delta_ref(q, k, v, la, beta, s0)
    np.testing.assert_allclose(o, o2, atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(st, st2, atol=2e-4, rtol=2e-3)


def test_unroll_flag_is_semantics_preserving():
    """UNROLL=True (cost-probe mode) must not change results."""
    q, k, v = mk(1, 2, 128, 16), mk(1, 2, 128, 16), mk(1, 2, 128, 16)
    base = chk.flash_chunked(q, k, v, block_k=32)
    chk.UNROLL = True
    try:
        unrolled = chk.flash_chunked(q, k, v, block_k=32)
    finally:
        chk.UNROLL = False
    np.testing.assert_allclose(base, unrolled, atol=1e-6, rtol=1e-6)

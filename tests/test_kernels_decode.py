"""Flash-decode Pallas kernel vs oracle: lengths, windows, GQA, dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attn import decode_attention

pytestmark = pytest.mark.slow      # JAX compiles dominate; -m "not slow" skips

RNG = np.random.default_rng(3)


def mk(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (2, 8, 8, 256, 64),
    (3, 8, 4, 300, 64),      # GQA + ragged
    (1, 16, 1, 512, 128),    # MQA
])
def test_decode_matches_oracle(B, Hq, Hkv, S, D):
    q = mk(B, Hq, D)
    kc, vc = mk(B, Hkv, S, D), mk(B, Hkv, S, D)
    lens = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, kc, vc, lens, interpret=True, block_k=64)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [16, 64, 200])
def test_decode_window(window):
    B, Hq, Hkv, S, D = 2, 4, 2, 256, 32
    q, kc, vc = mk(B, Hq, D), mk(B, Hkv, S, D), mk(B, Hkv, S, D)
    lens = jnp.asarray([50, 256], jnp.int32)
    out = decode_attention(q, kc, vc, lens, window=window, interpret=True,
                           block_k=64)
    want = ref.decode_attention_ref(q, kc, vc, lens, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_decode_tiny_lengths():
    """length=1 attends a single key."""
    B, H, S, D = 2, 2, 128, 32
    q, kc, vc = mk(B, H, D), mk(B, H, S, D), mk(B, H, S, D)
    lens = jnp.asarray([1, 1], jnp.int32)
    out = decode_attention(q, kc, vc, lens, interpret=True, block_k=64)
    np.testing.assert_allclose(out, vc[:, :, 0], atol=2e-5, rtol=2e-5)


def test_decode_dk_neq_dv():
    """Absorbed-MLA shape: K latent+rope, V latent."""
    B, Hq, S = 2, 6, 192
    q, kc, vc = mk(B, Hq, 80), mk(B, 1, S, 80), mk(B, 1, S, 64)
    lens = jnp.asarray([100, 192], jnp.int32)
    out = decode_attention(q, kc, vc, lens, interpret=True, block_k=64)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    assert out.shape == (B, Hq, 64)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_decode_bf16():
    B, H, S, D = 1, 4, 128, 64
    q = mk(B, H, D).astype(jnp.bfloat16)
    kc = mk(B, H, S, D).astype(jnp.bfloat16)
    vc = mk(B, H, S, D).astype(jnp.bfloat16)
    lens = jnp.asarray([100], jnp.int32)
    out = decode_attention(q, kc, vc, lens, interpret=True, block_k=64)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), atol=3e-2, rtol=3e-2)

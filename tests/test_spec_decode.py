"""Speculative multi-token decode tests (PR 10 tentpole).

The load-bearing property: with greedy decoding, the speculative engine —
n-gram drafter, one-dispatch verify, variable tokens-per-block — emits
EXACTLY the token sequences the plain one-token-per-dispatch path produces,
across full-attn, MLA + linear, SWA, and hybrid archs, on both the dense
and paged layouts, including chunked prompts past the prefill max bucket.
On top of identity:

  * ``spec_k=0`` runs the PR 6 block path untouched (no verify program is
    ever built);
  * greedy acceptance is exact at both edges — a draft equal to the
    model's own continuation accepts in full, a draft that never matches
    accepts nothing and every round still emits its one bonus token;
  * rejected speculative suffixes leave NO trace: the caches after a
    verify + commit round match running the accepted tokens through the
    plain ``decode_step`` (bit-exact on the scan-verify path);
  * the verify dispatch compiles once per (bucket, k) and never again
    under traffic; paged slots grow pages by the worst-case k+1 stride and
    retire cleanly on pool exhaustion.

Marked ``live`` (full scheduler loops on jitted smoke models).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.blockpool import BlockPool
from repro.models import Model
from repro.serving.api import Request
from repro.serving.engine import (DecodeEngine, PrefillEngine,
                                  RegionScheduler)

pytestmark = pytest.mark.live

SLOTS, CAPACITY, BLOCK = 4, 384, 8
MAX_BUCKET = 64
PAGE = 16
SPEC_K = 2

# one arch per decode-cache family: full-attn (parallel verify), MLA +
# linear, SWA, hybrid (scan verify with ring rollback / state snapshots)
ARCHS = ["mistral-nemo-12b", "kimi-linear-1t", "h2o-danube-1.8b",
         "zamba2-1.2b"]


@pytest.fixture(scope="module", params=ARCHS)
def arch(request):
    cfg = get_smoke_config(request.param)
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk_requests(cfg, lens, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        (L,)).astype(np.int32),
                    max_new_tokens=b)
            for i, (L, b) in enumerate(zip(lens, budgets))]


def _run(model, params, reqs, *, paged=False, spec_k=0, pool=None,
         spec_ngram=1):
    peng = PrefillEngine(model, params, min_bucket=32, max_bucket=MAX_BUCKET)
    dec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                       paged=paged, pool=pool, page_tokens=PAGE,
                       spec_k=spec_k, spec_ngram=spec_ngram)
    sched = RegionScheduler(peng, dec, max_prefill_batch=3)
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert not sched.has_work
    return ({rid: r.output_tokens for rid, r in dec.outputs.items()}, dec)


# mixed lengths (incl. one prompt past MAX_BUCKET -> chunked prefill),
# ragged budgets so retires land mid-block at every draft depth
LENS = [24, 40, 70, 16, 33, 64]
BUDGETS = [30, 44, 25, 38, 27, 21]


class TestTokenIdentity:
    @pytest.mark.parametrize("paged", [False, True])
    def test_speculative_matches_plain(self, arch, paged):
        """Greedy speculative streams == plain greedy streams through the
        scheduler (slot churn, chunked prompt, mid-block retires)."""
        cfg, model, params = arch
        plain, _ = _run(model, params, _mk_requests(cfg, LENS, BUDGETS),
                        paged=paged)
        spec, dec = _run(model, params, _mk_requests(cfg, LENS, BUDGETS),
                         paged=paged, spec_k=SPEC_K)
        assert spec == plain
        # speculation actually happened (every round emits >= 1 token;
        # the drafter must land > 1 sometimes on at least one arch family,
        # but even accept-nothing rounds keep the accounting exact)
        assert dec.verify_rounds > 0
        assert dec.accepted_tokens >= dec.verify_rounds

    def test_spec_k0_is_plain_block_path(self, arch):
        """spec_k=0 must BE the PR 6 path: same tokens, and no verify
        program is ever built or compiled."""
        cfg, model, params = arch
        plain, dec0 = _run(model, params, _mk_requests(cfg, LENS, BUDGETS))
        assert dec0.spec_compiles == 0
        assert dec0.verify_rounds == 0
        assert dec0.accepted_tokens_per_dispatch == 1.0


class TestAcceptanceEdges:
    """Drive ``decode_verify`` + ``commit_verify`` directly with crafted
    drafts: both edges of greedy acceptance, and bit-exact cache state
    after rollback."""

    def _admitted_engine(self, model, params, cfg, spec_k=SPEC_K):
        reqs = _mk_requests(cfg, [24, 40, 16, 33], [64] * 4, seed=5)
        peng = PrefillEngine(model, params, min_bucket=32,
                             max_bucket=MAX_BUCKET)
        dec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                           spec_k=spec_k, spec_ngram=1)
        sched = RegionScheduler(peng, dec, max_prefill_batch=4)
        for r in reqs:
            sched.submit(r)
        # tick until every slot is admitted and mid-stream (lengths,
        # history and caches past fresh-admission state)
        for _ in range(20):
            sched.tick()
            if dec.active.all():
                break
        assert dec.active.all()
        sched.tick()
        return dec

    def _greedy_continuation(self, model, params, dec, k):
        """The model's own next-k greedy tokens from the engine's live
        state (computed on a cache COPY via sequential decode steps)."""
        caches = jax.tree.map(lambda x: x, dec.caches)
        toks = jnp.asarray(dec.tokens)
        lens = jnp.asarray(dec.lengths)
        out = []
        for j in range(k):
            logits, caches = model.decode_step(params, toks, caches, lens)
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            lens = lens + 1
            out.append(toks)
        return jnp.stack(out, axis=1)                    # (B, k)

    def test_accept_all(self, arch):
        """Drafting the model's own continuation accepts every draft."""
        cfg, model, params = arch
        dec = self._admitted_engine(model, params, cfg)
        drafts = self._greedy_continuation(model, params, dec, SPEC_K)
        toks = jnp.asarray(dec.tokens)
        lens = jnp.asarray(dec.lengths)
        seq = jnp.concatenate([toks[:, None], drafts], axis=1)
        logits, _, _ = model.decode_verify(params, seq, dec.caches, lens)
        preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        match = (preds[:, :SPEC_K] == drafts).astype(jnp.int32)
        accept = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
        assert bool(jnp.all(accept == SPEC_K)), np.asarray(accept)

    def test_reject_all(self, arch):
        """Drafts crafted to never match accept nothing — and the round
        still emits its one always-correct bonus token."""
        cfg, model, params = arch
        dec = self._admitted_engine(model, params, cfg)
        cont = self._greedy_continuation(model, params, dec, SPEC_K)
        drafts = (cont + 1) % cfg.vocab_size             # guaranteed wrong
        toks = jnp.asarray(dec.tokens)
        lens = jnp.asarray(dec.lengths)
        seq = jnp.concatenate([toks[:, None], drafts], axis=1)
        logits, _, _ = model.decode_verify(params, seq, dec.caches, lens)
        preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        match = (preds[:, :SPEC_K] == drafts).astype(jnp.int32)
        accept = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
        assert bool(jnp.all(accept == 0)), np.asarray(accept)
        # position 0 is the plain next token regardless of the drafts
        step_logits, _ = model.decode_step(params, toks, dec.caches,
                                           lens)
        assert bool(jnp.array_equal(jnp.argmax(step_logits, -1),
                                    jnp.argmax(logits[:, 0], -1)))

    def test_rollback_leaves_no_trace(self, arch):
        """verify(reject-all) + commit == running ONE plain decode step:
        every cache leaf the continuing stream can read must match.  On
        the scan-verify path (SWA/linear/hybrid/MLA) the match is
        bit-exact; the parallel full-attn path writes f32-reassociated
        (argmax-identical) rows, so the read-visible region must be
        allclose and the model must keep emitting identical tokens (pinned
        by TestTokenIdentity)."""
        cfg, model, params = arch
        dec = self._admitted_engine(model, params, cfg)
        cont = self._greedy_continuation(model, params, dec, SPEC_K)
        drafts = (cont + 1) % cfg.vocab_size
        toks = jnp.asarray(dec.tokens)
        lens = jnp.asarray(dec.lengths)
        seq = jnp.concatenate([toks[:, None], drafts], axis=1)
        _, ver_caches, pending = model.decode_verify(params, seq,
                                                     dec.caches, lens)
        accept = jnp.zeros((SLOTS,), jnp.int32)
        committed = model.commit_verify(ver_caches, pending, lens, accept,
                                        SPEC_K + 1)
        _, stepped = model.decode_step(params, toks, dec.caches, lens)

        exact = not model._verify_parallel
        for (pc, c), (ps, s) in zip(
                jax.tree_util.tree_flatten_with_path(committed)[0],
                jax.tree_util.tree_flatten_with_path(stepped)[0]):
            assert pc == ps
            cf = np.asarray(c, dtype=np.float32)
            sf = np.asarray(s, dtype=np.float32)
            seq_axes = [i for i, d in enumerate(c.shape) if d == CAPACITY]
            if seq_axes:
                # append-only seq caches, laid out (layers, B, S, ...) —
                # the rejected suffix wrote rows lens+1..lens+k that one
                # plain step never touches; those rows are unreadable by
                # the length mask, so only rows < lens+1 must match
                assert c.shape[1] == SLOTS, c.shape
                for b in range(SLOTS):
                    r = int(lens[b]) + 1
                    idx = [slice(None)] * c.ndim
                    idx[1] = b
                    idx[seq_axes[0]] = slice(None, r)
                    idx = tuple(idx)
                    if exact:
                        np.testing.assert_array_equal(cf[idx], sf[idx])
                    else:
                        np.testing.assert_allclose(cf[idx], sf[idx],
                                                   rtol=1e-4, atol=1e-4)
            elif exact:
                # SWA rings are rolled back and mixer states rewound: the
                # whole leaf must match one plain step bit-exactly
                np.testing.assert_array_equal(cf, sf)
            else:
                np.testing.assert_allclose(cf, sf, rtol=1e-4, atol=1e-4)


class TestParallelVerifyUnit:
    """The batched one-pass verify (append-only full-attn archs) against q
    sequential ``decode_step`` calls at a shipped engine shape."""

    def test_parallel_verify_matches_sequential_steps(self):
        cfg = get_smoke_config("mistral-nemo-12b")
        model = Model(cfg, use_kernels=False)
        assert model._verify_parallel
        params = model.init(jax.random.PRNGKey(0))
        B, Q = 4, SPEC_K + 1
        caches = model.init_cache(B, CAPACITY)
        lengths = jnp.array([5, 17, 120, 300], jnp.int32)
        leaves, td = jax.tree_util.tree_flatten(caches)
        caches = jax.tree_util.tree_unflatten(td, [
            (jax.random.normal(jax.random.PRNGKey(90 + i), l.shape)
             * 0.02).astype(l.dtype) for i, l in enumerate(leaves)])
        seq = jax.random.randint(jax.random.PRNGKey(3), (B, Q), 0,
                                 cfg.vocab_size)

        lg_p, _, pending = model.decode_verify(params, seq, caches, lengths)
        assert pending["snaps"] is None and pending["rings"] is None

        c_s = caches
        logits = []
        for j in range(Q):
            lg, c_s = model.decode_step(params, seq[:, j], c_s, lengths + j)
            logits.append(lg)
        lg_s = jnp.stack(logits, axis=1)
        # float-equivalent logits, identical greedy tokens (the engine
        # contract): see verify_attention_ref's numerics note
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_s),
                                   rtol=1e-5, atol=1e-5)
        assert bool(jnp.array_equal(jnp.argmax(lg_p, -1),
                                    jnp.argmax(lg_s, -1)))

    def test_scan_verify_is_bitwise(self):
        """The lax.scan verify path (here: SWA arch) must be BIT-identical
        to sequential decode steps — it is the same program."""
        cfg = get_smoke_config("h2o-danube-1.8b")
        model = Model(cfg, use_kernels=False)
        assert not model._verify_parallel
        params = model.init(jax.random.PRNGKey(0))
        B, Q = 4, SPEC_K + 1
        caches = model.init_cache(B, CAPACITY)
        lengths = jnp.array([5, 17, 120, 300], jnp.int32)
        seq = jax.random.randint(jax.random.PRNGKey(3), (B, Q), 0,
                                 cfg.vocab_size)
        lg_p, _, _ = model.decode_verify(params, seq, caches, lengths)
        c_s = caches
        logits = []
        for j in range(Q):
            lg, c_s = model.decode_step(params, seq[:, j], c_s, lengths + j)
            logits.append(lg)
        assert bool(jnp.array_equal(lg_p, jnp.stack(logits, axis=1)))


class TestCompileStability:
    def test_one_verify_compile_after_warmup(self, arch):
        """``warmup_block`` compiles the verify program once; real traffic
        afterwards never recompiles it."""
        cfg, model, params = arch
        peng = PrefillEngine(model, params, min_bucket=32,
                             max_bucket=MAX_BUCKET)
        dec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                           spec_k=SPEC_K, spec_ngram=1)
        dec.warmup_block()
        assert dec.spec_compiles == 1
        sched = RegionScheduler(peng, dec, max_prefill_batch=3)
        for r in _mk_requests(cfg, LENS, BUDGETS):
            sched.submit(r)
        sched.run()
        assert dec.spec_compiles == 1, "verify dispatch recompiled"

    def test_greedy_only_guard(self, arch):
        cfg, model, params = arch
        with pytest.raises(ValueError, match="temperature"):
            DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                         spec_k=1, temperature=0.8)


class TestPagedSpecGrowth:
    def test_pool_exhaustion_during_spec_growth_retires_cleanly(self):
        """Paged speculative slots reserve pages at the worst-case
        block_size * (k+1) stride; a deliberately tight pool must exhaust,
        retire page-starved slots (not crash or corrupt), and conserve
        pages."""
        cfg = get_smoke_config("mistral-nemo-12b")
        model = Model(cfg, use_kernels=False)
        params = model.init(jax.random.PRNGKey(0))
        pool = BlockPool(14, PAGE, 1)            # 224 tokens for 4 slots
        peng = PrefillEngine(model, params, min_bucket=32,
                             max_bucket=MAX_BUCKET)
        dec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                           paged=True, pool=pool, page_tokens=PAGE,
                           spec_k=SPEC_K, spec_ngram=1)
        sched = RegionScheduler(peng, dec, max_prefill_batch=4)
        for r in _mk_requests(cfg, [32, 32, 32, 32], [120] * 4, seed=9):
            sched.submit(r)
        sched.run()
        assert not sched.has_work
        assert dec.page_fail_retires > 0, \
            "spec growth must actually exhaust the pool"
        assert len(dec.outputs) == 4             # every request produced
        pool.check_invariants()
        s = pool.stats
        assert s["allocated"] == s["freed"] + s["evicted"] + pool.resident

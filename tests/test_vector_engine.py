"""Vectorized SoA engine (``SimConfig(engine="vector")``): equivalence to
the exact event engine — pinned scenario + property-style over randomized
control-plane configs — plus byte conservation on the vectorized link
solver, epoch-grid snapping, and the trace-driven workload layer."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (PRFAAS, PrfaasSimulator, SimConfig, ThroughputModel,
                        Trace, Workload, conversation_trace, diurnal_trace,
                        flash_crowd_trace, paper_h20_profile,
                        paper_h200_profile)

_EQ_KEYS = ("throughput_rps", "ttft_mean", "ttft_p90", "offload_frac",
            "egress_gbps")

_SETUP: list = []             # lazy module cache (fixtures can't mix with
                              # @given under the hypothesis fallback shim)


def _setup():
    if not _SETUP:
        w = Workload(session_prob=0.35, burst_factor=1.6)
        tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
        sc, lam, _ = tm.grid_search(6, 12, 100e9 / 8)
        _SETUP.append((tm, sc, lam, w))
    return _SETUP[0]


def _run(tm, sc, w, engine, **kw):
    return PrfaasSimulator(tm, sc, w, SimConfig(engine=engine, **kw)).run()


def _assert_close(v, e, keys=_EQ_KEYS, rel=0.05):
    for k in keys:
        assert v[k] == pytest.approx(e[k], rel=rel, abs=1e-9), k


# --------------------------------------------------------------------------
# event vs vector equivalence
# --------------------------------------------------------------------------
class TestVectorEquivalence:
    def test_pinned_scenario_within_5pct(self):
        """The pinned two-cluster scenario (sessions + bursts + OU link
        noise on a congested 25 Gbps star) must agree with the exact
        engine on every headline metric."""
        w = Workload(session_prob=0.3, burst_factor=1.5)
        tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
        sc, lam, _ = tm.grid_search(4, 8, 100e9 / 8)
        kw = dict(arrival_rate=0.8 * lam, sim_time=360, dt=0.02, seed=11,
                  link_gbps=25.0, link_fluctuation=0.15, vector_dt=0.05)
        e = _run(tm, sc, w, "event", **kw)
        v = _run(tm, sc, w, "vector", **kw)
        _assert_close(v, e)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(1, 4),                    # regional PD clusters
           st.integers(0, 1000),                 # seed
           st.sampled_from([0.0, 0.15, 0.3]),    # session roaming
           st.sampled_from([0.0, 0.1, 0.2]),     # OU link fluctuation
           st.sampled_from([0.0, 8.0]),          # PD<->PD mesh Gbps
           st.sampled_from([0, 8]),              # decode block tokens
           st.sampled_from([0.6, 0.85]),         # load fraction
           st.booleans())                        # regional autoscaling
    def test_randomized_configs_within_5pct(self, k, seed, roam, fluct,
                                            mesh, dbt, load, autoscale):
        """Property-style: random topology / roaming / autoscale /
        block-granularity configs from the supported envelope must stay in
        the 5% equivalence band on every headline metric."""
        tm, sc, lam, w = _setup()
        kw = dict(arrival_rate=load * lam, sim_time=240, dt=0.02, seed=seed,
                  link_gbps=25.0, link_fluctuation=fluct, vector_dt=0.05,
                  decode_block_tokens=dbt, autoscale=autoscale,
                  pd_clusters=k, pd_mesh_gbps=mesh if k > 1 else 0.0,
                  roam_prob=roam if k > 1 else 0.0)
        e = _run(tm, sc, w, "event", **kw)
        v = _run(tm, sc, w, "vector", **kw)
        _assert_close(v, e)

    def test_slo_metrics_match_event_engine(self):
        """With a TTFT SLO set, attainment/goodput keys exist in both
        engines and agree on an uncongested scenario."""
        tm, sc, lam, w = _setup()
        kw = dict(arrival_rate=0.6 * lam, sim_time=240, seed=3,
                  vector_dt=0.05, ttft_slo_s=4.0)
        e = _run(tm, sc, w, "event", **kw)
        v = _run(tm, sc, w, "vector", **kw)
        assert v["ttft_slo_s"] == e["ttft_slo_s"] == 4.0
        assert v["slo_attainment"] == pytest.approx(e["slo_attainment"],
                                                    abs=0.05)
        assert v["goodput_rps"] == pytest.approx(e["goodput_rps"], rel=0.05)


# --------------------------------------------------------------------------
# vectorized link solver: bytes sent == bytes charged by routing decisions
# --------------------------------------------------------------------------
class TestVectorLinkConservation:
    def test_bytes_sent_equal_bytes_charged(self):
        """Replay a roaming conversation trace whose arrivals all land in
        the first quarter of the horizon (long drain tail): after the run,
        every pair link's fluid-solver sent bytes must equal the KV bytes
        the routing decisions charged to that pair, and no backlog may
        linger."""
        tm, sc, lam, w = _setup()
        names = ("pd0", "pd1", "pd2")
        starts = diurnal_trace(0.1 * lam, 60.0, seed=5, depth=0.0).arrival
        tr = conversation_trace(starts, 200.0, seed=5, home_names=names,
                                turns_mean=3.0, think_mean_s=10.0,
                                roam_prob=0.3)
        sim = PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=1.0, sim_time=400.0, seed=5, engine="vector",
            vector_dt=0.05, pd_clusters=3, pd_mesh_gbps=8.0,
            pool_blocks=2_000_000))
        sim.inject_soa_trace(tr)
        sim.run()
        eng = sim._vector_state
        prof = tm.prfaas_profile

        def s_kv(tok):
            return prof.s_kv(tok)

        charged = {}

        def charge(a, b, nb):
            key = f"{min(a, b)}|{max(a, b)}"
            charged[key] = charged.get(key, 0.0) + nb

        started = eng.pf_start >= 0
        for i in np.flatnonzero(started):
            tgt = eng.names[eng.target[i]]
            home = eng.names[1 + eng.home[i]]
            cached = int(eng.cached[i])
            if tgt == PRFAAS:
                nb = s_kv(int(eng.total[i]))
                if cached:
                    nb -= s_kv(cached)
                charge(PRFAAS, home, max(nb, 1.0))
            if eng.cross[i] and cached:
                charge(eng.names[eng.cache_cl[i]], tgt,
                       max(s_kv(cached), 1.0))
        for (a, b), L in zip(eng.link_keys, eng.links):
            pair = f"{min(a, b)}|{max(a, b)}"
            assert L.backlog == pytest.approx(0.0, abs=1e-3), pair
            assert L.S == pytest.approx(charged.get(pair, 0.0),
                                        rel=1e-6, abs=1.0), pair

    def test_epoch_grid_snaps_to_control_grid(self):
        """``vector_dt`` must land on a divisor (or multiple) of
        ``control_dt`` so routing signals are sampled at the control
        instants — misaligned grids systematically skew route decisions."""
        from repro.core.vector_engine import _VectorEngine
        tm, sc, lam, w = _setup()

        def eng(vdt, cdt=0.25):
            sim = PrfaasSimulator(tm, sc, w, SimConfig(
                arrival_rate=1.0, sim_time=10.0, engine="vector",
                vector_dt=vdt, control_dt=cdt))
            return _VectorEngine(sim)

        assert eng(0.11).dt == pytest.approx(0.125)   # 0.25 / 2
        assert eng(0.05).dt == pytest.approx(0.05)    # already a divisor
        assert eng(0.6).dt == pytest.approx(0.5)      # 0.25 * 2
        assert eng(1.0).dt == pytest.approx(1.0)      # 0.25 * 4


# --------------------------------------------------------------------------
# trace-driven workload layer
# --------------------------------------------------------------------------
class TestTraceLayer:
    def test_save_load_round_trip(self, tmp_path):
        tr = diurnal_trace(2.0, 300.0, seed=9,
                           home_names=("pd0", "pd1"), shares=(0.7, 0.3),
                           tz_offsets_s=(0.0, 150.0), day_s=300.0)
        path = str(tmp_path / "trace.npz")
        tr.save(path)
        back = Trace.load(path)
        np.testing.assert_array_equal(tr.arrival, back.arrival)
        np.testing.assert_array_equal(tr.total_len, back.total_len)
        np.testing.assert_array_equal(tr.session, back.session)
        np.testing.assert_array_equal(tr.home, back.home)
        assert back.home_names == ("pd0", "pd1")
        assert back.meta["family"] == "diurnal"
        assert back.meta["seed"] == 9

    def test_diurnal_mean_rate_and_phases(self):
        tr = diurnal_trace(5.0, 2000.0, seed=1,
                           home_names=("a", "b"), tz_offsets_s=(0.0, 1000.0),
                           day_s=2000.0)
        assert len(tr) / 2000.0 == pytest.approx(5.0, rel=0.1)
        # opposite phase: region a peaks in the first half-day, b in the
        # second (tz offset = half a day)
        a_first = (tr.arrival[tr.home == 0] < 1000.0).mean()
        b_first = (tr.arrival[tr.home == 1] < 1000.0).mean()
        assert a_first > 0.55 > 0.45 > b_first

    def test_flash_crowd_spikes_local_rate(self):
        tr = flash_crowd_trace(2.0, 600.0, seed=2, flash_times=(300.0,),
                               flash_amp=4.0, flash_decay_s=30.0)
        during = ((tr.arrival >= 300.0) & (tr.arrival < 330.0)).sum() / 30.0
        before = ((tr.arrival >= 200.0) & (tr.arrival < 290.0)).sum() / 90.0
        assert during > 2.0 * before
        assert tr.meta["family"] == "flash_crowd"

    def test_conversation_sessions_grow_and_gap(self):
        starts = np.arange(0.0, 100.0, 5.0)
        tr = conversation_trace(starts, 10_000.0, seed=3, turns_mean=5.0,
                                think_mean_s=30.0)
        assert tr.n_sessions == len(starts)
        for s in range(tr.n_sessions):
            m = tr.session == s
            assert np.all(np.diff(tr.arrival[m]) > 0.0)       # think gaps
            assert np.all(np.diff(tr.total_len[m]) >= 0.0)    # ctx grows
        # mean turns per session ~ geometric(1/5)
        assert len(tr) / tr.n_sessions == pytest.approx(5.0, rel=0.35)

    def test_conversation_roaming_rehomes_turns_not_sessions(self):
        starts = np.arange(0.0, 200.0, 2.0)
        tr = conversation_trace(starts, 10_000.0, seed=4,
                                home_names=("x", "y", "z"), turns_mean=6.0,
                                roam_prob=0.4)
        moved = 0
        for s in range(tr.n_sessions):
            h = tr.home[tr.session == s]
            moved += int((np.diff(h) != 0).sum())
        assert moved > 0
        tr0 = conversation_trace(starts, 10_000.0, seed=4,
                                 home_names=("x", "y", "z"), turns_mean=6.0,
                                 roam_prob=0.0)
        for s in range(tr0.n_sessions):
            h = tr0.home[tr0.session == s]
            assert np.all(h == h[0])

    def test_trace_validation_rejects_bad_columns(self):
        with pytest.raises(ValueError, match="sorted"):
            Trace(np.array([1.0, 0.5]), np.array([10, 10]),
                  np.array([0, 1]), np.array([0, 0]))
        with pytest.raises(ValueError, match="equal length"):
            Trace(np.array([1.0]), np.array([10, 10]),
                  np.array([0]), np.array([0]))
        with pytest.raises(ValueError, match="home index"):
            Trace(np.array([1.0]), np.array([10]),
                  np.array([0]), np.array([2]), home_names=("pd",))

    def test_soa_trace_replay_matches_event_replay(self):
        """The same trace replayed through the vector engine (SoA fast
        path) and the event engine (object path) must agree within the
        equivalence band."""
        tm, sc, lam, w = _setup()
        names = ("pd0", "pd1")
        tr = diurnal_trace(0.5 * lam, 240.0, seed=6, home_names=names,
                           tz_offsets_s=(0.0, 120.0), day_s=240.0)
        out = {}
        for engine in ("event", "vector"):
            sim = PrfaasSimulator(tm, sc, w, SimConfig(
                arrival_rate=0.5 * lam, sim_time=240.0, seed=6,
                engine=engine, vector_dt=0.05, pd_clusters=2))
            sim.inject_soa_trace(tr)
            out[engine] = sim.run()
        _assert_close(out["vector"], out["event"],
                      keys=("throughput_rps", "ttft_mean", "ttft_p90"))

"""Serving hot-path tests: bucketed/chunked prefill exactness and compile
stability, blocked decode equivalence, batched admission, free-slot deque,
truncation accounting.

Marked ``slow`` (they jit real smoke models); the compile-count guards are
the load-bearing ones — they pin the recompile-free property the ISSUE-5
refactor exists for.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving.api import Request
from repro.serving.engine import (DecodeEngine, PrefillEngine, next_pow2,
                                  trim_request_cache)

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def kimi():
    """Hybrid smoke model (KDA conv + MLA): the hardest cache layout."""
    cfg = get_smoke_config("kimi-linear-1t")
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def danube():
    """Full-attention smoke model."""
    cfg = get_smoke_config("h2o-danube-1.8b")
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    toks = np.zeros((len(lens), max(lens)), np.int32)
    for i, L in enumerate(lens):
        toks[i, :L] = rng.integers(0, cfg.vocab_size, (L,))
    return toks, np.asarray(lens, np.int32)


class TestPrefillBuckets:
    def test_bucket_padding_is_exact(self, kimi):
        """A short prompt padded into a larger bucket must produce the same
        first token and (trimmed) cache as an unpadded prefill — including
        linear-mixer states and the conv window."""
        cfg, model, params = kimi
        toks, lens = _prompts(cfg, [45])
        ref_first, ref_caches = jax.jit(model.prefill)(
            params, {"tokens": jnp.asarray(toks)})
        eng = PrefillEngine(model, params, min_bucket=32)
        first, caches, _ = eng.prefill(toks, lens)
        assert int(first[0]) == int(jnp.argmax(ref_first[0]))
        got = trim_request_cache(caches, 0, 45)
        want = trim_request_cache(ref_caches, 0, 45)
        for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-4)

    def test_one_compile_per_bucket(self, danube):
        cfg, model, params = danube
        eng = PrefillEngine(model, params, min_bucket=32)
        toks, lens = _prompts(cfg, [33, 40, 50, 60])
        eng.prefill(toks, lens)
        after_first = eng.compiles
        # same (batch, length) bucket, different raw lengths: NO new compile
        for lens2 in ([34, 61, 64, 35], [50, 50, 50, 50]):
            toks2, l2 = _prompts(cfg, lens2, seed=3)
            eng.prefill(toks2, l2)
        assert eng.compiles == after_first
        # a new bucket compiles exactly once more
        toks3, l3 = _prompts(cfg, [100, 120, 90, 70], seed=4)
        eng.prefill(toks3, l3)
        assert eng.compiles == after_first + 1

    def test_warmup_then_zero_recompiles(self, danube):
        cfg, model, params = danube
        eng = PrefillEngine(model, params, min_bucket=32)
        eng.warmup([2], [32, 64, 128])
        warm = eng.compiles
        rng = np.random.default_rng(7)
        for _ in range(5):
            lens = rng.integers(9, 128, (2,)).tolist()
            toks, l = _prompts(cfg, lens, seed=int(rng.integers(1 << 30)))
            eng.prefill(toks, l)
        assert eng.compiles == warm

    def test_warmup_covers_chunked_prompts(self, danube):
        """Warmup lengths past max_bucket pre-trace the chunk programs for
        their exact chunk count, so serving a past-max-bucket prompt later
        never recompiles (the PR 6 chunk-interleaving hot path)."""
        cfg, model, params = danube
        eng = PrefillEngine(model, params, min_bucket=32, max_bucket=64)
        # 300 -> ceil(300/64)=5 chunks: warms every chunk index 0..4, which
        # also covers any shorter chunked prompt (fewer chunks, same shapes)
        eng.warmup([2], [32, 64, 300])
        warm = eng.compiles
        rng = np.random.default_rng(11)
        for _ in range(4):
            lens = rng.integers(9, 300, (2,)).tolist()
            toks, l = _prompts(cfg, lens, seed=int(rng.integers(1 << 30)))
            eng.prefill(toks, l)
        assert eng.compiles == warm

    # kimi = KDA conv + MLA latents; qwen = plain GQA; danube = SWA with a
    # 64-token window, so chunk-2 queries straddle the band across the
    # chunk boundary (the q_offset + window path in gqa_forward_chunk)
    @pytest.mark.parametrize(
        "arch", ["kimi-linear-1t", "qwen2.5-3b", "h2o-danube-1.8b"])
    def test_chunked_prefill_matches_full(self, arch):
        """Prompts past max_bucket run as fixed-shape chunks and must match
        the one-shot prefill (logits + valid cache region)."""
        cfg = get_smoke_config(arch)
        model = Model(cfg, use_kernels=False)
        params = model.init(jax.random.PRNGKey(0))
        toks, lens = _prompts(cfg, [150, 100], seed=2)
        full = PrefillEngine(model, params, min_bucket=32)
        chunked = PrefillEngine(model, params, min_bucket=32, max_bucket=64)
        f_first, f_caches, _ = full.prefill(toks, lens)
        c_first, c_caches, _ = chunked.prefill(toks, lens)
        np.testing.assert_array_equal(f_first, c_first)
        for i, L in enumerate(lens):
            want = trim_request_cache(f_caches, i, int(L))
            got = trim_request_cache(c_caches, i, int(L))
            for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32),
                                           atol=1e-3)

    def test_next_pow2(self):
        assert [next_pow2(n) for n in (1, 2, 3, 8, 9)] == [1, 2, 4, 8, 16]
        assert next_pow2(5, lo=32) == 32


def _admit_all(eng, cfg, model, params, lens, max_new, seed=0):
    peng = PrefillEngine(model, params, min_bucket=32)
    toks, l = _prompts(cfg, lens, seed=seed)
    first, caches, _ = peng.prefill(toks, l)
    entries = [
        (Request(rid=i, tokens=toks[i, :L], max_new_tokens=max_new),
         int(first[i]), trim_request_cache(caches, i, int(L)), int(L))
        for i, L in enumerate(lens)]
    return entries, eng.admit_many(entries)


class TestDecodeBlock:
    def test_block_matches_per_token(self, kimi):
        cfg, model, params = kimi
        lens = [16, 24, 33, 40]
        a = DecodeEngine(model, params, 4, 128, block_size=4)
        b = DecodeEngine(model, params, 4, 128, block_size=4)
        _admit_all(a, cfg, model, params, lens, max_new=6)
        _admit_all(b, cfg, model, params, lens, max_new=6)
        while a.active.any():
            a.step()                       # per-token loop
        b.run_until_drained()              # blocked loop
        for i in range(4):
            assert a.outputs[i].output_tokens == b.outputs[i].output_tokens
            assert b.outputs[i].finished and not b.outputs[i].truncated

    def test_block_compiles_once(self, danube):
        cfg, model, params = danube
        eng = DecodeEngine(model, params, 4, 128, block_size=4)
        _admit_all(eng, cfg, model, params, [16, 20, 24, 30], max_new=13)
        eng.run_until_drained()            # several blocks, ragged finish
        assert eng.block_compiles == 1
        # admit again (different lengths): still one compiled block program
        _admit_all(eng, cfg, model, params, [40, 8, 12, 50], max_new=5,
                   seed=9)
        eng.run_until_drained()
        assert eng.block_compiles == 1

    def test_truncation_flag_and_counter(self, danube):
        cfg, model, params = danube
        eng = DecodeEngine(model, params, 2, 64, block_size=4)
        # rid 0 hits the capacity wall with budget left; rid 1 finishes clean
        entries, n = _admit_all(eng, cfg, model, params, [60, 16],
                                max_new=30)
        assert n == 2
        eng.run_until_drained()
        trunc, clean = eng.outputs[0], eng.outputs[1]
        assert trunc.finished and trunc.truncated
        # first token + the 3 decode steps that fit before capacity-1
        assert len(trunc.output_tokens) == 4
        assert clean.finished and not clean.truncated
        assert len(clean.output_tokens) == 31          # first + 30
        assert eng.truncations == 1

    def test_capacity_wall_admission_boundary(self, danube):
        """A slot admitted AT the capacity wall (prompt_len == capacity-1)
        must behave identically in both loops: emit exactly one token, then
        retire truncated."""
        cfg, model, params = danube
        block = DecodeEngine(model, params, 1, 64, block_size=4)
        per_tok = DecodeEngine(model, params, 1, 64, block_size=4)
        _admit_all(block, cfg, model, params, [63], max_new=10)
        _admit_all(per_tok, cfg, model, params, [63], max_new=10)
        block.run_until_drained()
        while per_tok.active.any():
            per_tok.step()
        assert (block.outputs[0].output_tokens
                == per_tok.outputs[0].output_tokens)
        assert len(block.outputs[0].output_tokens) == 2  # first + 1 decode
        assert block.outputs[0].truncated and per_tok.outputs[0].truncated
        assert block.budget[0] == per_tok.budget[0]
        assert block.lengths[0] == per_tok.lengths[0]

    def test_per_token_truncation_matches(self, danube):
        """The satellite fix: the legacy step() loop must also report the
        capacity-wall retirement as truncated."""
        cfg, model, params = danube
        eng = DecodeEngine(model, params, 1, 64, block_size=4)
        _admit_all(eng, cfg, model, params, [60], max_new=50)
        while eng.active.any():
            eng.step()
        assert eng.outputs[0].truncated and eng.truncations == 1


class TestAdmission:
    def test_batched_matches_serial(self, kimi):
        cfg, model, params = kimi
        lens = [16, 22, 30]
        batched = DecodeEngine(model, params, 4, 128, block_size=4)
        serial = DecodeEngine(model, params, 4, 128, block_size=4)
        entries, n = _admit_all(batched, cfg, model, params, lens, max_new=4)
        assert n == 3
        for e in entries:
            assert serial.admit(*e)
        batched.run_until_drained()
        serial.run_until_drained()
        for i in range(3):
            assert (batched.outputs[i].output_tokens
                    == serial.outputs[i].output_tokens)

    def test_admits_up_to_free_slots(self, danube):
        cfg, model, params = danube
        eng = DecodeEngine(model, params, 2, 128, block_size=4)
        entries, n = _admit_all(eng, cfg, model, params, [16, 20, 24],
                                max_new=3)
        assert n == 2 and not eng.free_slots()
        eng.run_until_drained()
        assert len(eng.free_slots()) == 2
        assert eng.admit_many(entries[2:]) == 1

    def test_deployment_overflow_drains_and_admits_rest(self, danube):
        """A batch larger than a region's decode slots must not silently
        drop requests: the deployment drains active streams and admits the
        remainder, and every request gets a finished Response."""
        from repro.serving import CrossDCDeployment, DeploymentConfig
        cfg, model, params = danube
        dep = CrossDCDeployment(model, params,
                                DeploymentConfig(threshold=1024,
                                                 decode_slots=2,
                                                 capacity=128))
        rng = np.random.default_rng(5)
        reqs = [Request(rid=i, tokens=rng.integers(
            0, cfg.vocab_size, (L,)).astype(np.int32), max_new_tokens=3)
            for i, L in enumerate([16, 20, 24, 30, 40])]
        out = dep.submit_batch(reqs)
        assert sorted(out) == [0, 1, 2, 3, 4]
        assert all(r.finished for r in out.values())
        assert all(len(r.output_tokens) == 4 for r in out.values())

    def test_free_slot_deque_recycling(self, danube):
        cfg, model, params = danube
        eng = DecodeEngine(model, params, 3, 128, block_size=4)
        assert eng.free_slots() == [0, 1, 2]
        entries, _ = _admit_all(eng, cfg, model, params, [16, 20], max_new=2)
        assert eng.free_slots() == [2]
        eng.run_until_drained()
        # retired slots return to the tail; next admit pops from the head
        assert set(eng.free_slots()) == {0, 1, 2}
        assert eng.free_slots()[0] == 2
        eng.admit_many(entries[:1])
        assert eng.active[2] and not eng.active[0]

"""Link/transfer engine + end-to-end cluster simulator."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (Link, PrfaasSimulator, SimConfig, SystemConfig,
                        ThroughputModel, Workload, layerwise_release,
                        paper_h20_profile, paper_h200_profile)


def run_link(link, seconds, dt=0.01):
    steps = int(seconds / dt)
    for i in range(steps):
        link.tick(i * dt, dt)
    return steps * dt


class TestLink:
    def test_single_flow_takes_expected_time(self):
        link = Link(8e9)                       # 1 GB/s
        done = []
        link.submit(2e9, 0.0, on_done=lambda t: done.append(t))
        run_link(link, 3.0)
        assert done and abs(done[0] - 2.0) < 0.05

    def test_fair_share_two_flows(self):
        link = Link(8e9)
        done = []
        link.submit(1e9, 0.0, on_done=lambda t: done.append(("a", t)))
        link.submit(1e9, 0.0, on_done=lambda t: done.append(("b", t)))
        run_link(link, 3.0)
        # both share -> each finishes ~2s (processor sharing)
        assert len(done) == 2
        assert all(abs(t - 2.0) < 0.1 for _, t in done)

    def test_conservation(self):
        """Property: bytes sent can never exceed capacity x time."""
        link = Link(8e9, fluctuation=0.0)
        for i in range(5):
            link.submit(5e8, 0.0)
        elapsed = run_link(link, 1.5)
        assert link.sent_bytes <= 1e9 * elapsed * 1.001

    def test_layerwise_release_overlaps_compute(self):
        """With pipelining the transfer tail beyond prefill is ~bytes/bw -
        overlapped portion, vs full serialization without it."""
        link = Link(8e9)
        done = []
        rel = layerwise_release(0.0, 2.0, 1e9, n_layers=10)
        link.submit(1e9, 0.0, release=rel, on_done=lambda t: done.append(t))
        run_link(link, 4.0)
        # 1 GB at 1 GB/s with 2s compute: finishes ~max(2.0+tail, 1.0)
        assert done and 2.0 <= done[0] < 2.5

    def test_congestion_signal(self):
        link = Link(1e9)                       # tiny link
        for _ in range(10):
            link.submit(1e9, 0.0)
        run_link(link, 1.0)
        sig = link.congestion_signal()
        assert sig["util"] > 0.5 and sig["queue_bytes"] > 0

    @settings(max_examples=20, deadline=None)
    @given(st.floats(0.05, 0.4), st.integers(0, 100))
    def test_fluctuating_capacity_bounded(self, fluct, seed):
        link = Link(8e9, fluctuation=fluct, seed=seed)
        for i in range(200):
            link.tick(i * 0.05, 0.05)
            assert 0.2 <= link._mult <= 1.6


@pytest.fixture(scope="module")
def table6_setup():
    w = Workload()
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, rate, _ = tm.grid_search(4, 8, 100e9 / 8)
    return tm, sc, rate, w


class TestSimulator:
    def test_sim_tracks_analytic_capacity(self, table6_setup):
        tm, sc, rate, w = table6_setup
        sim = PrfaasSimulator(tm, sc, w,
                              SimConfig(arrival_rate=0.85 * rate,
                                        sim_time=400, dt=0.05, seed=0))
        m = sim.run()
        # sim throughput ~= offered (below capacity) and > 70% of it
        assert m["throughput_rps"] > 0.7 * 0.85 * rate
        assert m["ttft_mean"] > 0 and m["ttft_p90"] >= m["ttft_p50"]
        assert m["offload_frac"] == pytest.approx(0.5, abs=0.12)

    def test_overload_saturates_at_capacity(self, table6_setup):
        tm, sc, rate, w = table6_setup
        sim = PrfaasSimulator(tm, sc, w,
                              SimConfig(arrival_rate=2.0 * rate,
                                        sim_time=300, dt=0.05, seed=1))
        m = sim.run()
        assert m["throughput_rps"] < 1.25 * rate     # can't exceed capacity

    def test_egress_stays_within_link(self, table6_setup):
        tm, sc, rate, w = table6_setup
        sim = PrfaasSimulator(tm, sc, w,
                              SimConfig(arrival_rate=0.9 * rate,
                                        sim_time=300, dt=0.05, seed=2,
                                        link_gbps=100.0))
        m = sim.run()
        assert m["egress_gbps"] < 100.0
        assert 5.0 < m["egress_gbps"] < 20.0          # paper: ~13 Gbps

    def test_sessions_produce_cache_hits(self, table6_setup):
        tm, sc, rate, w = table6_setup
        w2 = Workload(session_prob=0.5)
        sim = PrfaasSimulator(tm, sc, w2,
                              SimConfig(arrival_rate=0.6 * rate,
                                        sim_time=300, dt=0.05, seed=3,
                                        pool_blocks=2_000_000))
        m = sim.run()
        hit = max(c["hit_rate"] for c in m["cache"].values())
        assert hit > 0.15

    def test_congestion_triggers_threshold_adjustments(self, table6_setup):
        tm, sc, rate, w = table6_setup
        sim = PrfaasSimulator(
            tm, sc, w, SimConfig(arrival_rate=1.2 * rate, sim_time=240,
                                 dt=0.05, seed=4, link_gbps=3.0,
                                 link_fluctuation=0.2))
        m = sim.run()
        assert m["router_adjustments"] > 0            # short-term loop fired

    def test_autoscaler_converts_nodes(self, table6_setup):
        tm, _, rate, w = table6_setup
        bad = SystemConfig(4, 6, 2, 100e9 / 8, 19_400.0)   # decode-starved
        sim = PrfaasSimulator(tm, bad, w,
                              SimConfig(arrival_rate=0.8 * rate,
                                        sim_time=900, dt=0.05, seed=5,
                                        autoscale=True))
        m = sim.run()
        assert sim.autoscaler.conversions, "autoscaler never rebalanced"
        _, n_p, n_d = sim.autoscaler.conversions[-1]
        assert n_d > 2

"""Link/transfer engine + end-to-end cluster simulator.

Includes the PR 3 property harness: for random topologies, seeds, and
roaming rates, (a) every byte a pair link reports sending was charged to
that pair by a routing decision (and vice versa), and (b) ``LinkTopology``
conserves backlog across ``advance`` — no bytes are created, lost, or
migrated between pair links by the exact solver."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (PRFAAS, Link, LinkTopology, PrfaasSimulator,
                        SimConfig, SystemConfig, ThroughputModel, Workload,
                        layerwise_release, paper_h20_profile,
                        paper_h200_profile, split_even, star_pairs)


def run_link(link, seconds, dt=0.01):
    steps = int(seconds / dt)
    for i in range(steps):
        link.tick(i * dt, dt)
    return steps * dt


class TestLink:
    def test_single_flow_takes_expected_time(self):
        link = Link(8e9)                       # 1 GB/s
        done = []
        link.submit(2e9, 0.0, on_done=lambda t: done.append(t))
        run_link(link, 3.0)
        assert done and abs(done[0] - 2.0) < 0.05

    def test_fair_share_two_flows(self):
        link = Link(8e9)
        done = []
        link.submit(1e9, 0.0, on_done=lambda t: done.append(("a", t)))
        link.submit(1e9, 0.0, on_done=lambda t: done.append(("b", t)))
        run_link(link, 3.0)
        # both share -> each finishes ~2s (processor sharing)
        assert len(done) == 2
        assert all(abs(t - 2.0) < 0.1 for _, t in done)

    def test_conservation(self):
        """Property: bytes sent can never exceed capacity x time."""
        link = Link(8e9, fluctuation=0.0)
        for i in range(5):
            link.submit(5e8, 0.0)
        elapsed = run_link(link, 1.5)
        assert link.sent_bytes <= 1e9 * elapsed * 1.001

    def test_layerwise_release_overlaps_compute(self):
        """With pipelining the transfer tail beyond prefill is ~bytes/bw -
        overlapped portion, vs full serialization without it."""
        link = Link(8e9)
        done = []
        rel = layerwise_release(0.0, 2.0, 1e9, n_layers=10)
        link.submit(1e9, 0.0, release=rel, on_done=lambda t: done.append(t))
        run_link(link, 4.0)
        # 1 GB at 1 GB/s with 2s compute: finishes ~max(2.0+tail, 1.0)
        assert done and 2.0 <= done[0] < 2.5

    def test_congestion_signal(self):
        link = Link(1e9)                       # tiny link
        for _ in range(10):
            link.submit(1e9, 0.0)
        run_link(link, 1.0)
        sig = link.congestion_signal()
        assert sig["util"] > 0.5 and sig["queue_bytes"] > 0

    @settings(max_examples=20, deadline=None)
    @given(st.floats(0.05, 0.4), st.integers(0, 100))
    def test_fluctuating_capacity_bounded(self, fluct, seed):
        link = Link(8e9, fluctuation=fluct, seed=seed)
        for i in range(200):
            link.tick(i * 0.05, 0.05)
            assert 0.2 <= link._mult <= 1.6


@pytest.fixture(scope="module")
def table6_setup():
    w = Workload()
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, rate, _ = tm.grid_search(4, 8, 100e9 / 8)
    return tm, sc, rate, w


# --------------------------------------------------------------------------
# property harness: routing-decision byte charging + topology conservation
# --------------------------------------------------------------------------
_PROP_SETUP: list = []        # lazy module cache (fixtures can't mix with
                              # @given under the hypothesis fallback shim)


def _prop_setup():
    if not _PROP_SETUP:
        w = Workload()
        tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
        sc, rate, _ = tm.grid_search(4, 8, 100e9 / 8)
        _PROP_SETUP.append((tm, sc, rate))
    return _PROP_SETUP[0]


class TestTopologyProperties:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(1, 3), st.integers(0, 1000), st.floats(0.0, 0.5),
           st.sampled_from([1.0, 2.6073844964237387]))
    def test_bytes_sent_per_pair_equal_bytes_charged(self, k, seed, roam,
                                                     comp):
        """Every pair link's sent bytes (after draining) equal the bytes
        the routing decisions charged to that pair: prefill KV flows on
        the (PrfaaS, home) star link, cross-cache copies on the
        (cache owner, prefill target) pair — including roaming copies on
        the PD<->PD mesh.  With int8 wire compression on
        (``kv_wire_compression`` = a measured quantized/raw ratio), the
        expected bytes are recomputed here from the PROFILE directly
        (S_kv / ratio), independent of the simulator's own helpers."""
        tm, sc, rate = _prop_setup()
        w = Workload(session_prob=0.5)
        sc = SystemConfig(sc.n_prfaas, sc.n_p, sc.n_d, sc.b_out,
                          sc.threshold, kv_wire_compression=comp,
                          n_p_clusters=tuple(split_even(sc.n_p, k))
                          if k > 1 else None,
                          n_d_clusters=tuple(split_even(sc.n_d, k))
                          if k > 1 else None)
        sim = PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=0.4 * rate, sim_time=60.0, seed=seed,
            engine="event", pool_blocks=2_000_000, pd_clusters=k,
            pd_mesh_gbps=10.0 if k > 1 else 0.0,
            roam_prob=roam if k > 1 else 0.0))
        sim.run()
        sim.topology.run_until_idle()            # drain in-flight flows
        charged: dict = {}
        prof = tm.prfaas_profile

        def _charge(a, b, nbytes):
            key = f"{min(a, b)}|{max(a, b)}"
            charged[key] = charged.get(key, 0.0) + nbytes

        for r in sim.all_requests:
            if r.decision is None or r.prefill_start < 0:
                continue                         # never started: no flows
            if r.decision.target == PRFAAS:
                nb = prof.s_kv(r.total_len)
                if r.decision.cached_tokens:
                    nb -= prof.s_kv(r.decision.cached_tokens)
                _charge(PRFAAS, r.home, max(nb / comp, 1.0))
            if r.decision.cross_cache_transfer and r.decision.cached_tokens:
                _charge(r.decision.cache_cluster, r.decision.target,
                        max(prof.s_kv(r.decision.cached_tokens) / comp, 1.0))
        stats = sim.topology.pair_stats()
        for pair, s in stats.items():
            assert s["sent_bytes"] == pytest.approx(
                charged.get(pair, 0.0), rel=1e-6, abs=1.0), pair
        assert sim.topology.sent_bytes == pytest.approx(
            sum(charged.values()), rel=1e-6, abs=1.0)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 1000), st.floats(0.0, 0.4), st.integers(2, 4))
    def test_topology_conserves_backlog_across_advance(self, seed, fluct, k):
        """At every advance boundary, each pair link satisfies
        sent_bytes + live backlog == total bytes submitted to that pair
        (no creation, loss, or cross-pair migration), and full drain
        delivers exactly what was submitted."""
        rng = np.random.default_rng(seed)
        pds = [f"pd{i}" for i in range(k)]
        pairs = star_pairs(PRFAAS, pds, mesh=True)
        topo = LinkTopology.build(
            [PRFAAS] + pds, pairs,
            [float(rng.uniform(2.0, 10.0)) for _ in pairs],
            fluctuation=fluct, seed=seed)
        submitted = {f"{min(a, b)}|{max(a, b)}": 0.0 for a, b in pairs}
        for _ in range(25):
            a, b = pairs[int(rng.integers(len(pairs)))]
            nbytes = float(rng.uniform(1e6, 5e8))
            start = float(rng.uniform(0.0, 2.0))
            topo.submit(a, b, nbytes, start,
                        ramp_end=start + float(rng.uniform(0.0, 1.0)))
            submitted[f"{min(a, b)}|{max(a, b)}"] += nbytes
        t = 0.0
        for _ in range(12):
            t += float(rng.uniform(0.05, 0.8))
            topo.advance(t)
            backlogs = topo.pair_backlogs()
            for pair, s in topo.pair_stats().items():
                assert s["sent_bytes"] + backlogs[pair] == pytest.approx(
                    submitted[pair], rel=1e-9, abs=1e-3), (pair, t)
        topo.run_until_idle()
        for pair, s in topo.pair_stats().items():
            assert s["sent_bytes"] == pytest.approx(submitted[pair],
                                                    rel=1e-9, abs=1e-3)
            assert topo.pair_backlogs()[pair] == pytest.approx(0.0, abs=1e-3)


class TestSimulator:
    def test_sim_tracks_analytic_capacity(self, table6_setup):
        tm, sc, rate, w = table6_setup
        sim = PrfaasSimulator(tm, sc, w,
                              SimConfig(arrival_rate=0.85 * rate,
                                        sim_time=400, dt=0.05, seed=0))
        m = sim.run()
        # sim throughput ~= offered (below capacity) and > 70% of it
        assert m["throughput_rps"] > 0.7 * 0.85 * rate
        assert m["ttft_mean"] > 0 and m["ttft_p90"] >= m["ttft_p50"]
        assert m["offload_frac"] == pytest.approx(0.5, abs=0.12)

    def test_overload_saturates_at_capacity(self, table6_setup):
        tm, sc, rate, w = table6_setup
        sim = PrfaasSimulator(tm, sc, w,
                              SimConfig(arrival_rate=2.0 * rate,
                                        sim_time=300, dt=0.05, seed=1))
        m = sim.run()
        assert m["throughput_rps"] < 1.25 * rate     # can't exceed capacity

    def test_egress_stays_within_link(self, table6_setup):
        tm, sc, rate, w = table6_setup
        sim = PrfaasSimulator(tm, sc, w,
                              SimConfig(arrival_rate=0.9 * rate,
                                        sim_time=300, dt=0.05, seed=2,
                                        link_gbps=100.0))
        m = sim.run()
        assert m["egress_gbps"] < 100.0
        assert 5.0 < m["egress_gbps"] < 20.0          # paper: ~13 Gbps

    def test_sessions_produce_cache_hits(self, table6_setup):
        tm, sc, rate, w = table6_setup
        w2 = Workload(session_prob=0.5)
        sim = PrfaasSimulator(tm, sc, w2,
                              SimConfig(arrival_rate=0.6 * rate,
                                        sim_time=300, dt=0.05, seed=3,
                                        pool_blocks=2_000_000))
        m = sim.run()
        hit = max(c["hit_rate"] for c in m["cache"].values())
        assert hit > 0.15

    def test_congestion_triggers_threshold_adjustments(self, table6_setup):
        tm, sc, rate, w = table6_setup
        sim = PrfaasSimulator(
            tm, sc, w, SimConfig(arrival_rate=1.2 * rate, sim_time=240,
                                 dt=0.05, seed=4, link_gbps=3.0,
                                 link_fluctuation=0.2))
        m = sim.run()
        assert m["router_adjustments"] > 0            # short-term loop fired

    def test_autoscaler_converts_nodes(self, table6_setup):
        tm, _, rate, w = table6_setup
        bad = SystemConfig(4, 6, 2, 100e9 / 8, 19_400.0)   # decode-starved
        sim = PrfaasSimulator(tm, bad, w,
                              SimConfig(arrival_rate=0.8 * rate,
                                        sim_time=900, dt=0.05, seed=5,
                                        autoscale=True))
        m = sim.run()
        assert sim.autoscaler.conversions, "autoscaler never rebalanced"
        _, n_p, n_d = sim.autoscaler.conversions[-1]
        assert n_d > 2

"""Pallas flash-attention kernel vs pure-jnp oracle: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attn import flash_attention

pytestmark = pytest.mark.slow      # JAX compiles dominate; -m "not slow" skips

RNG = np.random.default_rng(0)


def mk(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 4, 4, 128, 64),      # MHA, aligned
    (2, 8, 2, 200, 64),      # GQA, ragged seq (padding path)
    (1, 8, 1, 96, 128),      # MQA
    (2, 4, 4, 257, 32),      # prime-ish seq
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(B, Hq, Hkv, S, D, causal):
    q, k, v = mk(B, Hq, S, D), mk(B, Hkv, S, D), mk(B, Hkv, S, D)
    out = flash_attention(q, k, v, causal=causal, interpret=True,
                          block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_sliding_window(window):
    q, k, v = mk(1, 4, 192, 32), mk(1, 2, 192, 32), mk(1, 2, 192, 32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    q = mk(1, 4, 128, 64).astype(dtype)
    k = mk(1, 4, 128, 64).astype(dtype)
    v = mk(1, 4, 128, 64).astype(dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert out.dtype == dtype
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), atol=tol(dtype),
                               rtol=tol(dtype))


def test_flash_dk_neq_dv():
    q, k, v = mk(1, 4, 100, 48), mk(1, 2, 100, 48), mk(1, 2, 100, 32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert out.shape == (1, 4, 100, 32)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_q_offset_matches_suffix():
    """Continuing from a cached prefix: q covers the suffix only."""
    S, Sq = 160, 32
    q_full, k, v = mk(1, 2, S, 32), mk(1, 2, S, 32), mk(1, 2, S, 32)
    full = ref.flash_attention_ref(q_full, k, v, causal=True)
    out = flash_attention(q_full[:, :, -Sq:], k, v, causal=True,
                          interpret=True, block_q=16, block_k=64)
    np.testing.assert_allclose(out, full[:, :, -Sq:], atol=2e-5, rtol=2e-5)


def test_flash_grad_matches_oracle_grad():
    q, k, v = mk(1, 2, 64, 32), mk(1, 2, 64, 32), mk(1, 2, 64, 32)
    ops.FORCE_KERNEL_ON_CPU = True   # exercise kernel fwd + recompute bwd

    def loss_kernel(q, k, v):
        return jnp.sum(ops.attention(q, k, v, causal=True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v, causal=True) ** 2)

    try:
        g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    finally:
        ops.FORCE_KERNEL_ON_CPU = False
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)

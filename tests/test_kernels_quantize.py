"""Fused int8 quantize-on-write kernel vs jnp ref — byte-identity required.

The wire format is part of the serving contract: the fused Pallas pass must
produce the exact int8 payload (and scale) the ref produces, or admission
on the receiving side would dequantize different bytes than the sender
accounted for.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.quantize import quantize_int8_fused

pytestmark = pytest.mark.slow      # JAX compiles dominate; -m "not slow" skips

RNG = np.random.default_rng(11)


def mk(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


@pytest.mark.parametrize("shape", [
    (256, 128),          # exactly one tile
    (4, 512, 64),        # multiple tiles, lane-aligned total
    (1, 2, 100, 64),     # KV-cache-like leaf, needs padding
    (7, 33),             # tiny ragged leaf
    (1,),                # degenerate scalar-ish leaf
])
def test_quantize_byte_identity(shape):
    x = mk(*shape)
    q, s = quantize_int8_fused(x, interpret=True)
    q2, s2 = ref.quantize_int8_ref(x)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))


def test_quantize_zero_leaf():
    """All-zero leaves (warmup payloads) must encode without div-by-zero:
    scale floors at 1e-30/127 and every code is 0."""
    x = jnp.zeros((3, 64, 32), jnp.float32)
    q, s = quantize_int8_fused(x, interpret=True)
    q2, s2 = ref.quantize_int8_ref(x)
    assert int(jnp.sum(jnp.abs(q.astype(jnp.int32)))) == 0
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))
    assert np.isfinite(float(s))


def test_quantize_extremes_clip():
    """Values at +-absmax hit codes +-127 exactly in both paths."""
    x = jnp.asarray([[3.0, -3.0, 1.5, 0.0] * 32] * 8, jnp.float32)
    q, s = quantize_int8_fused(x, interpret=True)
    q2, s2 = ref.quantize_int8_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    assert int(jnp.max(q)) == 127 and int(jnp.min(q)) == -127
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))


def test_ops_dispatch_quantize():
    """ops.quantize_wire: ref on CPU, interpret kernel when forced — and
    the two are byte-identical, so the dispatch seam cannot change wires."""
    x = mk(2, 4, 37, 64)
    want_q, want_s = ref.quantize_int8_ref(x)
    got_q, got_s = ops.quantize_wire(x)
    np.testing.assert_array_equal(np.asarray(got_q), np.asarray(want_q))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    ops.FORCE_KERNEL_ON_CPU = True
    try:
        k_q, k_s = ops.quantize_wire(x)
    finally:
        ops.FORCE_KERNEL_ON_CPU = False
    np.testing.assert_array_equal(np.asarray(k_q), np.asarray(want_q))
    np.testing.assert_array_equal(np.asarray(k_s), np.asarray(want_s))


def test_wire_pytree_identity_kernel_vs_ref():
    """quantize_cache_for_wire produces byte-identical wire pytrees whether
    leaves encode through the fused kernel (interpret) or the jnp ref."""
    from repro.models.kvcache import (dequantize_cache_from_wire,
                                      quantize_cache_for_wire)
    cache = {"layers": [{"k": mk(1, 2, 48, 64, dtype=np.float32),
                         "v": mk(1, 2, 48, 64).astype(jnp.bfloat16),
                         "state": mk(1, 2, 16, 16)}]}
    wire_ref, nb_ref = quantize_cache_for_wire(cache, use_kernel=False)
    ops.FORCE_KERNEL_ON_CPU = True
    try:
        wire_k, nb_k = quantize_cache_for_wire(cache, use_kernel=True)
    finally:
        ops.FORCE_KERNEL_ON_CPU = False
    assert nb_ref == nb_k
    leaf = wire_ref["layers"][0]
    assert set(leaf["k"]) == {"q", "scale"} and leaf["k"]["q"].dtype == jnp.int8
    assert not isinstance(leaf["state"], dict)   # fp32 state ships raw
    for a, b in zip(jax.tree_util.tree_leaves(wire_ref),
                    jax.tree_util.tree_leaves(wire_k)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    back = dequantize_cache_from_wire(wire_k)
    assert back["layers"][0]["v"].dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(back["layers"][0]["k"], np.float32),
        np.asarray(cache["layers"][0]["k"], np.float32), atol=2e-2, rtol=2e-2)

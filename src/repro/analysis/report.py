"""Assemble the §Roofline table: dry-run compile artifacts (memory,
collective schedule, compile proof) x cost-fit predictions (trip-count-exact
FLOPs/bytes/collective-bytes) -> three roofline terms per cell.

    PYTHONPATH=src python -m repro.analysis.report
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.analysis import costfit
from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.configs import SHAPES, get_config

ART = os.path.join(os.path.dirname(__file__), "../../../benchmarks/artifacts")
DRYRUN = os.path.join(ART, "dryrun")
FITS = os.path.join(ART, "costfit")

CHIPS_SINGLE = 256
TRAIN_MB = {"train_4k": 16}


def load_fit(arch: str, kind: str):
    path = os.path.join(FITS, f"fit__{arch}__{kind}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def cell_report(arch: str, shape_name: str, variant: str = "baseline"):
    """Merge full-compile artifact + fitted costs into one roofline row."""
    tag = f"{arch}__{shape_name}__single"
    if variant != "baseline":
        tag += f"__{variant}"
    path = os.path.join(DRYRUN, tag + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        full = json.load(f)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    kind = shape.kind
    fit = load_fit(arch, kind)
    mb = TRAIN_MB.get(shape_name, 1) if kind == "train" else 1
    if fit is not None:
        pred = costfit.predict(fit, cfg, kind, shape.global_batch,
                               shape.seq_len, mb)
        flops_dev, bytes_dev, coll_dev = (max(pred["flops"], 0.0),
                                          max(pred["bytes"], 0.0),
                                          max(pred["coll"], 0.0))
        source = "costfit"
    else:  # fall back to raw (loop-undercounted) compile numbers
        c = full["cost_analysis"]
        flops_dev = c.get("flops", 0.0)
        bytes_dev = c.get("bytes accessed", 0.0)
        coll_dev = full["roofline"]["coll_bytes"] / full["chips"]
        source = "raw-hlo (loop bodies counted once)"

    chips = CHIPS_SINGLE
    t_c = flops_dev / PEAK_FLOPS            # per-device flops / per-chip peak
    t_m = bytes_dev / HBM_BW
    t_x = coll_dev / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    tokens = (shape.seq_len * shape.global_batch if kind != "decode"
              else shape.global_batch)
    mult = 6.0 if kind == "train" else 2.0
    model_flops = mult * cfg.active_param_count() * tokens
    hlo_flops_global = flops_dev * chips
    ideal = model_flops / (chips * PEAK_FLOPS)
    achievable = max(terms.values())
    return {
        "arch": arch, "shape": shape_name, "variant": variant,
        "kind": kind, "chips": chips, "cost_source": source,
        "hlo_flops_global": hlo_flops_global,
        "hlo_bytes_global": bytes_dev * chips,
        "coll_bytes_global": coll_dev * chips,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_frac": (model_flops / hlo_flops_global
                              if hlo_flops_global else 0.0),
        "roofline_frac": ideal / achievable if achievable else 0.0,
        "compile_s": full["compile_s"],
        "memory_analysis": full.get("memory_analysis", {}),
        "collective_schedule": full["roofline"].get("collective_detail", {}),
    }


def all_cells(variant: str = "baseline"):
    out = []
    for fn in sorted(os.listdir(DRYRUN)):
        if not fn.endswith("__single.json"):
            continue
        arch, shape_name, _ = fn[:-5].split("__")
        rep = cell_report(arch, shape_name, variant)
        if rep:
            out.append(rep)
    return out


def markdown_table(cells):
    hdr = ("| arch | shape | kind | compute s | memory s | collective s | "
           "dominant | 6ND/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for c in cells:
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['kind']} "
            f"| {c['t_compute_s']:.4f} | {c['t_memory_s']:.4f} "
            f"| {c['t_collective_s']:.4f} | **{c['dominant']}** "
            f"| {c['useful_flops_frac']:.2f} | {c['roofline_frac']:.3f} |")
    return "\n".join(lines)


def main():
    cells = all_cells()
    out = os.path.join(ART, "roofline_baseline.json")
    with open(out, "w") as f:
        json.dump(cells, f, indent=1)
    print(markdown_table(cells))
    print(f"\n{len(cells)} cells -> {out}")


if __name__ == "__main__":
    main()

"""Fit a measured kernel sweep into a ``CalibratedProfile``.

The calibrated-profile flow (ROADMAP "kernel-level prefill profiles"):

    benchmarks.kernel_bench --sweep   ->  BENCH_kernel.json
        (machine peak FLOP/s + bytes/s, MFU at each prefill length)
    analysis.calibrate.load_calibration(path)  ->  Calibration
    CalibratedProfile(model_cfg, calibration)  ->  core.hardware.Profile
        plugged into ThroughputModel -> Router thresholds and
        PrfaasSimulator service times derive from THIS machine.

``serving.deployment.DeploymentConfig.calibration`` and
``launch.serve --calibration`` select it on the live path; the simulator
side is exercised by ``launch.serve --cross-validate`` (the replay builds
its ThroughputModel from the same Calibration).

The MFU saturation curve mfu(l) = mfu_max * l / (l + l_half) linearizes as
1/mfu = 1/mfu_max + (l_half/mfu_max) * (1/l), so the fit is a closed-form
least squares in (1/l, 1/mfu) space — no optimizer dependency.

    PYTHONPATH=src python -m repro.analysis.calibrate BENCH_kernel.json
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence, Tuple

import numpy as np

from repro.core.hardware import AnalyticProfile, CalibratedProfile, Calibration


def fit_mfu_curve(lens: Sequence[float],
                  mfus: Sequence[float]) -> Tuple[float, float]:
    """Least-squares fit of mfu(l) = mfu_max * l / (l + l_half).

    Returns (mfu_max, l_half), clamped to sane ranges (mfu_max in (0, 1],
    l_half >= 0) so a noisy sweep can't produce a pathological profile.
    """
    lens = np.asarray(lens, np.float64)
    mfus = np.asarray(mfus, np.float64)
    if lens.size < 2:
        raise ValueError("need >= 2 sweep points to fit the MFU curve")
    x = 1.0 / np.maximum(lens, 1.0)
    y = 1.0 / np.maximum(mfus, 1e-9)
    slope, intercept = np.polyfit(x, y, 1)
    intercept = max(float(intercept), 1.0)       # mfu_max <= 1
    mfu_max = 1.0 / intercept
    l_half = max(float(slope) / intercept, 0.0)
    return mfu_max, l_half


def calibration_from_points(points: Sequence[Tuple[float, float]],
                            peak_flops: float, mem_bw: float,
                            source: str = "kernel_bench") -> Calibration:
    """points: (prefill_length, measured_mfu) pairs (any order)."""
    pts = tuple(sorted((float(l), float(m)) for l, m in points))
    mfu_max, l_half = fit_mfu_curve([p[0] for p in pts],
                                    [p[1] for p in pts])
    return Calibration(peak_flops=float(peak_flops), mem_bw=float(mem_bw),
                       mfu_max=mfu_max, l_half=l_half, points=pts,
                       source=source)


def calibration_to_json(calib: Calibration) -> dict:
    return dataclasses.asdict(calib)


def calibration_from_json(obj: dict) -> Calibration:
    return Calibration(
        peak_flops=float(obj["peak_flops"]), mem_bw=float(obj["mem_bw"]),
        mfu_max=float(obj["mfu_max"]), l_half=float(obj["l_half"]),
        points=tuple((float(l), float(m)) for l, m in obj.get("points", ())),
        source=obj.get("source", "kernel_bench"))


def load_calibration(path: str) -> Calibration:
    """Read the ``calibration`` block of a BENCH_kernel.json (or a bare
    calibration dict)."""
    with open(path) as f:
        obj = json.load(f)
    return calibration_from_json(obj.get("calibration", obj))


def calibrated_profile(model_cfg, calibration: Calibration,
                       chips_per_instance: int = 1,
                       kv_dtype_bytes: int = 2) -> CalibratedProfile:
    return CalibratedProfile(model_cfg, calibration,
                             chips_per_instance=chips_per_instance,
                             kv_dtype_bytes=kv_dtype_bytes)


def main():
    import argparse

    from repro.configs import get_config

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench_json", help="BENCH_kernel.json from kernel_bench")
    ap.add_argument("--arch", default="kimi-linear-1t",
                    help="model config used for the T_prefill table")
    args = ap.parse_args()
    calib = load_calibration(args.bench_json)
    print(f"machine peak: {calib.peak_flops/1e9:.1f} GFLOP/s, "
          f"{calib.mem_bw/1e9:.1f} GB/s")
    print(f"fit: mfu_max={calib.mfu_max:.4f} l_half={calib.l_half:.1f}")
    prof = calibrated_profile(get_config(args.arch), calib)
    print(f"{'l':>8} {'mfu_meas':>9} {'mfu_fit':>8} {'T_prefill':>10}")
    for l, m in calib.points:
        fit = AnalyticProfile.mfu(prof, l)
        print(f"{int(l):8d} {m:9.4f} {fit:8.4f} {prof.t_prefill(l):9.3f}s")


if __name__ == "__main__":
    main()

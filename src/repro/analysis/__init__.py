from repro.analysis.calibrate import (calibrated_profile,
                                      calibration_from_points,
                                      fit_mfu_curve, load_calibration)
from repro.analysis.roofline import (RooflineReport, analyze,
                                     collective_bytes, model_flops_for)

__all__ = ["RooflineReport", "analyze", "collective_bytes",
           "model_flops_for", "fit_mfu_curve", "calibration_from_points",
           "calibrated_profile", "load_calibration"]

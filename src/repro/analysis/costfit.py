"""Trip-count-exact HLO cost accounting via unrolled probe compiles.

Problem: ``compiled.cost_analysis()`` counts ``while``-loop bodies ONCE
(verified empirically), so any scanned model (layers, microbatches, chunked
attention) under-reports FLOPs/bytes/collective-bytes by the trip counts.

Method: compile small probe variants with every scan UNROLLED (repeats
R in {1,2}, 2-3 sequence points, 2 batch points, microbatches in {1,2}) on
the production single-pod mesh, then least-squares fit the exact polynomial
structure

  F(B, S, R, mb) = B*(a0 + a1 S + a2 S^2)                    # embed/logits
                 + sum_g B*Rg*(b0 + b1 S + b2 S^2)           # per-layer
                 + sum_g (mb*Rg*c_g + Rg*d_g)                # param colls/opt
                 + mb*e + f                                  # per-ub/step const
  (per-device; sample work scales with B only — microbatching splits the
  same batch — while per-ub overheads like FSDP all-gathers scale with mb)

and evaluate it at full scale. Exact by construction: every HLO cost is a
polynomial in these variables (attention quadratic only via full-attn
layers; SWA-banded/linear mixers are linear in S; MoE capacity is linear in
tokens; optimizer/param-collective terms scale with R only).

Probe artifacts are cached as JSON (resumable).
"""
from __future__ import annotations

import dataclasses
import json
import os
from contextlib import contextmanager

if __name__ == "__main__":   # standalone probe runs need the 512-dev mesh
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import collective_bytes
from repro.configs import SHAPES, get_config
from repro.configs.base import GroupSpec, ModelConfig
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        params_shardings)
from repro.launch import input_specs as ispecs
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.models import chunked_attention as chk
from repro.models import linear_attention as lin_mod
from repro.models.perf_flags import VARIANTS, use_variant
from repro.training import TrainConfig, init_opt_state, make_train_step

PROBE_DIR = os.path.join(os.path.dirname(__file__),
                         "../../../benchmarks/artifacts/costfit")

METRICS = ("flops", "bytes", "coll")


@contextmanager
def unrolled():
    chk.UNROLL, lin_mod.UNROLL = True, True
    try:
        yield
    finally:
        chk.UNROLL, lin_mod.UNROLL = False, False


PROBE_POINT_OVERRIDES = {
    # 2-group hybrid: unrolled-grad probes at S=8192 compile for >30 min on
    # this container; the polynomial fit is exact at any 3 points
    "zamba2-1.2b": (1024, 2048, 3072),
}


def probe_points(cfg: ModelConfig):
    """Per-arch sequence points (must exceed SWA windows so the banded
    dispatch matches full scale; tiny for sLSTM whose scan unrolls per
    token)."""
    if cfg.name in PROBE_POINT_OVERRIDES:
        return PROBE_POINT_OVERRIDES[cfg.name]
    if any(getattr(b.mixer, "kind", "") == "slstm"
           for *_, b in cfg.iter_blocks()):
        return (128, 256, 384)
    windows = [b.mixer.window for *_, b in cfg.iter_blocks()
               if hasattr(b.mixer, "window") and b.mixer.window]
    if windows:
        w = max(windows)
        return (w + 1024, w + 2048, w + 4096)
    return (1024, 2048, 4096)


def _n_groups(cfg: ModelConfig) -> int:
    return len(cfg.groups) + len(cfg.encoder_groups or ())


def scaled_config(cfg: ModelConfig, r_vec):
    """Replace group repeats with r_vec (decoder groups then encoder)."""
    gs = list(cfg.groups)
    egs = list(cfg.encoder_groups or ())
    out_g = [dataclasses.replace(g, repeats=r_vec[i])
             for i, g in enumerate(gs)]
    out_e = [dataclasses.replace(g, repeats=r_vec[len(gs) + i])
             for i, g in enumerate(egs)]
    return dataclasses.replace(cfg, groups=tuple(out_g),
                               encoder_groups=tuple(out_e) or None)


def basis_row(kind: str, B, S, r_vec, mb):
    """Per-DEVICE cost basis.

    Sample-work terms scale with B only: microbatching splits the same
    global batch, so per-device FLOPs/bytes from token processing are
    mb-independent ((B/mb per ub) x (mb ubs) = B). mb enters only through
    per-microbatch overheads (e.g. FSDP param all-gathers run once per ub)
    and R through parameter-sized work (optimizer, param collectives).
    """
    row = [B, B * S, B * S * S]
    for r in r_vec:
        row += [B * r, B * S * r, B * S * S * r,
                mb * r, float(r)]
    row += [float(mb), 1.0]
    return np.array(row, np.float64)


def probe_compile(cfg: ModelConfig, kind: str, B: int, S: int, r_vec,
                  mb: int, variant: str = "baseline"):
    """Compile one unrolled probe on the single-pod mesh; return metrics."""
    from repro.launch.dryrun import VARIANT_KNOBS
    knobs = VARIANT_KNOBS.get(variant, VARIANT_KNOBS["baseline"])
    fsdp_flag = knobs["fsdp"]
    pcfg = scaled_config(cfg, r_vec)
    mesh = make_production_mesh(multi_pod=False)
    flags_name = variant if variant in VARIANTS else "baseline"
    with use_variant(flags_name), unrolled(), mesh:
        model = Model(pcfg, use_kernels=True, remat=True)
        model.unroll = True
        p_specs = ispecs.params_specs(pcfg)
        ps = params_shardings(p_specs, mesh, fsdp=fsdp_flag)
        if kind == "train":
            tc = TrainConfig(microbatches=mb, remat=True, unroll=True)
            step = make_train_step(model, tc)
            o_specs = jax.eval_shape(lambda p: init_opt_state(p, tc), p_specs)
            os_ = params_shardings(o_specs, mesh, fsdp=fsdp_flag)
            batch = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
            batch = _extras(pcfg, B, S, batch)
            bs = batch_shardings(batch, mesh)
            lowered = jax.jit(step, in_shardings=(ps, os_, bs),
                              donate_argnums=(0, 1)).lower(
                                  p_specs, o_specs, batch)
        elif kind == "prefill":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            batch = _extras(pcfg, B, S, batch)
            bs = batch_shardings(batch, mesh)
            out_caches = jax.eval_shape(model.prefill, p_specs, batch)[1]
            ocs = cache_shardings(out_caches, mesh)
            lowered = jax.jit(model.prefill, in_shardings=(ps, bs),
                              out_shardings=(None, ocs)).lower(p_specs,
                                                               batch)
        else:
            model_d = Model(pcfg, use_kernels=True)
            model_d.unroll = True
            enc_len = S if pcfg.encoder_groups is not None else 0
            caches = jax.eval_shape(
                lambda: model_d.init_cache(B, S + 64, enc_len=enc_len))
            cs = cache_shardings(caches, mesh, shard_seq_over_data=(B == 1),
                                 shard_headdim=knobs["headdim"])
            tok = jax.ShapeDtypeStruct((B,), jnp.int32)
            ts = batch_shardings({"t": tok}, mesh)["t"]
            lowered = jax.jit(model_d.decode_step,
                              in_shardings=(ps, ts, cs, ts),
                              donate_argnums=(2,)).lower(
                                  p_specs, tok, caches, tok)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]),
            "coll_detail": {k: coll[k] for k in coll}}


def _extras(cfg, B, S, batch):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.num_image_patches:
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_patches, cfg.d_model), dt)
    if cfg.encoder_groups is not None:
        batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.encoder_input_dim),
                                               dt)
    return batch


def probe_plan(cfg: ModelConfig, kind: str):
    """(B, S, r_vec, mb) probe grid."""
    ng = _n_groups(cfg)
    ss = probe_points(cfg)
    r_pats = [(1,) * ng]
    for g in range(ng):
        r_pats.append(tuple(2 if i == g else 1 for i in range(ng)))
    plan = []
    for rp in r_pats:
        for s in ss:
            plan.append((16, s, rp, 1))
        plan.append((32, ss[0], rp, 1))
    if kind == "train":
        # B=32 so each microbatch still divides the 16-way data axis
        plan.append((32, ss[0], r_pats[0], 2))
        plan.append((32, ss[0], r_pats[-1], 2))
    return plan


def nnls_fit(A, y):
    """Non-negative least squares via iterative active-set clamping.

    Every true cost coefficient is >= 0 (flops/bytes/collective terms are
    sums of work); unconstrained lstsq on an exactly-determined probe grid
    amplifies percent-level XLA fusion noise into sign-flipped coefficients
    that explode under 10-30x sequence extrapolation. Clamping negatives to
    zero and re-solving restricts the fit to the physical cone.
    """
    scale = np.maximum(np.abs(A).max(0), 1e-12)
    As = A / scale
    active = np.ones(A.shape[1], dtype=bool)
    c = np.zeros(A.shape[1])
    for _ in range(A.shape[1]):
        if not active.any():
            break
        sol, *_ = np.linalg.lstsq(As[:, active], y, rcond=None)
        if (sol >= -1e-12).all():
            c[active] = np.maximum(sol, 0.0)
            break
        idx = np.where(active)[0]
        active[idx[sol < 0]] = False
    else:
        c[active] = 0.0
    return c / scale


def fit_arch_kind(arch: str, kind: str, out_dir: str = PROBE_DIR,
                  verbose: bool = True, variant: str = "baseline"):
    """Run (or load) all probes for (arch, kind); fit coefficients."""
    os.makedirs(out_dir, exist_ok=True)
    cfg = get_config(arch)
    plan = probe_plan(cfg, kind)
    suffix = "" if variant == "baseline" else f"__{variant}"
    rows, ys = [], {m: [] for m in METRICS}
    for (B, S, rp, mb) in plan:
        tag = (f"{arch}__{kind}__B{B}_S{S}_R{'-'.join(map(str, rp))}_mb{mb}"
               f"{suffix}")
        path = os.path.join(out_dir, tag + ".json")
        if os.path.exists(path):
            with open(path) as f:
                m = json.load(f)
        else:
            if verbose:
                print(f"  [probe] {tag}", flush=True)
            m = probe_compile(cfg, kind, B, S, rp, mb, variant)
            with open(path, "w") as f:
                json.dump(m, f)
        rows.append(basis_row(kind, B, S, rp, mb))
        for k in METRICS:
            ys[k].append(m[k])
    A = np.stack(rows)
    coeffs = {}
    for k in METRICS:
        y = np.array(ys[k], np.float64)
        coeffs[k] = nnls_fit(A, y).tolist()
    fit = {"arch": arch, "kind": kind, "coeffs": coeffs, "variant": variant,
           "n_groups": _n_groups(cfg), "probe_points": probe_points(cfg)}
    with open(os.path.join(out_dir,
                           f"fit__{arch}__{kind}{suffix}.json"), "w") as f:
        json.dump(fit, f, indent=1)
    return fit


def predict(fit: dict, cfg: ModelConfig, kind: str, B: int, S: int,
            mb: int = 1) -> dict:
    """Evaluate the fitted cost model at full scale (global quantities,
    per-device program x 256 chips is already what probes measured —
    coefficients are per-device; multiply by chips for global)."""
    r_full = [g.repeats for g in cfg.groups] \
        + [g.repeats for g in (cfg.encoder_groups or ())]
    row = basis_row(kind, B, S, r_full, mb)
    out = {}
    for k in METRICS:
        out[k] = float(np.dot(np.array(fit["coeffs"][k]), row))
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--kind", default=None)
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    from repro.configs import ASSIGNED_ARCHS
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS + ["kimi-linear-1t"]
    kinds = [args.kind] if args.kind else ["train", "prefill", "decode"]
    for arch in archs:
        for kind in kinds:
            if arch == "kimi-linear-1t" and kind == "train":
                continue
            print(f"[fit] {arch} / {kind} / {args.variant}", flush=True)
            try:
                fit_arch_kind(arch, kind, variant=args.variant)
            except Exception as e:
                print(f"[FAIL] {arch}/{kind}: {type(e).__name__}: "
                      f"{str(e)[:200]}", flush=True)


if __name__ == "__main__":
    main()

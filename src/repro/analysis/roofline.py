"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the assignment:

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (per-device
program — multiplied back to global). collective_bytes is parsed from the
compiled HLO text: the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction (per-device
wire-byte approximation), times the device count for the global figure.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64"
                       r"|f64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective kind, from result shapes."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # match " = <shape> <kind>(" — result side only
            marker = f" {kind}("
            if marker in stripped and "=" in stripped:
                result_part = stripped.split(marker)[0]
                result_part = result_part.split("=", 1)[1]
                out[kind] += _shape_bytes(result_part)
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # global quantities
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    # terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    dominant: str = ""
    useful_flops_frac: float = 0.0
    roofline_frac: float = 0.0
    peak_memory_bytes: float = 0.0
    collective_detail: dict = field(default_factory=dict)

    def finalize(self):
        self.t_compute = self.hlo_flops / (self.chips * PEAK_FLOPS)
        self.t_memory = self.hlo_bytes / (self.chips * HBM_BW)
        self.t_collective = self.coll_bytes / (self.chips * ICI_BW)
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.dominant = max(terms, key=terms.get)
        self.useful_flops_frac = (self.model_flops / self.hlo_flops
                                  if self.hlo_flops else 0.0)
        # fraction of roofline: ideal time (compute at peak with useful
        # flops) over achievable time (max of the three terms)
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        achievable = max(terms.values())
        self.roofline_frac = ideal / achievable if achievable else 0.0
        return self

    def to_dict(self):
        return asdict(self)


def model_flops_for(cfg, shape_kind: str, seq_len: int, global_batch: int,
                    train: bool) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (fwd-only)."""
    n = cfg.active_param_count()
    tokens = seq_len * global_batch if shape_kind != "decode" else global_batch
    mult = 6.0 if train else 2.0
    return mult * n * tokens


def analyze(arch: str, shape_name: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, cfg, shape, kind: str,
            memory_stats=None) -> RooflineReport:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    rep = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops_dev * chips,
        hlo_bytes=bytes_dev * chips,
        coll_bytes=float(coll["total"]) * chips,
        model_flops=model_flops_for(cfg, kind, shape.seq_len,
                                    shape.global_batch, kind == "train"),
        collective_detail=coll,
        peak_memory_bytes=float(memory_stats or 0.0),
    )
    return rep.finalize()

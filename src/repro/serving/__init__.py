from repro.serving.api import Request, Response
from repro.serving.deployment import CrossDCDeployment, DeploymentConfig
from repro.serving.engine import (DecodeEngine, PrefillEngine,
                                  slice_request_cache, trim_request_cache)

__all__ = ["Request", "Response", "CrossDCDeployment", "DeploymentConfig",
           "DecodeEngine", "PrefillEngine", "slice_request_cache",
           "trim_request_cache"]

from repro.serving.api import Request, Response
from repro.serving.deployment import CrossDCDeployment, DeploymentConfig
from repro.serving.engine import (ChunkedPrefill, DecodeEngine,
                                  PrefillEngine, RegionScheduler,
                                  slice_request_cache, trim_request_cache)

__all__ = ["Request", "Response", "CrossDCDeployment", "DeploymentConfig",
           "ChunkedPrefill", "DecodeEngine", "PrefillEngine",
           "RegionScheduler", "slice_request_cache", "trim_request_cache"]

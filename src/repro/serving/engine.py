"""Continuously-batched region engine: ONE scheduler loop for prefill
chunks and decode blocks.

``RegionScheduler`` is the region's state machine.  Every request moves

    queued -> prefilling -> [chunk-interleaved] -> ready -> decoding
           -> retired

  * **queued** — routed requests wait in a FIFO prefill queue owned by the
    scheduler (grouped on dequeue into same-bucket batches, so the
    recompile-free bucket property is preserved).
  * **prefilling** — one bucketed ``PrefillEngine.prefill`` call per unit;
    prompts past ``max_bucket`` become a **chunk-interleaved** unit instead:
    a ``ChunkedPrefill`` that advances ONE fixed-shape chunk per scheduler
    tick, so a long prompt never blocks decode for more than one chunk.
  * **ready** — prefill finished (KV trimmed / shipped); the request waits
    for the next decode block boundary.
  * **decoding** — ``admit_many`` places every ready request into free
    slots in one jit'd call at the block boundary, then ``step_block``
    advances all active streams ``block_size`` tokens in one dispatch.
    Slots freed by retiring streams are refilled at the NEXT boundary —
    decode never drains to empty while work is queued.
  * **retired** — budget exhausted or KV-capacity wall (the latter flagged
    ``Response.truncated`` and counted, never a fake clean finish).

One ``tick()`` = admit ready -> advance one prefill unit -> one decode
block.  The old alternating regime (prefill a whole batch, admit, drain to
empty, repeat) exists only as the measured baseline in
``benchmarks.engine_bench``.

``PrefillEngine`` (PrfaaS / PD-P): pow2 length x batch buckets compile
exactly once; per-request ``lengths`` keep padded results EXACT; past
``max_bucket`` prompts run as fixed-shape ``ChunkedPrefill`` chunks (the
``q_offset`` flash path + linear-mixer state carry), with compiles bounded
per chunk index.  ``warmup()`` precompiles the bucket grid AND the chunk
programs for past-``max_bucket`` lengths (chunk-count exact).

``DecodeEngine`` (PD-D): slot-based batched decode.  ``admit_many`` writes
K caches in one jit'd scatter; ``step_block`` runs ``block_size`` steps of
``model.decode_step`` in one jit'd ``lax.scan`` with the next token fed
back on-device.  An RNG key is threaded through the scan: with
``temperature > 0`` tokens are sampled (optionally top-k) from a
deterministic per-block key; the default ``temperature=0`` takes the
argmax through the identical program and stays bit-identical to the
pre-sampling engine.  The engine also integrates slot-occupancy telemetry
(``slot_busy_s`` / ``decode_wall_s`` / ``tokens_out``) so schedulers and
benchmarks can report decode-slot occupancy and goodput.

Compile counts are observable (``PrefillEngine.compiles``,
``DecodeEngine.block_compiles``) so benchmarks and tests can assert the
zero-recompile property instead of trusting it.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model, prepare_decode_caches
from repro.models.kvcache import cache_num_bytes
from repro.serving.api import Request, Response

_SEQ_LEAVES = ("k", "v", "ckv", "kpe")


def next_pow2(n: int, lo: int = 1) -> int:
    v = max(int(lo), 1)
    while v < n:
        v *= 2
    return v


def _jit_cache_size(fn) -> Optional[int]:
    size = getattr(fn, "_cache_size", None)
    return size() if callable(size) else None


class PrefillEngine:
    """Bucketed (and, past ``max_bucket``, chunked) prefill.

    ``min_bucket``: smallest length bucket (pow2).  ``max_bucket``: when
    set, prompts padded beyond it are prefetched in fixed ``max_bucket``-
    token chunks (decoder-only models).  ``pad_batch``: round the batch
    dimension up to a power of two as well (exactly one compile per
    (batch-bucket, length-bucket) pair).
    """

    def __init__(self, model: Model, params, *, min_bucket: int = 32,
                 max_bucket: Optional[int] = None, pad_batch: bool = True):
        self.model = model
        self.params = params
        self.min_bucket = next_pow2(min_bucket)
        if max_bucket is not None and next_pow2(max_bucket) != max_bucket:
            raise ValueError("max_bucket must be a power of two")
        self.max_bucket = max_bucket
        self.pad_batch = pad_batch
        self._prefill = jax.jit(self._prefill_impl)
        self._chunk = jax.jit(self.model.prefill_chunk)
        self._carry_last = jax.jit(self._carry_last_impl)
        self._finish = jax.jit(self._finish_impl)
        self._shape_keys = set()         # fallback compile tracking
        self.calls = 0

    # ------------------------------------------------------------- jit fns
    def _prefill_impl(self, params, tokens, lengths):
        logits, caches = self.model.prefill(
            params, {"tokens": tokens, "lengths": lengths})
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    @staticmethod
    def _carry_last_impl(hidden, last, lengths, offset):
        """Fold a chunk's hidden states (B, C, d) into the (B, 1, d)
        last-valid-hidden carry: rows whose final prompt position falls in
        [offset, offset+C) take their row from this chunk."""
        C = hidden.shape[1]
        pos = lengths.astype(jnp.int32) - 1
        idx = jnp.clip(pos - offset, 0, C - 1)
        cand = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)
        in_chunk = (pos >= offset) & (pos < offset + C)
        return jnp.where(in_chunk[:, None, None], cand, last)

    def _finish_impl(self, params, hidden, lengths):
        logits = self.model.last_logits(params, hidden, lengths)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------- buckets
    def bucket_for(self, max_len: int) -> int:
        return next_pow2(max_len, self.min_bucket)

    def is_chunked(self, length: int) -> bool:
        """True when a prompt of ``length`` tokens runs as chunked prefill
        (its bucket exceeds ``max_bucket``)."""
        return (self.max_bucket is not None
                and self.bucket_for(int(length)) > self.max_bucket)

    @property
    def compiles(self) -> int:
        """Number of distinct compiled prefill programs (actual jit-cache
        entries when the runtime exposes them, tracked shape keys else)."""
        sizes = [_jit_cache_size(f)
                 for f in (self._prefill, self._chunk, self._carry_last,
                           self._finish)]
        if any(s is None for s in sizes):
            return len(self._shape_keys)
        return sum(sizes)

    def warmup(self, batch_sizes: Sequence[int], lengths: Sequence[int]):
        """Compile every (batch-bucket, length-bucket) pair up front — and,
        for engines with ``max_bucket`` set, the chunked-prefill chunk
        programs past it.  Chunk warmup is chunk-count exact: a length L
        past the max bucket warms ``ceil(L / max_bucket)`` chunk programs
        (each chunk index is its own program — the prior-cache operand
        grows with the index), which covers every shorter chunked prompt;
        the pre-fix code rounded L up to a power of two first, compiling
        chunk programs no real prompt of length <= L ever reaches."""
        shapes = set()
        for l in lengths:
            if self.is_chunked(l):
                C = self.max_bucket
                shapes.add(-(-int(l) // C) * C)     # ceil to chunk multiple
            else:
                shapes.add(self.bucket_for(l))
        for b in sorted({next_pow2(b) for b in batch_sizes}):
            for l in sorted(shapes):
                toks = np.zeros((b, l), np.int32)
                self.prefill(toks, np.full((b,), l, np.int32))

    def _pad(self, tokens: np.ndarray, lengths):
        """Pad a (B, S) prompt batch to its schedulable shape: pow2 length
        bucket (or chunk-multiple past ``max_bucket``) x pow2 batch bucket.
        Returns (toks, lens, B, chunked)."""
        tokens = np.asarray(tokens)
        B, S = tokens.shape
        if lengths is None:
            lengths = np.full((B,), S, np.int32)
        lengths = np.asarray(lengths, np.int32)
        max_len = int(lengths.max()) if B else S
        Sb = self.bucket_for(max_len)
        chunked = self.max_bucket is not None and Sb > self.max_bucket
        if chunked:
            C = self.max_bucket
            Sb = -(-max_len // C) * C                    # ceil to chunks
        Bb = next_pow2(B) if self.pad_batch else B
        toks = np.zeros((Bb, Sb), np.int32)
        toks[:B, :min(S, Sb)] = tokens[:, :Sb]
        lens = np.ones((Bb,), np.int32)                  # pad rows: 1 token
        lens[:B] = np.maximum(lengths, 1)
        return toks, lens, B, chunked

    # -------------------------------------------------------------- public
    def prefill(self, tokens: np.ndarray, lengths=None):
        """tokens: (B, S) right-padded prompts; lengths: (B,) valid counts
        (defaults to S).  Returns (first_token (B,), caches, wall_s).

        The returned caches are bucket-padded; slice a request out with
        ``trim_request_cache(caches, i, length)`` before shipping so wire
        bytes reflect the prompt, not the bucket.
        """
        t0 = time.perf_counter()
        toks, lens, B, chunked = self._pad(tokens, lengths)
        self.calls += 1
        if chunked:
            cp = ChunkedPrefill(self, toks, lens, B)
            while not cp.done:
                cp.step()
            first, caches = cp.finish()
        else:
            Bb, Sb = toks.shape
            self._shape_keys.add(("prefill", Bb, Sb))
            first, caches = self._prefill(self.params, jnp.asarray(toks),
                                          jnp.asarray(lens))
        jax.block_until_ready(first)
        return np.asarray(first)[:B], caches, time.perf_counter() - t0

    def start_chunked(self, tokens: np.ndarray, lengths=None
                      ) -> "ChunkedPrefill":
        """Begin an incremental chunked prefill the scheduler can advance
        one chunk at a time (``ChunkedPrefill.step`` between decode
        blocks).  The prompt batch must be past ``max_bucket``."""
        toks, lens, B, chunked = self._pad(tokens, lengths)
        if not chunked:
            raise ValueError("prompt fits a plain bucket; use prefill()")
        self.calls += 1
        return ChunkedPrefill(self, toks, lens, B)


class ChunkedPrefill:
    """One in-flight chunked prefill, schedulable a fixed-shape chunk at a
    time — the unit ``RegionScheduler`` interleaves between decode blocks.

    ``step()`` runs ONE ``max_bucket``-token chunk through
    ``model.prefill_chunk`` (attention chunks attend over the prior cache
    via ``q_offset``; linear mixers carry state) and folds the chunk's
    hidden states into the (B, 1, d) last-valid-hidden carry;
    ``finish()`` computes the first decode token from the carry.  Wall time
    is accumulated across steps so callers account the full prefill cost.
    """

    def __init__(self, eng: PrefillEngine, toks: np.ndarray,
                 lens: np.ndarray, n_valid: int):
        self.eng = eng
        self.toks = toks                     # (Bb, Sb), Sb = n_chunks * C
        self.lens = lens
        self.n_valid = n_valid               # real (unpadded) rows
        self.C = eng.max_bucket
        self.n_chunks = toks.shape[1] // self.C
        self.i = 0                           # next chunk index
        self.caches = None
        self._last = None                    # (Bb, 1, d) last-hidden carry
        self._lens_dev = jnp.asarray(lens)
        self.wall_s = 0.0

    @property
    def done(self) -> bool:
        return self.i >= self.n_chunks

    def step(self) -> bool:
        """Advance one chunk; returns True once all chunks have run."""
        t0 = time.perf_counter()
        eng, C, i = self.eng, self.C, self.i
        Bb = self.toks.shape[0]
        eng._shape_keys.add(("chunk", Bb, C, i))
        pos = np.broadcast_to(
            np.arange(i * C, (i + 1) * C, dtype=np.int32)[None], (Bb, C))
        chunk_lens = np.clip(self.lens - i * C, 0, C).astype(np.int32)
        h, self.caches = eng._chunk(
            eng.params,
            {"tokens": jnp.asarray(self.toks[:, i * C:(i + 1) * C]),
             "positions": jnp.asarray(pos),
             "lengths": jnp.asarray(chunk_lens)},
            self.caches)
        if self._last is None:
            self._last = jnp.zeros((Bb, 1, h.shape[-1]), h.dtype)
        self._last = eng._carry_last(h, self._last, self._lens_dev,
                                     jnp.int32(i * C))
        eng._shape_keys.add(("carry", Bb, C))
        self.i += 1
        if self.done:
            jax.block_until_ready(self._last)
        self.wall_s += time.perf_counter() - t0
        return self.done

    def finish(self):
        """Epilogue after the last ``step()``: returns (first_token
        (n_valid,) np.int32, caches)."""
        if not self.done:
            raise RuntimeError(f"chunked prefill at chunk {self.i}"
                               f"/{self.n_chunks}; not finished")
        t0 = time.perf_counter()
        Bb = self.toks.shape[0]
        self.eng._shape_keys.add(("finish", Bb))
        first = self.eng._finish(self.eng.params, self._last,
                                 jnp.ones((Bb,), jnp.int32))
        jax.block_until_ready(first)
        self.wall_s += time.perf_counter() - t0
        return np.asarray(first)[:self.n_valid], self.caches


class DecodeEngine:
    """Slot-based continuous batching decode cluster (see module doc)."""

    def __init__(self, model: Model, params, num_slots: int, capacity: int,
                 block_size: int = 8, *, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.capacity = capacity
        self.block_size = max(1, int(block_size))
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._key = jax.random.PRNGKey(int(seed))
        self._blocks = 0               # step_block dispatch counter (RNG)
        self.caches = jax.jit(
            lambda: model.init_cache(num_slots, capacity))()
        self.lengths = np.zeros((num_slots,), np.int32)
        self.tokens = np.zeros((num_slots,), np.int32)
        self.active = np.zeros((num_slots,), bool)
        self.budget = np.zeros((num_slots,), np.int32)
        self.slot_req: List[Optional[int]] = [None] * num_slots
        self.outputs: Dict[int, Response] = {}
        self.truncations = 0
        # occupancy telemetry: wall seconds spent inside step_block, the
        # same seconds weighted by #active slots, and tokens emitted —
        # occupancy = slot_busy_s / (num_slots * makespan), goodput =
        # tokens_out / makespan for whatever makespan the caller measures
        self.decode_wall_s = 0.0
        self.slot_busy_s = 0.0
        self.tokens_out = 0
        self._free = deque(range(num_slots))
        self._step = jax.jit(model.decode_step, donate_argnums=(2,))
        self._block = jax.jit(self._block_impl, donate_argnums=(2,))
        self._place_many = jax.jit(self._place_many_impl, donate_argnums=(0,))

    # ---------------------------------------------------------------- admit
    @staticmethod
    def _place_many_impl(caches, payloads, slots):
        """Write K request caches into their slots in ONE jit'd call.

        ``payloads``: tuple of K prepared caches (slot axis = 1, size 1);
        ``slots``: (K,) int32.  Lowered as K in-place slot updates on the
        donated buffers — one dispatch total, vs the old one-jit-call-per-
        request admission."""
        def place(buf, *news):
            for j, new in enumerate(news):
                buf = jax.lax.dynamic_update_slice_in_dim(
                    buf, new.astype(buf.dtype), slots[j], axis=1)
            return buf

        return jax.tree.map(place, caches, *payloads)

    def free_slots(self) -> List[int]:
        return list(self._free)

    def admit(self, req: Request, first_token: int, one_cache,
              prompt_len: int) -> bool:
        """Place one request's shipped KV into a free slot."""
        return self.admit_many([(req, first_token, one_cache,
                                 prompt_len)]) == 1

    def admit_many(self, entries: Sequence[Tuple]) -> int:
        """entries: [(req, first_token, one_cache, prompt_len), ...].
        Admits up to the number of free slots (in order); returns the
        number admitted.  One jit'd scatter regardless of K; K is padded to
        a power of two (repeating the last entry) to bound compiles."""
        n = min(len(entries), len(self._free))
        if n == 0:
            return 0
        take = list(entries[:n])
        slots = [self._free.popleft() for _ in range(n)]
        placed = [prepare_decode_caches(self.model.cfg, c, self.capacity)
                  for (_, _, c, _) in take]
        K = next_pow2(n)
        pad_slots = slots + [slots[-1]] * (K - n)   # duplicate writes of the
        placed += [placed[-1]] * (K - n)            # same payload: harmless
        self.caches = self._place_many(self.caches, tuple(placed),
                                       jnp.asarray(pad_slots, jnp.int32))
        for slot, (req, first_token, _, prompt_len) in zip(slots, take):
            self.lengths[slot] = prompt_len
            self.tokens[slot] = first_token
            self.active[slot] = True
            self.budget[slot] = req.max_new_tokens
            self.slot_req[slot] = req.rid
            self.outputs[req.rid] = Response(req.rid, [int(first_token)])
        return n

    # ----------------------------------------------------------------- step
    def _retire(self, slot: int):
        rid = self.slot_req[slot]
        resp = self.outputs[rid]
        resp.finished = True
        # at the KV-capacity wall with budget remaining: NOT a clean finish
        truncated = (self.lengths[slot] >= self.capacity - 1
                     and self.budget[slot] > 0)
        resp.truncated = bool(truncated)
        self.truncations += int(truncated)
        self.active[slot] = False
        self.slot_req[slot] = None
        self._free.append(slot)

    def step(self):
        """One decode iteration for all active slots (one host round-trip
        per token — the measured baseline for ``step_block``). Returns
        #active."""
        if not self.active.any():
            return 0
        logits, self.caches = self._step(
            self.params, jnp.asarray(self.tokens),
            self.caches, jnp.asarray(self.lengths))
        nxt = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        for i in range(self.num_slots):
            if not self.active[i]:
                continue
            rid = self.slot_req[i]
            self.outputs[rid].output_tokens.append(int(nxt[i]))
            self.lengths[i] += 1
            self.tokens[i] = nxt[i]
            self.budget[i] -= 1
            if self.budget[i] <= 0 or self.lengths[i] >= self.capacity - 1:
                self._retire(i)
        return int(self.active.sum())

    def _select(self, logits, key):
        """Next-token rule traced into the block program.  ``temperature``
        and ``top_k`` are Python-static, so the default greedy engine traces
        the exact pre-sampling argmax graph (bit-identical tokens); with
        ``temperature > 0`` tokens are sampled, optionally from the top-k
        renormalized logits (``top_k=1`` degenerates to greedy)."""
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits = logits / jnp.float32(self.temperature)
        if self.top_k > 0:
            kth = jax.lax.top_k(logits, self.top_k)[0][..., -1:]
            logits = jnp.where(logits < kth, -jnp.inf, logits)
        return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)

    def _block_impl(self, params, tokens, caches, lengths, key):
        """``block_size`` decode steps fully on-device; the RNG key rides
        the scan carry, split once per step."""
        def body(carry, _):
            toks, caches, lens, key = carry
            key, sub = jax.random.split(key)
            logits, caches = self.model.decode_step(params, toks, caches,
                                                    lens)
            nxt = self._select(logits, sub)
            return (nxt, caches, lens + 1, key), nxt

        (_, caches, _, _), toks = jax.lax.scan(
            body, (tokens, caches, lengths, key), None,
            length=self.block_size)
        return toks, caches

    @property
    def block_compiles(self) -> Optional[int]:
        return _jit_cache_size(self._block)

    def step_block(self):
        """Advance every active stream by up to ``block_size`` tokens with
        ONE device dispatch and one host sync. Returns #active.

        Inactive slots decode garbage into their (about-to-be-overwritten)
        cache region; streams that hit their budget or the capacity wall
        mid-block have the surplus tokens discarded on the host — identical
        retirement semantics to ``step()``."""
        if not self.active.any():
            return 0
        t0 = time.perf_counter()
        key = jax.random.fold_in(self._key, self._blocks)
        self._blocks += 1
        toks, self.caches = self._block(
            self.params, jnp.asarray(self.tokens),
            self.caches, jnp.asarray(self.lengths), key)
        toks = np.asarray(toks)                       # (block, num_slots)
        idx = np.where(self.active)[0]
        wall = time.perf_counter() - t0
        self.decode_wall_s += wall
        self.slot_busy_s += len(idx) * wall
        # tokens a slot emits before retiring, exactly as step() would:
        # min(budget, room to capacity-1) per block — floored at 1 because
        # step() appends once BEFORE its retirement check, so a slot
        # admitted at/over the capacity wall still emits one token
        valid = np.clip(
            np.minimum(self.budget[idx],
                       self.capacity - 1 - self.lengths[idx]),
            1, self.block_size).astype(int)
        self.tokens_out += int(valid.sum())
        self.lengths[idx] += valid
        self.budget[idx] -= valid
        self.tokens[idx] = toks[valid - 1, idx]
        done = (self.budget[idx] <= 0) | \
               (self.lengths[idx] >= self.capacity - 1)
        for j, i in enumerate(idx):
            out = self.outputs[self.slot_req[i]].output_tokens
            out.extend(int(t) for t in toks[:valid[j], i])
            if done[j]:
                self._retire(i)
        return int(self.active.sum())

    def run_until_drained(self, max_steps: int = 10_000):
        """Drain all active streams via ``step_block`` (``max_steps`` counts
        blocks)."""
        steps = 0
        while self.active.any() and steps < max_steps:
            self.step_block()
            steps += 1
        return steps


class RegionScheduler:
    """One continuously-batched loop per region: owns the prefill queue and
    the decode slot pool together (module doc has the state machine).

    ``submit`` enqueues a routed request, optionally naming which
    ``PrefillEngine`` runs it — deployments share one PrfaaS engine and one
    PD engine across regions, so the engine is per-request state, not
    per-scheduler.  ``tick()`` is one scheduler iteration:

      1. admit every READY request into free decode slots in one
         ``admit_many`` scatter — each tick IS a decode block boundary;
      2. advance ONE prefill unit: the next fixed-shape chunk of an
         in-flight ``ChunkedPrefill``, or a freshly formed same-(engine,
         bucket) FIFO batch run in a single bucketed ``prefill`` call;
      3. one ``step_block`` over all active decode slots.

    Finished units pass through ``on_unit_done`` (when set) so callers can
    do trim/wire/metrics accounting and hand back admit entries; the
    default trims each request's cache out of the bucket-padded batch.
    Starvation is impossible by construction — ``_admit`` runs FIFO at
    every boundary — and ``max_admit_wait`` (boundaries a request spent
    ready-but-unadmitted) makes that assertable instead of trusted.
    """

    def __init__(self, prefill: PrefillEngine, decode: DecodeEngine, *,
                 max_prefill_batch: int = 8, on_unit_done=None):
        self.prefill = prefill
        self.decode = decode
        self.max_prefill_batch = max(1, int(max_prefill_batch))
        self.on_unit_done = on_unit_done
        self.queue: deque = deque()          # (req, engine) — FIFO
        self.ready: deque = deque()          # (admit entry, ready boundary)
        self._inflight = None                # (ChunkedPrefill, reqs, lens)
        self.boundaries = 0                  # ticks == block boundaries
        self.max_admit_wait = 0
        self.starved_boundaries = 0          # ready waited w/ free slots
        self.wall_s = 0.0                    # scheduler makespan

    # ------------------------------------------------------------- intake
    def submit(self, req: Request, engine: Optional[PrefillEngine] = None):
        """Enqueue one routed request (state: queued)."""
        self.queue.append((req, engine if engine is not None
                           else self.prefill))

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.ready or self._inflight is not None
                    or self.decode.active.any())

    # -------------------------------------------------------------- phases
    def _admit(self) -> int:
        """Block boundary: move ready -> decoding, as many as slots allow."""
        if not self.ready:
            return 0
        n = self.decode.admit_many([e for e, _ in self.ready])
        for _ in range(n):
            _, born = self.ready.popleft()
            self.max_admit_wait = max(self.max_admit_wait,
                                      self.boundaries - born)
        # the starvation guard: after a boundary admit, a request may only
        # remain ready because every slot is occupied
        if self.ready and self.decode.free_slots():
            self.starved_boundaries += 1
        return n

    def _finish_unit(self, engine, reqs, lengths, first, caches,
                     wall_s: float):
        if self.on_unit_done is not None:
            entries = self.on_unit_done(engine, reqs, lengths, first,
                                        caches, wall_s)
        else:
            entries = [(r, int(first[i]),
                        trim_request_cache(caches, i, int(lengths[i])),
                        int(lengths[i]))
                       for i, r in enumerate(reqs)]
        for e in entries:
            self.ready.append((e, self.boundaries))

    def _prefill_one(self):
        """Advance exactly one prefill unit: a chunk of the in-flight
        chunked prefill, or one bucketed batch from the queue head."""
        if self._inflight is not None:
            cp, reqs, lengths = self._inflight
            cp.step()
            if cp.done:
                self._inflight = None
                first, caches = cp.finish()
                self._finish_unit(cp.eng, reqs, lengths, first, caches,
                                  cp.wall_s)
            return
        if not self.queue:
            return
        req0, e0 = self.queue[0]
        if e0.is_chunked(len(req0.tokens)):
            # long prompt: becomes the chunk-interleaved unit (batch of 1 —
            # one fixed-shape chunk advances per tick, decode keeps running)
            self.queue.popleft()
            lengths = np.array([len(req0.tokens)], np.int32)
            toks = np.asarray(req0.tokens, np.int32)[None, :]
            self._inflight = (e0.start_chunked(toks, lengths), [req0],
                              lengths)
            self._prefill_one()              # run its first chunk this tick
            return
        # form one same-(engine, bucket) unit in FIFO order
        bucket = e0.bucket_for(len(req0.tokens))
        unit: List[Request] = []
        rest: deque = deque()
        while self.queue:
            r, e = self.queue.popleft()
            if (len(unit) < self.max_prefill_batch and e is e0
                    and not e.is_chunked(len(r.tokens))
                    and e.bucket_for(len(r.tokens)) == bucket):
                unit.append(r)
            else:
                rest.append((r, e))
        self.queue = rest
        lengths = np.array([len(r.tokens) for r in unit], np.int32)
        toks = np.zeros((len(unit), int(lengths.max())), np.int32)
        for i, r in enumerate(unit):
            toks[i, :len(r.tokens)] = r.tokens
        first, caches, wall = e0.prefill(toks, lengths)
        self._finish_unit(e0, unit, lengths, first, caches, wall)

    # ---------------------------------------------------------------- loop
    def tick(self):
        """One scheduler iteration: admit -> one prefill unit -> one decode
        block.  Returns #active decode slots after the block."""
        t0 = time.perf_counter()
        self._admit()
        self._prefill_one()
        n = self.decode.step_block()
        self.boundaries += 1
        self.wall_s += time.perf_counter() - t0
        return n

    def run(self, max_ticks: int = 100_000) -> int:
        """Tick until every submitted request has retired."""
        ticks = 0
        while self.has_work and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks

    # ------------------------------------------------------------- metrics
    def occupancy(self) -> float:
        """Fraction of decode-slot-time occupied over the scheduler's own
        makespan (prefill gaps count against it — that is the point)."""
        denom = self.decode.num_slots * self.wall_s
        return self.decode.slot_busy_s / denom if denom > 0 else 0.0

    def goodput_tok_s(self) -> float:
        return (self.decode.tokens_out / self.wall_s
                if self.wall_s > 0 else 0.0)

    def stats(self) -> dict:
        return {"boundaries": self.boundaries,
                "max_admit_wait": self.max_admit_wait,
                "starved_boundaries": self.starved_boundaries,
                "occupancy": self.occupancy(),
                "goodput_tok_s": self.goodput_tok_s(),
                "tokens_out": self.decode.tokens_out,
                "truncations": self.decode.truncations}


def slice_request_cache(caches, idx: int):
    """Extract request ``idx`` from a batched prefill cache -> batch of 1."""
    return jax.tree.map(lambda x: x[:, idx:idx + 1], caches)


def trim_request_cache(caches, idx: int, length: int):
    """Extract request ``idx`` from a batched (bucket-padded) prefill cache
    and trim sequence-major leaves (k/v/ckv/kpe) to ``length`` — the bytes
    that actually need to cross the wire.  O(1) state leaves pass through.
    (Decoder-only caches; cross-attention caches keep their encoder len.)"""

    def cut(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        leaf = leaf[:, idx:idx + 1]
        if name in _SEQ_LEAVES and "cross" not in jax.tree_util.keystr(path):
            leaf = leaf[:, :, :min(length, leaf.shape[2])]
        return leaf

    return jax.tree_util.tree_map_with_path(cut, caches)

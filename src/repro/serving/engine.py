"""Continuously-batched region engine: ONE scheduler loop for prefill
chunks and decode blocks.

``RegionScheduler`` is the region's state machine.  Every request moves

    queued -> prefilling -> [chunk-interleaved] -> ready -> decoding
           -> retired

  * **queued** — routed requests wait in a FIFO prefill queue owned by the
    scheduler (grouped on dequeue into same-bucket batches, so the
    recompile-free bucket property is preserved).
  * **prefilling** — one bucketed ``PrefillEngine.prefill`` call per unit;
    prompts past ``max_bucket`` become a **chunk-interleaved** unit instead:
    a ``ChunkedPrefill`` that advances ONE fixed-shape chunk per scheduler
    tick, so a long prompt never blocks decode for more than one chunk.
  * **ready** — prefill finished (KV trimmed / shipped); the request waits
    for the next decode block boundary.
  * **decoding** — ``admit_many`` places every ready request into free
    slots in one jit'd call at the block boundary, then ``step_block``
    advances all active streams ``block_size`` tokens in one dispatch.
    Slots freed by retiring streams are refilled at the NEXT boundary —
    decode never drains to empty while work is queued.
  * **retired** — budget exhausted or KV-capacity wall (the latter flagged
    ``Response.truncated`` and counted, never a fake clean finish).

One ``tick()`` = admit ready -> advance one prefill unit -> one decode
block.  The old alternating regime (prefill a whole batch, admit, drain to
empty, repeat) exists only as the measured baseline in
``benchmarks.engine_bench``.

``PrefillEngine`` (PrfaaS / PD-P): pow2 length x batch buckets compile
exactly once; per-request ``lengths`` keep padded results EXACT; past
``max_bucket`` prompts run as fixed-shape ``ChunkedPrefill`` chunks (the
``q_offset`` flash path + linear-mixer state carry), with compiles bounded
per chunk index.  ``warmup()`` precompiles the bucket grid AND the chunk
programs for past-``max_bucket`` lengths (chunk-count exact).

``DecodeEngine`` (PD-D): slot-based batched decode.  ``admit_many`` writes
K caches in one jit'd scatter; ``step_block`` runs ``block_size`` steps of
``model.decode_step`` in one jit'd ``lax.scan`` with the next token fed
back on-device.  An RNG key is threaded through the scan: with
``temperature > 0`` tokens are sampled (optionally top-k) from a
deterministic per-block key; the default ``temperature=0`` takes the
argmax through the identical program and stays bit-identical to the
pre-sampling engine.  The engine also integrates slot-occupancy telemetry
(``slot_busy_s`` / ``decode_wall_s`` / ``tokens_out``) so schedulers and
benchmarks can report decode-slot occupancy and goodput.

Compile counts are observable (``PrefillEngine.compiles``,
``DecodeEngine.block_compiles``) so benchmarks and tests can assert the
zero-recompile property instead of trusting it.

**Paged KV (``DecodeEngine(..., paged=True)``)** replaces the dense
per-slot buffers with the ``core.blockpool.BlockPool`` as the real device
cache layout (``models/paged.py``):

  * full-attn k/v live in shared page pools ``(R, Hkv, P, T, D)`` and MLA
    latents in ``(R, P, T, rank)``, where ``T`` is the pool's block size
    and ``P`` its page count + 1 sink page; linear/SSM state stays per-slot.
  * each slot addresses its pages through two host-side int32 block tables:
    ``seq`` ``(num_slots, capacity/T)`` for append-only full/MLA layers and
    ``ring`` ``(num_slots, W_buf/T)`` for SWA ring buffers.
  * ``admit_many`` writes only the request's *pages* in one jit'd scatter
    (no capacity-sized zero padding, no monolithic slot copy); a prefix hit
    maps the matched pages read-only into the slot's table head via
    BlockPool ref-counts instead of rewriting them.  ``step_block`` reads
    and appends through the tables (``kernels/paged_decode_attn.py``);
    retiring a slot releases its refs — prompt pages registered in the
    prefix cache stay LRU-resident, decode tail pages free immediately.

  Prefer ``paged_kv=False`` (the default, dense layout) when the arch has
  encoder/cross-attention blocks (unsupported), when slots are few and
  long-lived (dense buffers have no table indirection overhead), or when
  byte-identical legacy traces matter; paged pays off under prefix reuse
  and many short concurrent streams, where resident KV bytes track the
  *used* pages instead of ``num_slots x capacity``.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockpool import PREFIX, BlockPool
from repro.models import Model, prepare_decode_caches
from repro.models import paged as paged_mod
from repro.models.kvcache import cache_num_bytes, quantize_cache_for_wire
from repro.serving.api import Request, Response

_SEQ_LEAVES = ("k", "v", "ckv", "kpe")


def _dequant_pages(pg, dtype):
    """Admission page tensor -> pool dtype.  Wire-form pages ({"q": int8,
    "scale": (n_pages,) f32}) dequantize here, INSIDE the page-scatter
    program — fusing what used to be a separate full-cache
    ``dequantize_cache_from_wire`` pass before admission.  The op chain
    (int8 -> f32, multiply by the f32-upcast stored scale, cast to the pool
    dtype) is exactly the eager path's, so pool bytes are identical."""
    if isinstance(pg, dict):
        q, scale = pg["q"], pg["scale"]
        shape = [1] * q.ndim
        shape[2 if q.ndim == 5 else 1] = scale.shape[0]
        return (q.astype(jnp.float32) * scale.reshape(shape)).astype(dtype)
    return pg.astype(dtype)


def next_pow2(n: int, lo: int = 1) -> int:
    v = max(int(lo), 1)
    while v < n:
        v *= 2
    return v


def _jit_cache_size(fn) -> Optional[int]:
    size = getattr(fn, "_cache_size", None)
    return size() if callable(size) else None


class PrefillEngine:
    """Bucketed (and, past ``max_bucket``, chunked) prefill.

    ``min_bucket``: smallest length bucket (pow2).  ``max_bucket``: when
    set, prompts padded beyond it are prefetched in fixed ``max_bucket``-
    token chunks (decoder-only models).  ``pad_batch``: round the batch
    dimension up to a power of two as well (exactly one compile per
    (batch-bucket, length-bucket) pair).
    """

    def __init__(self, model: Model, params, *, min_bucket: int = 32,
                 max_bucket: Optional[int] = None, pad_batch: bool = True):
        self.model = model
        self.params = params
        self.min_bucket = next_pow2(min_bucket)
        if max_bucket is not None and next_pow2(max_bucket) != max_bucket:
            raise ValueError("max_bucket must be a power of two")
        self.max_bucket = max_bucket
        self.pad_batch = pad_batch
        self._prefill = jax.jit(self._prefill_impl)
        self._chunk = jax.jit(self.model.prefill_chunk)
        self._carry_last = jax.jit(self._carry_last_impl)
        self._finish = jax.jit(self._finish_impl)
        self._shape_keys = set()         # fallback compile tracking
        self.calls = 0
        self.tokens_prefilled = 0        # valid prompt tokens computed

    # ------------------------------------------------------------- jit fns
    def _prefill_impl(self, params, tokens, lengths):
        logits, caches = self.model.prefill(
            params, {"tokens": tokens, "lengths": lengths})
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    @staticmethod
    def _carry_last_impl(hidden, last, lengths, offset):
        """Fold a chunk's hidden states (B, C, d) into the (B, 1, d)
        last-valid-hidden carry: rows whose final prompt position falls in
        [offset, offset+C) take their row from this chunk."""
        C = hidden.shape[1]
        pos = lengths.astype(jnp.int32) - 1
        idx = jnp.clip(pos - offset, 0, C - 1)
        cand = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)
        in_chunk = (pos >= offset) & (pos < offset + C)
        return jnp.where(in_chunk[:, None, None], cand, last)

    def _finish_impl(self, params, hidden, lengths):
        logits = self.model.last_logits(params, hidden, lengths)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------- buckets
    def bucket_for(self, max_len: int) -> int:
        return next_pow2(max_len, self.min_bucket)

    def is_chunked(self, length: int) -> bool:
        """True when a prompt of ``length`` tokens runs as chunked prefill
        (its bucket exceeds ``max_bucket``)."""
        return (self.max_bucket is not None
                and self.bucket_for(int(length)) > self.max_bucket)

    @property
    def compiles(self) -> int:
        """Number of distinct compiled prefill programs (actual jit-cache
        entries when the runtime exposes them, tracked shape keys else)."""
        sizes = [_jit_cache_size(f)
                 for f in (self._prefill, self._chunk, self._carry_last,
                           self._finish)]
        if any(s is None for s in sizes):
            return len(self._shape_keys)
        return sum(sizes)

    def warmup(self, batch_sizes: Sequence[int], lengths: Sequence[int],
               decode: Optional["DecodeEngine"] = None):
        """Compile every (batch-bucket, length-bucket) pair up front — and,
        for engines with ``max_bucket`` set, the chunked-prefill chunk
        programs past it.  Chunk warmup is chunk-count exact: a length L
        past the max bucket warms ``ceil(L / max_bucket)`` chunk programs
        (each chunk index is its own program — the prior-cache operand
        grows with the index), which covers every shorter chunked prompt;
        the pre-fix code rounded L up to a power of two first, compiling
        chunk programs no real prompt of length <= L ever reaches.

        Pass the region's ``decode`` engine to also warm its paged
        admission programs (the page-write scatter per pow2 page-count
        bucket) for the same traffic shape — a no-op for dense engines."""
        shapes = set()
        for l in lengths:
            if self.is_chunked(l):
                C = self.max_bucket
                shapes.add(-(-int(l) // C) * C)     # ceil to chunk multiple
            else:
                shapes.add(self.bucket_for(l))
        for b in sorted({next_pow2(b) for b in batch_sizes}):
            for l in sorted(shapes):
                toks = np.zeros((b, l), np.int32)
                self.prefill(toks, np.full((b,), l, np.int32))
        if decode is not None and getattr(decode, "paged", False):
            decode.warmup_admission(batch_sizes, lengths)
        if decode is not None:
            decode.warmup_block()

    def _pad(self, tokens: np.ndarray, lengths):
        """Pad a (B, S) prompt batch to its schedulable shape: pow2 length
        bucket (or chunk-multiple past ``max_bucket``) x pow2 batch bucket.
        Returns (toks, lens, B, chunked)."""
        tokens = np.asarray(tokens)
        B, S = tokens.shape
        if lengths is None:
            lengths = np.full((B,), S, np.int32)
        lengths = np.asarray(lengths, np.int32)
        max_len = int(lengths.max()) if B else S
        Sb = self.bucket_for(max_len)
        chunked = self.max_bucket is not None and Sb > self.max_bucket
        if chunked:
            C = self.max_bucket
            Sb = -(-max_len // C) * C                    # ceil to chunks
        Bb = next_pow2(B) if self.pad_batch else B
        toks = np.zeros((Bb, Sb), np.int32)
        toks[:B, :min(S, Sb)] = tokens[:, :Sb]
        lens = np.ones((Bb,), np.int32)                  # pad rows: 1 token
        lens[:B] = np.maximum(lengths, 1)
        return toks, lens, B, chunked

    # -------------------------------------------------------------- public
    def prefill(self, tokens: np.ndarray, lengths=None):
        """tokens: (B, S) right-padded prompts; lengths: (B,) valid counts
        (defaults to S).  Returns (first_token (B,), caches, wall_s).

        The returned caches are bucket-padded; slice a request out with
        ``trim_request_cache(caches, i, length)`` before shipping so wire
        bytes reflect the prompt, not the bucket.
        """
        t0 = time.perf_counter()
        toks, lens, B, chunked = self._pad(tokens, lengths)
        self.calls += 1
        if chunked:
            cp = ChunkedPrefill(self, toks, lens, B)
            while not cp.done:
                cp.step()
            first, caches = cp.finish()
        else:
            Bb, Sb = toks.shape
            self._shape_keys.add(("prefill", Bb, Sb))
            first, caches = self._prefill(self.params, jnp.asarray(toks),
                                          jnp.asarray(lens))
            self.tokens_prefilled += int(lens[:B].sum())
        jax.block_until_ready(first)
        return np.asarray(first)[:B], caches, time.perf_counter() - t0

    def start_chunked(self, tokens: np.ndarray, lengths=None
                      ) -> "ChunkedPrefill":
        """Begin an incremental chunked prefill the scheduler can advance
        one chunk at a time (``ChunkedPrefill.step`` between decode
        blocks).  The prompt batch must be past ``max_bucket``."""
        toks, lens, B, chunked = self._pad(tokens, lengths)
        if not chunked:
            raise ValueError("prompt fits a plain bucket; use prefill()")
        self.calls += 1
        return ChunkedPrefill(self, toks, lens, B)

    def start_suffix(self, tokens, prior_caches, cached_len: int
                     ) -> "ChunkedPrefill":
        """Suffix-only prefill for a device prefix hit: compute tokens
        [cached_len, L) as fixed-shape chunks over the prior caches
        (positions offset by ``cached_len``; the chunked-prefill
        ``q_offset`` path masks exactly as a full prefill would, so the
        resulting tokens and merged caches are identical — only the
        cached-prefix FLOPs are skipped).  Batch of 1, scheduled like a
        chunked unit."""
        full = np.asarray(tokens, np.int32).reshape(-1)
        suffix = full[cached_len:]
        n_suffix = int(suffix.shape[0])
        if n_suffix <= 0:
            raise ValueError("suffix prefill needs >= 1 uncached token")
        C = self.bucket_for(n_suffix)
        if self.max_bucket is not None:
            C = min(C, self.max_bucket)
        n_chunks = -(-n_suffix // C)
        toks = np.zeros((1, n_chunks * C), np.int32)
        toks[0, :n_suffix] = suffix
        self.calls += 1
        return ChunkedPrefill(self, toks, np.array([n_suffix], np.int32), 1,
                              caches=prior_caches, pos_offset=cached_len,
                              chunk=C)


class ChunkedPrefill:
    """One in-flight chunked prefill, schedulable a fixed-shape chunk at a
    time — the unit ``RegionScheduler`` interleaves between decode blocks.

    ``step()`` runs ONE ``max_bucket``-token chunk through
    ``model.prefill_chunk`` (attention chunks attend over the prior cache
    via ``q_offset``; linear mixers carry state) and folds the chunk's
    hidden states into the (B, 1, d) last-valid-hidden carry;
    ``finish()`` computes the first decode token from the carry.  Wall time
    is accumulated across steps so callers account the full prefill cost.
    """

    def __init__(self, eng: PrefillEngine, toks: np.ndarray,
                 lens: np.ndarray, n_valid: int, *, caches=None,
                 pos_offset: int = 0, chunk: Optional[int] = None):
        self.eng = eng
        self.toks = toks                     # (Bb, Sb), Sb = n_chunks * C
        self.lens = lens
        self.n_valid = n_valid               # real (unpadded) rows
        self.C = eng.max_bucket if chunk is None else chunk
        self.n_chunks = toks.shape[1] // self.C
        self.i = 0                           # next chunk index
        # suffix-prefill mode: ``caches`` already cover [0, pos_offset) and
        # the chunk positions (RoPE phases, causal masks) start there;
        # ``lens`` then count SUFFIX tokens, not the full prompt
        self.caches = caches
        self.off = int(pos_offset)
        # table-direct suffix prefill: the prior caches carry pool page
        # leaves + block tables ("pk"/"pv"/"tbl", see paged.build_prior)
        # instead of a gathered dense prior — a distinct chunk program
        self.table_direct = caches is not None and any(
            getattr(p[-1], "key", None) == "pk"
            for p, _ in jax.tree_util.tree_flatten_with_path(caches)[0])
        self._last = None                    # (Bb, 1, d) last-hidden carry
        self._lens_dev = jnp.asarray(lens)
        self.wall_s = 0.0

    @property
    def done(self) -> bool:
        return self.i >= self.n_chunks

    def step(self) -> bool:
        """Advance one chunk; returns True once all chunks have run."""
        t0 = time.perf_counter()
        eng, C, i = self.eng, self.C, self.i
        Bb = self.toks.shape[0]
        eng._shape_keys.add(("chunk", Bb, C, i, self.off)
                            + (("paged",) if self.table_direct else ()))
        pos = np.broadcast_to(
            np.arange(self.off + i * C, self.off + (i + 1) * C,
                      dtype=np.int32)[None], (Bb, C))
        chunk_lens = np.clip(self.lens - i * C, 0, C).astype(np.int32)
        h, self.caches = eng._chunk(
            eng.params,
            {"tokens": jnp.asarray(self.toks[:, i * C:(i + 1) * C]),
             "positions": jnp.asarray(pos),
             "lengths": jnp.asarray(chunk_lens)},
            self.caches)
        if self._last is None:
            self._last = jnp.zeros((Bb, 1, h.shape[-1]), h.dtype)
        self._last = eng._carry_last(h, self._last, self._lens_dev,
                                     jnp.int32(i * C))
        eng._shape_keys.add(("carry", Bb, C))
        self.i += 1
        if self.done:
            jax.block_until_ready(self._last)
        self.wall_s += time.perf_counter() - t0
        return self.done

    def finish(self):
        """Epilogue after the last ``step()``: returns (first_token
        (n_valid,) np.int32, caches)."""
        if not self.done:
            raise RuntimeError(f"chunked prefill at chunk {self.i}"
                               f"/{self.n_chunks}; not finished")
        t0 = time.perf_counter()
        Bb = self.toks.shape[0]
        self.eng._shape_keys.add(("finish", Bb))
        first = self.eng._finish(self.eng.params, self._last,
                                 jnp.ones((Bb,), jnp.int32))
        jax.block_until_ready(first)
        self.eng.tokens_prefilled += int(self.lens[:self.n_valid].sum())
        self.wall_s += time.perf_counter() - t0
        caches = self.caches
        if self.table_direct:
            # the pool pages/tables were only operands for the chunk steps;
            # the returned payload keeps the dense suffix rows (plus the
            # "off" marker recording where they start) for trim + admission
            caches = _strip_prior_pages(caches)
        return np.asarray(first)[:self.n_valid], caches


class DecodeEngine:
    """Slot-based continuous batching decode cluster (see module doc)."""

    def __init__(self, model: Model, params, num_slots: int, capacity: int,
                 block_size: int = 8, *, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0, paged: bool = False,
                 pool: Optional[BlockPool] = None, page_tokens: int = 16,
                 spec_k: int = 0, spec_ngram: int = 2):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.capacity = capacity
        self.block_size = max(1, int(block_size))
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._key = jax.random.PRNGKey(int(seed))
        self._blocks = 0               # step_block dispatch counter
        self._steps = 0                # tokens-emitted counter (RNG fold_in)
        self.paged = bool(paged)
        self.spec_k = max(0, int(spec_k))
        self.spec_ngram = max(1, int(spec_ngram))
        if self.spec_k and self.temperature > 0.0:
            raise ValueError("speculative decode verifies the longest "
                             "greedy-matching prefix; it requires "
                             "temperature=0 (got spec_k "
                             f"{self.spec_k}, temperature {temperature})")
        if self.spec_k:
            # SWA ring rollback restores the q = spec_k + 1 rows a verify
            # dispatch overwrites; the per-slot row indices are distinct
            # only while q <= w_buf
            w_min = min([min(b.mixer.window, capacity)
                         for g in model.cfg.groups for b in g.blocks
                         if getattr(b.mixer, "kind", "") == "swa"
                         and getattr(b.mixer, "window", 0) > 0]
                        or [capacity])
            if self.spec_k + 1 > w_min:
                raise ValueError(f"spec_k + 1 = {self.spec_k + 1} exceeds "
                                 f"the smallest SWA ring buffer ({w_min})")
        if self.paged:
            if pool is None:
                # standalone default: same token headroom the dense layout
                # reserves (num_slots * capacity), as pool pages
                pool = BlockPool(num_slots * capacity // page_tokens,
                                 page_tokens)
            if pool.block_tokens != page_tokens:
                raise ValueError(
                    f"pool block_tokens {pool.block_tokens} != "
                    f"page_tokens {page_tokens}")
            self.pool = pool
            lay = paged_mod.paged_layout(model.cfg, capacity, page_tokens,
                                         pool.num_blocks)
            self._layout = lay
            self.caches = jax.jit(lambda: paged_mod.init_paged_cache(
                model.cfg, num_slots, lay))()
            # device bytes one pool page occupies across every paged leaf
            # (one page id addresses the same row in ALL attention layers)
            self.page_bytes = paged_mod.page_bytes(model.cfg, lay)
            # host-side block tables; retired/empty rows point at the sink
            self.table_seq = np.full((num_slots, lay.seq_cols), lay.sink,
                                     np.int32)
            self.table_ring = np.full((num_slots, lay.ring_cols), lay.sink,
                                      np.int32)
            self._slot_shared: List[List[int]] = [[] for _ in range(num_slots)]
            self._slot_owned: List[List[int]] = [[] for _ in range(num_slots)]
            self._seq_pages: List[List[int]] = [[] for _ in range(num_slots)]
            self._block_paged = jax.jit(self._block_paged_impl,
                                        donate_argnums=(2,))
            self._write_pages = jax.jit(self._write_pages_impl,
                                        donate_argnums=(0,))
            # deployment hooks: prefix-cache registration at admission (page
            # content is final then) and pin accounting at retirement
            self.on_admit = None       # fn(req, prompt_len, seq_ids, snap)
            self.on_retire = None      # fn(rid)
            self.page_fail_retires = 0
            self._warming = False      # hooks muted during warmup_admission
            # deployments shipping int8 wire pytrees set this so
            # warmup_admission also warms the dequantize-in-scatter
            # program variant (wire payloads have a distinct operand tree)
            self.wire_admission = False
        else:
            self.pool = pool
            self.caches = jax.jit(
                lambda: model.init_cache(num_slots, capacity))()
            self._warming = False
        # speculative decode: per-slot token history (prompt + emitted) for
        # the device-resident n-gram drafter, plus accept telemetry
        self._hist = np.zeros((num_slots, capacity), np.int32)
        self.verify_rounds = 0
        self.accepted_tokens = 0
        if self.spec_k:
            self._block_spec = jax.jit(self._block_spec_impl,
                                       donate_argnums=(2,))
            if self.paged:
                self._block_spec_paged = jax.jit(self._block_spec_paged_impl,
                                                 donate_argnums=(2,))
        # per-request time-between-tokens: wall seconds per emitted token
        # after the first, recorded at retirement
        self._admit_wall: Dict[int, float] = {}
        self.tbt_s: List[float] = []
        self.lengths = np.zeros((num_slots,), np.int32)
        self.tokens = np.zeros((num_slots,), np.int32)
        self.active = np.zeros((num_slots,), bool)
        self.budget = np.zeros((num_slots,), np.int32)
        self.slot_req: List[Optional[int]] = [None] * num_slots
        self.outputs: Dict[int, Response] = {}
        self.truncations = 0
        # occupancy telemetry: wall seconds spent inside step_block, the
        # same seconds weighted by #active slots, and tokens emitted —
        # occupancy = slot_busy_s / (num_slots * makespan), goodput =
        # tokens_out / makespan for whatever makespan the caller measures
        self.decode_wall_s = 0.0
        self.slot_busy_s = 0.0
        self.tokens_out = 0
        self._free = deque(range(num_slots))
        self._step = jax.jit(model.decode_step, donate_argnums=(2,))
        self._block = jax.jit(self._block_impl, donate_argnums=(2,))
        self._place_many = jax.jit(self._place_many_impl, donate_argnums=(0,))

    # ---------------------------------------------------------------- admit
    @staticmethod
    def _place_many_impl(caches, payloads, slots):
        """Write K request caches into their slots in ONE jit'd call.

        ``payloads``: tuple of K prepared caches (slot axis = 1, size 1);
        ``slots``: (K,) int32.  Lowered as K in-place slot updates on the
        donated buffers — one dispatch total, vs the old one-jit-call-per-
        request admission."""
        def place(buf, *news):
            for j, new in enumerate(news):
                buf = jax.lax.dynamic_update_slice_in_dim(
                    buf, new.astype(buf.dtype), slots[j], axis=1)
            return buf

        return jax.tree.map(place, caches, *payloads)

    # ---------------------------------------------------------- paged admit
    def _write_pages_impl(self, caches, seq_pages, ids_seq, ring_pages,
                          ids_ring, states, slots):
        """One scatter for a whole paged admission: every full/MLA layer's
        new pages land at ``ids_seq`` in its pool, every SWA layer's ring
        pages at ``ids_ring``; linear state is K per-slot updates.  Padded
        id tails repeat the last id with the same payload page — duplicate
        writes of identical content, harmless."""
        cfg = self.model.cfg
        groups = []
        for gi, g in enumerate(cfg.groups):
            gc = {}
            for bi, b in enumerate(g.blocks):
                key = f"b{bi}"
                m = b.mixer
                leaves = caches["groups"][gi][key]
                if paged_mod._is_ring(m):
                    pg = ring_pages[gi][key]
                    gc[key] = {n: leaves[n].at[:, :, ids_ring].set(
                        pg[n].astype(leaves[n].dtype)) for n in leaves}
                elif paged_mod._is_seq(m):
                    pg = seq_pages[gi][key]
                    if m.kind == "mla":
                        gc[key] = {n: leaves[n].at[:, ids_seq].set(
                            _dequant_pages(pg[n], leaves[n].dtype))
                            for n in leaves}
                    else:
                        gc[key] = {n: leaves[n].at[:, :, ids_seq].set(
                            _dequant_pages(pg[n], leaves[n].dtype))
                            for n in leaves}
                else:
                    def place(buf, *news):
                        for j, new in enumerate(news):
                            buf = jax.lax.dynamic_update_slice_in_dim(
                                buf, new.astype(buf.dtype), slots[j], axis=1)
                        return buf
                    gc[key] = jax.tree.map(
                        place, leaves, *[s[gi][key] for s in states])
            groups.append(gc)
        return {"groups": groups}

    @staticmethod
    def _cat_pad(parts, n_pad: int, axis: int):
        """Concatenate page tensors along their page axis and pad to
        ``n_pad`` pages by repeating the last page."""
        x = jnp.concatenate(parts, axis=axis) if len(parts) > 1 else parts[0]
        n = x.shape[axis]
        if n < n_pad:
            idx = [slice(None)] * x.ndim
            idx[axis] = slice(n - 1, n)
            reps = [1] * x.ndim
            reps[axis] = n_pad - n
            x = jnp.concatenate([x, jnp.tile(x[tuple(idx)], reps)], axis=axis)
        return x

    def _gather_pages(self, payloads, kind: str, n_pad: int):
        """Merge per-entry admission payloads of one kind ("seq"/"ring")
        into the single padded operand tree ``_write_pages`` consumes.

        Wire-form parts (int8 ``{"q", "scale"}`` page tensors) stay
        quantized: the per-request scalar scales broadcast into one
        per-page scale vector and the scatter dequantizes in place.  A
        batch mixing wire and raw payloads (e.g. an offloaded flow admitted
        alongside a local prefix-hit suffix) dequantizes its wire parts
        here instead, keeping one scatter program shape."""
        out = []
        for gi in range(len(self.model.cfg.groups)):
            if payloads[0][kind][gi] is None:
                out.append(None)
                continue
            gd = {}
            for key, d0 in payloads[0][kind][gi].items():
                gd[key] = {}
                for name in d0:
                    parts = [p[kind][gi][key][name] for p in payloads]
                    wire = [isinstance(x, dict) for x in parts]
                    if all(wire):
                        qs = [x["q"] for x in parts]
                        axis = 2 if qs[0].ndim == 5 else 1   # k/v vs MLA
                        scales = jnp.concatenate([
                            jnp.broadcast_to(
                                jnp.asarray(x["scale"],
                                            jnp.float32).reshape((1,)),
                                (x["q"].shape[axis],)) for x in parts])
                        ns = scales.shape[0]
                        if ns < n_pad:
                            scales = jnp.concatenate(
                                [scales, jnp.broadcast_to(scales[-1:],
                                                          (n_pad - ns,))])
                        gd[key][name] = {
                            "q": self._cat_pad(qs, n_pad, axis),
                            "scale": scales}
                        continue
                    if any(wire):
                        parts = [
                            (x["q"].astype(jnp.float32)
                             * jnp.asarray(x["scale"], jnp.float32)
                             ).astype(x["scale"].dtype)
                            if isinstance(x, dict) else x for x in parts]
                    axis = 2 if parts[0].ndim == 5 else 1    # k/v vs MLA
                    gd[key][name] = self._cat_pad(parts, n_pad, axis)
            out.append(gd)
        return out

    def _admit_paged(self, entries: Sequence[Tuple]) -> int:
        lay = self._layout
        T = lay.page_tokens
        taken = []
        for (req, first, cache, L) in entries[:len(self._free)]:
            pin = getattr(req, "device_pin", None)
            c = pin.cached_len if pin is not None else 0
            need_seq = -(-(L - c) // T) if lay.seq_cols else 0
            ids = self.pool.allocate(need_seq + lay.ring_cols, PREFIX)
            if ids is None:
                break              # pool exhausted: request stays ready
            taken.append((req, first, cache, L, pin, c,
                          list(ids[:need_seq]), list(ids[need_seq:])))
        if not taken:
            return 0
        n = len(taken)
        slots = [self._free.popleft() for _ in range(n)]
        payloads = [paged_mod.build_admit_payload(self.model.cfg, cache, lay,
                                                  c, L)
                    for (_, _, cache, L, _, c, _, _) in taken]
        # one padded scatter: pow2 page counts + pow2 state-entry count
        ids_seq = [b for t in taken for b in t[6]]
        ids_ring = [b for t in taken for b in t[7]]
        if ids_seq:
            np_seq = next_pow2(len(ids_seq))
            seq_tree = self._gather_pages(payloads, "seq", np_seq)
            ids_seq += [ids_seq[-1]] * (np_seq - len(ids_seq))
        else:
            seq_tree, ids_seq = None, [0]
        if ids_ring:
            np_ring = next_pow2(len(ids_ring))
            ring_tree = self._gather_pages(payloads, "ring", np_ring)
            ids_ring += [ids_ring[-1]] * (np_ring - len(ids_ring))
        else:
            ring_tree, ids_ring = None, [0]
        K = next_pow2(n)
        states = [p["state"] for p in payloads]
        states += [states[-1]] * (K - n)
        pad_slots = slots + [slots[-1]] * (K - n)
        self.caches = self._write_pages(
            self.caches, seq_tree, jnp.asarray(ids_seq, jnp.int32),
            ring_tree, jnp.asarray(ids_ring, jnp.int32), tuple(states),
            jnp.asarray(pad_slots, jnp.int32))
        for slot, payload, (req, first, _, L, pin, c, seq_new, ring_ids) in \
                zip(slots, payloads, taken):
            shared = list(pin.seq_ids) if pin is not None else []
            seq_all = shared + seq_new
            self.table_seq[slot, :] = lay.sink
            self.table_seq[slot, :len(seq_all)] = seq_all
            self.table_ring[slot, :] = lay.sink
            self.table_ring[slot, :len(ring_ids)] = ring_ids
            self._slot_shared[slot] = shared
            self._slot_owned[slot] = seq_new + ring_ids
            self._seq_pages[slot] = seq_all
            self.lengths[slot] = L
            self.tokens[slot] = first
            self.active[slot] = True
            self.budget[slot] = req.max_new_tokens
            self.slot_req[slot] = req.rid
            self.outputs[req.rid] = Response(req.rid, [int(first)])
            self._seed_slot_history(slot, req, first, L)
            if self.on_admit is not None and not self._warming:
                snap = ({"ring": payload["ring"], "state": payload["state"]}
                        if L % T == 0 else None)
                self.on_admit(req, L, seq_all, snap)
        return n

    def _ensure_pages(self):
        """Before a decode block: grow each active slot's seq table to cover
        the block's writes.  A slot the pool cannot serve retires truncated
        (the paged analogue of the dense capacity wall)."""
        lay = self._layout
        if not lay.seq_cols:
            return
        T = lay.page_tokens
        # speculative blocks advance up to spec_k + 1 tokens per round
        stride = self.block_size * (self.spec_k + 1)
        for slot in np.where(self.active)[0]:
            end = min(int(self.lengths[slot]) + stride, self.capacity)
            need = -(-end // T)
            have = len(self._seq_pages[slot])
            if need <= have:
                continue
            ids = self.pool.allocate(need - have, PREFIX)
            if ids is None:
                self.page_fail_retires += 1
                self._retire(int(slot), force_truncate=True)
                continue
            self.table_seq[slot, have:need] = ids
            self._seq_pages[slot].extend(ids)
            self._slot_owned[slot].extend(ids)

    def _block_paged_impl(self, params, tokens, caches, lengths, key, step0,
                          tables):
        """Paged twin of ``_block_impl``: the block tables ride into every
        ``decode_step`` (page geometry is closure-static)."""
        lay = self._layout

        def body(carry, i):
            toks, caches, lens = carry
            sub = jax.random.fold_in(key, step0 + i)
            logits, caches = self.model.decode_step(
                params, toks, caches, lens, tables=tables,
                page_tokens=lay.page_tokens, capacity=self.capacity)
            nxt = self._select(logits, sub)
            return (nxt, caches, lens + 1), nxt

        (_, caches, _), toks = jax.lax.scan(
            body, (tokens, caches, lengths),
            jnp.arange(self.block_size, dtype=jnp.int32))
        return toks, caches

    def warmup_admission(self, batch_sizes: Sequence[int],
                         lengths: Sequence[int]):
        """Precompile the paged-admission scatter programs (pow2 page-count
        x state-entry buckets) for the given traffic shape: zero-payload
        requests are admitted into real slots and immediately retired, so
        the pool round-trips (allocated == freed) and live traffic finds
        every program warm."""
        if not self.paged:
            return
        self._warming = True
        try:
            for b in sorted({next_pow2(min(int(x), self.num_slots))
                             for x in batch_sizes}):
                for l in sorted({int(x) for x in lengths}):
                    payload = paged_mod.zero_request_payload(self.model.cfg,
                                                             l)
                    payloads = [payload]
                    if self.wire_admission:
                        from repro.models.kvcache import \
                            quantize_cache_for_wire
                        payloads.append(quantize_cache_for_wire(payload)[0])
                    for p in payloads:
                        entries = [(Request(rid=-(10_000 + i),
                                            tokens=np.zeros((l,), np.int32),
                                            max_new_tokens=1), 0, p, l)
                                   for i in range(b)]
                        self.admit_many(entries)
                        for slot in range(self.num_slots):
                            rid = self.slot_req[slot]
                            if rid is not None and rid <= -10_000:
                                self._retire(slot)
                                self.outputs.pop(rid, None)
        finally:
            self._warming = False

    @property
    def admit_compiles(self) -> Optional[int]:
        """Distinct compiled paged-admission scatter programs."""
        return _jit_cache_size(self._write_pages) if self.paged else 0

    def free_slots(self) -> List[int]:
        return list(self._free)

    def admit(self, req: Request, first_token: int, one_cache,
              prompt_len: int) -> bool:
        """Place one request's shipped KV into a free slot."""
        return self.admit_many([(req, first_token, one_cache,
                                 prompt_len)]) == 1

    def admit_many(self, entries: Sequence[Tuple]) -> int:
        """entries: [(req, first_token, one_cache, prompt_len), ...].
        Admits up to the number of free slots (in order); returns the
        number admitted.  One jit'd scatter regardless of K; K is padded to
        a power of two (repeating the last entry) to bound compiles.

        Paged mode writes only each request's *pages* (and honors
        ``req.device_pin``: the pinned prefix pages are mapped, not
        rewritten); admission then also needs pool pages, so it may admit
        fewer than the free-slot count."""
        if self.paged:
            return self._admit_paged(entries)
        n = min(len(entries), len(self._free))
        if n == 0:
            return 0
        take = list(entries[:n])
        slots = [self._free.popleft() for _ in range(n)]
        placed = [prepare_decode_caches(self.model.cfg, c, self.capacity)
                  for (_, _, c, _) in take]
        K = next_pow2(n)
        pad_slots = slots + [slots[-1]] * (K - n)   # duplicate writes of the
        placed += [placed[-1]] * (K - n)            # same payload: harmless
        self.caches = self._place_many(self.caches, tuple(placed),
                                       jnp.asarray(pad_slots, jnp.int32))
        for slot, (req, first_token, _, prompt_len) in zip(slots, take):
            self.lengths[slot] = prompt_len
            self.tokens[slot] = first_token
            self.active[slot] = True
            self.budget[slot] = req.max_new_tokens
            self.slot_req[slot] = req.rid
            self.outputs[req.rid] = Response(req.rid, [int(first_token)])
            self._seed_slot_history(slot, req, first_token, prompt_len)
        return n

    def _seed_slot_history(self, slot: int, req: Request, first_token: int,
                           prompt_len: int):
        """Drafter history (prompt + first token) and TBT admission stamp."""
        if self.spec_k:
            self._hist[slot, :] = 0
            L = min(prompt_len, self._hist.shape[1])
            self._hist[slot, :L] = np.asarray(req.tokens[:L], np.int32)
            if prompt_len < self._hist.shape[1]:
                self._hist[slot, prompt_len] = first_token
        if not self._warming:
            self._admit_wall[req.rid] = time.perf_counter()

    # ----------------------------------------------------------------- step
    def _retire(self, slot: int, force_truncate: bool = False):
        rid = self.slot_req[slot]
        resp = self.outputs[rid]
        resp.finished = True
        # at the KV-capacity wall with budget remaining: NOT a clean finish
        # (force_truncate: the paged pool ran out of pages mid-stream)
        truncated = force_truncate or (self.lengths[slot] >= self.capacity - 1
                                       and self.budget[slot] > 0)
        resp.truncated = bool(truncated)
        self.truncations += int(truncated)
        t_admit = self._admit_wall.pop(rid, None)
        if t_admit is not None and not self._warming:
            n_tok = len(resp.output_tokens)
            self.tbt_s.append((time.perf_counter() - t_admit)
                              / max(1, n_tok - 1))
        self.active[slot] = False
        self.slot_req[slot] = None
        self._free.append(slot)
        if self.paged:
            # drop the prefix pins and this slot's own pages: registered
            # (populated) prompt pages stay LRU-resident for later hits,
            # decode-tail/ring pages free immediately.  The table rows point
            # at the sink so in-flight garbage writes land where no live
            # request reads.
            self.pool.release(self._slot_shared[slot])
            self.pool.release(self._slot_owned[slot])
            self._slot_shared[slot] = []
            self._slot_owned[slot] = []
            self._seq_pages[slot] = []
            self.table_seq[slot, :] = self._layout.sink
            self.table_ring[slot, :] = self._layout.sink
            if self.on_retire is not None and not self._warming:
                self.on_retire(rid)

    def step(self):
        """One decode iteration for all active slots (one host round-trip
        per token — the measured baseline for ``step_block``). Returns
        #active."""
        if self.paged:
            raise RuntimeError("the paged engine decodes in blocks "
                               "(page growth is per-block); use step_block")
        if not self.active.any():
            return 0
        logits, self.caches = self._step(
            self.params, jnp.asarray(self.tokens),
            self.caches, jnp.asarray(self.lengths))
        nxt = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        for i in range(self.num_slots):
            if not self.active[i]:
                continue
            rid = self.slot_req[i]
            self.outputs[rid].output_tokens.append(int(nxt[i]))
            self.lengths[i] += 1
            self.tokens[i] = nxt[i]
            self.budget[i] -= 1
            if self.budget[i] <= 0 or self.lengths[i] >= self.capacity - 1:
                self._retire(i)
        return int(self.active.sum())

    def _select(self, logits, key):
        """Next-token rule traced into the block program.  ``temperature``
        and ``top_k`` are Python-static, so the default greedy engine traces
        the exact pre-sampling argmax graph (bit-identical tokens); with
        ``temperature > 0`` tokens are sampled, optionally from the top-k
        renormalized logits (``top_k=1`` degenerates to greedy)."""
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits = logits / jnp.float32(self.temperature)
        if self.top_k > 0:
            kth = jax.lax.top_k(logits, self.top_k)[0][..., -1:]
            logits = jnp.where(logits < kth, -jnp.inf, logits)
        return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)

    def _block_impl(self, params, tokens, caches, lengths, key, step0):
        """``block_size`` decode steps fully on-device.  The sampling key
        for scan step ``i`` is ``fold_in(key, step0 + i)`` — indexed by
        tokens emitted, not by dispatch, so a sampled stream is reproducible
        no matter how the scheduler partitions it into blocks (and so the
        variable-stride speculative accounting can share the counter)."""
        def body(carry, i):
            toks, caches, lens = carry
            sub = jax.random.fold_in(key, step0 + i)
            logits, caches = self.model.decode_step(params, toks, caches,
                                                    lens)
            nxt = self._select(logits, sub)
            return (nxt, caches, lens + 1), nxt

        (_, caches, _), toks = jax.lax.scan(
            body, (tokens, caches, lengths),
            jnp.arange(self.block_size, dtype=jnp.int32))
        return toks, caches

    # --------------------------------------------------- speculative decode
    def _draft(self, hist, lens):
        """n-gram / prompt-lookup drafter, fully on-device: propose
        ``spec_k`` tokens per slot by suffix-matching the last ``spec_ngram``
        tokens of ``hist[b, :lens[b]+1]`` (prompt + everything emitted)
        against every earlier position and replaying what followed the most
        recent match.  No second model — drafts are just gathered history.
        Slots without a match (or reading past their frontier) propose
        whatever lies there; a wrong draft only costs its rejection."""
        n, k = self.spec_ngram, self.spec_k
        B, C = hist.shape
        pos = jnp.arange(C, dtype=jnp.int32)[None, :]
        ok = (pos >= n - 1) & (pos < lens[:, None])
        for d in range(n):
            shifted = hist if d == 0 else \
                jnp.pad(hist, ((0, 0), (d, 0)))[:, :C]
            tgt = jnp.take_along_axis(
                hist, jnp.clip(lens[:, None] - d, 0, C - 1), axis=1)
            ok &= (shifted == tgt)
        j = jnp.max(jnp.where(ok, pos, -1), axis=1)      # latest match or -1
        cols = jnp.clip(j[:, None] + 1 + jnp.arange(k, dtype=jnp.int32),
                        0, C - 1)
        return jnp.take_along_axis(hist, cols, axis=1)   # (B, k)

    def _spec_round(self, params, toks, caches, lens, hist, tables=None):
        """One draft -> verify -> accept -> commit round for every slot.
        Greedy acceptance: step j's prediction is compared against draft j;
        ``accept[b]`` = length of the matching prefix, and the (always
        correct) prediction after the last accepted draft rides along as a
        bonus token — so every round emits accept+1 tokens, ≥ 1."""
        k = self.spec_k
        q = k + 1
        kw = {}
        if tables is not None:
            kw = dict(tables=tables, page_tokens=self._layout.page_tokens,
                      capacity=self.capacity)
        drafts = self._draft(hist, lens)
        seq = jnp.concatenate([toks[:, None], drafts], axis=1)
        logits, caches, pending = self.model.decode_verify(
            params, seq, caches, lens, **kw)
        preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)    # (B, q)
        match = (preds[:, :k] == drafts).astype(jnp.int32)
        accept = jnp.sum(jnp.cumprod(match, axis=1), axis=1)     # (B,)
        caches = self.model.commit_verify(caches, pending, lens, accept, q,
                                          **kw)
        nxt = jnp.take_along_axis(preds, accept[:, None], axis=1)[:, 0]
        # history frontier: positions lens+1+j take preds[j] for accepted j
        # (rejected columns route out of range and drop), keeping the
        # invariant hist[b, :lens[b]+1] == prompt + accepted stream
        B, C = hist.shape
        step = jnp.arange(q, dtype=jnp.int32)[None, :]
        cols = jnp.where(step <= accept[:, None],
                         lens[:, None] + 1 + step, C)
        hist = hist.at[jnp.arange(B)[:, None], cols].set(preds, mode="drop")
        return caches, lens + accept + 1, hist, nxt, preds, accept

    def _block_spec_impl(self, params, tokens, caches, lengths, hist):
        """Speculative twin of ``_block_impl``: ``block_size`` verify rounds
        on-device, each emitting a VARIABLE 1..spec_k+1 tokens per slot.
        The accept-counts thread the scan carry (lengths advance by
        accept+1), and the stacked (round, slot, q) predictions + accepts go
        back to the host for variable-stride budget/retire accounting."""
        def body(carry, _):
            toks, caches, lens, hist = carry
            caches, lens, hist, nxt, preds, accept = self._spec_round(
                params, toks, caches, lens, hist)
            return (nxt, caches, lens, hist), (preds, accept)

        (_, caches, _, _), (preds, accepts) = jax.lax.scan(
            body, (tokens, caches, lengths, hist), None,
            length=self.block_size)
        return preds, accepts, caches

    def _block_spec_paged_impl(self, params, tokens, caches, lengths, hist,
                               tables):
        def body(carry, _):
            toks, caches, lens, hist = carry
            caches, lens, hist, nxt, preds, accept = self._spec_round(
                params, toks, caches, lens, hist, tables=tables)
            return (nxt, caches, lens, hist), (preds, accept)

        (_, caches, _, _), (preds, accepts) = jax.lax.scan(
            body, (tokens, caches, lengths, hist), None,
            length=self.block_size)
        return preds, accepts, caches

    @property
    def accepted_tokens_per_dispatch(self) -> float:
        """Mean tokens emitted per verify round (1.0 for the plain path)."""
        if self.verify_rounds == 0:
            return 1.0
        return self.accepted_tokens / self.verify_rounds

    @property
    def spec_compiles(self) -> Optional[int]:
        if not self.spec_k:
            return 0
        return _jit_cache_size(self._block_spec_paged if self.paged
                               else self._block_spec)

    @property
    def block_compiles(self) -> Optional[int]:
        return _jit_cache_size(self._block_paged if self.paged
                               else self._block)

    def step_block(self):
        """Advance every active stream by up to ``block_size`` tokens with
        ONE device dispatch and one host sync. Returns #active.

        Inactive slots decode garbage into their (about-to-be-overwritten)
        cache region; streams that hit their budget or the capacity wall
        mid-block have the surplus tokens discarded on the host — identical
        retirement semantics to ``step()``."""
        if not self.active.any():
            return 0
        if self.paged:
            self._ensure_pages()          # may retire page-starved slots
            if not self.active.any():
                return 0
        if self.spec_k:
            return self._step_block_spec()
        t0 = time.perf_counter()
        key = self._key
        step0 = jnp.int32(self._steps)
        self._blocks += 1
        self._steps += self.block_size
        if self.paged:
            tables = {"seq": jnp.asarray(self.table_seq),
                      "ring": jnp.asarray(self.table_ring)}
            toks, self.caches = self._block_paged(
                self.params, jnp.asarray(self.tokens),
                self.caches, jnp.asarray(self.lengths), key, step0, tables)
        else:
            toks, self.caches = self._block(
                self.params, jnp.asarray(self.tokens),
                self.caches, jnp.asarray(self.lengths), key, step0)
        toks = np.asarray(toks)                       # (block, num_slots)
        idx = np.where(self.active)[0]
        wall = time.perf_counter() - t0
        self.decode_wall_s += wall
        self.slot_busy_s += len(idx) * wall
        # tokens a slot emits before retiring, exactly as step() would:
        # min(budget, room to capacity-1) per block — floored at 1 because
        # step() appends once BEFORE its retirement check, so a slot
        # admitted at/over the capacity wall still emits one token
        valid = np.clip(
            np.minimum(self.budget[idx],
                       self.capacity - 1 - self.lengths[idx]),
            1, self.block_size).astype(int)
        self.tokens_out += int(valid.sum())
        self.lengths[idx] += valid
        self.budget[idx] -= valid
        self.tokens[idx] = toks[valid - 1, idx]
        done = (self.budget[idx] <= 0) | \
               (self.lengths[idx] >= self.capacity - 1)
        for j, i in enumerate(idx):
            out = self.outputs[self.slot_req[i]].output_tokens
            out.extend(int(t) for t in toks[:valid[j], i])
            if done[j]:
                self._retire(i)
        return int(self.active.sum())

    def _step_block_spec(self):
        """Speculative ``step_block``: ``block_size`` draft/verify rounds in
        ONE dispatch, each emitting 1..spec_k+1 tokens per slot.  The host
        unpacks the per-round (predictions, accepts) into variable-stride
        budget/length/retire accounting.  A slot whose budget or capacity
        wall lands mid-stream takes only its valid prefix and retires, so
        the device-side history/length frontier stays authoritative exactly
        for the slots that continue."""
        t0 = time.perf_counter()
        self._blocks += 1
        toks = jnp.asarray(self.tokens)
        lens = jnp.asarray(self.lengths)
        hist = jnp.asarray(self._hist)
        if self.paged:
            tables = {"seq": jnp.asarray(self.table_seq),
                      "ring": jnp.asarray(self.table_ring)}
            preds, accepts, self.caches = self._block_spec_paged(
                self.params, toks, self.caches, lens, hist, tables)
        else:
            preds, accepts, self.caches = self._block_spec(
                self.params, toks, self.caches, lens, hist)
        preds = np.asarray(preds)        # (rounds, num_slots, spec_k + 1)
        accepts = np.asarray(accepts)    # (rounds, num_slots)
        idx = np.where(self.active)[0]
        wall = time.perf_counter() - t0
        self.decode_wall_s += wall
        self.slot_busy_s += len(idx) * wall
        self.verify_rounds += int(accepts[:, idx].size)
        self.accepted_tokens += int((accepts[:, idx] + 1).sum())
        for i in idx:
            stream = np.concatenate(
                [preds[r, i, :accepts[r, i] + 1]
                 for r in range(preds.shape[0])])
            valid = int(np.clip(
                min(self.budget[i], self.capacity - 1 - self.lengths[i]),
                1, len(stream)))
            take = stream[:valid]
            self.outputs[self.slot_req[i]].output_tokens.extend(
                int(t) for t in take)
            L = int(self.lengths[i])
            hi = min(L + 1 + valid, self._hist.shape[1])
            self._hist[i, L + 1:hi] = take[:max(0, hi - (L + 1))]
            self.tokens[i] = take[-1]
            self.lengths[i] += valid
            self.budget[i] -= valid
            self.tokens_out += valid
            if self.budget[i] <= 0 or self.lengths[i] >= self.capacity - 1:
                self._retire(int(i))
        return int(self.active.sum())

    def warmup_block(self):
        """Precompile the decode block program(s) on the live (zeroed or
        garbage) buffers: one throwaway dispatch with every slot inactive.
        Dense garbage writes land in regions a later admission fully
        rewrites; paged tables all point at the sink page.  After this the
        hot path never compiles again (``block_compiles`` /
        ``spec_compiles`` stay at 1)."""
        toks = jnp.zeros((self.num_slots,), jnp.int32)
        lens = jnp.zeros((self.num_slots,), jnp.int32)
        if self.paged:
            tables = {"seq": jnp.asarray(self.table_seq),
                      "ring": jnp.asarray(self.table_ring)}
            if self.spec_k:
                _, _, self.caches = self._block_spec_paged(
                    self.params, toks, self.caches, lens,
                    jnp.asarray(self._hist), tables)
            else:
                _, self.caches = self._block_paged(
                    self.params, toks, self.caches, lens, self._key,
                    jnp.int32(0), tables)
        else:
            if self.spec_k:
                _, _, self.caches = self._block_spec(
                    self.params, toks, self.caches, lens,
                    jnp.asarray(self._hist))
            else:
                _, self.caches = self._block(
                    self.params, toks, self.caches, lens, self._key,
                    jnp.int32(0))

    def run_until_drained(self, max_steps: int = 10_000):
        """Drain all active streams via ``step_block`` (``max_steps`` counts
        blocks)."""
        steps = 0
        while self.active.any() and steps < max_steps:
            self.step_block()
            steps += 1
        return steps


class RegionScheduler:
    """One continuously-batched loop per region: owns the prefill queue and
    the decode slot pool together (module doc has the state machine).

    ``submit`` enqueues a routed request, optionally naming which
    ``PrefillEngine`` runs it — deployments share one PrfaaS engine and one
    PD engine across regions, so the engine is per-request state, not
    per-scheduler.  ``tick()`` is one scheduler iteration:

      1. admit every READY request into free decode slots in one
         ``admit_many`` scatter — each tick IS a decode block boundary;
      2. advance ONE prefill unit: the next fixed-shape chunk of an
         in-flight ``ChunkedPrefill``, or a freshly formed same-(engine,
         bucket) FIFO batch run in a single bucketed ``prefill`` call;
      3. one ``step_block`` over all active decode slots.

    Finished units pass through ``on_unit_done`` (when set) so callers can
    do trim/wire/metrics accounting and hand back admit entries; the
    default trims each request's cache out of the bucket-padded batch.
    Starvation is impossible by construction — ``_admit`` runs FIFO at
    every boundary — and ``max_admit_wait`` (boundaries a request spent
    ready-but-unadmitted) makes that assertable instead of trusted.
    """

    def __init__(self, prefill: PrefillEngine, decode: DecodeEngine, *,
                 max_prefill_batch: int = 8, on_unit_done=None):
        self.prefill = prefill
        self.decode = decode
        self.max_prefill_batch = max(1, int(max_prefill_batch))
        self.on_unit_done = on_unit_done
        self.queue: deque = deque()          # (req, engine) — FIFO
        self.ready: deque = deque()          # (admit entry, ready boundary)
        self._inflight = None                # (ChunkedPrefill, reqs, lens)
        self.boundaries = 0                  # ticks == block boundaries
        self.max_admit_wait = 0
        self.starved_boundaries = 0          # ready waited w/ free slots
        self.wall_s = 0.0                    # scheduler makespan

    # ------------------------------------------------------------- intake
    def submit(self, req: Request, engine: Optional[PrefillEngine] = None):
        """Enqueue one routed request (state: queued)."""
        self.queue.append((req, engine if engine is not None
                           else self.prefill))

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.ready or self._inflight is not None
                    or self.decode.active.any())

    # -------------------------------------------------------------- phases
    def _admit(self) -> int:
        """Block boundary: move ready -> decoding, as many as slots allow."""
        if not self.ready:
            return 0
        n = self.decode.admit_many([e for e, _ in self.ready])
        for _ in range(n):
            _, born = self.ready.popleft()
            self.max_admit_wait = max(self.max_admit_wait,
                                      self.boundaries - born)
        # the starvation guard: after a boundary admit, a request may only
        # remain ready because every slot is occupied
        if self.ready and self.decode.free_slots():
            self.starved_boundaries += 1
        return n

    def _finish_unit(self, engine, reqs, lengths, first, caches,
                     wall_s: float):
        if self.on_unit_done is not None:
            entries = self.on_unit_done(engine, reqs, lengths, first,
                                        caches, wall_s)
        else:
            entries = [(r, int(first[i]),
                        trim_request_cache(caches, i, int(lengths[i])),
                        int(lengths[i]))
                       for i, r in enumerate(reqs)]
        for e in entries:
            self.ready.append((e, self.boundaries))

    def _prefill_one(self):
        """Advance exactly one prefill unit: a chunk of the in-flight
        chunked prefill, or one bucketed batch from the queue head."""
        if self._inflight is not None:
            cp, reqs, lengths = self._inflight
            cp.step()
            if cp.done:
                self._inflight = None
                first, caches = cp.finish()
                self._finish_unit(cp.eng, reqs, lengths, first, caches,
                                  cp.wall_s)
            return
        if not self.queue:
            return
        req0, e0 = self.queue[0]
        pin = getattr(req0, "device_pin", None)
        if (pin is not None and pin.cached_len > 0
                and getattr(self.decode, "paged", False)):
            # device prefix hit: prefill only the uncached suffix, reading
            # the cached prefix straight out of the pinned pool pages
            self.queue.popleft()
            dec = self.decode
            prior = paged_mod.build_prior(
                dec.model.cfg, dec.caches, dec._layout, pin.seq_ids,
                None if pin.snapshot is None else pin.snapshot.payload,
                pin.cached_len, table_direct=True)
            lengths = np.array([len(req0.tokens)], np.int32)
            self._inflight = (e0.start_suffix(req0.tokens, prior,
                                              pin.cached_len),
                              [req0], lengths)
            self._prefill_one()              # run its first chunk this tick
            return
        if e0.is_chunked(len(req0.tokens)):
            # long prompt: becomes the chunk-interleaved unit (batch of 1 —
            # one fixed-shape chunk advances per tick, decode keeps running)
            self.queue.popleft()
            lengths = np.array([len(req0.tokens)], np.int32)
            toks = np.asarray(req0.tokens, np.int32)[None, :]
            self._inflight = (e0.start_chunked(toks, lengths), [req0],
                              lengths)
            self._prefill_one()              # run its first chunk this tick
            return
        # form one same-(engine, bucket) unit in FIFO order
        bucket = e0.bucket_for(len(req0.tokens))
        unit: List[Request] = []
        rest: deque = deque()
        while self.queue:
            r, e = self.queue.popleft()
            if (len(unit) < self.max_prefill_batch and e is e0
                    and not e.is_chunked(len(r.tokens))
                    and e.bucket_for(len(r.tokens)) == bucket):
                unit.append(r)
            else:
                rest.append((r, e))
        self.queue = rest
        lengths = np.array([len(r.tokens) for r in unit], np.int32)
        toks = np.zeros((len(unit), int(lengths.max())), np.int32)
        for i, r in enumerate(unit):
            toks[i, :len(r.tokens)] = r.tokens
        first, caches, wall = e0.prefill(toks, lengths)
        self._finish_unit(e0, unit, lengths, first, caches, wall)

    # ---------------------------------------------------------------- loop
    def tick(self):
        """One scheduler iteration: admit -> one prefill unit -> one decode
        block.  Returns #active decode slots after the block."""
        t0 = time.perf_counter()
        self._admit()
        self._prefill_one()
        n = self.decode.step_block()
        self.boundaries += 1
        self.wall_s += time.perf_counter() - t0
        return n

    def run(self, max_ticks: int = 100_000) -> int:
        """Tick until every submitted request has retired."""
        ticks = 0
        while self.has_work and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks

    # ------------------------------------------------------------- metrics
    def occupancy(self) -> float:
        """Fraction of decode-slot-time occupied over the scheduler's own
        makespan (prefill gaps count against it — that is the point)."""
        denom = self.decode.num_slots * self.wall_s
        return self.decode.slot_busy_s / denom if denom > 0 else 0.0

    def goodput_tok_s(self) -> float:
        return (self.decode.tokens_out / self.wall_s
                if self.wall_s > 0 else 0.0)

    def stats(self) -> dict:
        return {"boundaries": self.boundaries,
                "max_admit_wait": self.max_admit_wait,
                "starved_boundaries": self.starved_boundaries,
                "occupancy": self.occupancy(),
                "goodput_tok_s": self.goodput_tok_s(),
                "tokens_out": self.decode.tokens_out,
                "truncations": self.decode.truncations,
                "accepted_tokens_per_dispatch":
                    self.decode.accepted_tokens_per_dispatch}


def slice_request_cache(caches, idx: int):
    """Extract request ``idx`` from a batched prefill cache -> batch of 1."""
    return jax.tree.map(lambda x: x[:, idx:idx + 1], caches)


def _strip_prior_pages(node):
    """Drop the table-direct prior operands (pool page leaves + block
    table) from a finished suffix prefill's caches, keeping the dense
    suffix rows and the ``off`` start marker."""
    if isinstance(node, dict):
        return {k: _strip_prior_pages(v) for k, v in node.items()
                if k not in ("pk", "pv", "tbl")}
    if isinstance(node, list):
        return [_strip_prior_pages(v) for v in node]
    return node


def trim_request_cache(caches, idx: int, length: int):
    """Extract request ``idx`` from a batched (bucket-padded) prefill cache
    and trim sequence-major leaves (k/v/ckv/kpe) to ``length`` — the bytes
    that actually need to cross the wire.  O(1) state leaves pass through.
    (Decoder-only caches; cross-attention caches keep their encoder len.)

    A block carrying an ``off`` marker (table-direct suffix prefill) holds
    only rows [off, length) in its seq leaves, so those trim to
    ``length - off``."""
    offs = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "off":
            offs[jax.tree_util.keystr(path[:-1])] = int(
                np.asarray(leaf).reshape(-1)[0])

    def cut(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        leaf = leaf[:, idx:idx + 1]
        if name in _SEQ_LEAVES and "cross" not in jax.tree_util.keystr(path):
            off = offs.get(jax.tree_util.keystr(path[:-1]), 0)
            leaf = leaf[:, :, :min(max(length - off, 0), leaf.shape[2])]
        return leaf

    return jax.tree_util.tree_map_with_path(cut, caches)

"""Prefill + continuous-batching decode engines (pure JAX).

``PrefillEngine`` plays the PrfaaS / PD-P role: runs full-sequence prefill
and emits the request's KVCache (the bytes that cross the inter-DC link).
``DecodeEngine`` plays PD-D: a slot-based continuous-batching loop over a
single jit'd ``decode_step`` — requests are admitted into free slots (their
shipped KV placed into the engine's preallocated buffers), step() advances
every active stream by one token, finished streams retire and free slots.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model, prepare_decode_caches
from repro.models.kvcache import cache_num_bytes
from repro.serving.api import Request, Response


class PrefillEngine:
    def __init__(self, model: Model, params):
        self.model = model
        self.params = params
        self._prefill = jax.jit(model.prefill)

    def prefill(self, tokens: np.ndarray):
        """tokens: (B, S). Returns (first_token (B,), caches, wall_s)."""
        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        logits, caches = self._prefill(self.params, batch)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(first)
        return np.asarray(first), caches, time.perf_counter() - t0


class DecodeEngine:
    """Slot-based continuous batching decode cluster."""

    def __init__(self, model: Model, params, num_slots: int, capacity: int):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.capacity = capacity
        self.caches = jax.jit(
            lambda: model.init_cache(num_slots, capacity))()
        self.lengths = np.zeros((num_slots,), np.int32)
        self.tokens = np.zeros((num_slots,), np.int32)
        self.active = np.zeros((num_slots,), bool)
        self.budget = np.zeros((num_slots,), np.int32)
        self.slot_req: List[Optional[int]] = [None] * num_slots
        self.outputs: Dict[int, Response] = {}
        self._step = jax.jit(model.decode_step, donate_argnums=(2,))
        self._place = jax.jit(self._place_impl, donate_argnums=(0,))

    # ---------------------------------------------------------------- admit
    @staticmethod
    def _place_impl(caches, one_cache, slot):
        def put(buf, new):
            # write request cache (axis 1 = slot) at [slot]
            idx = (0, slot) + (0,) * (buf.ndim - 2)
            return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype),
                                                idx)

        return jax.tree.map(put, caches, one_cache)

    def free_slots(self) -> List[int]:
        return [i for i in range(self.num_slots) if not self.active[i]]

    def admit(self, req: Request, first_token: int, one_cache, prompt_len: int):
        """Place a request's shipped KV into a free slot."""
        slots = self.free_slots()
        if not slots:
            return False
        slot = slots[0]
        placed = prepare_decode_caches(self.model.cfg, one_cache,
                                       self.capacity)
        self.caches = self._place(self.caches, placed, slot)
        self.lengths[slot] = prompt_len
        self.tokens[slot] = first_token
        self.active[slot] = True
        self.budget[slot] = req.max_new_tokens
        self.slot_req[slot] = req.rid
        self.outputs[req.rid] = Response(req.rid, [int(first_token)])
        return True

    # ----------------------------------------------------------------- step
    def step(self):
        """One decode iteration for all active slots. Returns #active."""
        if not self.active.any():
            return 0
        logits, self.caches = self._step(
            self.params, jnp.asarray(self.tokens),
            self.caches, jnp.asarray(self.lengths))
        nxt = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        for i in range(self.num_slots):
            if not self.active[i]:
                continue
            rid = self.slot_req[i]
            self.outputs[rid].output_tokens.append(int(nxt[i]))
            self.lengths[i] += 1
            self.tokens[i] = nxt[i]
            self.budget[i] -= 1
            if self.budget[i] <= 0 or self.lengths[i] >= self.capacity - 1:
                self.outputs[rid].finished = True
                self.active[i] = False
                self.slot_req[i] = None
        return int(self.active.sum())

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while self.active.any() and steps < max_steps:
            self.step()
            steps += 1
        return steps


def slice_request_cache(caches, idx: int):
    """Extract request ``idx`` from a batched prefill cache -> batch of 1."""
    return jax.tree.map(lambda x: x[:, idx:idx + 1], caches)

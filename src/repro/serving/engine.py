"""Prefill + continuous-batching decode engines (pure JAX), built around a
recompile-free hot path.

``PrefillEngine`` plays the PrfaaS / PD-P role: runs full-sequence prefill
and emits the request's KVCache (the bytes that cross the inter-DC link).
Prompts are padded to power-of-two **length buckets** (and batches to
power-of-two batch buckets), so each (batch, length) bucket compiles
exactly once; per-request ``lengths`` are threaded into ``model.prefill``
so logits and linear-mixer states are EXACT despite the padding (see
``models.model.prefill``).  Prompts longer than ``max_bucket`` run as
**chunked prefill**: fixed-shape chunks of ``max_bucket`` tokens through
``model.prefill_chunk`` — attention chunks attend over the prior chunks'
cache via the ``q_offset`` flash path, linear mixers carry state — so the
compile set stays bounded (one compile per chunk index) for arbitrarily
long prompts.

``DecodeEngine`` plays PD-D: a slot-based continuous-batching loop.

  * **batched admission** — ``admit_many`` writes K shipped request caches
    into their slots in ONE jit'd call (K in-place slot updates on the
    donated buffers; K padded to a power of two so admission compiles are
    bounded), instead of K serial one-jit-call-per-request placements.
  * **multi-token decode** — ``step_block`` runs ``block_size`` iterations
    of ``model.decode_step`` inside one jit'd ``lax.scan`` with the greedy
    token fed back on-device; tokens/lengths sync to host ONCE per block
    and slot bookkeeping is vectorized numpy between blocks.  ``step()``
    (one host round-trip per token) is kept as the measured baseline.
  * free slots live in a deque maintained on admit/retire (the old
    ``free_slots()`` O(num_slots) scan ran on every admission).
  * a stream retired at the KV-capacity wall with generation budget left is
    flagged ``Response.truncated`` and counted in ``truncations`` instead
    of masquerading as a clean finish.

Compile counts are observable (``PrefillEngine.compiles``,
``DecodeEngine.block_compiles``) so benchmarks and tests can assert the
zero-recompile property instead of trusting it.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model, prepare_decode_caches
from repro.models.kvcache import cache_num_bytes
from repro.serving.api import Request, Response

_SEQ_LEAVES = ("k", "v", "ckv", "kpe")


def next_pow2(n: int, lo: int = 1) -> int:
    v = max(int(lo), 1)
    while v < n:
        v *= 2
    return v


def _jit_cache_size(fn) -> Optional[int]:
    size = getattr(fn, "_cache_size", None)
    return size() if callable(size) else None


class PrefillEngine:
    """Bucketed (and, past ``max_bucket``, chunked) prefill.

    ``min_bucket``: smallest length bucket (pow2).  ``max_bucket``: when
    set, prompts padded beyond it are prefetched in fixed ``max_bucket``-
    token chunks (decoder-only models).  ``pad_batch``: round the batch
    dimension up to a power of two as well (exactly one compile per
    (batch-bucket, length-bucket) pair).
    """

    def __init__(self, model: Model, params, *, min_bucket: int = 32,
                 max_bucket: Optional[int] = None, pad_batch: bool = True):
        self.model = model
        self.params = params
        self.min_bucket = next_pow2(min_bucket)
        if max_bucket is not None and next_pow2(max_bucket) != max_bucket:
            raise ValueError("max_bucket must be a power of two")
        self.max_bucket = max_bucket
        self.pad_batch = pad_batch
        self._prefill = jax.jit(self._prefill_impl)
        self._chunk = jax.jit(self.model.prefill_chunk)
        self._carry_last = jax.jit(self._carry_last_impl)
        self._finish = jax.jit(self._finish_impl)
        self._shape_keys = set()         # fallback compile tracking
        self.calls = 0

    # ------------------------------------------------------------- jit fns
    def _prefill_impl(self, params, tokens, lengths):
        logits, caches = self.model.prefill(
            params, {"tokens": tokens, "lengths": lengths})
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    @staticmethod
    def _carry_last_impl(hidden, last, lengths, offset):
        """Fold a chunk's hidden states (B, C, d) into the (B, 1, d)
        last-valid-hidden carry: rows whose final prompt position falls in
        [offset, offset+C) take their row from this chunk."""
        C = hidden.shape[1]
        pos = lengths.astype(jnp.int32) - 1
        idx = jnp.clip(pos - offset, 0, C - 1)
        cand = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)
        in_chunk = (pos >= offset) & (pos < offset + C)
        return jnp.where(in_chunk[:, None, None], cand, last)

    def _finish_impl(self, params, hidden, lengths):
        logits = self.model.last_logits(params, hidden, lengths)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------- buckets
    def bucket_for(self, max_len: int) -> int:
        return next_pow2(max_len, self.min_bucket)

    @property
    def compiles(self) -> int:
        """Number of distinct compiled prefill programs (actual jit-cache
        entries when the runtime exposes them, tracked shape keys else)."""
        sizes = [_jit_cache_size(f)
                 for f in (self._prefill, self._chunk, self._carry_last,
                           self._finish)]
        if any(s is None for s in sizes):
            return len(self._shape_keys)
        return sum(sizes)

    def warmup(self, batch_sizes: Sequence[int], lengths: Sequence[int]):
        """Compile every (batch-bucket, length-bucket) pair up front."""
        for b in sorted({next_pow2(b) for b in batch_sizes}):
            for l in sorted({self.bucket_for(l) for l in lengths}):
                toks = np.zeros((b, l), np.int32)
                self.prefill(toks, np.full((b,), l, np.int32))

    # -------------------------------------------------------------- public
    def prefill(self, tokens: np.ndarray, lengths=None):
        """tokens: (B, S) right-padded prompts; lengths: (B,) valid counts
        (defaults to S).  Returns (first_token (B,), caches, wall_s).

        The returned caches are bucket-padded; slice a request out with
        ``trim_request_cache(caches, i, length)`` before shipping so wire
        bytes reflect the prompt, not the bucket.
        """
        t0 = time.perf_counter()
        tokens = np.asarray(tokens)
        B, S = tokens.shape
        if lengths is None:
            lengths = np.full((B,), S, np.int32)
        lengths = np.asarray(lengths, np.int32)
        max_len = int(lengths.max()) if B else S
        Sb = self.bucket_for(max_len)
        chunked = self.max_bucket is not None and Sb > self.max_bucket
        if chunked:
            C = self.max_bucket
            Sb = -(-max_len // C) * C                    # ceil to chunks
        Bb = next_pow2(B) if self.pad_batch else B
        toks = np.zeros((Bb, Sb), np.int32)
        toks[:B, :min(S, Sb)] = tokens[:, :Sb]
        lens = np.ones((Bb,), np.int32)                  # pad rows: 1 token
        lens[:B] = np.maximum(lengths, 1)
        self.calls += 1

        if chunked:
            first, caches = self._chunked_prefill(toks, lens, C)
        else:
            self._shape_keys.add(("prefill", Bb, Sb))
            first, caches = self._prefill(self.params, jnp.asarray(toks),
                                          jnp.asarray(lens))
        jax.block_until_ready(first)
        return np.asarray(first)[:B], caches, time.perf_counter() - t0

    def _chunked_prefill(self, toks: np.ndarray, lens: np.ndarray, C: int):
        Bb, Sb = toks.shape
        caches = None
        # (B, 1, d) carry of each row's hidden state at its last prompt
        # position — O(chunk) activation memory regardless of prompt length,
        # and the epilogue compiles once per (Bb, C), not per chunk count
        last = None
        lens_dev = jnp.asarray(lens)
        for i in range(Sb // C):
            self._shape_keys.add(("chunk", Bb, C, i))
            pos = np.broadcast_to(
                np.arange(i * C, (i + 1) * C, dtype=np.int32)[None],
                (Bb, C))
            chunk_lens = np.clip(lens - i * C, 0, C).astype(np.int32)
            h, caches = self._chunk(
                self.params,
                {"tokens": jnp.asarray(toks[:, i * C:(i + 1) * C]),
                 "positions": jnp.asarray(pos),
                 "lengths": jnp.asarray(chunk_lens)},
                caches)
            if last is None:
                last = jnp.zeros((Bb, 1, h.shape[-1]), h.dtype)
            last = self._carry_last(h, last, lens_dev,
                                    jnp.int32(i * C))
            self._shape_keys.add(("carry", Bb, C))
        self._shape_keys.add(("finish", Bb))
        first = self._finish(self.params, last,
                             jnp.ones((Bb,), jnp.int32))
        return first, caches


class DecodeEngine:
    """Slot-based continuous batching decode cluster (see module doc)."""

    def __init__(self, model: Model, params, num_slots: int, capacity: int,
                 block_size: int = 8):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.capacity = capacity
        self.block_size = max(1, int(block_size))
        self.caches = jax.jit(
            lambda: model.init_cache(num_slots, capacity))()
        self.lengths = np.zeros((num_slots,), np.int32)
        self.tokens = np.zeros((num_slots,), np.int32)
        self.active = np.zeros((num_slots,), bool)
        self.budget = np.zeros((num_slots,), np.int32)
        self.slot_req: List[Optional[int]] = [None] * num_slots
        self.outputs: Dict[int, Response] = {}
        self.truncations = 0
        self._free = deque(range(num_slots))
        self._step = jax.jit(model.decode_step, donate_argnums=(2,))
        self._block = jax.jit(self._block_impl, donate_argnums=(2,))
        self._place_many = jax.jit(self._place_many_impl, donate_argnums=(0,))

    # ---------------------------------------------------------------- admit
    @staticmethod
    def _place_many_impl(caches, payloads, slots):
        """Write K request caches into their slots in ONE jit'd call.

        ``payloads``: tuple of K prepared caches (slot axis = 1, size 1);
        ``slots``: (K,) int32.  Lowered as K in-place slot updates on the
        donated buffers — one dispatch total, vs the old one-jit-call-per-
        request admission."""
        def place(buf, *news):
            for j, new in enumerate(news):
                buf = jax.lax.dynamic_update_slice_in_dim(
                    buf, new.astype(buf.dtype), slots[j], axis=1)
            return buf

        return jax.tree.map(place, caches, *payloads)

    def free_slots(self) -> List[int]:
        return list(self._free)

    def admit(self, req: Request, first_token: int, one_cache,
              prompt_len: int) -> bool:
        """Place one request's shipped KV into a free slot."""
        return self.admit_many([(req, first_token, one_cache,
                                 prompt_len)]) == 1

    def admit_many(self, entries: Sequence[Tuple]) -> int:
        """entries: [(req, first_token, one_cache, prompt_len), ...].
        Admits up to the number of free slots (in order); returns the
        number admitted.  One jit'd scatter regardless of K; K is padded to
        a power of two (repeating the last entry) to bound compiles."""
        n = min(len(entries), len(self._free))
        if n == 0:
            return 0
        take = list(entries[:n])
        slots = [self._free.popleft() for _ in range(n)]
        placed = [prepare_decode_caches(self.model.cfg, c, self.capacity)
                  for (_, _, c, _) in take]
        K = next_pow2(n)
        pad_slots = slots + [slots[-1]] * (K - n)   # duplicate writes of the
        placed += [placed[-1]] * (K - n)            # same payload: harmless
        self.caches = self._place_many(self.caches, tuple(placed),
                                       jnp.asarray(pad_slots, jnp.int32))
        for slot, (req, first_token, _, prompt_len) in zip(slots, take):
            self.lengths[slot] = prompt_len
            self.tokens[slot] = first_token
            self.active[slot] = True
            self.budget[slot] = req.max_new_tokens
            self.slot_req[slot] = req.rid
            self.outputs[req.rid] = Response(req.rid, [int(first_token)])
        return n

    # ----------------------------------------------------------------- step
    def _retire(self, slot: int):
        rid = self.slot_req[slot]
        resp = self.outputs[rid]
        resp.finished = True
        # at the KV-capacity wall with budget remaining: NOT a clean finish
        truncated = (self.lengths[slot] >= self.capacity - 1
                     and self.budget[slot] > 0)
        resp.truncated = bool(truncated)
        self.truncations += int(truncated)
        self.active[slot] = False
        self.slot_req[slot] = None
        self._free.append(slot)

    def step(self):
        """One decode iteration for all active slots (one host round-trip
        per token — the measured baseline for ``step_block``). Returns
        #active."""
        if not self.active.any():
            return 0
        logits, self.caches = self._step(
            self.params, jnp.asarray(self.tokens),
            self.caches, jnp.asarray(self.lengths))
        nxt = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        for i in range(self.num_slots):
            if not self.active[i]:
                continue
            rid = self.slot_req[i]
            self.outputs[rid].output_tokens.append(int(nxt[i]))
            self.lengths[i] += 1
            self.tokens[i] = nxt[i]
            self.budget[i] -= 1
            if self.budget[i] <= 0 or self.lengths[i] >= self.capacity - 1:
                self._retire(i)
        return int(self.active.sum())

    def _block_impl(self, params, tokens, caches, lengths):
        """``block_size`` greedy decode steps fully on-device."""
        def body(carry, _):
            toks, caches, lens = carry
            logits, caches = self.model.decode_step(params, toks, caches,
                                                    lens)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (nxt, caches, lens + 1), nxt

        (_, caches, _), toks = jax.lax.scan(
            body, (tokens, caches, lengths), None, length=self.block_size)
        return toks, caches

    @property
    def block_compiles(self) -> Optional[int]:
        return _jit_cache_size(self._block)

    def step_block(self):
        """Advance every active stream by up to ``block_size`` tokens with
        ONE device dispatch and one host sync. Returns #active.

        Inactive slots decode garbage into their (about-to-be-overwritten)
        cache region; streams that hit their budget or the capacity wall
        mid-block have the surplus tokens discarded on the host — identical
        retirement semantics to ``step()``."""
        if not self.active.any():
            return 0
        toks, self.caches = self._block(
            self.params, jnp.asarray(self.tokens),
            self.caches, jnp.asarray(self.lengths))
        toks = np.asarray(toks)                       # (block, num_slots)
        idx = np.where(self.active)[0]
        # tokens a slot emits before retiring, exactly as step() would:
        # min(budget, room to capacity-1) per block — floored at 1 because
        # step() appends once BEFORE its retirement check, so a slot
        # admitted at/over the capacity wall still emits one token
        valid = np.clip(
            np.minimum(self.budget[idx],
                       self.capacity - 1 - self.lengths[idx]),
            1, self.block_size).astype(int)
        self.lengths[idx] += valid
        self.budget[idx] -= valid
        self.tokens[idx] = toks[valid - 1, idx]
        done = (self.budget[idx] <= 0) | \
               (self.lengths[idx] >= self.capacity - 1)
        for j, i in enumerate(idx):
            out = self.outputs[self.slot_req[i]].output_tokens
            out.extend(int(t) for t in toks[:valid[j], i])
            if done[j]:
                self._retire(i)
        return int(self.active.sum())

    def run_until_drained(self, max_steps: int = 10_000):
        """Drain all active streams via ``step_block`` (``max_steps`` counts
        blocks)."""
        steps = 0
        while self.active.any() and steps < max_steps:
            self.step_block()
            steps += 1
        return steps


def slice_request_cache(caches, idx: int):
    """Extract request ``idx`` from a batched prefill cache -> batch of 1."""
    return jax.tree.map(lambda x: x[:, idx:idx + 1], caches)


def trim_request_cache(caches, idx: int, length: int):
    """Extract request ``idx`` from a batched (bucket-padded) prefill cache
    and trim sequence-major leaves (k/v/ckv/kpe) to ``length`` — the bytes
    that actually need to cross the wire.  O(1) state leaves pass through.
    (Decoder-only caches; cross-attention caches keep their encoder len.)"""

    def cut(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        leaf = leaf[:, idx:idx + 1]
        if name in _SEQ_LEAVES and "cross" not in jax.tree_util.keystr(path):
            leaf = leaf[:, :, :min(length, leaf.shape[2])]
        return leaf

    return jax.tree_util.tree_map_with_path(cut, caches)

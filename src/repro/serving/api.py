"""Serving API types."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class PagePin:
    """Device-prefix hit handle (paged KV): the matched pool pages, pinned
    via BlockPool ref-counts between routing and admission so LRU eviction
    cannot reclaim them while the request is queued. ``seq_ids`` become the
    head of the slot's seq block table; ``snapshot`` (when the arch carries
    exact-length SWA/linear state) supplies the ring/state payload."""
    cached_len: int                    # page-aligned resumable prefix tokens
    seq_ids: List[int]                 # pinned full/MLA pages, logical order
    snapshot: Optional[object] = None  # core.prefix_cache.LinearSnapshot


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # prompt token ids (int32)
    max_new_tokens: int = 32
    home: str = ""                     # originating PD region ("" = first)
    # timeline (seconds; wall for compute, virtual for the inter-DC link)
    arrival: float = 0.0
    route: str = ""
    cached_tokens: int = 0
    prefill_s: float = 0.0
    transfer_s: float = 0.0
    kv_bytes: int = 0                  # bytes on the wire (quantized if on)
    kv_bytes_raw: int = 0              # raw cache bytes before compression
    cross_kv_bytes: float = 0.0        # cross-cluster cached-prefix copy
    ttft_s: float = 0.0
    # the core.router.RoutingDecision that placed this request (set by
    # CrossDCDeployment._route; None until routed)
    decision: Optional[object] = None
    # paged-KV device prefix hit (set when the home region resumes from
    # pool pages; pages stay ref-pinned until the request retires)
    device_pin: Optional[PagePin] = None


@dataclass
class Response:
    rid: int
    output_tokens: List[int] = field(default_factory=list)
    finished: bool = False
    # retired at the decode KV-capacity wall with generation budget left
    # (NOT a clean finish; counted in DecodeEngine.truncations)
    truncated: bool = False

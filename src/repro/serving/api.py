"""Serving API types."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # prompt token ids (int32)
    max_new_tokens: int = 32
    home: str = ""                     # originating PD region ("" = first)
    # timeline (seconds; wall for compute, virtual for the inter-DC link)
    arrival: float = 0.0
    route: str = ""
    cached_tokens: int = 0
    prefill_s: float = 0.0
    transfer_s: float = 0.0
    kv_bytes: int = 0                  # bytes on the wire (quantized if on)
    kv_bytes_raw: int = 0              # raw cache bytes before compression
    cross_kv_bytes: float = 0.0        # cross-cluster cached-prefix copy
    ttft_s: float = 0.0
    # the core.router.RoutingDecision that placed this request (set by
    # CrossDCDeployment._route; None until routed)
    decision: Optional[object] = None


@dataclass
class Response:
    rid: int
    output_tokens: List[int] = field(default_factory=list)
    finished: bool = False
    # retired at the decode KV-capacity wall with generation budget left
    # (NOT a clean finish; counted in DecodeEngine.truncations)
    truncated: bool = False

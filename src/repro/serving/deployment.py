"""Two-cluster PrfaaS-PD deployment, in-process: real token generation with
the KVCache crossing a simulated commodity-Ethernet link.

  * "PrfaaS cluster"  — a PrefillEngine (long requests, l > t)
  * "PD cluster"      — a PrefillEngine (short requests) + DecodeEngine
  * inter-DC link     — virtual-clock byte-accurate transfer with layer-wise
                        pipelining (transfer overlaps prefill compute)

The router applies the paper's length-threshold + cache-aware policy using a
real HybridPrefixCache per cluster. This is the live-system mirror of
``core.simulator`` (which scales the same logic to cluster counts no single
process could execute).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.blockpool import BlockPool
from repro.core.prefix_cache import HybridPrefixCache
from repro.core.transfer import Link
from repro.models import Model
from repro.models.kvcache import cache_num_bytes
from repro.serving.api import Request, Response
from repro.serving.engine import (DecodeEngine, PrefillEngine,
                                  slice_request_cache)


@dataclass
class DeploymentConfig:
    threshold: int = 256               # routing threshold t (tokens)
    link_gbps: float = 1.0             # inter-DC link
    decode_slots: int = 8
    capacity: int = 2048               # decode KV capacity per slot
    block_tokens: int = 16
    pool_blocks: int = 4096
    layerwise_pipeline: bool = True


class CrossDCDeployment:
    def __init__(self, model: Model, params, cfg: DeploymentConfig,
                 prfaas_model: Optional[Model] = None,
                 prfaas_params=None):
        self.model = model
        self.cfg = cfg
        self.prfaas = PrefillEngine(prfaas_model or model,
                                    prfaas_params if prfaas_params is not None
                                    else params)
        self.pd_prefill = PrefillEngine(model, params)
        self.decode = DecodeEngine(model, params, cfg.decode_slots,
                                   cfg.capacity)
        self.caches = {
            "prfaas": HybridPrefixCache(
                BlockPool(cfg.pool_blocks, cfg.block_tokens, 1 << 16), 0, 1),
            "pd": HybridPrefixCache(
                BlockPool(cfg.pool_blocks, cfg.block_tokens, 1 << 16), 0, 1),
        }
        self.completed: List[Request] = []
        # exact fair-share flow model of the inter-DC link (virtual clock):
        # concurrent transfers within a prefill batch contend for bandwidth
        # and are solved by progressive filling, not serialized
        self.link = Link(cfg.link_gbps * 1e9)
        self.virtual_now = 0.0

    # ------------------------------------------------------------- routing
    def _route(self, req: Request) -> str:
        matches = {name: c.match(list(map(int, req.tokens)))
                   for name, c in self.caches.items()}
        l_pd = matches["pd"]
        if len(req.tokens) - l_pd <= self.cfg.threshold:
            req.route, req.cached_tokens = "pd", l_pd
        else:
            req.route, req.cached_tokens = "prfaas", matches["prfaas"]
        return req.route

    # ------------------------------------------------------------ lifecycle
    def submit_batch(self, reqs: List[Request]) -> Dict[int, Response]:
        """Serve a batch of requests end-to-end; returns responses."""
        groups = {"prfaas": [], "pd": []}
        for r in reqs:
            groups[self._route(r)].append(r)

        for cluster, rs in groups.items():
            if not rs:
                continue
            engine = self.prfaas if cluster == "prfaas" else self.pd_prefill
            # pad to the longest prompt in the group (one prefill batch)
            maxlen = max(len(r.tokens) for r in rs)
            toks = np.zeros((len(rs), maxlen), np.int32)
            for i, r in enumerate(rs):
                toks[i, :len(r.tokens)] = r.tokens   # left-aligned
            first, caches, wall = engine.prefill(toks)
            self.link.advance(self.virtual_now)   # sync link clock to batch
            flows = {}
            for i, r in enumerate(rs):
                r.prefill_s = wall
                one = slice_request_cache(caches, i)
                r.kv_bytes = cache_num_bytes(one)
                if cluster == "prfaas":
                    # layer-wise pipelined: KV becomes wire-eligible as
                    # prefill computes (linear ramp over the prefill);
                    # unpipelined: the flow only starts once prefill ends.
                    # Either way the batch's flows contend on the exact
                    # fair-share link solver.
                    start = (self.virtual_now if self.cfg.layerwise_pipeline
                             else self.virtual_now + wall)
                    flows[r.rid] = self.link.submit(
                        max(r.kv_bytes, 1.0), start,
                        ramp_end=self.virtual_now + wall)
                else:
                    r.transfer_s = 0.0
                self.caches[cluster].insert(list(map(int, r.tokens)))
                self.decode.admit(r, int(first[i]), one, len(r.tokens))
            if flows:
                self.link.run_until_idle()
                floor = 1.0 / max(1, self.model.cfg.n_layers)
                for r in rs:
                    f = flows.get(r.rid)
                    if f is None:
                        continue
                    exposed = f.done_time - (self.virtual_now + wall)
                    # the last layer's KV can never overlap its own compute
                    serial_tail = f.total_bytes * floor \
                        / self.link.current_capacity()
                    r.transfer_s = max(exposed, serial_tail)
            for r in rs:
                r.ttft_s = r.prefill_s + r.transfer_s
            self.virtual_now += wall
        self.decode.run_until_drained()
        self.completed.extend(reqs)
        return self.decode.outputs

    # -------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        done = self.completed
        ttft = [r.ttft_s for r in done]
        return {
            "requests": len(done),
            "offloaded": sum(1 for r in done if r.route == "prfaas"),
            "ttft_mean_s": float(np.mean(ttft)) if ttft else 0.0,
            "kv_bytes_total": sum(r.kv_bytes for r in done
                                  if r.route == "prfaas"),
            "cache_hit_rate": {k: c.hit_rate()
                               for k, c in self.caches.items()},
        }

"""Multi-region PrfaaS-PD deployment, in-process, sharing ONE control plane
with the cluster simulator.

Topology (``DeploymentConfig.pd_clusters`` = N regions):

  * "PrfaaS cluster"   — a shared ``PrefillEngine`` (long requests, l > t)
                         with its own ``HybridPrefixCache``
  * N "PD regions"     — each with its own ``DecodeEngine``,
                         ``HybridPrefixCache``, and ``RegionScheduler``
                         (local prefill runs on a shared PD
                         ``PrefillEngine``: in-process the compute is
                         identical, the policy state is per-region).
                         Routed requests feed the home region's scheduler
                         immediately; each scheduler tick interleaves one
                         prefill unit (bucket batch or long-prompt chunk)
                         with one decode block, admitting finished prefills
                         at block boundaries — no drain-and-re-admit batch
                         loop, no decode idle while prefill runs.  Every
                         finished unit passes through ``_unit_done``, which
                         keeps the wire/TTFT/truncation accounting of the
                         old batch loop at unit granularity.
  * inter-DC links     — a ``core.transfer.LinkTopology``: one exact
                         fair-share ``Link`` per PrfaaS<->region star pair,
                         plus an optional PD<->PD mesh for cross-region
                         cache copies.  Byte accounting uses the same
                         virtual-clock flow solver as the simulator.

The deployment contains NO routing policy of its own.  Route choice, cache
placement, and threshold adaptation all go through ``core.router.Router``:
each request's per-cluster prefix matches and its home pair-link telemetry
are handed to ``Router.route(l, matches, signal, home=)``, and after every
batch each region's aggregated congestion view (``LinkTopology.dest_signal``)
is fed back through ``Router.observe_congestion(signal, home=)`` so per-home
thresholds adapt during a live run — exactly the short-term loop the
simulator runs.  ``launch.serve --cross-validate`` replays a live run's
arrival trace through ``core.simulator.PrfaasSimulator`` and checks the two
agree per request.

int8 KV on the wire (``DeploymentConfig.wire_compression``): the quantized
pytree from ``models.kvcache.quantize_cache_for_wire`` is what actually
crosses the links — flow bytes are measured from the quantized leaves, and
the cache is dequantized before decode admission.  The running
quantized/raw ratio (``measured_compression``) is the value
``SystemConfig.kv_wire_compression`` should carry in the analytic model and
the simulator.

Cache metadata goes through one ``core.kv_manager.GlobalKVManager``: every
cluster cache registers there, ``_route`` reads its per-cluster matches
(restricted to link-reachable clusters), and finished prefills record
through it — so hotspot rebalancing and its ``rebalanced`` /
``cross_transfers`` counters observe live traffic exactly as they observe
the simulator's.

Device prefix reuse (``DeploymentConfig.paged_kv``): each PD region's
``DecodeEngine`` runs the paged layout, sharing ONE ``BlockPool`` with the
region's ``HybridPrefixCache`` — prompt pages register at admission
(``insert_device``) and stay LRU-resident after the request retires.  A
locally-prefilled request whose prefix matches resumes from those pages:
``match_resume`` pins them (ref-counts) and the scheduler prefills only
the uncached suffix, so a prefix hit skips the cached-prefix compute
instead of recomputing and reshipping it.  Offloaded (PrfaaS) requests
still ship the full cache — the prefill ran in another datacenter, where
the home region's device pages don't exist — so live egress upper-bounds
the simulator's incremental ``S_kv(total) - S_kv(cached)`` charge on that
path, while the local path now matches it.  With ``paged_kv=False`` (the
default) the dense per-slot layout and the byte-accounting-twin pools are
bit-identical to the pre-paged deployment.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import AttentionSpec
from repro.core.blockpool import BlockPool
from repro.core.hardware import CHIPS, AnalyticProfile
from repro.core.kv_manager import GlobalKVManager
from repro.core.prefix_cache import HybridPrefixCache
from repro.core.router import PD, PRFAAS, Router, RouterConfig, RoutingDecision
from repro.core.throughput_model import SystemConfig, ThroughputModel
from repro.core.transfer import Link, LinkTopology, star_pairs
from repro.core.workload import Workload
from repro.models import Model, paged_layout
from repro.models.kvcache import (cache_num_bytes, dequantize_cache_from_wire,
                                  kv_bytes, quantize_cache_for_wire)
from repro.serving.api import PagePin, Request, Response
from repro.serving.engine import (DecodeEngine, PrefillEngine,
                                  RegionScheduler, trim_request_cache)


@dataclass
class DeploymentConfig:
    threshold: int = 256               # base routing threshold t (tokens)
    link_gbps: float = 1.0             # PrfaaS->region star links (shared)
    pd_link_gbps: Optional[Tuple[float, ...]] = None  # per-region override
    pd_mesh_gbps: float = 0.0          # PD<->PD links (0 = star only)
    pd_clusters: int = 1               # regional PD clusters
    decode_slots: int = 8
    capacity: int = 2048               # decode KV capacity per slot
    decode_block_size: int = 8         # tokens per on-device decode block
    min_prefill_bucket: int = 32       # smallest pow2 prefill length bucket
    max_prefill_bucket: Optional[int] = None  # chunked prefill past this
    max_prefill_batch: int = 8         # requests per scheduler prefill unit
    temperature: float = 0.0           # 0 = greedy (bit-identical default)
    top_k: int = 0                     # 0 = full vocab when sampling
    sample_seed: int = 0               # decode sampling PRNG seed
    spec_k: int = 0                    # speculative draft tokens per round
    spec_ngram: int = 2                # drafter suffix-match length
    tbt_slo_s: float = 0.0             # TBT SLO for attainment (0 = off)
    block_tokens: int = 16
    pool_blocks: int = 4096
    # paged device KV: region decode engines use BlockPool pages as the
    # real cache layout and resume prefix hits from registered pages
    # (suffix-only prefill); False keeps the dense per-slot layout with the
    # pools as byte-accounting twins (bit-identical legacy behavior)
    paged_kv: bool = False
    layerwise_pipeline: bool = True
    wire_compression: bool = False     # int8 KV quantization on the wire
    adapt_thresholds: bool = True      # live per-home congestion feedback
    chip: str = "h200"                 # AnalyticProfile chip for the Router
    chips_per_instance: int = 8
    # path to a BENCH_kernel.json written by benchmarks.kernel_bench: the
    # Router's profile (thresholds, S_kv/T_prefill trade-off) then derives
    # from THIS machine's measured kernels (analysis.calibrate) instead of
    # the named chip's roofline
    calibration: Optional[str] = None


class CrossDCDeployment:
    def __init__(self, model: Model, params, cfg: DeploymentConfig,
                 prfaas_model: Optional[Model] = None,
                 prfaas_params=None,
                 router_cfg: Optional[RouterConfig] = None):
        self.model = model
        self.cfg = cfg
        k = cfg.pd_clusters
        if k < 1:
            raise ValueError("pd_clusters must be >= 1")
        # region naming matches the simulator: the classic two-cluster
        # deployment keeps the legacy "pd" name
        self.pd_names = [PD] if k == 1 else [f"pd{i}" for i in range(k)]
        bucket_kw = dict(min_bucket=cfg.min_prefill_bucket,
                         max_bucket=cfg.max_prefill_bucket)
        self.prfaas = PrefillEngine(prfaas_model or model,
                                    prfaas_params if prfaas_params is not None
                                    else params, **bucket_kw)
        self.pd_prefill = PrefillEngine(model, params, **bucket_kw)
        # paged regions share ONE BlockPool between the decode engine (page
        # storage) and the region's prefix cache (page index): a cache hit
        # names real device pages
        pools: Dict[str, BlockPool] = {}
        if cfg.paged_kv:
            for name in self.pd_names:
                pools[name] = BlockPool(cfg.pool_blocks, cfg.block_tokens,
                                        1 << 16)
        self.decoders: Dict[str, DecodeEngine] = {
            name: DecodeEngine(model, params, cfg.decode_slots, cfg.capacity,
                               block_size=cfg.decode_block_size,
                               temperature=cfg.temperature, top_k=cfg.top_k,
                               seed=cfg.sample_seed, paged=cfg.paged_kv,
                               pool=pools.get(name),
                               page_tokens=cfg.block_tokens,
                               spec_k=cfg.spec_k, spec_ngram=cfg.spec_ngram)
            for name in self.pd_names}
        # one continuously-batched scheduler loop per region: it owns the
        # region's prefill queue and decode slots together; every finished
        # unit flows through _unit_done for wire/metrics accounting
        self.schedulers: Dict[str, RegionScheduler] = {
            name: RegionScheduler(self.pd_prefill, self.decoders[name],
                                  max_prefill_batch=cfg.max_prefill_batch,
                                  on_unit_done=self._unit_done)
            for name in self.pd_names}
        self.caches: Dict[str, HybridPrefixCache] = {PRFAAS: self._new_cache()}
        for name in self.pd_names:
            if cfg.paged_kv:
                self.caches[name] = self._paged_cache(pools[name])
                self._wire_admission(name)
            else:
                self.caches[name] = self._new_cache()
        # all cache metadata flows through the global manager: per-cluster
        # matching for routing, prefill registration, hotspot rebalancing
        self.kv = GlobalKVManager()
        for name, cache in self.caches.items():
            self.kv.register_cluster(name, cache)

        # ------- shared control plane: the simulator's Router + topology ---
        star = (list(cfg.pd_link_gbps) if cfg.pd_link_gbps is not None
                else [cfg.link_gbps] * k)
        if len(star) != k:
            raise ValueError("pd_link_gbps must have one entry per region")
        if cfg.calibration:
            from repro.analysis.calibrate import (calibrated_profile,
                                                  load_calibration)
            profile = calibrated_profile(model.cfg,
                                         load_calibration(cfg.calibration),
                                         cfg.chips_per_instance)
        else:
            profile = AnalyticProfile(model.cfg, CHIPS[cfg.chip],
                                      cfg.chips_per_instance)
        self.profile = profile
        self.throughput_model = ThroughputModel(profile, profile, Workload())
        self.system = SystemConfig(1, k, k, sum(star) * 1e9 / 8.0,
                                   float(cfg.threshold))
        self.router = Router(self.throughput_model, self.system, router_cfg)
        pairs = star_pairs(PRFAAS, self.pd_names,
                           mesh=cfg.pd_mesh_gbps > 0 and k > 1)
        gbps = star + [cfg.pd_mesh_gbps] * (len(pairs) - k)
        self.topology = LinkTopology.build([PRFAAS] + self.pd_names, pairs,
                                           gbps)

        self.completed: List[Request] = []
        self.virtual_now = 0.0
        self._wire_raw = 0.0           # raw bytes of caches put on the wire
        self._wire_quant = 0.0         # their measured quantized bytes
        self._seed_ratio = 1.0         # dry-run ratio used before any flow
        if cfg.wire_compression:
            # seed the measured ratio from a one-page dry-run quantization
            # so measured_compression() reflects the configured wire format
            # from construction instead of reporting 1.0 until the first
            # quantized flow ships.  The seed is kept OUT of the running
            # accumulators: once real flows exist the ratio is exactly
            # theirs, not skewed by the probe.
            from repro.models.paged import zero_request_payload
            probe = zero_request_payload(model.cfg, cfg.block_tokens)
            self._seed_ratio = (float(cache_num_bytes(probe))
                                / float(quantize_cache_for_wire(probe)[1]))

    def _new_cache(self) -> HybridPrefixCache:
        return HybridPrefixCache(
            BlockPool(self.cfg.pool_blocks, self.cfg.block_tokens, 1 << 16),
            0, 1)

    def _paged_cache(self, pool: BlockPool) -> HybridPrefixCache:
        """Region prefix cache sharing the decode engine's page pool: its
        entries are registered at admission (``insert_device``) and name
        live device pages, so a match is device-resumable."""
        lay = paged_layout(self.model.cfg, self.cfg.capacity,
                           self.cfg.block_tokens, 1)
        has_state = any(not isinstance(b.mixer, AttentionSpec)
                        for g in self.model.cfg.groups for b in g.blocks)
        return HybridPrefixCache(pool, 0, 1,
                                 has_full_attn=lay.seq_cols > 0,
                                 has_linear=lay.ring_cols > 0 or has_state)

    def _wire_admission(self, name: str):
        cache, dec = self.caches[name], self.decoders[name]
        dec.on_admit = lambda req, L, ids, snap: cache.insert_device(
            [int(t) for t in req.tokens], ids, snap)
        # offloaded prefills arriving as int8 wire pytrees admit AS wire:
        # dequantization fuses into the page scatter instead of a separate
        # full-cache pass on the admission path
        dec.wire_admission = bool(self.cfg.wire_compression)

    # ------------------------------------------------- two-cluster aliases
    @property
    def link(self) -> Link:
        """First region's star link (the classic single inter-DC link)."""
        return self.topology.link(PRFAAS, self.pd_names[0])

    @property
    def decode(self) -> DecodeEngine:
        return self.decoders[self.pd_names[0]]

    # ------------------------------------------------------------- routing
    def _route(self, req: Request) -> RoutingDecision:
        home = req.home or self.pd_names[0]
        if home not in self.pd_names:
            raise ValueError(f"unknown home region {home!r}; "
                             f"expected one of {self.pd_names}")
        req.home = home
        toks = list(map(int, req.tokens))
        matches = self.kv.match_all(
            toks, names=[n for n in self.caches
                         if self.topology.cache_reachable(home, n,
                                                          hub=PRFAAS)])
        decision = self.router.route(len(toks), matches,
                                     self.topology.pair_signal(PRFAAS, home),
                                     home=home)
        req.decision = decision
        req.route = decision.target
        req.cached_tokens = decision.cached_tokens
        if self.cfg.paged_kv and decision.target == home:
            # local prefill on a paged region: pin the device-resident
            # prefix pages (ref-counts transfer to the engine at admission)
            # so only the uncached suffix is computed.  An offloaded
            # prefill cannot use home device pages — it ships the full
            # cache as before.
            c, ids, snap = self.caches[home].match_resume(toks)
            if c:
                self.decoders[home].pool.retain(ids)
                req.device_pin = PagePin(c, ids, snap)
        return decision

    # ------------------------------------------------------------ lifecycle
    def _unit_done(self, engine: PrefillEngine, rs: List[Request], lengths,
                   first, caches, wall: float) -> list:
        """Per-unit accounting hook the region schedulers call when a
        prefill unit (bucketed batch or chunked prompt) finishes: trim to
        true lengths, quantize + submit wire flows, insert prefix-cache
        entries, compute transfer exposure and TTFT — exactly the
        accounting the old per-cluster batch loop did, at unit granularity.
        Returns the decode admit entries for the scheduler's ready queue."""
        self.topology.advance(self.virtual_now)      # sync link clocks
        flows: Dict[int, list] = {}
        entries = []
        for i, r in enumerate(rs):
            cluster = r.decision.target
            r.prefill_s = wall
            # trim to the request's true length: bucket padding must not
            # inflate wire bytes (or corrupt SWA ring placement)
            payload = trim_request_cache(caches, i, len(r.tokens))
            r.kv_bytes_raw = cache_num_bytes(payload)
            r.transfer_s = 0.0
            fl = []
            if cluster == PRFAAS:
                if self.cfg.wire_compression:
                    # the quantized pytree IS what crosses the link: bytes
                    # come from the quantized leaves, and the cache is
                    # dequantized before decode admission
                    payload, nbytes = quantize_cache_for_wire(payload)
                    self._wire_raw += r.kv_bytes_raw
                    self._wire_quant += nbytes
                else:
                    nbytes = r.kv_bytes_raw
                r.kv_bytes = nbytes
                # layer-wise pipelined: KV becomes wire-eligible as prefill
                # computes (linear ramp over the prefill); unpipelined: the
                # flow only starts once prefill ends.  Either way the
                # unit's flows contend on the exact fair-share pair link
                # solver.
                start = (self.virtual_now if self.cfg.layerwise_pipeline
                         else self.virtual_now + wall)
                fl.append(("kv", PRFAAS, r.home, self.topology.submit(
                    PRFAAS, r.home, max(float(nbytes), 1.0), start,
                    ramp_end=self.virtual_now + wall)))
            else:
                r.kv_bytes = r.kv_bytes_raw          # intra-cluster RDMA
            d = r.decision
            if d.cross_cache_transfer and d.cached_tokens:
                # cached prefix lives in another cluster: the copy is
                # already materialized (eager flow), charged to the
                # owner<->target pair link, compressed like the rest of the
                # wire traffic
                nb = float(kv_bytes(self.model.cfg, d.cached_tokens))
                if self.cfg.wire_compression:
                    nb /= self.measured_compression()
                nb = max(nb, 1.0)
                r.cross_kv_bytes = nb
                fl.append(("copy", d.cache_cluster, d.target,
                           self.topology.submit(
                               d.cache_cluster, d.target, nb,
                               self.virtual_now,
                               ramp_end=self.virtual_now)))
            flows[r.rid] = fl
            if not (self.cfg.paged_kv and cluster != PRFAAS):
                # paged regions register their device pages at ADMISSION
                # (insert_device): inserting metadata blocks here would bind
                # prefix hashes to pageless entries that match_resume would
                # hand back as if they held KV
                self.kv.record_prefill(cluster, list(map(int, r.tokens)))
            if (self.cfg.wire_compression and cluster == PRFAAS
                    and not getattr(self.decoders[r.home],
                                    "wire_admission", False)):
                # dense admission needs the dense pytree back; paged homes
                # with wire admission dequantize inside the page scatter
                payload = dequantize_cache_from_wire(payload)
            entries.append((r, int(first[i]), payload, len(r.tokens)))
        if any(flows.values()):
            self.topology.run_until_idle()
        for r in rs:
            exposure = 0.0
            for kind, a, b, f in flows.get(r.rid, ()):
                tail = 0.0
                if kind == "kv":
                    # the pipelined prefill KV's last layer can never
                    # overlap its own compute (eager "copy" flows are
                    # already materialized: no serial tail)
                    floor = 1.0 / max(1, self.model.cfg.n_layers)
                    tail = f.total_bytes * floor \
                        / self.topology.link(a, b).current_capacity()
                exposed = f.done_time - (self.virtual_now + wall)
                exposure = max(exposure, exposed, tail)
            if flows.get(r.rid):
                r.transfer_s = max(exposure, 0.0)
            r.ttft_s = r.prefill_s + r.transfer_s
        self.virtual_now += wall
        return entries

    def submit_batch(self, reqs: List[Request]) -> Dict[int, Response]:
        """Serve a batch of requests end-to-end; returns responses.

        Requests feed their home region's ``RegionScheduler`` as they
        route; the scheduler loops then run concurrently (round-robin
        ticks, in-process) — prefill units interleave with decode blocks
        and admission happens at block boundaries, never by draining a
        region to empty first."""
        for r in reqs:
            decision = self._route(r)
            engine = (self.prfaas if decision.target == PRFAAS
                      else self.pd_prefill)
            self.schedulers[r.home].submit(r, engine)

        scheds = list(self.schedulers.values())
        while any(s.has_work for s in scheds):
            for s in scheds:
                if s.has_work:
                    s.tick()

        # live short-term loop: every region feeds its OWN aggregated
        # congestion view back into the shared Router, adapting that home's
        # threshold alone — identical to the simulator's control epoch
        if self.cfg.adapt_thresholds:
            for name in self.pd_names:
                self.router.observe_congestion(
                    self.topology.dest_signal(name), home=name)

        out: Dict[int, Response] = {}
        for dec in self.decoders.values():
            out.update(dec.outputs)
        self.completed.extend(reqs)
        return out

    # -------------------------------------------------------------- metrics
    def measured_compression(self) -> float:
        """Running measured raw/quantized byte ratio of the KV put on the
        wire.  With ``wire_compression`` enabled the ratio is seeded at
        construction from a one-page dry-run quantization, so it reflects
        the wire format immediately; live flows then dominate the running
        ratio.  Without compression (nothing ever quantized) it is 1.0."""
        if self._wire_quant > 0:
            return self._wire_raw / self._wire_quant
        return self._seed_ratio

    @staticmethod
    def _tbt_stats(tbt: List[float], slo_s: float) -> dict:
        """Measured per-request mean time-between-tokens: percentiles plus
        SLO attainment (fraction of requests at/under ``slo_s``; 1.0 when
        the SLO is unset or nothing finished yet)."""
        if not tbt:
            return {"tbt_mean_s": 0.0, "tbt_p50_s": 0.0, "tbt_p90_s": 0.0,
                    "tbt_p99_s": 0.0, "tbt_slo_s": slo_s,
                    "tbt_attainment": 1.0}
        arr = np.asarray(tbt)
        return {
            "tbt_mean_s": float(arr.mean()),
            "tbt_p50_s": float(np.percentile(arr, 50)),
            "tbt_p90_s": float(np.percentile(arr, 90)),
            "tbt_p99_s": float(np.percentile(arr, 99)),
            "tbt_slo_s": slo_s,
            "tbt_attainment": (float((arr <= slo_s).mean())
                               if slo_s > 0 else 1.0),
        }

    def metrics(self) -> dict:
        done = self.completed
        ttft = [r.ttft_s for r in done]
        per_region = {}
        for name in self.pd_names:
            rs = [r for r in done if r.home == name]
            dec = self.decoders[name]
            per_region[name] = {
                "requests": len(rs),
                "offloaded": sum(1 for r in rs if r.route == PRFAAS),
                "ttft_mean_s": float(np.mean([r.ttft_s for r in rs]))
                if rs else 0.0,
                "threshold": self.router.threshold_for(name),
                "cache_hit_rate": self.caches[name].hit_rate(),
                "truncations": self.decoders[name].truncations,
                "occupancy": self.schedulers[name].occupancy(),
                "goodput_tok_s": self.schedulers[name].goodput_tok_s(),
                "max_admit_wait": self.schedulers[name].max_admit_wait,
                "accepted_tokens_per_dispatch":
                    dec.accepted_tokens_per_dispatch,
                **self._tbt_stats(dec.tbt_s, self.cfg.tbt_slo_s),
            }
            if self.cfg.paged_kv:
                dec = self.decoders[name]
                pool = dec.pool
                per_region[name]["pool"] = {
                    **pool.stats, "resident": pool.resident,
                    "used_blocks": pool.used_blocks,
                    "num_blocks": pool.num_blocks}
                # headroom: device bytes held by LRU-resident prefix pages
                # (reclaimable on demand, reusable on a hit)
                per_region[name]["resident_kv_bytes"] = \
                    pool.resident * dec.page_bytes
                per_region[name]["page_fail_retires"] = dec.page_fail_retires
        busy = sum(d.slot_busy_s for d in self.decoders.values())
        span = sum(self.cfg.decode_slots * s.wall_s
                   for s in self.schedulers.values())
        all_tbt = [t for d in self.decoders.values() for t in d.tbt_s]
        rounds = sum(d.verify_rounds for d in self.decoders.values())
        accepted = sum(d.accepted_tokens for d in self.decoders.values())
        return {
            "requests": len(done),
            "offloaded": sum(1 for r in done if r.route == PRFAAS),
            "ttft_mean_s": float(np.mean(ttft)) if ttft else 0.0,
            "kv_bytes_total": sum(r.kv_bytes for r in done
                                  if r.route == PRFAAS),
            "cache_hit_rate": {k: c.hit_rate()
                               for k, c in self.caches.items()},
            "thresholds": {n: self.router.threshold_for(n)
                           for n in self.pd_names},
            "router_decisions": dict(self.router.decisions),
            "cross_transfers": self.router.cross_transfers,
            "kv_manager": {"rebalanced": self.kv.rebalanced,
                           "cross_transfers": self.kv.cross_transfers,
                           "clusters": self.kv.stats()},
            "paged_kv": self.cfg.paged_kv,
            "truncations": sum(d.truncations for d in self.decoders.values()),
            "occupancy": busy / span if span > 0 else 0.0,
            "goodput_tok_s": sum(s.goodput_tok_s()
                                 for s in self.schedulers.values()),
            "accepted_tokens_per_dispatch": (accepted / rounds if rounds
                                             else 1.0),
            **self._tbt_stats(all_tbt, self.cfg.tbt_slo_s),
            "wire_compression": self.measured_compression(),
            "clusters": per_region,
            "links": self.topology.pair_stats(),
        }

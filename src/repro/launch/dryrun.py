import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost analysis + collective bytes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The XLA_FLAGS line above MUST run before any jax import (device count is
locked at first init). Artifacts land in benchmarks/artifacts/dryrun/ as one
JSON per cell; existing artifacts are skipped (resumable) unless --force.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.roofline import analyze
from repro.configs import SHAPES, all_configs, get_config
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        params_shardings)
from repro.launch import input_specs as specs
from repro.launch.mesh import make_production_mesh, mesh_fingerprint
from repro.models import Model
from repro.models.perf_flags import VARIANTS, use_variant
from repro.training import TrainConfig, init_opt_state, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts/dryrun")

# per-shape training knobs (activation-memory control)
TRAIN_MICROBATCHES = {"train_4k": 16}
DECODE_HEADROOM = 64


def _memory_stats(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
    except Exception as e:  # pragma: no cover - backend specific
        out["error"] = str(e)
    return out


def _cost(compiled):
    try:
        c = compiled.cost_analysis()
        if isinstance(c, list):
            c = c[0]
        return {k: float(v) for k, v in c.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


# per-variant launcher knobs (model-side flags live in perf_flags.VARIANTS)
VARIANT_KNOBS = {
    "baseline":   dict(fsdp=True, headdim=False),
    "moe_shard":  dict(fsdp=True, headdim=False),
    "no_fsdp":    dict(fsdp=False, headdim=False),
    "decode_opt": dict(fsdp=False, headdim=True),
    "seqpar":     dict(fsdp=True, headdim=False),
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               perf_variant: str = "baseline"):
    """Lower + compile one cell. Returns (report dict, compiled)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    model = Model(cfg, use_kernels=True, remat=True)
    p_specs = specs.params_specs(cfg)
    t0 = time.time()

    # hillclimb knobs (see EXPERIMENTS.md §Perf)
    base = perf_variant.split("+")[0]
    knobs = VARIANT_KNOBS.get(base, VARIANT_KNOBS["baseline"])
    fsdp = knobs["fsdp"]
    mb = TRAIN_MICROBATCHES.get(shape_name, 1)
    for part in perf_variant.split("+")[1:]:
        if part.startswith("mb"):
            mb = int(part[2:])

    flags_name = base if base in VARIANTS else "baseline"
    with use_variant(flags_name), mesh:
        ps = params_shardings(p_specs, mesh, fsdp=fsdp)
        if shape.kind == "train":
            tc = TrainConfig(microbatches=mb, remat=True)
            step = make_train_step(model, tc)
            o_specs = jax.eval_shape(lambda p: init_opt_state(p, tc), p_specs)
            os_ = params_shardings(
                {"master": o_specs["master"], "mu": o_specs["mu"],
                 "nu": o_specs["nu"]}, mesh, fsdp=fsdp)
            opt_sh = {"step": NamedSharding(mesh, P()), **os_}
            batch = specs.train_batch_specs(cfg, shape)
            bs = batch_shardings(batch, mesh)
            fn = jax.jit(step, in_shardings=(ps, opt_sh, bs),
                         out_shardings=(ps, opt_sh, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_specs, o_specs, batch)
        elif shape.kind == "prefill":
            batch = specs.prefill_batch_specs(cfg, shape)
            bs = batch_shardings(batch, mesh)
            out_caches = jax.eval_shape(model.prefill, p_specs, batch)[1]
            ocs = cache_shardings(out_caches, mesh)
            fn = jax.jit(model.prefill, in_shardings=(ps, bs),
                         out_shardings=(None, ocs))
            lowered = fn.lower(p_specs, batch)
        else:  # decode
            model_d = Model(cfg, use_kernels=True)
            B = shape.global_batch
            capacity = shape.seq_len + DECODE_HEADROOM
            enc_len = shape.seq_len if cfg.encoder_groups is not None else 0
            caches = jax.eval_shape(
                lambda: model_d.init_cache(B, capacity, enc_len=enc_len))
            cs = cache_shardings(caches, mesh,
                                 shard_seq_over_data=(B == 1),
                                 shard_headdim=knobs["headdim"])
            tok = jax.ShapeDtypeStruct((B,), jnp.int32)
            lng = jax.ShapeDtypeStruct((B,), jnp.int32)
            ts = batch_shardings({"t": tok}, mesh)["t"]
            fn = jax.jit(model_d.decode_step,
                         in_shardings=(ps, ts, cs, ts),
                         out_shardings=(None, cs),
                         donate_argnums=(2,))
            lowered = fn.lower(p_specs, tok, caches, lng)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = _cost(compiled)
    mem = _memory_stats(compiled)
    hlo = compiled.as_text()
    mesh_name = "multi" if multi_pod else "single"
    rep = analyze(arch, shape_name, mesh_name, chips, cost, hlo, cfg, shape,
                  shape.kind, memory_stats=mem.get("temp_size_in_bytes", 0))
    report = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "perf_variant": perf_variant,
        "chips": chips, "kind": shape.kind,
        "mesh_fingerprint": mesh_fingerprint(mesh),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost_analysis": cost, "memory_analysis": mem,
        "roofline": rep.to_dict(),
        "hlo_bytes_len": len(hlo),
    }
    return report, compiled


def cell_list(archs=None, shapes=None, include_paper_model=False):
    cfgs = all_configs(assigned_only=not include_paper_model)
    out = []
    for name, cfg in cfgs.items():
        if archs and name not in archs:
            continue
        for sname, shape in SHAPES.items():
            if shapes and sname not in shapes:
                continue
            if shape.sub_quadratic_only and not cfg.runs_long_context:
                continue
            if name == "kimi-linear-1t" and shape.kind == "train":
                continue  # 1T training needs >512 v5e chips (documented)
            out.append((name, sname))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--perf-variant", default="baseline")
    ap.add_argument("--include-paper-model", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = cell_list([args.arch] if args.arch else None,
                      [args.shape] if args.shape else None,
                      include_paper_model=args.include_paper_model)
    failures = []
    for arch, sname in cells:
        for multi in meshes:
            mesh_name = "multi" if multi else "single"
            tag = f"{arch}__{sname}__{mesh_name}"
            if args.perf_variant != "baseline":
                tag += f"__{args.perf_variant}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[skip] {tag}")
                continue
            print(f"[run ] {tag} ...", flush=True)
            try:
                report, _ = lower_cell(arch, sname, multi,
                                       args.perf_variant)
                with open(path, "w") as f:
                    json.dump(report, f, indent=1)
                r = report["roofline"]
                print(f"[ok  ] {tag}: compile={report['compile_s']}s "
                      f"dominant={r['dominant']} "
                      f"roofline={r['roofline_frac']:.3f} "
                      f"(c={r['t_compute']:.4f}s m={r['t_memory']:.4f}s "
                      f"x={r['t_collective']:.4f}s)", flush=True)
            except Exception as e:
                failures.append((tag, str(e)))
                with open(path + ".fail", "w") as f:
                    f.write(traceback.format_exc())
                print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}",
                      flush=True)
    print(f"\n{len(cells) * len(meshes) - len(failures)} ok, "
          f"{len(failures)} failed")
    if failures:
        for t, e in failures:
            print(" -", t, e[:120])
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Serving launcher: the two-cluster PrfaaS-PD deployment, end to end.

    PYTHONPATH=src python -m repro.launch.serve --arch kimi-linear-1t \
        --smoke --requests 8 --threshold 64
"""
import argparse
import json

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import Model
from repro.serving import CrossDCDeployment, DeploymentConfig, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--threshold", type=int, default=64)
    ap.add_argument("--link-gbps", type=float, default=1.0)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    dep = CrossDCDeployment(
        model, params,
        DeploymentConfig(threshold=args.threshold, capacity=512,
                         decode_slots=max(4, args.requests),
                         link_gbps=args.link_gbps))
    rng = np.random.default_rng(args.seed)
    lens = rng.integers(8, 256, args.requests)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size, (int(L),))
                    .astype(np.int32),
                    max_new_tokens=args.max_new_tokens)
            for i, L in enumerate(lens)]
    out = dep.submit_batch(reqs)
    for rid in sorted(out):
        r, resp = reqs[rid], out[rid]
        print(f"req {rid}: len={len(r.tokens):4d} route={r.route:7s} "
              f"kv={r.kv_bytes:9d}B ttft={r.ttft_s*1000:8.1f}ms "
              f"tokens={resp.output_tokens[:8]}...")
    print(json.dumps(dep.metrics(), indent=1, default=str))


if __name__ == "__main__":
    main()

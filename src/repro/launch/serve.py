"""Multi-region serving launcher + policy/actual cross-validation.

Drives the live ``CrossDCDeployment`` — N PD regions fed by one PrfaaS
cluster over a ``LinkTopology``, all routed by the SAME
``core.router.Router`` the simulator uses — under a sessionful synthetic
workload, then (``--cross-validate``) replays the live run's arrival trace
through ``core.simulator.PrfaasSimulator`` and reports per-request route
agreement plus TTFT/egress deltas.  With ``--freeze-thresholds`` (no
congestion feedback on either side) the two control planes are the same
code over the same state and must agree on EVERY request; with live
feedback they may drift slightly where telemetry timing differs.  Two
fidelity caveats: (a) freezing pins thresholds, not the abundant/scarce
bandwidth regime — ``Router.route`` still reads live link utilization, so
exact agreement additionally needs links that stay on one side of
``util_abundant`` (true for the fat-link smoke configs; a deliberately
saturated link can legitimately flip a request); (b) the live TTFT/egress
are upper bounds, not equalities — the in-process deployment reships the
FULL prefill cache even when a prefix was cached (decode engines share no
storage), while the simulator charges incremental ``S_kv(total) -
S_kv(cached)`` bytes, so the reported egress ratio dips below 1 on
sessionful workloads.

    PYTHONPATH=src python -m repro.launch.serve --arch kimi-linear-1t \
        --smoke --requests 12 --pd-clusters 3 --pd-mesh-gbps 10 \
        --wire-compression --freeze-thresholds --cross-validate

The topology flags (``--pd-clusters/--pd-shares/--pd-link-gbps/
--pd-mesh-gbps``) mirror ``SimConfig`` so a planned simulator scenario maps
1:1 onto a live launch.
"""
import argparse
import json

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import (PrfaasSimulator, SimConfig, SystemConfig,
                        ThroughputModel, Workload)
from repro.core.hardware import CHIPS, AnalyticProfile
from repro.serving import CrossDCDeployment, DeploymentConfig, Request


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8, help="total requests")
    ap.add_argument("--batches", type=int, default=2)
    ap.add_argument("--threshold", type=int, default=64)
    ap.add_argument("--link-gbps", type=float, default=1.0,
                    help="PrfaaS->region star link capacity (all regions)")
    ap.add_argument("--pd-clusters", type=int, default=1)
    ap.add_argument("--pd-shares", type=str, default=None,
                    help="comma-separated regional traffic shares")
    ap.add_argument("--pd-link-gbps", type=str, default=None,
                    help="comma-separated per-region star-link Gbps")
    ap.add_argument("--pd-mesh-gbps", type=float, default=0.0)
    ap.add_argument("--wire-compression", action="store_true",
                    help="int8-quantize KV on the inter-DC wire")
    ap.add_argument("--calibration", default=None,
                    help="BENCH_kernel.json from benchmarks.kernel_bench: "
                         "route thresholds + simulator service times then "
                         "derive from this machine's measured kernels "
                         "(CalibratedProfile) instead of the default "
                         "chip roofline")
    ap.add_argument("--freeze-thresholds", action="store_true",
                    help="disable congestion feedback (deterministic "
                         "routing for exact cross-validation)")
    ap.add_argument("--cross-validate", action="store_true",
                    help="replay the live arrival trace through "
                         "PrfaasSimulator and report route agreement")
    ap.add_argument("--session-prob", type=float, default=0.35,
                    help="P(request continues an open session)")
    ap.add_argument("--decode-block-size", type=int, default=8,
                    help="tokens per on-device decode block (admission "
                         "happens at these boundaries, live and replayed)")
    ap.add_argument("--max-prefill-bucket", type=int, default=None,
                    help="pow2 bucket cap; longer prompts run as chunked "
                         "prefill interleaved between decode blocks")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="decode sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample from the top-k logits (0 = full vocab)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft tokens per verify round "
                         "(0 = plain decode; greedy only)")
    ap.add_argument("--spec-ngram", type=int, default=2,
                    help="drafter suffix-match length")
    ap.add_argument("--tbt-slo", type=float, default=0.0,
                    help="TBT SLO seconds for attainment metrics (0 = off)")
    ap.add_argument("--batch-gap-s", type=float, default=120.0,
                    help="virtual seconds between batches (replay spacing)")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def _parse_floats(text, k, what):
    if text is None:
        return None
    vals = tuple(float(x) for x in text.split(","))
    if len(vals) != k:
        raise SystemExit(f"{what} needs {k} comma-separated values")
    return vals


def _session_tokens(seed: int, sid: int, length: int, vocab: int):
    """Deterministic per-session token stream: a longer turn of the same
    session is an exact prefix extension (prefix-cache hits are real)."""
    rng = np.random.default_rng((seed * 1_000_003 + sid) & 0x7FFFFFFF)
    return rng.integers(0, vocab, (length,)).astype(np.int32)


def generate_workload(args, cfg, pd_names, shares):
    """Sessionful multi-region batches + the matching simulator trace.

    Returns (batches, trace) where ``trace`` rows are
    ``(arrival_s, total_len, session_id, home)`` in request order — exactly
    what ``PrfaasSimulator.inject_trace`` consumes."""
    from repro.core import split_even

    rng = np.random.default_rng(args.seed)
    sessions: dict = {}                    # sid -> (length, home)
    batches, trace = [], []
    rid, next_sid = 0, 0
    # exactly --requests total, remainder spread over the early batches
    # (fewer batches than asked when requests < batches)
    sizes = [n for n in split_even(args.requests, max(1, args.batches))
             if n > 0]
    for b, size in enumerate(sizes):
        arrival = b * args.batch_gap_s
        batch = []
        for _ in range(size):
            if sessions and rng.random() < args.session_prob:
                sid = sorted(sessions)[int(rng.integers(len(sessions)))]
                length, home = sessions[sid]
                length = min(length + int(rng.integers(16, 64)), 480)
                sessions[sid] = (length, home)
            else:
                sid, next_sid = next_sid, next_sid + 1
                length = int(rng.integers(8, 256))
                home = pd_names[int(rng.choice(len(pd_names), p=shares))] \
                    if len(pd_names) > 1 else pd_names[0]
                sessions[sid] = (length, home)
            batch.append(Request(
                rid=rid, tokens=_session_tokens(args.seed, sid, length,
                                                cfg.vocab_size),
                max_new_tokens=args.max_new_tokens, arrival=arrival,
                home=home))
            trace.append((arrival, length, sid, home))
            rid += 1
        batches.append(batch)
    return batches, trace


def cross_validate(args, model_cfg, dep: CrossDCDeployment, trace,
                   live_reqs) -> dict:
    """Replay the live run's arrival trace through the discrete-event
    simulator (same Router policy, same topology shape, analytic service
    times) and compare per-request routing plus TTFT/egress."""
    k = args.pd_clusters
    if dep.cfg.calibration:
        # the replay must price prefill with the SAME measured profile the
        # live Router used, or thresholds/agreement are meaningless
        profile = dep.profile
    else:
        profile = AnalyticProfile(
            model_cfg, CHIPS[dep.cfg.chip], dep.cfg.chips_per_instance,
            kv_dtype_bytes=2 if model_cfg.dtype == "bfloat16" else 4)
    w = Workload()
    tm = ThroughputModel(profile, profile, w)
    ratio = dep.measured_compression() if args.wire_compression else 1.0
    sc = SystemConfig(1, k, k, dep.system.b_out, float(args.threshold),
                      kv_wire_compression=ratio)
    horizon = trace[-1][0] + args.batch_gap_s + 60.0
    # price speculation with the LIVE run's measured acceptance: mean
    # accepted draft tokens per verify dispatch (0.0 when spec is off, so
    # the replay stays byte-identical to the pre-spec golden path)
    rounds = sum(d.verify_rounds for d in dep.decoders.values())
    accepted = sum(d.accepted_tokens for d in dep.decoders.values())
    accept_rate = (accepted / rounds - 1.0) if rounds else 0.0
    sim = PrfaasSimulator(tm, sc, w, SimConfig(
        arrival_rate=1.0, sim_time=horizon, seed=args.seed,
        link_gbps=args.link_gbps, pd_clusters=k,
        pd_shares=_parse_floats(args.pd_shares, k, "--pd-shares"),
        pd_link_gbps=_parse_floats(args.pd_link_gbps, k, "--pd-link-gbps"),
        pd_mesh_gbps=args.pd_mesh_gbps,
        block_tokens=dep.cfg.block_tokens,
        # replay decode admission at the live engine's block-boundary
        # cadence (the RegionScheduler admits at step_block boundaries)
        decode_block_tokens=dep.cfg.decode_block_size,
        spec_accept_rate=accept_rate, tbt_slo_s=dep.cfg.tbt_slo_s,
        pool_blocks=200_000, engine="event",
        # frozen: no control epochs -> per-home thresholds never move on
        # either side, so routing must agree exactly
        control_dt=0.0 if args.freeze_thresholds else 0.25))
    sim_reqs = sim.inject_trace(trace)
    sim.run()
    sim.topology.run_until_idle()

    routed = [(lr, sr) for lr, sr in zip(live_reqs, sim_reqs)
              if lr.decision is not None and sr.decision is not None]
    agree = [lr.decision.target == sr.decision.target for lr, sr in routed]
    mismatches = [
        {"rid": lr.rid, "live": lr.decision.target,
         "sim": sr.decision.target, "home": lr.home}
        for (lr, sr), ok in zip(routed, agree) if not ok]
    live_ttft = float(np.mean([lr.ttft_s for lr in live_reqs]))
    sim_ttft_v = [sr.first_token - sr.arrival for sr in sim_reqs
                  if sr.first_token > 0]
    sim_ttft = float(np.mean(sim_ttft_v)) if sim_ttft_v else float("nan")
    live_egress = dep.topology.sent_bytes
    sim_egress = sim.topology.sent_bytes
    return {
        "requests": len(routed),
        "route_agreement": (sum(agree) / len(agree)) if agree else 1.0,
        "mismatches": mismatches,
        "thresholds": {"live": {n: dep.router.threshold_for(n)
                                for n in dep.pd_names},
                       "sim": {n: sim.router.threshold_for(n)
                               for n in sim._pd_names}},
        "ttft": {"live_mean_s": live_ttft, "sim_mean_s": sim_ttft,
                 "delta_s": sim_ttft - live_ttft},
        "egress_bytes": {"live": live_egress, "sim": sim_egress,
                         "ratio": sim_egress / max(live_egress, 1.0)},
        "kv_wire_compression": ratio,
        "spec_accept_rate": accept_rate,
    }


def run_serve(args) -> dict:
    import jax

    from repro.models import Model

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    k = args.pd_clusters
    shares = _parse_floats(args.pd_shares, k, "--pd-shares")
    if shares is not None:
        shares = tuple(s / sum(shares) for s in shares)
    elif k > 1:
        shares = tuple([1.0 / k] * k)
    dep_cfg = DeploymentConfig(
        threshold=args.threshold, link_gbps=args.link_gbps,
        pd_link_gbps=_parse_floats(args.pd_link_gbps, k, "--pd-link-gbps"),
        pd_mesh_gbps=args.pd_mesh_gbps, pd_clusters=k,
        decode_slots=max(4, -(-args.requests // max(1, args.batches))),
        capacity=512, wire_compression=args.wire_compression,
        adapt_thresholds=not args.freeze_thresholds,
        decode_block_size=args.decode_block_size,
        max_prefill_bucket=args.max_prefill_bucket,
        temperature=args.temperature, top_k=args.top_k,
        sample_seed=args.seed,
        spec_k=args.spec_k, spec_ngram=args.spec_ngram,
        tbt_slo_s=args.tbt_slo,
        calibration=args.calibration)
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    dep = CrossDCDeployment(model, params, dep_cfg)

    batches, trace = generate_workload(args, cfg, dep.pd_names, shares)
    live_reqs = [r for batch in batches for r in batch]
    for batch in batches:
        dep.submit_batch(batch)

    report = {"deployment": dep.metrics()}
    if args.cross_validate:
        report["cross_validate"] = cross_validate(args, cfg, dep, trace,
                                                  live_reqs)
    report["_requests"] = live_reqs       # stripped before printing
    return report


def main():
    args = build_parser().parse_args()
    report = run_serve(args)
    for r in report.pop("_requests"):
        print(f"req {r.rid}: len={len(r.tokens):4d} home={r.home:5s} "
              f"route={r.route:7s} cached={r.cached_tokens:4d} "
              f"kv={r.kv_bytes:9d}B ttft={r.ttft_s*1000:8.1f}ms")
    print(json.dumps(report, indent=1, default=str))


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation anywhere: params/opt-state come from ``eval_shape`` of
the init functions, batches are synthesized structs, and decode caches come
from ``eval_shape`` of ``Model.init_cache``. Modality frontends are STUBS:
VLM cells get precomputed patch embeddings, audio cells get precomputed
frame embeddings, per the assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.configs.base import ModelConfig
from repro.models import Model


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _frontend_extras(cfg: ModelConfig, batch: int, seq: int, specs: dict):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.num_image_patches:
        specs["patches"] = _sds((batch, cfg.num_image_patches, cfg.d_model),
                                dt)
    if cfg.encoder_groups is not None:
        specs["frames"] = _sds((batch, seq, cfg.encoder_input_dim), dt)
    return specs


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    specs = {"tokens": _sds((shape.global_batch, shape.seq_len + 1),
                            jnp.int32)}
    return _frontend_extras(cfg, shape.global_batch, shape.seq_len, specs)


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    specs = {"tokens": _sds((shape.global_batch, shape.seq_len), jnp.int32)}
    return _frontend_extras(cfg, shape.global_batch, shape.seq_len, specs)


def decode_specs(cfg: ModelConfig, shape: ShapeSpec):
    """(tokens, caches, lengths) structs for serve_step: one new token with
    a KV cache of seq_len."""
    model = Model(cfg, use_kernels=True)
    B = shape.global_batch
    capacity = shape.seq_len + 8            # decode headroom
    enc_len = shape.seq_len if cfg.encoder_groups is not None else 0
    caches = jax.eval_shape(
        lambda: model.init_cache(B, capacity, enc_len=enc_len))
    tokens = _sds((B,), jnp.int32)
    lengths = _sds((B,), jnp.int32)
    return tokens, caches, lengths


def params_specs(cfg: ModelConfig):
    model = Model(cfg, use_kernels=True)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

"""Production mesh construction.

Single-pod: (16, 16) -> ("data", "model")   = 256 chips (one v5e pod)
Multi-pod : (2, 16, 16) -> ("pod", "data", "model") = 512 chips

Defined as a FUNCTION so importing this module never touches jax device
state (device count is locked at first jax init — dryrun.py sets
XLA_FLAGS before importing anything).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_fingerprint(mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())


def make_local_mesh():
    """1-device mesh with the production axis names (tests/examples)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)

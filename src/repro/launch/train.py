"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --steps 50 --batch 8 --seq 128

On this CPU container use --smoke (reduced config). On real hardware the
same entry point builds the production mesh and shards params/batch with
the rules in repro.distributed.sharding.
"""
import argparse
import json

import jax

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_local_mesh, make_production_mesh, \
    mesh_fingerprint
from repro.models import Model
from repro.training import (AdamWConfig, DataConfig, SyntheticLM,
                            TrainConfig, TrainLoop, init_opt_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg, use_kernels=False, remat=True)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    params = model.init(jax.random.PRNGKey(0))
    tc = TrainConfig(
        microbatches=args.microbatches, remat=True,
        compress_grads=args.compress_grads,
        adamw=AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                          total_steps=args.steps),
        checkpoint_every=max(10, args.steps // 4),
        checkpoint_dir=args.ckpt_dir)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch))
    loop = TrainLoop(model, tc, data, mesh_fingerprint=mesh_fingerprint(mesh))
    with mesh:
        _, _, hist = loop.run(params, init_opt_state(params, tc), args.steps)
    print(json.dumps({"first_loss": hist[0]["loss"],
                      "final_loss": hist[-1]["loss"],
                      "steps": len(hist),
                      "mean_step_s": sum(h["time_s"] for h in hist)
                      / len(hist)}, indent=1))


if __name__ == "__main__":
    main()

"""Unified Model API: init / train_loss / prefill / decode_step.

Every architecture (dense, MoE, hybrid, SSM, VLM, enc-dec) is the same
machine: a stack of repeated block groups applied with ``lax.scan`` over the
repeats (stacked parameters), which keeps HLO size ~O(#distinct blocks)
instead of O(#layers) — essential for 50+ layer dry-run compiles.

Cache layout (what prefill produces and PrfaaS ships): a pytree mirroring
the group structure; per block one of
  * {"k","v"}:    (R, B, S, Hkv, D)      full attention
  * {"ckv","kpe"}:(R, B, S, rank/rope)   MLA latent
  * {"state"[, "conv"]}: O(1) recurrent state   linear mixers
  * {"state": {c,n,m,h}}: sLSTM scalar cells
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (AttentionSpec, BlockSpec, GroupSpec,
                                LinearSpec, ModelConfig)
from repro.models import attention as attn_mod
from repro.models import linear_attention as lin_mod
from repro.models.layers import (apply_ffn, apply_moe, init_ffn, init_linear,
                                 init_moe, moe_aux_loss, rms_norm)
from repro.models.perf_flags import FLAGS, shard_hint

AUX_LOSS_WEIGHT = 0.01


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def sinusoidal_positions(positions, d_model):
    """positions: (B, S) -> (B, S, d) float32 sinusoidal embedding."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class Model:
    """Functional model wrapper; all methods are jit/shard-friendly."""

    def __init__(self, cfg: ModelConfig, use_kernels: bool = True,
                 remat: bool = False, moe_dropless_inference: bool = True):
        self.cfg = cfg
        self.use_kernels = use_kernels
        self.remat = remat
        # serving path uses exact (dropless) MoE so decode-from-cache
        # reproduces prefill logits; training keeps capacity semantics
        self.moe_dropless_inference = moe_dropless_inference
        self._inference = False
        self.unroll = False          # cost-probe mode (analysis.costfit)

    # ------------------------------------------------------------------ init

    def _init_block(self, rng, spec: BlockSpec):
        cfg = self.cfg
        dt = _dtype(cfg)
        ks = jax.random.split(rng, 6)
        p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32)}
        m = spec.mixer
        if isinstance(m, AttentionSpec):
            p["mixer"] = attn_mod.init_attention(ks[0], cfg.d_model, m, dt)
        else:
            p["mixer"] = lin_mod.init_linear_mixer(ks[0], cfg.d_model, m, dt)
        if spec.cross is not None:
            p["ln_cross"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["cross"] = attn_mod.init_attention(ks[1], cfg.d_model,
                                                 spec.cross, dt)
        if spec.ffn.kind == "dense":
            p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["ffn"] = init_ffn(ks[2], cfg.d_model, spec.ffn, dt)
        elif spec.ffn.kind == "moe":
            p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["ffn"] = init_moe(ks[2], cfg.d_model, spec.ffn, dt)
        return p

    def _init_group(self, rng, g: GroupSpec):
        """Stacked params (R, ...) for unshared blocks; single for shared."""
        stacked, shared = {}, {}
        for bi, b in enumerate(g.blocks):
            key = jax.random.fold_in(rng, bi)
            if b.shared:
                shared[f"b{bi}"] = self._init_block(key, b)
            else:
                reps = [self._init_block(jax.random.fold_in(key, r), b)
                        for r in range(g.repeats)]
                stacked[f"b{bi}"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *reps)
        return {"stacked": stacked, "shared": shared}

    def init(self, rng):
        cfg = self.cfg
        dt = _dtype(cfg)
        ks = jax.random.split(rng, 8 + len(cfg.groups)
                              + len(cfg.encoder_groups or ()))
        p = {
            "embed": jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                       dt) * 0.02,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "groups": [self._init_group(ks[8 + i], g)
                       for i, g in enumerate(cfg.groups)],
        }
        if not cfg.tie_embeddings:
            p["unembed"] = jax.random.normal(
                ks[1], (cfg.d_model, cfg.vocab_size), dt) * 0.02
        if cfg.encoder_groups:
            off = 8 + len(cfg.groups)
            p["enc_groups"] = [self._init_group(ks[off + i], g)
                               for i, g in enumerate(cfg.encoder_groups)]
            p["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
            if cfg.encoder_input_dim:
                p["enc_proj"] = init_linear(ks[2], cfg.encoder_input_dim,
                                            cfg.d_model, dt)
        if cfg.num_image_patches:
            p["patch_proj"] = init_linear(ks[3], cfg.d_model, cfg.d_model, dt)
        return p

    # ------------------------------------------------------- block dispatch

    def _apply_block(self, spec: BlockSpec, p, x, positions, *, causal=True,
                     enc_out=None, aux=None, lengths=None):
        """Full-sequence (train/prefill). Returns (x, cache, aux).

        ``lengths`` (B,): valid token counts of a right-padded batch
        (bucketed prefill).  Attention needs no masking — causal attention
        at positions < length never sees padding, and pad K/V rows are
        trimmed/overwritten downstream — but linear mixers must hold their
        recurrent state past each row's length (see ``linear_forward``).
        """
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        m = spec.mixer
        if isinstance(m, AttentionSpec):
            y, cache = attn_mod.attention_forward(
                p["mixer"], h, m, positions, causal=causal,
                use_kernels=self.use_kernels)
        else:
            y, cache = lin_mod.linear_forward(p["mixer"], h, m,
                                              lengths=lengths,
                                              use_kernels=self.use_kernels)
        x = x + y
        if spec.cross is not None:
            h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
            y, ccache = attn_mod.attention_forward(
                p["cross"], h, spec.cross, positions, kv_source=enc_out,
                use_kernels=self.use_kernels)
            x = x + y
            cache = {"self": cache, "cross": ccache}
        if spec.ffn.kind == "dense":
            x = x + apply_ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps),
                              spec.ffn)
        elif spec.ffn.kind == "moe":
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + apply_moe(p["ffn"], h, spec.ffn,
                              dropless=self._moe_dropless(
                                  h.shape[0] * h.shape[1]))
            if aux is not None:
                aux = aux + moe_aux_loss(p["ffn"], h, spec.ffn)
        return x, cache, aux

    def _moe_dropless(self, tokens: int):
        return (self._inference and self.moe_dropless_inference
                and tokens <= FLAGS.moe_dropless_max_tokens)

    def _decode_block(self, spec: BlockSpec, p, x, cache, lengths,
                      tables=None, page_tokens=None, capacity=None):
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        m = spec.mixer
        own_cache = cache["self"] if spec.cross is not None else cache
        if isinstance(m, AttentionSpec):
            if tables is not None:
                if spec.cross is not None:
                    raise ValueError("paged decode does not support "
                                     "cross-attention blocks")
                y, new_cache = attn_mod.attention_decode_paged(
                    p["mixer"], h, m, own_cache, lengths, tables,
                    page_tokens=page_tokens, capacity=capacity,
                    use_kernels=self.use_kernels)
            else:
                y, new_cache = attn_mod.attention_decode(
                    p["mixer"], h, m, own_cache, lengths,
                    use_kernels=self.use_kernels)
        else:
            y, new_cache = lin_mod.linear_decode(p["mixer"], h, m, own_cache,
                                                 use_kernels=self.use_kernels)
        x = x + y
        if spec.cross is not None:
            h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
            y, _ = attn_mod.attention_decode(p["cross"], h, spec.cross,
                                             cache["cross"], lengths,
                                             use_kernels=self.use_kernels)
            x = x + y
            new_cache = {"self": new_cache, "cross": cache["cross"]}
        if spec.ffn.kind == "dense":
            x = x + apply_ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps),
                              spec.ffn)
        elif spec.ffn.kind == "moe":
            x = x + apply_moe(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps),
                              spec.ffn,
                              dropless=self._moe_dropless(x.shape[0]))
        return x, new_cache

    # ------------------------------------------------------------ stacks

    def _run_groups(self, groups, params_groups, x, positions, *, causal=True,
                    enc_out=None, collect_aux=False, lengths=None):
        """scan over repeats of each group. Returns (x, caches, aux)."""
        aux_total = jnp.zeros((), jnp.float32) if collect_aux else None
        all_caches = []
        for g, gp in zip(groups, params_groups):
            def body(carry, rep_params, _g=g, _gp=gp):
                x, aux = carry
                caches = {}
                for bi, bspec in enumerate(_g.blocks):
                    p = (_gp["shared"][f"b{bi}"] if bspec.shared
                         else rep_params[f"b{bi}"])
                    x, c, aux = self._apply_block(
                        bspec, p, x, positions, causal=causal,
                        enc_out=enc_out, aux=aux, lengths=lengths)
                    caches[f"b{bi}"] = c
                if FLAGS.sequence_parallel:
                    x = shard_hint(x, ("pod", "data"), "model", None)
                return (x, aux), caches

            if self.remat:
                body = jax.checkpoint(body)
            if gp["stacked"]:
                (x, aux_total), caches = jax.lax.scan(
                    body, (x, aux_total), gp["stacked"],
                    unroll=True if self.unroll else 1)
            else:  # group of only-shared blocks
                caches = []
                for _ in range(g.repeats):
                    (x, aux_total), c = body((x, aux_total), {})
                    caches.append(c)
                caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
            all_caches.append(caches)
        return x, all_caches, aux_total

    def _decode_groups(self, groups, params_groups, x, caches, lengths,
                       tables=None, page_tokens=None, capacity=None):
        new_all = []
        for g, gp, gc in zip(groups, params_groups, caches):
            def body(x, xs, _g=g, _gp=gp):
                rep_params, rep_caches = xs
                new_caches = {}
                for bi, bspec in enumerate(_g.blocks):
                    p = (_gp["shared"][f"b{bi}"] if bspec.shared
                         else rep_params[f"b{bi}"])
                    x, c = self._decode_block(bspec, p, x,
                                              rep_caches[f"b{bi}"], lengths,
                                              tables=tables,
                                              page_tokens=page_tokens,
                                              capacity=capacity)
                    new_caches[f"b{bi}"] = c
                return x, new_caches

            x, new_caches = jax.lax.scan(body, x, (gp["stacked"], gc),
                                         unroll=True if self.unroll else 1)
            new_all.append(new_caches)
        return x, new_all

    def _apply_block_chunk(self, spec: BlockSpec, p, x, positions, cache,
                           lengths):
        """One block of an incremental (chunked) prefill: attention blocks
        append to / attend over their prior-chunk cache, linear mixers
        continue from their carried state. Returns (x, merged cache)."""
        cfg = self.cfg
        if spec.cross is not None:
            raise ValueError("chunked prefill does not support cross-attn")
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        m = spec.mixer
        if isinstance(m, AttentionSpec):
            y, new_cache = attn_mod.attention_forward_chunk(
                p["mixer"], h, m, positions, cache,
                use_kernels=self.use_kernels)
        else:
            y, new_cache = lin_mod.linear_forward(
                p["mixer"], h, m, initial_state=cache["state"],
                conv_state=cache.get("conv"), lengths=lengths,
                use_kernels=self.use_kernels)
        x = x + y
        if spec.ffn.kind == "dense":
            x = x + apply_ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps),
                              spec.ffn)
        elif spec.ffn.kind == "moe":
            x = x + apply_moe(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps),
                              spec.ffn,
                              dropless=self._moe_dropless(
                                  x.shape[0] * x.shape[1]))
        return x, new_cache

    def _chunk_groups(self, groups, params_groups, x, positions, caches,
                      lengths):
        new_all = []
        for g, gp, gc in zip(groups, params_groups, caches):
            def body(x, xs, _g=g, _gp=gp):
                rep_params, rep_caches = xs
                new_caches = {}
                for bi, bspec in enumerate(_g.blocks):
                    p = (_gp["shared"][f"b{bi}"] if bspec.shared
                         else rep_params[f"b{bi}"])
                    x, c = self._apply_block_chunk(
                        bspec, p, x, positions, rep_caches[f"b{bi}"], lengths)
                    new_caches[f"b{bi}"] = c
                return x, new_caches

            if gp["stacked"]:
                x, new_caches = jax.lax.scan(
                    body, x, (gp["stacked"], gc),
                    unroll=True if self.unroll else 1)
            else:  # group of only-shared blocks
                reps = []
                for r in range(g.repeats):
                    x, c = body(x, ({}, jax.tree.map(lambda t: t[r], gc)))
                    reps.append(c)
                new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
            new_all.append(new_caches)
        return x, new_all

    # --------------------------------------------------------------- embeds

    def _embed_tokens(self, params, tokens):
        return params["embed"][tokens]

    def _logits(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            return x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
        return x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32)

    def _encode(self, params, frames, positions):
        cfg = self.cfg
        x = frames
        if cfg.encoder_input_dim:
            x = x.astype(_dtype(cfg)) @ params["enc_proj"]["w"]
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
        x, _, _ = self._run_groups(cfg.encoder_groups, params["enc_groups"],
                                   x, positions, causal=False)
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _decoder_input(self, params, batch):
        """Embeds tokens (+ VLM patches, + sinusoidal pos for non-rope)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        n_prefix = 0
        if cfg.num_image_patches:
            patches = batch["patches"].astype(x.dtype) @ params["patch_proj"]["w"]
            x = jnp.concatenate([patches, x], axis=1)
            n_prefix = patches.shape[1]
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        if cfg.encoder_groups is not None:
            x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
        return x, positions, n_prefix

    # ------------------------------------------------------------------ API

    def train_loss(self, params, batch):
        """batch: {"tokens": (B, S+1)} [+ "patches" | + "frames"].

        Next-token CE over the token stream (VLM patch positions excluded).
        Returns (loss, metrics dict).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs = {**batch, "tokens": tokens[:, :-1]}
        labels = tokens[:, 1:]
        x, positions, n_prefix = self._decoder_input(params, inputs)
        enc_out = None
        if cfg.encoder_groups is not None:
            B, S_enc = batch["frames"].shape[:2]
            enc_pos = jnp.broadcast_to(
                jnp.arange(S_enc, dtype=jnp.int32)[None], (B, S_enc))
            enc_out = self._encode(params, batch["frames"], enc_pos)
        x, _, aux = self._run_groups(cfg.groups, params["groups"], x,
                                     positions, enc_out=enc_out,
                                     collect_aux=True)
        if n_prefix:
            x = x[:, n_prefix:]
        loss = self._chunked_ce(params, x, labels)
        total = loss + AUX_LOSS_WEIGHT * aux / max(1, cfg.n_layers)
        return total, {"ce": loss, "aux": aux}

    # chunk size for the CE scan: bounds the transient (B, C, V) logits —
    # essential for huge-vocab archs (seamless V=256206 is not divisible by
    # |model|, so full-sequence logits cannot shard over the model axis and
    # would replicate ~62 GB f32 per device)
    CE_CHUNK = 512

    def _chunked_ce(self, params, x, labels):
        """Exact mean next-token CE via a scan over sequence chunks; full
        (B, S, V) logits are never materialized (log_softmax is per-position,
        so chunking is semantics-preserving)."""
        B, S, d = x.shape
        C = min(self.CE_CHUNK, S)
        pad = (-S) % C
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)))
        nc = (S + pad) // C
        xc = x.reshape(B, nc, C, d).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, nc, C).transpose(1, 0, 2)
        valid = (jnp.arange(S + pad) < S).reshape(nc, C)

        def body(acc, inp):
            xb, lb, vb = inp
            xb = shard_hint(xb, ("pod", "data"), None, None)
            logits = self._logits(params, xb)                # (B, C, V) f32
            logp = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(logp, lb[..., None], -1)[..., 0]
            return acc + jnp.sum(ll * vb[None, :]), None

        total, _ = jax.lax.scan(
            jax.checkpoint(body),   # recompute chunk logits in backward
            jnp.zeros((), jnp.float32), (xc, lc, valid),
            unroll=True if self.unroll else 1)
        return -total / (B * S)

    def prefill(self, params, batch):
        """Returns (last_logits (B, V) f32, caches). The caches are the
        KVCache PrfaaS ships to the decode cluster.

        ``batch["lengths"]`` (B,), optional: per-row valid token counts of a
        right-padded batch (the serving engine's length buckets).  The
        logits are then taken at each row's ``lengths - 1`` position and
        linear-mixer states are held past each row's length, so outputs are
        exactly those of an unpadded prefill; without it the batch is
        treated as fully valid (legacy behavior, used by train/eval).
        """
        cfg = self.cfg
        self._inference = True
        lengths = batch.get("lengths")
        x, positions, n_prefix = self._decoder_input(params, batch)
        eff_lengths = None
        if lengths is not None:
            eff_lengths = lengths.astype(jnp.int32) + n_prefix
        enc_out = None
        if cfg.encoder_groups is not None:
            B, S_enc = batch["frames"].shape[:2]
            enc_pos = jnp.broadcast_to(
                jnp.arange(S_enc, dtype=jnp.int32)[None], (B, S_enc))
            enc_out = self._encode(params, batch["frames"], enc_pos)
        x, caches, _ = self._run_groups(cfg.groups, params["groups"], x,
                                        positions, enc_out=enc_out,
                                        lengths=eff_lengths)
        if eff_lengths is not None:
            x_last = jnp.take_along_axis(
                x, (eff_lengths - 1)[:, None, None], axis=1)
            logits = self._logits(params, x_last)[:, 0]
        else:
            logits = self._logits(params, x[:, -1:])[:, 0]
        self._inference = False
        return logits, {"groups": caches}

    def prefill_chunk(self, params, batch, caches=None):
        """One fixed-shape chunk of an incremental prefill (decoder-only).

        batch: {"tokens": (B, C), "positions": (B, C) absolute,
                "lengths": (B,) valid token counts WITHIN this chunk}.
        ``caches=None`` starts the prefill (plain bucket prefill of the
        first chunk); afterwards attention blocks attend over prior + new
        keys via the ``q_offset`` flash path and linear mixers carry state.
        Returns (hidden (B, C, d) pre-final-norm, caches) — the caller
        gathers last-token logits across chunks via ``last_logits``.
        """
        cfg = self.cfg
        if cfg.encoder_groups is not None or cfg.num_image_patches:
            raise ValueError("chunked prefill supports decoder-only token "
                             "models (no encoder / image prefix)")
        self._inference = True
        x = self._embed_tokens(params, batch["tokens"])
        positions = batch["positions"].astype(jnp.int32)
        lengths = batch.get("lengths")
        if lengths is not None:
            lengths = lengths.astype(jnp.int32)
        if caches is None:
            x, gc, _ = self._run_groups(cfg.groups, params["groups"], x,
                                        positions, lengths=lengths)
        else:
            x, gc = self._chunk_groups(cfg.groups, params["groups"], x,
                                       positions, caches["groups"], lengths)
        self._inference = False
        return x, {"groups": gc}

    def last_logits(self, params, hidden, lengths):
        """Gather per-row ``lengths - 1`` positions of ``hidden`` (B, S, d)
        and project to logits (B, V) — the chunked-prefill epilogue."""
        idx = (lengths.astype(jnp.int32) - 1)[:, None, None]
        x_last = jnp.take_along_axis(hidden, idx, axis=1)
        return self._logits(params, x_last)[:, 0]

    def decode_step(self, params, tokens, caches, lengths, tables=None,
                    page_tokens=None, capacity=None):
        """tokens: (B,) int32; lengths: (B,) current context sizes.

        ``tables``: optional paged-KV block tables ``{"seq": (B, capacity/T)
        int32, "ring": (B, W_buf/T) int32}`` — when given, ``caches`` holds
        page-pool leaves (see ``models/paged.py``) and ``page_tokens`` /
        ``capacity`` must be the (static) page size and slot capacity.

        Returns (logits (B, V) f32, updated caches).
        """
        cfg = self.cfg
        self._inference = True
        x = self._embed_tokens(params, tokens[:, None])
        if cfg.encoder_groups is not None:
            x = x + sinusoidal_positions(lengths[:, None],
                                         cfg.d_model).astype(x.dtype)
        x, new_caches = self._decode_groups(cfg.groups, params["groups"], x,
                                            caches["groups"], lengths,
                                            tables=tables,
                                            page_tokens=page_tokens,
                                            capacity=capacity)
        logits = self._logits(params, x)[:, 0]
        self._inference = False
        return logits, {"groups": new_caches}

    # ------------------------------------------------- speculative verify

    @property
    def _verify_parallel(self) -> bool:
        """True when every block is append-only full attention with a dense
        FFN (no SWA rings, no O(1) mixer state, no cross/encoder/VLM) — the
        shape where the batched one-pass verify scores q positions in a
        single shared sweep of the KV cache and nothing needs rollback."""
        cfg = self.cfg
        if cfg.encoder_groups is not None or cfg.num_image_patches:
            return False
        for g in cfg.groups:
            for b in g.blocks:
                m = b.mixer
                if not (isinstance(m, AttentionSpec) and m.kind == "full"
                        and not m.is_cross and b.cross is None
                        and b.ffn.kind == "dense"):
                    return False
        return True

    def _decode_block_verify(self, spec: BlockSpec, p, x, cache, lengths):
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, new_cache = attn_mod.gqa_decode_verify(
            p["mixer"], h, spec.mixer, cache, lengths,
            use_kernels=self.use_kernels)
        x = x + y
        x = x + apply_ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps),
                          spec.ffn)
        return x, new_cache

    def _decode_groups_verify(self, groups, params_groups, x, caches,
                              lengths):
        new_all = []
        for g, gp, gc in zip(groups, params_groups, caches):
            def body(x, xs, _g=g, _gp=gp):
                rep_params, rep_caches = xs
                new_caches = {}
                for bi, bspec in enumerate(_g.blocks):
                    p = (_gp["shared"][f"b{bi}"] if bspec.shared
                         else rep_params[f"b{bi}"])
                    x, c = self._decode_block_verify(
                        bspec, p, x, rep_caches[f"b{bi}"], lengths)
                    new_caches[f"b{bi}"] = c
                return x, new_caches

            x, new_caches = jax.lax.scan(body, x, (gp["stacked"], gc),
                                         unroll=True if self.unroll else 1)
            new_all.append(new_caches)
        return x, new_all

    def _ring_blocks(self, caches, fn):
        """Save pass: apply ``fn(spec, leaves) -> saved_rows`` to every SWA
        ring block's attention leaves (the only caches that need rollback
        after a rejected speculative suffix); non-ring blocks map to None."""
        out_groups = []
        for g, gc in zip(self.cfg.groups, caches["groups"]):
            ng = {}
            for bi, b in enumerate(g.blocks):
                c = gc[f"b{bi}"]
                m = b.mixer
                ring = (isinstance(m, AttentionSpec) and m.kind == "swa"
                        and m.window > 0 and not m.is_cross)
                if ring and b.cross is not None:
                    ng[f"b{bi}"] = {"self": fn(m, c["self"])}
                elif ring:
                    ng[f"b{bi}"] = fn(m, c)
                else:
                    ng[f"b{bi}"] = None
            out_groups.append(ng)
        return {"groups": out_groups}

    @staticmethod
    def _is_attn_leaf(path) -> bool:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        return name in ("k", "v", "ckv", "kpe")

    def decode_verify(self, params, seq, caches, lengths, tables=None,
                      page_tokens=None, capacity=None):
        """Score ``q = k + 1`` candidate positions per slot in ONE dispatch.

        ``seq``: (B, q) int32 — column 0 is each slot's current (already
        accepted, not yet processed) token, columns 1..k the drafted
        continuation.  Two strategies, both keeping greedy output
        token-identical to the plain one-token-per-dispatch path:
        append-only full-attn archs (dense layout) take a batched ONE-PASS
        verify over all q positions (``gqa_decode_verify`` — the perf win;
        float-equivalent attention, argmax-stable); everything else (SWA
        rings, linear/hybrid mixers, paged tables) runs ``q`` steps of the
        EXACT ``decode_step`` under a ``lax.scan``, bit-identical by
        construction.  (ISSUE 10 suggested
        reusing the chunked-prefill ``q_offset`` attention; that path uses
        un-absorbed MLA chunk math whose floating-point order differs from
        absorbed decode, which would break the greedy token-identity
        acceptance criterion — scanning the decode step keeps it exact.)

        Returns ``(logits (B, q, V) f32, caches after q writes, pending)``
        where ``pending`` carries what ``commit_verify`` needs to roll back
        the rejected suffix: pre-verify SWA ring rows (append-only caches
        need no rollback — a rejected position is never read before the real
        write lands there) and per-step snapshots of every O(1) mixer state
        (linear/conv/sLSTM), stacked along a leading (q,) axis.
        """
        q = seq.shape[1]
        if tables is None and self._verify_parallel:
            # Append-only full-attn arch: one batched pass over all q
            # positions (see ``gqa_decode_verify`` for the masking and
            # numerics argument); nothing to roll back, so ``pending`` is
            # empty and ``commit_verify`` is a no-op.
            self._inference = True
            x = self._embed_tokens(params, seq)              # (B,q,d)
            x, new_groups = self._decode_groups_verify(
                self.cfg.groups, params["groups"], x, caches["groups"],
                lengths)
            logits = self._logits(params, x)                 # (B,q,V) f32
            self._inference = False
            return logits, {"groups": new_groups}, {"rings": None,
                                                    "snaps": None}
        if tables is not None:
            saved = self._ring_blocks(
                caches, lambda m, c: attn_mod.ring_verify_save_paged(
                    c, lengths, q, tables["ring"], page_tokens=page_tokens,
                    capacity=capacity, window=m.window))
        else:
            saved = self._ring_blocks(
                caches, lambda m, c: attn_mod.ring_verify_save(
                    c, lengths, q))

        def snap_state(path, leaf):
            return jnp.zeros((), leaf.dtype) if self._is_attn_leaf(path) \
                else leaf

        def body(carry, tok):
            cc, lens = carry
            logits, cc = self.decode_step(params, tok, cc, lens,
                                          tables=tables,
                                          page_tokens=page_tokens,
                                          capacity=capacity)
            snap = jax.tree_util.tree_map_with_path(snap_state, cc)
            return (cc, lens + 1), (logits, snap)

        (caches, _), (logits, snaps) = jax.lax.scan(
            body, (caches, lengths), jnp.swapaxes(seq, 0, 1))
        pending = {"rings": saved, "snaps": snaps}
        return jnp.swapaxes(logits, 0, 1), caches, pending

    def commit_verify(self, caches, pending, lengths, accept, q,
                      tables=None, page_tokens=None, capacity=None):
        """Finalize a verify dispatch: roll back the SWA ring rows the
        rejected steps overwrote and rewind every O(1) mixer state to its
        post-``accept[b]``-step snapshot (step j is accepted iff
        ``j <= accept[b]``).  ``lengths`` must be the PRE-verify lengths the
        dispatch ran with."""
        if pending["snaps"] is None:     # parallel append-only verify path
            return caches
        caches = self._restore_rings(caches, pending["rings"], lengths,
                                     accept, q, tables=tables,
                                     page_tokens=page_tokens,
                                     capacity=capacity)

        def pick(path, leaf, snap):
            if self._is_attn_leaf(path):
                return leaf
            idx = accept.reshape((1, 1, -1) + (1,) * (snap.ndim - 3))
            return jnp.take_along_axis(snap, idx, axis=0)[0]

        return jax.tree_util.tree_map_with_path(pick, caches,
                                                pending["snaps"])

    def _restore_rings(self, caches, saved, lengths, accept, q, tables=None,
                       page_tokens=None, capacity=None):
        out_groups = []
        for g, gc, sg in zip(self.cfg.groups, caches["groups"],
                             saved["groups"]):
            ng = {}
            for bi, b in enumerate(g.blocks):
                c, s = gc[f"b{bi}"], sg[f"b{bi}"]
                m = b.mixer
                ring = (isinstance(m, AttentionSpec) and m.kind == "swa"
                        and m.window > 0 and not m.is_cross)
                if not ring:
                    ng[f"b{bi}"] = c
                    continue
                own = c["self"] if b.cross is not None else c
                sown = s["self"] if b.cross is not None else s
                if tables is not None:
                    new = attn_mod.ring_verify_restore_paged(
                        own, sown, lengths, accept, q, tables["ring"],
                        page_tokens=page_tokens, capacity=capacity,
                        window=m.window)
                else:
                    new = attn_mod.ring_verify_restore(own, sown, lengths,
                                                       accept, q)
                ng[f"b{bi}"] = ({"self": new, "cross": c["cross"]}
                                if b.cross is not None else new)
            out_groups.append(ng)
        return {"groups": out_groups}

    # ------------------------------------------------------- cache builders

    def init_cache(self, batch_size: int, capacity: int,
                   enc_len: int = 0):
        """Zeroed decode cache buffers with seq capacity ``capacity``.

        Used (a) under eval_shape to build dry-run input specs, (b) by the
        serving engine to allocate decode-side pools.
        """
        cfg = self.cfg
        dt = _dtype(cfg)
        B = batch_size

        def block_cache(bspec: BlockSpec):
            m = bspec.mixer
            if isinstance(m, AttentionSpec):
                S = m.kv_cache_tokens(capacity) if m.kind == "swa" else capacity
                c = {"k": jnp.zeros((B, S, m.kv_heads, m.head_dim), dt),
                     "v": jnp.zeros((B, S, m.kv_heads, m.head_dim), dt)}
                if m.kind == "mla":
                    c = {"ckv": jnp.zeros((B, capacity, m.mla_kv_rank), dt),
                         "kpe": jnp.zeros((B, capacity, m.mla_rope_dim), dt)}
            elif m.kind == "slstm":
                c = {"state": slstm_zero(B, m)}
            else:
                # mLSTM augments v with a normalizer column (dv + 1)
                dv = m.value_dim + (1 if m.kind == "mlstm" else 0)
                c = {"state": jnp.zeros((B, m.heads, m.key_dim, dv),
                                        jnp.float32)}
                if m.conv_kernel:
                    C = m.heads * (2 * m.key_dim + m.value_dim)
                    c["conv"] = jnp.zeros((B, m.conv_kernel - 1, C), dt)
            if bspec.cross is not None:
                cc = bspec.cross
                c = {"self": c,
                     "cross": {"k": jnp.zeros((B, enc_len, cc.kv_heads,
                                               cc.head_dim), dt),
                               "v": jnp.zeros((B, enc_len, cc.kv_heads,
                                               cc.head_dim), dt)}}
            return c

        groups = []
        for g in cfg.groups:
            gc = {}
            for bi, b in enumerate(g.blocks):
                one = block_cache(b)
                gc[f"b{bi}"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (g.repeats,) + x.shape), one)
            groups.append(gc)
        return {"groups": groups}


def slstm_zero(B, m: LinearSpec):
    z = jnp.zeros((B, m.heads, m.value_dim), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}


def prepare_decode_caches(cfg: ModelConfig, caches, capacity: int):
    """Place prefill-produced caches into decode buffers of ``capacity``.

    This is the decode-cluster side of the PrfaaS KV transfer: full-attn K/V
    and MLA latents are zero-padded to capacity; SWA layers keep only the
    last ``window`` entries, ring-placed at slot = position % window.
    """

    def place_attn(spec: AttentionSpec, c):
        if spec.kind == "mla":
            def padseq(x):
                pads = [(0, 0)] * x.ndim
                pads[2] = (0, capacity - x.shape[2])
                return jnp.pad(x, pads)
            return {k: padseq(v) for k, v in c.items()}
        S = c["k"].shape[2]
        if spec.kind == "swa" and spec.window and capacity > spec.window:
            W = min(spec.window, capacity)
            start = max(0, S - W)
            kept = min(S, W)
            # slot for global position s is s % W
            slots = (start + jnp.arange(kept)) % W
            order = jnp.argsort(slots)

            def ring(x):
                tail = x[:, :, start:]                       # (R,B,kept,...)
                buf = jnp.zeros(x.shape[:2] + (W,) + x.shape[3:], x.dtype)
                return buf.at[:, :, slots[order]].set(tail[:, :, order])

            return {k: ring(v) for k, v in c.items()}

        def padseq(x):
            pads = [(0, 0)] * x.ndim
            pads[2] = (0, max(0, capacity - x.shape[2]))
            return jnp.pad(x, pads)

        return {k: padseq(v) for k, v in c.items()}

    def place_block(bspec: BlockSpec, c):
        m = bspec.mixer
        if bspec.cross is not None:
            inner = (place_attn(m, c["self"])
                     if isinstance(m, AttentionSpec) else c["self"])
            return {"self": inner, "cross": c["cross"]}
        if isinstance(m, AttentionSpec):
            return place_attn(m, c)
        return c                                             # O(1) states

    out_groups = []
    for g, gc in zip(cfg.groups, caches["groups"]):
        out_groups.append({f"b{bi}": place_block(b, gc[f"b{bi}"])
                           for bi, b in enumerate(g.blocks)})
    return {"groups": out_groups}


def extend_caches(caches, extra: int):
    """Grow the seq capacity of prefill-produced caches by ``extra`` slots
    (zero-padded at the tail) so decode can append."""

    def pad(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v", "ckv", "kpe"):
            # (R, B, S, ...) -> pad axis 2
            pads = [(0, 0)] * leaf.ndim
            pads[2] = (0, extra)
            return jnp.pad(leaf, pads)
        return leaf

    return jax.tree_util.tree_map_with_path(pad, caches)

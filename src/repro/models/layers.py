"""Shared layer primitives: norms, RoPE, FFN, sort-based dropless MoE."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FFNSpec
from repro.models.perf_flags import FLAGS, shard_hint


def rms_norm(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, H, S, D); positions: (B, S) int32. Rotates pairs (2i, 2i+1)."""
    B, H, S, D = x.shape
    inv = rope_freqs(D, theta)                               # (D/2,)
    ang = positions.astype(jnp.float32)[:, None, :, None] * inv  # (B,1,S,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xf = x.astype(jnp.float32).reshape(B, H, S, D // 2, 2)
    x0, x1 = xf[..., 0], xf[..., 1]
    out = jnp.stack([x0 * cos - x1 * sin, x0 * sin + x1 * cos], axis=-1)
    return out.reshape(B, H, S, D).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------


def init_ffn(rng, d_model: int, spec: FFNSpec, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    std_in = d_model ** -0.5
    std_out = spec.d_ff ** -0.5
    p = {"w1": jax.random.normal(k1, (d_model, spec.d_ff), dtype) * std_in,
         "w2": jax.random.normal(k2, (spec.d_ff, d_model), dtype) * std_out}
    if spec.activation in ("swiglu", "geglu"):
        p["w3"] = jax.random.normal(k3, (d_model, spec.d_ff), dtype) * std_in
    return p


def apply_ffn(p, x, spec: FFNSpec):
    h = x @ p["w1"]
    if spec.activation == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"])
    elif spec.activation == "geglu":
        h = jax.nn.gelu(h) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(h)
    return h @ p["w2"]


# ---------------------------------------------------------------------------
# MoE: sort-based dropless-ish dispatch (gather/scatter, no TxExC einsum).
#
# Dense one-hot dispatch (GShard) costs O(T * E * C * d) matmul FLOPs, which
# at 352 experts exceeds the expert FLOPs themselves; the sort-based form is
# O(T*k log) index work + pure gathers, which XLA shards cleanly over the
# "model" axis (expert weights sharded on d_ff).
# ---------------------------------------------------------------------------


def init_moe(rng, d_model: int, spec: FFNSpec, dtype):
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    E, F = spec.num_experts, spec.d_ff
    std_in = d_model ** -0.5
    std_out = F ** -0.5
    p = {
        "router": jax.random.normal(k1, (d_model, E), jnp.float32) * std_in,
        "w1": jax.random.normal(k2, (E, d_model, F), dtype) * std_in,
        "w2": jax.random.normal(k3, (E, F, d_model), dtype) * std_out,
    }
    if spec.activation in ("swiglu", "geglu"):
        p["w3"] = jax.random.normal(k4, (E, d_model, F), dtype) * std_in
    if spec.shared_experts:
        shared = FFNSpec(kind="dense", d_ff=spec.d_ff * spec.shared_experts,
                         activation=spec.activation)
        p["shared"] = init_ffn(k5, d_model, shared, dtype)
    return p


def moe_capacity(T: int, spec: FFNSpec) -> int:
    cap = int(T * spec.top_k * spec.capacity_factor / spec.num_experts) + 1
    return max(8, min(cap, T))


def apply_moe_dropless(p, x, spec: FFNSpec):
    """Dropless MoE via ``lax.ragged_dot`` (MegaBlocks-style grouped GEMM).

    Exact (no capacity drop) — used on the serving path so that
    decode-from-cache reproduces prefill logits bit-for-bit. Training keeps
    the capacity-based path below (standard GShard semantics + aux loss).
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    T = x2.shape[0]
    E, k = spec.num_experts, spec.top_k

    logits = x2.astype(jnp.float32) @ p["router"]
    gate_logits, idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gate_logits, axis=-1)

    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(se, length=E).astype(jnp.int32)

    xs = x2[st]                                              # (T*k, d)
    if FLAGS.shard_moe_tokens:
        xs = shard_hint(xs, ("pod", "data"), None)
    h = jax.lax.ragged_dot(xs, p["w1"], counts)
    if spec.activation == "swiglu":
        h = jax.nn.silu(h) * jax.lax.ragged_dot(xs, p["w3"], counts)
    elif spec.activation == "geglu":
        h = jax.nn.gelu(h) * jax.lax.ragged_dot(xs, p["w3"], counts)
    else:
        h = jax.nn.gelu(h)
    ys = jax.lax.ragged_dot(h, p["w2"], counts)              # (T*k, d)
    if FLAGS.shard_moe_tokens:
        ys = shard_hint(ys, ("pod", "data"), "model")
    out = jnp.zeros((T, d), ys.dtype).at[st].add(
        ys * sg[:, None].astype(ys.dtype))
    if FLAGS.shard_moe_tokens:
        out = shard_hint(out, ("pod", "data"), None)

    if "shared" in p:
        shared = FFNSpec(kind="dense", d_ff=spec.d_ff * spec.shared_experts,
                         activation=spec.activation)
        out = out + apply_ffn(p["shared"], x2, shared)
    return out.reshape(orig_shape)


def apply_moe(p, x, spec: FFNSpec, dropless: bool = False):
    """x: (..., d) -> (..., d). Token-choice top-k with capacity drop.

    When FLAGS.moe_chunk_tokens is set and the batch is large, tokens are
    processed in a ``lax.scan`` over chunks: every dispatch/gather buffer is
    bounded by (chunk * k, d) regardless of total tokens — the GSPMD gather
    would otherwise replicate an (E*C, d) buffer across every device.
    """
    Q = FLAGS.moe_chunk_tokens
    total = 1
    for dim in x.shape[:-1]:
        total *= dim
    if Q and total > Q:
        d = x.shape[-1]
        x2 = x.reshape(-1, d)
        pad = (-total) % Q
        if pad:
            x2 = jnp.concatenate(
                [x2, jnp.zeros((pad, d), x2.dtype)], axis=0)
        chunks = x2.reshape(-1, Q, d)

        def body(_, xc):
            return None, _apply_moe_flat(p, xc, spec, dropless)

        _, out = jax.lax.scan(body, None, chunks)
        out = out.reshape(-1, d)[:total]
        return out.reshape(x.shape)
    return _apply_moe_flat(p, x, spec, dropless)


def _apply_moe_flat(p, x, spec: FFNSpec, dropless: bool = False):
    if dropless:
        return apply_moe_dropless(p, x, spec)
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    T = x2.shape[0]
    E, k = spec.num_experts, spec.top_k
    C = moe_capacity(T, spec)

    logits = (x2.astype(jnp.float32) @ p["router"])          # (T, E)
    gate_logits, idx = jax.lax.top_k(logits, k)              # (T, k)
    gates = jax.nn.softmax(gate_logits, axis=-1)             # (T, k)

    flat_e = idx.reshape(-1)                                 # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gates.reshape(-1)

    order = jnp.argsort(flat_e)                              # stable
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(se, length=E)                      # (E,)
    seg_start = jnp.cumsum(counts) - counts                  # exclusive
    pos_in_e = jnp.arange(T * k) - seg_start[se]
    keep = pos_in_e < C
    slot = se * C + jnp.where(keep, pos_in_e, 0)             # (T*k,)

    # gather tokens into (E*C, d); empty slots read a zero row
    buf_tok = jnp.full((E * C,), T, jnp.int32)
    buf_tok = buf_tok.at[jnp.where(keep, slot, E * C)].set(
        st.astype(jnp.int32), mode="drop")
    x_pad = jnp.concatenate([x2, jnp.zeros((1, d), x2.dtype)], axis=0)
    xs = x_pad[buf_tok].reshape(E, C, d)
    if FLAGS.shard_moe_tokens:
        xs = shard_hint(xs, None, ("pod", "data"), None)

    h = jnp.einsum("ecd,edf->ecf", xs, p["w1"])
    if spec.activation == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xs, p["w3"])
    elif spec.activation == "geglu":
        h = jax.nn.gelu(h) * jnp.einsum("ecd,edf->ecf", xs, p["w3"])
    else:
        h = jax.nn.gelu(h)
    if FLAGS.shard_moe_tokens:
        h = shard_hint(h, None, ("pod", "data"), "model")
    ys = jnp.einsum("ecf,efd->ecd", h, p["w2"]).reshape(E * C, d)

    # combine: each kept (token, expert) pair reads its slot, weighted scatter
    contrib = ys[slot] * sg[:, None].astype(ys.dtype)        # (T*k, d)
    contrib = jnp.where(keep[:, None], contrib, 0)
    if FLAGS.shard_moe_tokens:
        contrib = shard_hint(contrib, ("pod", "data"), "model")
    out = jnp.zeros((T, d), ys.dtype).at[st].add(contrib, mode="drop")
    if FLAGS.shard_moe_tokens:
        out = shard_hint(out, ("pod", "data"), None)

    if "shared" in p:
        shared = FFNSpec(kind="dense", d_ff=spec.d_ff * spec.shared_experts,
                         activation=spec.activation)
        out = out + apply_ffn(p["shared"], x2, shared)
    return out.reshape(orig_shape)


def moe_aux_loss(p, x, spec: FFNSpec):
    """Load-balancing auxiliary loss (Switch-style fraction*prob)."""
    x2 = x.reshape(-1, x.shape[-1])
    logits = x2.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(logits, spec.top_k)
    onehot = jax.nn.one_hot(idx, spec.num_experts).sum(1)    # (T, E)
    frac = onehot.mean(0)
    prob = probs.mean(0)
    return spec.num_experts * jnp.sum(frac * prob)


def init_linear(rng, d_in, d_out, dtype, bias=False):
    w = jax.random.normal(rng, (d_in, d_out), dtype) * (d_in ** -0.5)
    if bias:
        return {"w": w, "b": jnp.zeros((d_out,), dtype)}
    return {"w": w}


def apply_linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def causal_conv1d(x, w, conv_state=None, lengths=None):
    """Depthwise causal conv via K shifted multiply-adds. x: (B,S,C); w: (K,C).

    Deliberately NOT lax.conv with feature_group_count=C: GSPMD cannot
    partition large grouped convolutions and falls back to full
    rematerialization (replicating the (B,S,3*H*dk) qkv buffer on every
    device). K shifted elementwise FMAs shard trivially with the batch.
    Returns (y, new_state) where new_state is the last K-1 inputs.

    ``lengths`` (B,): per-row valid token counts for right-padded batches
    (bucketed prefill).  The returned state is then the K-1 inputs ending at
    position ``lengths`` — exactly the window a decode step would continue
    from — instead of the tail of the padded sequence.
    """
    B, S, C = x.shape
    K = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = jnp.zeros_like(x)
    for k in range(K):
        # tap k multiplies input shifted by (K-1-k) steps into the past
        y = y + xp[:, k:k + S] * w[k].astype(x.dtype)
    if K <= 1:
        new_state = conv_state
    elif lengths is not None:
        # xp index of padded position p is p + K - 1, so the window
        # [length-K+1, length) lives at xp[length : length+K-1]
        idx = lengths.astype(jnp.int32)[:, None] + jnp.arange(K - 1)[None]
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    else:
        new_state = xp[:, -(K - 1):]
    return y, new_state

"""Paged decode-cache layout: BlockPool pages as the real device KV.

Replaces the dense per-slot ``(num_slots, capacity, ...)`` decode buffers
with shared page pools indexed through per-slot block tables:

  * full attention:  ``k``/``v``  pools  ``(R, Hkv, P, T, D)``
  * MLA latents:     ``ckv`` ``(R, P, T, rank)``, ``kpe`` ``(R, P, T, rope)``
  * SWA attention:   same ``k``/``v`` pool leaves, addressed through a ring
    table (the ring buffer is paged too, from the same pool)
  * linear/SSM state: unchanged per-slot leaves (O(1) per request)

``P = num_pool_pages + 1``: the extra *sink* page (id ``num_pool_pages``,
never handed out by the BlockPool) is what retired slots' tables point at,
so their in-flight scatter writes in ``step_block`` land on a page no live
request reads. ``T`` (page tokens) equals the prefix cache's block size, so
one BlockPool id addresses both the metadata block and the device page.

Two tables per slot, both host-side numpy handed to each decode dispatch:

  * seq table ``(num_slots, capacity/T)`` — append-only full/MLA pages;
    the pages covering a prompt's full blocks are *prefix-shareable* (other
    slots map them read-only via BlockPool ref-counts).
  * ring table ``(num_slots, W_buf/T)`` — SWA ring pages, always privately
    owned: the ring content at length L is only valid for resuming at
    exactly L, so shared-prefix SWA/linear state travels as an exact-length
    snapshot payload (``core.prefix_cache.LinearSnapshot.payload``) copied
    into the new slot's own pages at admission.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionSpec, ModelConfig
from repro.models.model import _dtype, slstm_zero


@dataclass(frozen=True)
class PagedLayout:
    page_tokens: int
    num_pages: int              # pool pages (sink excluded)
    capacity: int
    seq_cols: int               # seq table width (0: no full/MLA layers)
    ring_cols: int              # ring table width (0: no SWA layers)
    ring_tokens: int            # W_buf of the SWA layers (0 if none)

    @property
    def sink(self) -> int:
        return self.num_pages

    @property
    def total_pages(self) -> int:
        return self.num_pages + 1


def _is_ring(m) -> bool:
    return isinstance(m, AttentionSpec) and m.kind == "swa" and m.window > 0


def _is_seq(m) -> bool:
    return isinstance(m, AttentionSpec) and not _is_ring(m)


def paged_layout(cfg: ModelConfig, capacity: int, page_tokens: int,
                 num_pages: int) -> PagedLayout:
    """Validate the arch for paged decode and derive the table geometry."""
    if cfg.encoder_groups is not None or cfg.num_image_patches:
        raise ValueError("paged KV supports decoder-only token models "
                         "(no encoder / image prefix)")
    T = page_tokens
    if capacity % T:
        raise ValueError(f"capacity {capacity} not a multiple of page "
                         f"size {T}")
    has_seq = False
    rings = set()
    for g in cfg.groups:
        for b in g.blocks:
            if b.cross is not None:
                raise ValueError("paged KV does not support cross-attention")
            m = b.mixer
            if _is_ring(m):
                w_buf = min(m.window, capacity)
                if w_buf % T:
                    raise ValueError(f"SWA buffer {w_buf} not a multiple of "
                                     f"page size {T}")
                rings.add(w_buf)
            elif _is_seq(m):
                has_seq = True
    if len(rings) > 1:
        raise ValueError("paged KV requires one SWA window per model, got "
                         f"{sorted(rings)}")
    ring_tokens = rings.pop() if rings else 0
    return PagedLayout(page_tokens=T, num_pages=num_pages, capacity=capacity,
                       seq_cols=capacity // T if has_seq else 0,
                       ring_cols=ring_tokens // T, ring_tokens=ring_tokens)


def init_paged_cache(cfg: ModelConfig, num_slots: int, layout: PagedLayout):
    """Zeroed page pools + per-slot state, same pytree structure as the
    dense ``Model.init_cache`` so the engine's scan/donation plumbing is
    shared."""
    dt = _dtype(cfg)
    P, T = layout.total_pages, layout.page_tokens

    def block_cache(bspec):
        m = bspec.mixer
        if isinstance(m, AttentionSpec):
            if m.kind == "mla":
                return {"ckv": jnp.zeros((P, T, m.mla_kv_rank), dt),
                        "kpe": jnp.zeros((P, T, m.mla_rope_dim), dt)}
            return {"k": jnp.zeros((m.kv_heads, P, T, m.head_dim), dt),
                    "v": jnp.zeros((m.kv_heads, P, T, m.head_dim), dt)}
        if m.kind == "slstm":
            return {"state": slstm_zero(num_slots, m)}
        dv = m.value_dim + (1 if m.kind == "mlstm" else 0)
        c = {"state": jnp.zeros((num_slots, m.heads, m.key_dim, dv),
                                jnp.float32)}
        if m.conv_kernel:
            C = m.heads * (2 * m.key_dim + m.value_dim)
            c["conv"] = jnp.zeros((num_slots, m.conv_kernel - 1, C), dt)
        return c

    groups = []
    for g in cfg.groups:
        gc = {}
        for bi, b in enumerate(g.blocks):
            one = block_cache(b)
            gc[f"b{bi}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (g.repeats,) + x.shape),
                one)
        groups.append(gc)
    return {"groups": groups}


def page_bytes(cfg: ModelConfig, layout: PagedLayout) -> int:
    """Device bytes one pool page occupies summed across every paged leaf
    (a single page id addresses the same row in ALL attention layers)."""
    size = jnp.dtype(_dtype(cfg)).itemsize
    total = 0
    for g in cfg.groups:
        for b in g.blocks:
            m = b.mixer
            if not isinstance(m, AttentionSpec):
                continue
            if m.kind == "mla":
                d = m.mla_kv_rank + m.mla_rope_dim
                total += g.repeats * layout.page_tokens * d * size
            else:
                total += (2 * g.repeats * m.kv_heads * layout.page_tokens
                          * m.head_dim * size)
    return total


def zero_request_payload(cfg: ModelConfig, L: int):
    """Zeroed single-request prefill caches (leaves (R, 1, L, ...)) in the
    trimmed-payload format ``admit_many`` consumes — lets the engine warm
    its paged-admission scatter programs without running a real prefill.
    (``Model.init_cache`` is close but window-clips SWA leaves; admission
    payloads keep the full L rows.)"""
    dt = _dtype(cfg)

    def block_cache(bspec):
        m = bspec.mixer
        if isinstance(m, AttentionSpec):
            if m.kind == "mla":
                return {"ckv": jnp.zeros((1, L, m.mla_kv_rank), dt),
                        "kpe": jnp.zeros((1, L, m.mla_rope_dim), dt)}
            return {"k": jnp.zeros((1, L, m.kv_heads, m.head_dim), dt),
                    "v": jnp.zeros((1, L, m.kv_heads, m.head_dim), dt)}
        if m.kind == "slstm":
            return {"state": slstm_zero(1, m)}
        dv = m.value_dim + (1 if m.kind == "mlstm" else 0)
        c = {"state": jnp.zeros((1, m.heads, m.key_dim, dv), jnp.float32)}
        if m.conv_kernel:
            C = m.heads * (2 * m.key_dim + m.value_dim)
            c["conv"] = jnp.zeros((1, m.conv_kernel - 1, C), dt)
        return c

    groups = []
    for g in cfg.groups:
        gc = {}
        for bi, b in enumerate(g.blocks):
            one = block_cache(b)
            gc[f"b{bi}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (g.repeats,) + x.shape),
                one)
        groups.append(gc)
    return {"groups": groups}


# ---------------------------------------------------------------------------
# request payload -> page tensors (admission)
# ---------------------------------------------------------------------------


def _is_wire(node) -> bool:
    """A leaf still in int8 wire form ({"q", "scale"}, see
    ``kvcache.quantize_cache_for_wire``)."""
    return isinstance(node, dict) and set(node) == {"q", "scale"}


def _pageify_seq(leaf, c: int, L: int, T: int):
    """(R, 1, L, ...) request leaf -> page tensor for pages [c/T, ceil(L/T)).

    k/v leaves (R, 1, L, Hkv, D) -> (R, Hkv, n, T, D); MLA latents
    (R, 1, L, d) -> (R, n, T, d). The tail page is zero-padded past L,
    matching the dense zero-initialized buffers.

    A wire-form leaf ({"q": int8, "scale": scalar}) is pageified in place —
    the int8 payload is reshaped, the scale rides along — so admission can
    dequantize inside the page-scatter instead of a separate full-cache
    pass (int8 zero-padding dequantizes to the same zeros)."""
    if _is_wire(leaf):
        return {"q": _pageify_seq(leaf["q"], c, L, T), "scale": leaf["scale"]}
    R = leaf.shape[0]
    n = -(-(L - c) // T)
    span = leaf[:, 0, c:L]
    pad = [(0, 0)] * span.ndim
    pad[1] = (0, c + n * T - L)
    span = jnp.pad(span, pad)
    if span.ndim == 4:                                       # (R, nT, Hkv, D)
        pages = span.reshape(R, n, T, span.shape[2], span.shape[3])
        return pages.transpose(0, 3, 1, 2, 4)                # (R,Hkv,n,T,D)
    return span.reshape(R, n, T, span.shape[-1])             # (R,n,T,d)


def _ring_from_payload(leaf, L: int, W: int, T: int):
    """Exact SWA ring at length L from the request leaf (R, 1, L, Hkv, D):
    positions [max(0, L-W), L) at ring slot ``pos % W`` (the leaf always
    carries exact rows there — a suffix prefill's merged caches keep the
    prior window rows from the un-rung snapshot). Returns page tensor
    (R, Hkv, W/T, T, D)."""
    R, _, _, Hkv, D = leaf.shape
    ring = jnp.zeros((R, W, Hkv, D), leaf.dtype)
    start = max(0, L - W)
    pos = jnp.arange(start, L)
    ring = ring.at[:, pos % W].set(leaf[:, 0, start:L].astype(ring.dtype))
    pages = ring.reshape(R, W // T, T, Hkv, D)
    return pages.transpose(0, 3, 1, 2, 4)                    # (R,Hkv,Wc,T,D)


def build_admit_payload(cfg: ModelConfig, payload, layout: PagedLayout,
                        c: int, L: int):
    """Split one request's prefill caches into paged-admission tensors.

    ``payload``: the trimmed request caches (leaves (R, 1, L, ...)) covering
    the full prompt [0, L) — a full prefill's caches, or a suffix prefill's
    merged prior+suffix caches. ``c``: device-cached prefix (page-aligned;
    its pages are shared, not rewritten).

    Returns ``{"seq": ..., "ring": ..., "state": ...}`` pytrees mirroring
    the cache group structure (None-valued groups where a kind is absent).
    The ring + state tensors double as the snapshot payload for
    ``insert_device`` when L is page-aligned.

    Two payload variants are handled transparently:

      * wire-form payloads (int8 ``{"q", "scale"}`` leaves from
        ``quantize_cache_for_wire``): seq pages stay quantized — the
        engine's page scatter dequantizes them in place of the old eager
        full-cache ``dequantize_cache_from_wire`` pass.  Ring/state leaves
        (tiny, snapshot-bound) are dequantized here.
      * table-direct suffix payloads (an ``"off"`` marker in a full-attn
        block, see ``build_prior``): the block's k/v rows cover only
        [off, L) — the cached prefix never left the pool — so pageification
        starts at row ``c - off`` instead of ``c``.
    """
    from repro.models.kvcache import dequantize_cache_from_wire

    T, W = layout.page_tokens, layout.ring_tokens
    seq_g, ring_g, state_g = [], [], []
    for gi, g in enumerate(cfg.groups):
        seq_b, ring_b, state_b = {}, {}, {}
        for bi, b in enumerate(g.blocks):
            m = b.mixer
            pc = payload["groups"][gi][f"b{bi}"]
            key = f"b{bi}"
            if _is_ring(m):
                pc = dequantize_cache_from_wire(pc)
                ring_b[key] = {
                    name: _ring_from_payload(pc[name], L, W, T)
                    for name in ("k", "v")}
            elif _is_seq(m):
                off = int(pc["off"].reshape(-1)[0]) if "off" in pc else 0
                seq_b[key] = {name: _pageify_seq(pc[name], c - off,
                                                 L - off, T)
                              for name in pc if name != "off"}
            else:
                state_b[key] = pc
        seq_g.append(seq_b or None)
        ring_g.append(ring_b or None)
        state_g.append(state_b or None)
    return {"seq": seq_g, "ring": ring_g, "state": state_g}


# ---------------------------------------------------------------------------
# pages -> chunk-format prior caches (suffix-only prefill on a prefix hit)
# ---------------------------------------------------------------------------


def build_prior(cfg: ModelConfig, paged_caches, layout: PagedLayout,
                seq_ids, snapshot, c: int, *, table_direct: bool = False):
    """Chunk-format prior caches covering [0, c) for a suffix prefill.

    Full/MLA rows are gathered from the shared pool pages ``seq_ids``
    (c/T of them, ref-pinned by the caller); SWA rows [max(0, c-W), c) are
    un-rung from the snapshot ring (rows below are zeros, masked by the
    window); linear state comes from the snapshot leaves. The result plugs
    straight into ``Model.prefill_chunk(..., caches=prior)`` with positions
    offset by c.

    ``table_direct=True`` skips the dense gather for full-attention (GQA)
    blocks: their prior cache instead carries the pool page leaves and the
    request's block table (``pk``/``pv``/``tbl``), plus an empty dense
    suffix accumulator and an ``off`` marker, and suffix chunks attend over
    the table via the paged-prefill kernel — the cached prefix is never
    materialized outside the pool.  MLA latents still gather (their prior
    must be re-decompressed against the chunk projections) and SWA still
    un-rings from the snapshot.
    """
    T, W = layout.page_tokens, layout.ring_tokens
    ids = jnp.asarray(seq_ids, jnp.int32)
    groups = []
    for gi, g in enumerate(cfg.groups):
        gc = {}
        for bi, b in enumerate(g.blocks):
            m = b.mixer
            key = f"b{bi}"
            pool = paged_caches["groups"][gi][key]
            if _is_ring(m):
                ring = {name: snapshot["ring"][gi][key][name]
                        for name in ("k", "v")}

                def unring(pages):
                    R, Hkv = pages.shape[0], pages.shape[1]
                    D = pages.shape[-1]
                    flat = pages.transpose(0, 2, 3, 1, 4).reshape(
                        R, W, Hkv, D)
                    start = max(0, c - W)
                    prior = jnp.zeros((R, 1, c, Hkv, D), pages.dtype)
                    pos = jnp.arange(start, c)
                    return prior.at[:, 0, start:].set(flat[:, pos % W])

                gc[key] = {name: unring(v) for name, v in ring.items()}
            elif _is_seq(m):
                if m.kind == "mla":
                    def gather2(pool_leaf):
                        R, d = pool_leaf.shape[0], pool_leaf.shape[-1]
                        return pool_leaf[:, ids].reshape(R, c, d)[:, None]
                    gc[key] = {name: gather2(v) for name, v in pool.items()}
                elif table_direct:
                    R = pool["k"].shape[0]
                    Hkv, D = pool["k"].shape[1], pool["k"].shape[-1]
                    gc[key] = {
                        "k": jnp.zeros((R, 1, 0, Hkv, D), pool["k"].dtype),
                        "v": jnp.zeros((R, 1, 0, Hkv, D), pool["v"].dtype),
                        "pk": pool["k"], "pv": pool["v"],
                        "tbl": jnp.broadcast_to(ids[None, None],
                                                (R, 1, ids.shape[0])),
                        "off": jnp.full((R, 1), c, jnp.int32)}
                else:
                    def gather4(pool_leaf):
                        R, Hkv = pool_leaf.shape[0], pool_leaf.shape[1]
                        D = pool_leaf.shape[-1]
                        g4 = pool_leaf[:, :, ids]            # (R,Hkv,n,T,D)
                        return g4.transpose(0, 2, 3, 1, 4).reshape(
                            R, c, Hkv, D)[:, None]
                    gc[key] = {name: gather4(v) for name, v in pool.items()}
            else:
                gc[key] = snapshot["state"][gi][key]
        groups.append(gc)
    return {"groups": groups}

"""Memory-efficient, XLA-lowerable attention/linear-mixer paths.

These are the implementations the multi-pod dry-run compiles (Pallas TPU
kernels validate in interpret mode but are opaque custom-calls to
``cost_analysis``; these chunked jnp forms expose the same FLOPs/bytes
structure to XLA):

  * ``flash_chunked``  — online-softmax scan over KV blocks, O(S*block)
    memory, GQA without head materialization;
  * ``swa_banded``     — scan over Q blocks, each attending only its
    (window + block) KV band -> *linear* FLOPs for sliding-window archs
    (a full-mask scan would report quadratic HLO FLOPs for SWA);
  * ``gla_chunked_jnp`` / ``delta_chunked_jnp`` — the same chunk math as
    the Pallas kernels (decay-safe exp-of-differences, WY/Neumann inverse),
    expressed as ``lax.scan`` over chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")

# cost-probe mode: unroll inner scans so compiled.cost_analysis() counts
# every iteration (XLA counts while bodies once). Set by analysis.costfit.
UNROLL = False


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=True if UNROLL else 1)


# ---------------------------------------------------------------------------
# full attention, chunked over KV (online softmax)
# ---------------------------------------------------------------------------


def flash_chunked(q, k, v, *, causal=True, window=0, scale=None, q_offset=0,
                  block_k=512):
    """q: (B,Hq,Sq,D); k,v: (B,Hkv,Sk,Dk/Dv). O(Sq*block_k) live memory."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if q_offset == 0 and causal and Sq != Sk:
        q_offset = Sk - Sq
    dtype = q.dtype

    block_k = min(block_k, Sk)
    pad = (-Sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = (Sk + pad) // block_k

    qf = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32) * scale
    kc = k.reshape(B, Hkv, nk, block_k, D).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, nk, block_k, Dv).transpose(2, 0, 1, 3, 4)

    qpos = q_offset + jnp.arange(Sq)[:, None]

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, j = inp                                # (B,Hkv,bk,D)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kb.astype(jnp.float32))
        kpos = j * block_k + jnp.arange(block_k)[None, :]
        mask = kpos < Sk
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
        corr = jnp.where(m == NEG_INF, 0.0, jnp.exp(m - safe))
        p = jnp.where(s == NEG_INF, 0.0, jnp.exp(s - safe))
        l = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = corr * acc + jnp.einsum("bhgqk,bhkd->bhgqd", p,
                                      vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = _scan(body, (m0, l0, a0), (kc, vc, jnp.arange(nk)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).reshape(B, Hq, Sq, Dv)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# sliding-window attention, banded over Q (linear FLOPs)
# ---------------------------------------------------------------------------


def swa_banded(q, k, v, *, window, scale=None, block_q=512):
    """Causal SWA: each Q block attends its (window + block_q) KV band.

    FLOPs = O(S * (window + block_q)) — linear in S, matching what the SWA
    Pallas kernel achieves on TPU via block skipping.
    """
    B, Hq, S, D = q.shape
    _, Hkv, _, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    dtype = q.dtype

    block_q = min(block_q, S)
    pad = (-S) % block_q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nq = (S + pad) // block_q
    band = window + block_q                            # KV span per q block
    # left-pad K/V so every band slice is in range
    kp = jnp.pad(k, ((0, 0), (0, 0), (band, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (band, pad), (0, 0)))

    qf = q.reshape(B, Hkv, G, nq, block_q, D).astype(jnp.float32) * scale

    def body(_, i):
        qb = qf[:, :, :, i]                            # (B,Hkv,G,bq,D)
        start = i * block_q                            # first q pos in block
        kb = jax.lax.dynamic_slice_in_dim(kp, start + block_q, band, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vp, start + block_q, band, axis=2)
        # kb covers absolute positions [start - window, start + block_q)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb.astype(jnp.float32))
        qpos = start + jnp.arange(block_q)[:, None]
        kpos = start - window + jnp.arange(band)[None, :]
        mask = (kpos >= 0) & (kpos <= qpos) & (kpos > qpos - window) \
            & (kpos < S) & (qpos < S)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.any(mask, -1)[None, None, None][..., None], p, 0.0)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
        return None, o

    body = jax.checkpoint(body)       # bwd recomputes per-band scores
    _, blocks = _scan(body, None, jnp.arange(nq))
    out = blocks.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, S + pad, Dv)
    return out[:, :, :S].astype(dtype)


# ---------------------------------------------------------------------------
# differentiable memory-efficient attention (checkpointed Q-block scan)
# ---------------------------------------------------------------------------


def mea_attention(q, k, v, *, causal=True, window=0, scale=None, q_offset=0,
                  block_q=512):
    """Flash-style memory profile for *training*: scan over Q blocks, each
    block's (bq x Sk) scores are checkpointed (recomputed in backward), so
    the saved residuals are O(S*Dv) instead of O(S^2)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if q_offset == 0 and causal and Sq != Sk:
        q_offset = Sk - Sq
    dtype = q.dtype

    block_q = min(block_q, Sq)
    pad = (-Sq) % block_q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nq = (Sq + pad) // block_q
    qf = (q.reshape(B, Hkv, G, nq, block_q, D)
          .transpose(3, 0, 1, 2, 4, 5).astype(jnp.float32) * scale)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kpos = jnp.arange(Sk)[None, :]

    def body(_, inp):
        qb, i = inp                                    # (B,Hkv,G,bq,D)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kf)
        qpos = q_offset + i * block_q + jnp.arange(block_q)[:, None]
        mask = (qpos - q_offset) < Sq
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.any(mask, -1)[None, None, None][..., None], p, 0.0)
        return None, jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)

    _, blocks = _scan(jax.checkpoint(body), None, (qf, jnp.arange(nq)))
    out = blocks.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, Sq + pad, Dv)
    return out[:, :, :Sq].astype(dtype)


# ---------------------------------------------------------------------------
# gated linear attention, chunk-scan (same math as the Pallas kernel)
# ---------------------------------------------------------------------------


def gla_chunked_jnp(q, k, v, log_a, initial_state, *, chunk=64):
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    dtype = q.dtype
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))
    nc = (S + pad) // chunk

    def split(x):
        return x.reshape(B, H, nc, chunk, -1).transpose(2, 0, 1, 3, 4) \
            .astype(jnp.float32)

    qc, kc, vc = split(q), split(k), split(v)
    lac = log_a.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3) \
        .astype(jnp.float32)
    row = jnp.arange(chunk)[:, None]
    col = jnp.arange(chunk)[None, :]
    incl = col <= row

    def body(state, inp):
        qb, kb, vb, la = inp
        csum = jnp.cumsum(la, axis=-1)                  # (B,H,C)
        gamma = jnp.exp(csum)[..., None]
        diff = csum[..., :, None] - csum[..., None, :]
        decay = jnp.where(incl, jnp.exp(jnp.where(incl, diff, 0.0)), 0.0)
        A = jnp.einsum("bhqd,bhkd->bhqk", qb, kb) * decay
        o = jnp.einsum("bhqk,bhkv->bhqv", A, vb) \
            + jnp.einsum("bhqd,bhdv->bhqv", qb * gamma, state)
        g_c = jnp.exp(csum[..., -1:])[..., None]
        kscale = jnp.exp(csum[..., -1:] - csum)[..., None]
        state = g_c * state + jnp.einsum("bhkd,bhkv->bhdv", kb * kscale, vb)
        return state, o

    state, os_ = _scan(body, initial_state.astype(jnp.float32),
                       (qc, kc, vc, lac))
    o = os_.transpose(1, 2, 0, 3, 4).reshape(B, H, S + pad, dv)[:, :, :S]
    return o.astype(dtype), state


# ---------------------------------------------------------------------------
# (gated) delta rule, chunk-scan (WY + Neumann, same math as kernel)
# ---------------------------------------------------------------------------


def delta_chunked_jnp(q, k, v, log_a, beta, initial_state, *, chunk=64):
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    dtype = q.dtype
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))
        beta = jnp.pad(beta, ((0, 0), (0, 0), (0, pad)))
    nc = (S + pad) // chunk

    def split(x):
        return x.reshape(B, H, nc, chunk, -1).transpose(2, 0, 1, 3, 4) \
            .astype(jnp.float32)

    qc, kc, vc = split(q), split(k), split(v)
    lac = log_a.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3) \
        .astype(jnp.float32)
    bc = beta.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3) \
        .astype(jnp.float32)
    row = jnp.arange(chunk)[:, None]
    col = jnp.arange(chunk)[None, :]
    strict = col < row
    incl = col <= row
    eye = jnp.eye(chunk, dtype=jnp.float32)
    steps = max(1, (chunk - 1).bit_length())

    def body(state, inp):
        qb, kb, vb, la, bb = inp
        csum = jnp.cumsum(la, axis=-1)
        gamma = jnp.exp(csum)[..., None]
        diff = csum[..., :, None] - csum[..., None, :]
        dstrict = jnp.where(strict, jnp.exp(jnp.where(strict, diff, 0.0)), 0.0)
        dincl = jnp.where(incl, jnp.exp(jnp.where(incl, diff, 0.0)), 0.0)
        kkt = jnp.einsum("bhqd,bhkd->bhqk", kb, kb)
        n = bb[..., :, None] * (kkt * dstrict)
        m = -n
        r = eye + m
        for _ in range(steps - 1):
            m = jnp.einsum("bhij,bhjk->bhik", m, m)
            r = r + jnp.einsum("bhij,bhjk->bhik", r, m)
        rhs = bb[..., None] * (vb - jnp.einsum("bhkd,bhdv->bhkv",
                                               kb * gamma, state))
        u = jnp.einsum("bhij,bhjv->bhiv", r, rhs)
        qkt = jnp.einsum("bhqd,bhkd->bhqk", qb, kb)
        o = jnp.einsum("bhqd,bhdv->bhqv", qb * gamma, state) \
            + jnp.einsum("bhqk,bhkv->bhqv", qkt * dincl, u)
        g_c = jnp.exp(csum[..., -1:])[..., None]
        kscale = jnp.exp(csum[..., -1:] - csum)[..., None]
        state = g_c * state + jnp.einsum("bhkd,bhkv->bhdv", kb * kscale, u)
        return state, o

    state, os_ = _scan(body, initial_state.astype(jnp.float32),
                       (qc, kc, vc, lac, bc))
    o = os_.transpose(1, 2, 0, 3, 4).reshape(B, H, S + pad, dv)[:, :, :S]
    return o.astype(dtype), state

from repro.models.model import (Model, extend_caches, prepare_decode_caches,
                                sinusoidal_positions)
from repro.models.paged import (PagedLayout, build_admit_payload, build_prior,
                                init_paged_cache, paged_layout,
                                zero_request_payload)

__all__ = ["Model", "extend_caches", "prepare_decode_caches",
           "sinusoidal_positions", "PagedLayout", "paged_layout",
           "init_paged_cache", "build_admit_payload", "build_prior",
           "zero_request_payload"]

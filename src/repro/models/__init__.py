from repro.models.model import (Model, extend_caches, prepare_decode_caches,
                                sinusoidal_positions)

__all__ = ["Model", "extend_caches", "prepare_decode_caches",
           "sinusoidal_positions"]

"""Full-attention blocks: GQA / MQA / MHA / SWA / MLA (+ cross-attention).

Three execution modes share one parameter set:
  * train   — full-sequence, differentiable (kernel fwd + oracle-VJP bwd)
  * prefill — full-sequence, returns the per-layer KVCache contribution
              (the bytes PrfaaS ships across the inter-DC link)
  * decode  — one token per request against a preallocated cache at
              per-request lengths; MLA uses the absorbed (MQA-style) form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionSpec
from repro.kernels import ops
from repro.models.layers import apply_rope, init_linear, rms_norm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_attention(rng, d_model: int, spec: AttentionSpec, dtype):
    ks = jax.random.split(rng, 8)
    H, Hkv, D = spec.q_heads, spec.kv_heads, spec.head_dim
    if spec.kind == "mla":
        R, Rp = spec.mla_kv_rank, spec.mla_rope_dim
        p = {}
        if spec.mla_q_rank:
            p["wq_a"] = init_linear(ks[0], d_model, spec.mla_q_rank, dtype)
            p["q_norm"] = jnp.ones((spec.mla_q_rank,), jnp.float32)
            p["wq_b"] = init_linear(ks[1], spec.mla_q_rank, H * (D + Rp), dtype)
        else:
            p["wq"] = init_linear(ks[0], d_model, H * (D + Rp), dtype)
        p["wkv_a"] = init_linear(ks[2], d_model, R + Rp, dtype)
        p["kv_norm"] = jnp.ones((R,), jnp.float32)
        p["wkv_b"] = init_linear(ks[3], R, Hkv * 2 * D, dtype)
        p["wo"] = init_linear(ks[4], H * D, d_model, dtype)
        return p
    p = {
        "wq": init_linear(ks[0], d_model, H * D, dtype, bias=spec.qkv_bias),
        "wk": init_linear(ks[1], d_model, Hkv * D, dtype, bias=spec.qkv_bias),
        "wv": init_linear(ks[2], d_model, Hkv * D, dtype, bias=spec.qkv_bias),
        "wo": init_linear(ks[3], H * D, d_model, dtype),
    }
    return p


def _lin(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def _split_heads(x, H, D):
    B, S, _ = x.shape
    return x.reshape(B, S, H, D).transpose(0, 2, 1, 3)      # (B,H,S,D)


def _merge_heads(x):
    B, H, S, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * D)


# ---------------------------------------------------------------------------
# GQA / SWA family
# ---------------------------------------------------------------------------


def gqa_forward(p, x, spec: AttentionSpec, positions, *, kv_source=None,
                causal=True, use_kernels=True):
    """Full-sequence attention. Returns (y, {"k","v"} cache contribution).

    ``kv_source``: encoder output for cross-attention (keys/values from it).
    ``causal=False`` for encoder (bidirectional) self-attention.
    """
    H, Hkv, D = spec.q_heads, spec.kv_heads, spec.head_dim
    kv_in = x if kv_source is None else kv_source
    q = _split_heads(_lin(p["wq"], x), H, D)
    k = _split_heads(_lin(p["wk"], kv_in), Hkv, D)
    v = _split_heads(_lin(p["wv"], kv_in), Hkv, D)
    if spec.rope and not spec.is_cross:
        q = apply_rope(q, positions, spec.rope_theta)
        k = apply_rope(k, positions, spec.rope_theta)
    causal = causal and not spec.is_cross
    o = ops.attention(q, k, v, causal=causal,
                      window=spec.window if spec.kind == "swa" else 0,
                      use_kernel=use_kernels)
    y = _merge_heads(o) @ p["wo"]["w"]
    # cache layout: (B, S, Hkv, D) — sequence-major for block-pool slicing
    cache = {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}
    return y, cache


def gqa_decode(p, x, spec: AttentionSpec, cache, lengths, *, use_kernels=True):
    """x: (B, 1, d); cache: {"k","v": (B, S_cap, Hkv, D)}; lengths: (B,).

    Writes the new token's K/V at ``lengths`` then attends over
    ``lengths + 1`` keys. Returns (y, updated cache).
    """
    B = x.shape[0]
    H, Hkv, D = spec.q_heads, spec.kv_heads, spec.head_dim
    q = _split_heads(_lin(p["wq"], x), H, D)                 # (B,H,1,D)
    pos = lengths.astype(jnp.int32)[:, None]                 # (B,1)

    if spec.is_cross:
        # cross-attention: cache holds precomputed encoder K/V, length fixed
        kc = cache["k"].transpose(0, 2, 1, 3)
        vc = cache["v"].transpose(0, 2, 1, 3)
        enc_len = jnp.full((B,), kc.shape[2], jnp.int32)
        o = ops.decode_attention(q[:, :, 0], kc, vc, enc_len,
                                 use_kernel=use_kernels)
        return _merge_heads(o[:, :, None]) @ p["wo"]["w"], cache

    k = _split_heads(_lin(p["wk"], x), Hkv, D)
    v = _split_heads(_lin(p["wv"], x), Hkv, D)
    if spec.rope:
        q = apply_rope(q, pos, spec.rope_theta)
        k = apply_rope(k, pos, spec.rope_theta)

    # SWA caches are window-sized ring buffers: slot = position % W_buf.
    # Softmax is order-invariant and RoPE phases are baked in at write time,
    # so ring placement preserves exact attention semantics while keeping
    # the decode-side KV footprint at O(window) — this is what makes SWA
    # archs "PrfaaS-friendly" on the decode cluster too.
    w_buf = cache["k"].shape[1]
    write_idx = jnp.mod(pos[:, 0], w_buf)
    eff_len = jnp.minimum(lengths + 1, w_buf)

    def upd(buf, new, idx):
        return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype),
                                            (idx, 0, 0))

    kbuf = jax.vmap(upd)(cache["k"], k.transpose(0, 2, 1, 3), write_idx)
    vbuf = jax.vmap(upd)(cache["v"], v.transpose(0, 2, 1, 3), write_idx)
    o = ops.decode_attention(
        q[:, :, 0], kbuf.transpose(0, 2, 1, 3), vbuf.transpose(0, 2, 1, 3),
        eff_len, use_kernel=use_kernels)
    y = _merge_heads(o[:, :, None]) @ p["wo"]["w"]
    return y, {"k": kbuf, "v": vbuf}


def gqa_decode_paged(p, x, spec: AttentionSpec, cache, lengths, tables, *,
                     page_tokens, capacity, use_kernels=True):
    """Paged decode: cache leaves are page pools ``(Hkv, P, T, D)`` shared by
    every request; ``tables`` maps each request's logical pages to physical
    ones. Full-attn layers append through the seq table; SWA layers ring-
    write through their privately-owned ring table (slot = pos % w_buf, same
    order-invariant-softmax argument as the dense ring). Inactive slots
    (length 0, table pointing at the sink page) scatter into the sink, which
    no live request's table references."""
    B = x.shape[0]
    H, Hkv, D = spec.q_heads, spec.kv_heads, spec.head_dim
    if spec.is_cross:
        raise ValueError("paged decode does not support cross-attention")
    q = _split_heads(_lin(p["wq"], x), H, D)                 # (B,H,1,D)
    pos = lengths.astype(jnp.int32)[:, None]
    k = _split_heads(_lin(p["wk"], x), Hkv, D)
    v = _split_heads(_lin(p["wv"], x), Hkv, D)
    if spec.rope:
        q = apply_rope(q, pos, spec.rope_theta)
        k = apply_rope(k, pos, spec.rope_theta)

    T = page_tokens
    if spec.kind == "swa" and spec.window:
        w_buf = min(spec.window, capacity)
        tbl = tables["ring"][:, :w_buf // T]
        wpos = jnp.mod(pos[:, 0], w_buf)
        eff_len = jnp.minimum(lengths + 1, w_buf)
    else:
        tbl = tables["seq"]
        wpos = pos[:, 0]
        eff_len = jnp.minimum(lengths + 1, capacity)
    cols = tbl.shape[1]
    lp, off = jnp.minimum(wpos // T, cols - 1), wpos % T
    phys = jnp.take_along_axis(tbl, lp[:, None], axis=1)[:, 0]
    # a slot surplus-stepping past the capacity wall mid-block (retired on
    # the host afterwards) must not clobber its last live page: route those
    # writes to the sink page, which no live table references
    phys = jnp.where(wpos >= cols * T, cache["k"].shape[1] - 1, phys)
    kbuf = cache["k"].at[:, phys, off].set(
        k[:, :, 0].transpose(1, 0, 2).astype(cache["k"].dtype))
    vbuf = cache["v"].at[:, phys, off].set(
        v[:, :, 0].transpose(1, 0, 2).astype(cache["v"].dtype))
    o = ops.paged_decode_attention(q[:, :, 0], kbuf, vbuf, tbl, eff_len,
                                   use_kernel=use_kernels)
    y = _merge_heads(o[:, :, None]) @ p["wo"]["w"]
    return y, {"k": kbuf, "v": vbuf}


def gqa_decode_verify(p, x, spec: AttentionSpec, cache, lengths, *,
                      use_kernels=True):
    """Batched speculative verify for APPEND-ONLY full attention.

    ``x``: (B, q, d) — the current token plus k drafted continuations,
    embedded.  Computes all q positions in ONE batched pass instead of a
    q-step scan: the f32 upcast and the two GEMM sweeps over the KV cache
    are shared across positions, which is what makes verify cheaper than
    q sequential decode steps.  Matches running ``gqa_decode`` q times up
    to float reassociation in the batched attention GEMMs (greedy argmax
    is stable under it — the engine tests pin token identity): (a)
    projections / RoPE / norms are row-independent, (b) the attention ref
    masks rows ``>= lengths+1+j`` with NEG_INF *before* softmax, so the
    not-yet-"written" future rows this pass pre-writes contribute exactly
    0 regardless of content.  Only valid for ``kind == "full"``
    (SWA rings re-read overwritten rows once the window wraps — those
    verify through the sequential scan path instead).

    Writes past the capacity wall are dropped rather than wrapped
    (sequential decode wraps modulo the buffer); both behaviours only
    touch rows that no kept token ever reads, so emitted streams match.
    """
    B, Q, _ = x.shape
    H, Hkv, D = spec.q_heads, spec.kv_heads, spec.head_dim
    q = _split_heads(_lin(p["wq"], x), H, D)                 # (B,H,Q,D)
    k = _split_heads(_lin(p["wk"], x), Hkv, D)
    v = _split_heads(_lin(p["wv"], x), Hkv, D)
    pos = lengths.astype(jnp.int32)[:, None] + jnp.arange(Q, dtype=jnp.int32)
    if spec.rope:
        q = apply_rope(q, pos, spec.rope_theta)
        k = apply_rope(k, pos, spec.rope_theta)

    rows_b = jnp.arange(B)[:, None]
    kbuf = cache["k"].at[rows_b, pos].set(
        k.transpose(0, 2, 1, 3).astype(cache["k"].dtype), mode="drop")
    vbuf = cache["v"].at[rows_b, pos].set(
        v.transpose(0, 2, 1, 3).astype(cache["v"].dtype), mode="drop")
    kt, vt = kbuf.transpose(0, 2, 1, 3), vbuf.transpose(0, 2, 1, 3)
    o = ops.verify_attention(q, kt, vt, lengths + 1,
                             use_kernel=use_kernels)         # (B,H,Q,D)
    y = _merge_heads(o) @ p["wo"]["w"]
    return y, {"k": kbuf, "v": vbuf}


def gqa_forward_chunk(p, x, spec: AttentionSpec, positions, cache, *,
                      use_kernels=True):
    """Incremental prefill: x is a chunk at absolute ``positions``; ``cache``
    holds the prior chunks' {"k","v"} (B, S_prior, Hkv, D).  The chunk's
    queries attend over prior + new keys via the ``Sq != Sk`` / ``q_offset``
    attention path.  Returns (y, merged cache).

    A table-direct prior cache (``build_prior(..., table_direct=True)``)
    additionally carries ``pk``/``pv`` pool page leaves and the request's
    block table ``tbl``; the dense ``k``/``v`` entries then hold only the
    SUFFIX rows and the chunk attends over pages + suffix via the
    paged-prefill kernel — the cached prefix stays in the pool."""
    H, Hkv, D = spec.q_heads, spec.kv_heads, spec.head_dim
    q = _split_heads(_lin(p["wq"], x), H, D)
    k = _split_heads(_lin(p["wk"], x), Hkv, D)
    v = _split_heads(_lin(p["wv"], x), Hkv, D)
    if spec.rope:
        q = apply_rope(q, positions, spec.rope_theta)
        k = apply_rope(k, positions, spec.rope_theta)
    k_seq = k.transpose(0, 2, 1, 3)                          # (B,C,Hkv,D)
    v_seq = v.transpose(0, 2, 1, 3)
    k_full = jnp.concatenate([cache["k"].astype(k_seq.dtype), k_seq], axis=1)
    v_full = jnp.concatenate([cache["v"].astype(v_seq.dtype), v_seq], axis=1)
    if "pk" in cache:
        # prior pages are all fully visible (every cached position precedes
        # every suffix query); the suffix mask is causal — build_prior only
        # emits table-direct priors for full attention, never SWA
        o = ops.paged_prefill_attention(
            q, cache["pk"], cache["pv"], cache["tbl"],
            k_full.transpose(0, 2, 1, 3), v_full.transpose(0, 2, 1, 3),
            use_kernel=use_kernels)
        y = _merge_heads(o) @ p["wo"]["w"]
        return y, {**cache, "k": k_full, "v": v_full}
    o = ops.attention(q, k_full.transpose(0, 2, 1, 3),
                      v_full.transpose(0, 2, 1, 3), causal=True,
                      window=spec.window if spec.kind == "swa" else 0,
                      q_offset=cache["k"].shape[1],
                      use_kernel=use_kernels)
    y = _merge_heads(o) @ p["wo"]["w"]
    return y, {"k": k_full, "v": v_full}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2-style latent KV)
# ---------------------------------------------------------------------------


def _mla_q(p, x, spec: AttentionSpec):
    H, D, Rp = spec.q_heads, spec.head_dim, spec.mla_rope_dim
    if spec.mla_q_rank:
        qa = rms_norm(_lin(p["wq_a"], x), p["q_norm"])
        q = _lin(p["wq_b"], qa)
    else:
        q = _lin(p["wq"], x)
    q = _split_heads(q, H, D + Rp)
    return q[..., :D], q[..., D:]                            # nope, pe


def mla_forward(p, x, spec: AttentionSpec, positions, *, use_kernels=True):
    """Prefill/train MLA: decompress K/V (MHA form), cache only latents."""
    B, S, _ = x.shape
    H, D, R, Rp = spec.q_heads, spec.head_dim, spec.mla_kv_rank, spec.mla_rope_dim
    q_nope, q_pe = _mla_q(p, x, spec)
    kv_a = _lin(p["wkv_a"], x)                               # (B,S,R+Rp)
    ckv = rms_norm(kv_a[..., :R], p["kv_norm"])
    k_pe = kv_a[..., R:][:, None]                            # (B,1,S,Rp)
    q_pe = apply_rope(q_pe, positions, spec.rope_theta)
    k_pe = apply_rope(k_pe, positions, spec.rope_theta)

    kv = _lin(p["wkv_b"], ckv)                               # (B,S,Hkv*2D)
    kv = kv.reshape(B, S, spec.kv_heads, 2 * D).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :D], kv[..., D:]
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_pe, (B, spec.kv_heads, S, Rp))],
                        axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    o = ops.attention(q, k, v, causal=True, scale=(D + Rp) ** -0.5,
                      use_kernel=use_kernels)
    y = _merge_heads(o) @ p["wo"]["w"]
    cache = {"ckv": ckv, "kpe": k_pe[:, 0]}                  # (B,S,R), (B,S,Rp)
    return y, cache


def mla_decode(p, x, spec: AttentionSpec, cache, lengths, *, use_kernels=True):
    """Absorbed MLA decode: MQA over the latent cache (Dk=R+Rp, Dv=R)."""
    B = x.shape[0]
    H, D, R, Rp = spec.q_heads, spec.head_dim, spec.mla_kv_rank, spec.mla_rope_dim
    pos = lengths.astype(jnp.int32)[:, None]
    q_nope, q_pe = _mla_q(p, x, spec)                        # (B,H,1,D/Rp)
    q_pe = apply_rope(q_pe, pos, spec.rope_theta)

    kv_a = _lin(p["wkv_a"], x)                               # (B,1,R+Rp)
    ckv_new = rms_norm(kv_a[..., :R], p["kv_norm"])
    kpe_new = apply_rope(kv_a[..., R:][:, None], pos, spec.rope_theta)[:, 0]

    def upd(buf, new, idx):
        return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype),
                                            (idx, 0))

    ckv_buf = jax.vmap(upd)(cache["ckv"], ckv_new, pos[:, 0])
    kpe_buf = jax.vmap(upd)(cache["kpe"], kpe_new, pos[:, 0])

    # absorb W_uk into q: q_abs[h, r] = sum_d q_nope[h, d] * W_uk[r, h, d]
    wkv_b = p["wkv_b"]["w"].reshape(R, spec.kv_heads, 2 * D)
    w_uk, w_uv = wkv_b[..., :D], wkv_b[..., D:]              # (R,Hkv,D)
    group = H // spec.kv_heads
    w_uk_q = jnp.repeat(w_uk, group, axis=1)                 # (R,H,D)
    w_uv_q = jnp.repeat(w_uv, group, axis=1)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, :, 0].astype(jnp.float32),
                       w_uk_q.astype(jnp.float32))           # (B,H,R)
    q_eff = jnp.concatenate([q_abs, q_pe[:, :, 0].astype(jnp.float32)], -1)
    k_eff = jnp.concatenate([ckv_buf, kpe_buf], -1)[:, None]  # (B,1,S,R+Rp)
    v_eff = ckv_buf[:, None]                                  # (B,1,S,R)
    o_lat = ops.decode_attention(q_eff.astype(x.dtype),
                                 k_eff.astype(x.dtype),
                                 v_eff.astype(x.dtype), lengths + 1,
                                 scale=(D + Rp) ** -0.5,
                                 use_kernel=use_kernels)     # (B,H,R)
    o = jnp.einsum("bhr,rhd->bhd", o_lat.astype(jnp.float32),
                   w_uv_q.astype(jnp.float32)).astype(x.dtype)
    y = o.reshape(B, 1, H * D) @ p["wo"]["w"]
    return y, {"ckv": ckv_buf, "kpe": kpe_buf}


def mla_decode_paged(p, x, spec: AttentionSpec, cache, lengths, tables, *,
                     page_tokens, capacity, use_kernels=True):
    """Absorbed MLA decode over paged latent pools ``(P, T, R)``/``(P, T,
    Rp)``; identical math to ``mla_decode`` with the latent append routed
    through the seq block table."""
    B = x.shape[0]
    H, D, R, Rp = spec.q_heads, spec.head_dim, spec.mla_kv_rank, spec.mla_rope_dim
    pos = lengths.astype(jnp.int32)[:, None]
    q_nope, q_pe = _mla_q(p, x, spec)                        # (B,H,1,D/Rp)
    q_pe = apply_rope(q_pe, pos, spec.rope_theta)

    kv_a = _lin(p["wkv_a"], x)                               # (B,1,R+Rp)
    ckv_new = rms_norm(kv_a[..., :R], p["kv_norm"])
    kpe_new = apply_rope(kv_a[..., R:][:, None], pos, spec.rope_theta)[:, 0]

    T = page_tokens
    cols = tables["seq"].shape[1]
    lp, off = jnp.minimum(pos[:, 0] // T, cols - 1), pos[:, 0] % T
    phys = jnp.take_along_axis(tables["seq"], lp[:, None], axis=1)[:, 0]
    # past-the-wall surplus writes go to the sink page (see gqa_decode_paged)
    phys = jnp.where(pos[:, 0] >= cols * T, cache["ckv"].shape[0] - 1, phys)
    ckv_buf = cache["ckv"].at[phys, off].set(
        ckv_new[:, 0].astype(cache["ckv"].dtype))
    kpe_buf = cache["kpe"].at[phys, off].set(
        kpe_new[:, 0].astype(cache["kpe"].dtype))

    wkv_b = p["wkv_b"]["w"].reshape(R, spec.kv_heads, 2 * D)
    w_uk, w_uv = wkv_b[..., :D], wkv_b[..., D:]              # (R,Hkv,D)
    group = H // spec.kv_heads
    w_uk_q = jnp.repeat(w_uk, group, axis=1)                 # (R,H,D)
    w_uv_q = jnp.repeat(w_uv, group, axis=1)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, :, 0].astype(jnp.float32),
                       w_uk_q.astype(jnp.float32))           # (B,H,R)
    q_eff = jnp.concatenate([q_abs, q_pe[:, :, 0].astype(jnp.float32)], -1)
    k_eff = jnp.concatenate([ckv_buf, kpe_buf], -1)[None]    # (1,P,T,R+Rp)
    v_eff = ckv_buf[None]                                    # (1,P,T,R)
    o_lat = ops.paged_decode_attention(q_eff.astype(x.dtype),
                                       k_eff.astype(x.dtype),
                                       v_eff.astype(x.dtype), tables["seq"],
                                       lengths + 1, scale=(D + Rp) ** -0.5,
                                       use_kernel=use_kernels)  # (B,H,R)
    o = jnp.einsum("bhr,rhd->bhd", o_lat.astype(jnp.float32),
                   w_uv_q.astype(jnp.float32)).astype(x.dtype)
    y = o.reshape(B, 1, H * D) @ p["wo"]["w"]
    return y, {"ckv": ckv_buf, "kpe": kpe_buf}


def mla_forward_chunk(p, x, spec: AttentionSpec, positions, cache, *,
                      use_kernels=True):
    """Incremental MLA prefill: append the chunk's latents to the cached
    ones, decompress K/V for the full prefix, attend chunk queries with
    ``q_offset``.  Returns (y, merged latent cache)."""
    B, C, _ = x.shape
    H, D, R, Rp = (spec.q_heads, spec.head_dim, spec.mla_kv_rank,
                   spec.mla_rope_dim)
    q_nope, q_pe = _mla_q(p, x, spec)
    q_pe = apply_rope(q_pe, positions, spec.rope_theta)
    kv_a = _lin(p["wkv_a"], x)                               # (B,C,R+Rp)
    ckv_new = rms_norm(kv_a[..., :R], p["kv_norm"])
    kpe_new = apply_rope(kv_a[..., R:][:, None], positions,
                         spec.rope_theta)[:, 0]              # (B,C,Rp)
    ckv = jnp.concatenate([cache["ckv"].astype(ckv_new.dtype), ckv_new], 1)
    kpe = jnp.concatenate([cache["kpe"].astype(kpe_new.dtype), kpe_new], 1)

    S = ckv.shape[1]
    kv = _lin(p["wkv_b"], ckv)                               # (B,S,Hkv*2D)
    kv = kv.reshape(B, S, spec.kv_heads, 2 * D).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :D], kv[..., D:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kpe[:, None], (B, spec.kv_heads, S, Rp))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    o = ops.attention(q, k, v, causal=True, scale=(D + Rp) ** -0.5,
                      q_offset=cache["ckv"].shape[1],
                      use_kernel=use_kernels)
    y = _merge_heads(o) @ p["wo"]["w"]
    return y, {"ckv": ckv, "kpe": kpe}


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def attention_forward(p, x, spec: AttentionSpec, positions, *, kv_source=None,
                      causal=True, use_kernels=True):
    if spec.kind == "mla":
        return mla_forward(p, x, spec, positions, use_kernels=use_kernels)
    return gqa_forward(p, x, spec, positions, kv_source=kv_source,
                       causal=causal, use_kernels=use_kernels)


def attention_forward_chunk(p, x, spec: AttentionSpec, positions, cache, *,
                            use_kernels=True):
    """Chunked-prefill step: attend a chunk at absolute ``positions`` over
    the prior chunks' cache (decoder-only self-attention)."""
    if spec.is_cross:
        raise ValueError("chunked prefill does not support cross-attention")
    if spec.kind == "mla":
        return mla_forward_chunk(p, x, spec, positions, cache,
                                 use_kernels=use_kernels)
    return gqa_forward_chunk(p, x, spec, positions, cache,
                             use_kernels=use_kernels)


def attention_decode(p, x, spec: AttentionSpec, cache, lengths, *,
                     use_kernels=True):
    if spec.kind == "mla":
        return mla_decode(p, x, spec, cache, lengths, use_kernels=use_kernels)
    return gqa_decode(p, x, spec, cache, lengths, use_kernels=use_kernels)


def attention_decode_paged(p, x, spec: AttentionSpec, cache, lengths, tables,
                           *, page_tokens, capacity, use_kernels=True):
    if spec.is_cross:
        raise ValueError("paged decode does not support cross-attention")
    if spec.kind == "mla":
        return mla_decode_paged(p, x, spec, cache, lengths, tables,
                                page_tokens=page_tokens, capacity=capacity,
                                use_kernels=use_kernels)
    return gqa_decode_paged(p, x, spec, cache, lengths, tables,
                            page_tokens=page_tokens, capacity=capacity,
                            use_kernels=use_kernels)


# ---------------------------------------------------------------------------
# speculative-verify ring rollback
# ---------------------------------------------------------------------------
#
# Only SWA ring buffers need rollback after a rejected speculative suffix:
# a ring write at slot (L + j) % w_buf clobbers the key that was living at
# global position L + j - w_buf, which IS still in-window for subsequent
# queries.  Append-only caches (full-attn, MLA latents, paged seq tables)
# need nothing — a rejected position p is only ever read once the slot's
# length exceeds p, and the length only gets there after the real write at
# p lands first.  The helpers below save the q rows a verify dispatch will
# overwrite and put the rejected ones back afterwards; accepted rows are
# re-written with their own (identical) values so the scatter needs no mask.


def _ring_write_slots(lengths, q, w_buf):
    """(B, q) ring slots the q verify steps write: (L + j) % w_buf."""
    steps = jnp.arange(q, dtype=jnp.int32)[None, :]
    return jnp.mod(lengths.astype(jnp.int32)[:, None] + steps, w_buf)


def ring_verify_save(cache, lengths, q):
    """Dense SWA ring cache leaves (R, B, w_buf, Hkv, D): gather the rows
    the next ``q`` decode steps will overwrite -> leaves (R, B, q, Hkv, D)."""
    w_buf = cache["k"].shape[2]
    idx = _ring_write_slots(lengths, q, w_buf)[None, :, :, None, None]
    return {n: jnp.take_along_axis(v, idx, axis=2) for n, v in cache.items()}


def ring_verify_restore(cache, saved, lengths, accept, q):
    """Put back the saved rows wherever the verify step was rejected
    (step j of a slot is rejected iff j > accept[b]); accepted rows are
    written back with their current — identical — values."""
    w_buf = cache["k"].shape[2]
    idx = _ring_write_slots(lengths, q, w_buf)               # (B, q)
    rej = jnp.arange(q, dtype=jnp.int32)[None, :] > accept[:, None]
    rows = jnp.arange(idx.shape[0])[:, None]                 # (B, 1)
    out = {}
    for n, buf in cache.items():
        cur = buf[:, rows, idx]                              # (R, B, q, Hkv, D)
        vals = jnp.where(rej[None, :, :, None, None],
                         saved[n].astype(buf.dtype), cur)
        out[n] = buf.at[:, rows, idx].set(vals)
    return out


def _ring_phys_off(lengths, q, w_buf, ring_table, page_tokens):
    """((B, q), (B, q)) physical page + in-page offset of the q ring writes.
    Inactive slots' tables point at the sink page; duplicate sink indices
    scatter garbage over garbage, which is fine."""
    T = page_tokens
    tbl = ring_table[:, :w_buf // T]
    wpos = _ring_write_slots(lengths, q, w_buf)              # (B, q)
    phys = jnp.take_along_axis(tbl, wpos // T, axis=1)
    return phys, wpos % T


def ring_verify_save_paged(cache, lengths, q, ring_table, *, page_tokens,
                           capacity, window):
    """Paged SWA pool leaves (R, Hkv, P, T, D): gather the q rows per slot
    the verify dispatch will ring-write -> leaves (R, Hkv, B, q, D)."""
    w_buf = min(window, capacity)
    phys, off = _ring_phys_off(lengths, q, w_buf, ring_table, page_tokens)
    return {n: v[:, :, phys, off] for n, v in cache.items()}


def ring_verify_restore_paged(cache, saved, lengths, accept, q, ring_table, *,
                              page_tokens, capacity, window):
    w_buf = min(window, capacity)
    phys, off = _ring_phys_off(lengths, q, w_buf, ring_table, page_tokens)
    rej = jnp.arange(q, dtype=jnp.int32)[None, :] > accept[:, None]
    out = {}
    for n, buf in cache.items():
        cur = buf[:, :, phys, off]                           # (R, Hkv, B, q, D)
        vals = jnp.where(rej[None, None, :, :, None],
                         saved[n].astype(buf.dtype), cur)
        out[n] = buf.at[:, :, phys, off].set(vals)
    return out

"""KVCache byte accounting + (de)serialization helpers for transfer.

``kv_bytes`` / ``kv_bytes_per_token`` implement the paper's S_kv(l) exactly
(Eq. 1 numerator): full-attn layers scale with min(l, window), MLA layers
cache latents, linear/SSM layers contribute O(1) state. These numbers drive
the throughput model, the router, and the link simulator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def kv_bytes(cfg: ModelConfig, seq_len: int, dtype_bytes: int = 2) -> int:
    """Total per-request KVCache+state bytes at context length seq_len."""
    return cfg.kv_cache_bytes(seq_len, dtype_bytes)


def kv_bytes_incremental(cfg: ModelConfig, cached_len: int, total_len: int,
                         dtype_bytes: int = 2) -> int:
    """Bytes produced by prefilling [cached_len, total_len) — what actually
    crosses the inter-DC link for a prefix-cache-hit request. Linear-state
    layers always resend their (fixed-size) state snapshot."""
    full = kv_bytes(cfg, total_len, dtype_bytes)
    prior = kv_bytes(cfg, cached_len, dtype_bytes) if cached_len else 0
    # linear states are included in both -> add one state snapshot back
    state = sum(b.mixer.state_bytes() for *_, b in cfg.iter_blocks()
                if not hasattr(b.mixer, "q_heads"))
    return max(full - prior, 0) + (state if cached_len else 0)


def cache_num_bytes(caches) -> int:
    """Actual byte size of a prefill cache pytree (for link simulation)."""
    leaves = jax.tree.leaves(caches)
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in leaves))


def flatten_cache_for_transfer(caches):
    """Flatten a cache pytree to a list of (path, array) wire chunks, one per
    layer tensor — the unit of layer-wise pipelined transfer (paper §3.3)."""
    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out.append((name, leaf))
    return out


def quantize_cache_for_wire(caches):
    """int8-quantize K/V/latent leaves for the inter-DC wire (KIVI-style
    per-tensor symmetric). Recurrent fp32 states ship uncompressed (tiny,
    numerically sensitive). Returns (wire pytree, bytes)."""
    import jax.numpy as jnp
    from repro.distributed.collectives import quantize_int8

    def enc(path, leaf):
        name = jax.tree_util.keystr(path)
        if leaf.dtype == jnp.bfloat16 and any(
                k in name for k in ("'k'", "'v'", "'ckv'", "'kpe'")):
            q, scale = quantize_int8(leaf.astype(jnp.float32))
            return {"q": q, "scale": scale}
        return leaf

    wire = jax.tree_util.tree_map_with_path(enc, caches)
    return wire, cache_num_bytes(wire)


def dequantize_cache_from_wire(wire):
    import jax
    import jax.numpy as jnp
    from repro.distributed.collectives import dequantize_int8

    def dec(leaf):
        return leaf

    def walk(node):
        if isinstance(node, dict) and set(node) == {"q", "scale"}:
            return dequantize_int8(node["q"], node["scale"]).astype(
                jnp.bfloat16)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(wire)

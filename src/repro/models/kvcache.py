"""KVCache byte accounting + (de)serialization helpers for transfer.

``kv_bytes`` / ``kv_bytes_per_token`` implement the paper's S_kv(l) exactly
(Eq. 1 numerator): full-attn layers scale with min(l, window), MLA layers
cache latents, linear/SSM layers contribute O(1) state. These numbers drive
the throughput model, the router, and the link simulator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionSpec, LinearSpec, ModelConfig


def kv_bytes(cfg: ModelConfig, seq_len: int, dtype_bytes: int = 2) -> int:
    """Total per-request KVCache+state bytes at context length seq_len."""
    return cfg.kv_cache_bytes(seq_len, dtype_bytes)


def kv_bytes_incremental(cfg: ModelConfig, cached_len: int, total_len: int,
                         dtype_bytes: int = 2) -> int:
    """Bytes produced by prefilling [cached_len, total_len) — what actually
    crosses the inter-DC link for a prefix-cache-hit request. Linear-state
    layers always resend their (fixed-size) state snapshot."""
    full = kv_bytes(cfg, total_len, dtype_bytes)
    prior = kv_bytes(cfg, cached_len, dtype_bytes) if cached_len else 0
    # linear states are included in both -> add one state snapshot back.
    # Explicit spec predicate: a mixer is linear-state iff it IS a
    # LinearSpec — duck-typing on a ``q_heads`` attribute misclassified any
    # non-attention mixer that happened to carry one (and would silently
    # drop the state resend for it).
    state = linear_state_bytes(cfg)
    return max(full - prior, 0) + (state if cached_len else 0)


def linear_state_bytes(cfg: ModelConfig) -> int:
    """Summed fixed-size recurrent-state bytes over the model's linear/SSM
    blocks (the O(1) part of S_kv that every incremental transfer resends)."""
    total = 0
    for *_, b in cfg.iter_blocks():
        m = b.mixer
        if isinstance(m, AttentionSpec):
            continue
        if not isinstance(m, LinearSpec) and not hasattr(m, "state_bytes"):
            raise TypeError(f"unknown mixer spec {type(m).__name__!r}: "
                            "expected AttentionSpec or LinearSpec")
        total += m.state_bytes()
    return total


def cache_num_bytes(caches) -> int:
    """Actual byte size of a prefill cache pytree (for link simulation)."""
    leaves = jax.tree.leaves(caches)
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in leaves))


def flatten_cache_for_transfer(caches):
    """Flatten a cache pytree to a list of (path, array) wire chunks, one per
    layer tensor — the unit of layer-wise pipelined transfer (paper §3.3)."""
    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out.append((name, leaf))
    return out


def quantize_cache_for_wire(caches, *, use_kernel: bool = True):
    """int8-quantize K/V/latent leaves for the inter-DC wire (KIVI-style
    per-tensor symmetric). Recurrent fp32 states ship uncompressed (tiny,
    numerically sensitive). The scale is stored in the leaf's original
    dtype so dequantization restores it. Returns (wire pytree, bytes).

    Each leaf's encode runs through ``ops.quantize_wire``: the fused Pallas
    absmax+encode kernel on TPU, the (byte-identical) jnp ref on CPU or with
    ``use_kernel=False``."""
    import jax.numpy as jnp
    from repro.kernels import ops

    def enc(path, leaf):
        name = jax.tree_util.keystr(path)
        if leaf.dtype in (jnp.bfloat16, jnp.float32) and any(
                k in name for k in ("'k'", "'v'", "'ckv'", "'kpe'")):
            q, scale = ops.quantize_wire(leaf.astype(jnp.float32),
                                         use_kernel=use_kernel)
            return {"q": q, "scale": scale.astype(leaf.dtype)}
        return leaf

    wire = jax.tree_util.tree_map_with_path(enc, caches)
    return wire, cache_num_bytes(wire)


def dequantize_cache_from_wire(wire):
    import jax
    import jax.numpy as jnp
    from repro.distributed.collectives import dequantize_int8

    def walk(node):
        if isinstance(node, dict) and set(node) == {"q", "scale"}:
            scale = node["scale"]
            return dequantize_int8(node["q"],
                                   scale.astype(jnp.float32)).astype(
                scale.dtype)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(wire)


def wire_compression_ratio(caches) -> float:
    """MEASURED raw/quantized byte ratio of a real prefill cache pytree —
    the value ``SystemConfig.kv_wire_compression`` should carry, instead of
    a hand-picked constant: the throughput model and simulator then charge
    exactly the bytes the quantized pytree actually puts on the wire."""
    raw = cache_num_bytes(caches)
    _, wire = quantize_cache_for_wire(caches)
    return raw / max(wire, 1)

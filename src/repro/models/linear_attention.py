"""Bounded-state sequence mixers: KDA / GDN / GLA / Mamba2 / mLSTM / sLSTM.

These are the paper's "Type A" blocks: their recurrent state is O(1) in
sequence length, which is what collapses S_kv(l) growth and makes
cross-datacenter KVCache transfer plausible (paper §2.2).

Implementation notes (TPU adaptation, see DESIGN.md §3/§7):
  * kda/gdn -> chunked gated delta rule kernel (scalar per-head decay; KDA's
    per-channel gate is proxied by the scalar gate — S_kv accounting, which
    is what the paper measures, is identical).
  * mamba2  -> GLA kernel (SSD is gated linear attention with scalar decay).
  * mlstm   -> GLA kernel with sigmoid input/forget gates (xLSTM-7B variant)
    and the normalizer computed via an augmented all-ones value column.
  * slstm   -> true sequential recurrence (h feeds gates) — lax.scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LinearSpec
from repro.kernels import ops
from repro.models.layers import causal_conv1d, init_linear, rms_norm


def _heads(x, H, D):
    B, S, _ = x.shape
    return x.reshape(B, S, H, D).transpose(0, 2, 1, 3)


def _unheads(x):
    B, H, S, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * D)


def _l2norm(x, eps=1e-6):
    return x * jax.lax.rsqrt(jnp.sum(x.astype(jnp.float32) ** 2, -1,
                                     keepdims=True) + eps).astype(x.dtype)


def _per_head_norm(o, scale, eps=1e-5):
    """RMSNorm over the value dim of (B,H,S,dv), scale (H*dv,)."""
    B, H, S, dv = o.shape
    of = o.astype(jnp.float32)
    var = jnp.mean(of * of, axis=-1, keepdims=True)
    of = of * jax.lax.rsqrt(var + eps)
    return (of * scale.astype(jnp.float32).reshape(1, H, 1, dv)).astype(o.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_linear_mixer(rng, d_model: int, spec: LinearSpec, dtype):
    ks = jax.random.split(rng, 12)
    H, dk, dv = spec.heads, spec.key_dim, spec.value_dim
    kind = spec.kind
    if kind == "slstm":
        p = {
            "w_gates": init_linear(ks[0], d_model, 4 * H * dv, dtype),
            "r_gates": jax.random.normal(ks[1], (H, dv, 4 * dv), dtype)
                       * (dv ** -0.5),
            "b_gates": jnp.zeros((4 * H * dv,), jnp.float32),
            "wo": init_linear(ks[2], H * dv, d_model, dtype),
            "g_norm": jnp.ones((H * dv,), jnp.float32),
        }
        return p
    p = {
        "wq": init_linear(ks[0], d_model, H * dk, dtype),
        "wk": init_linear(ks[1], d_model, H * dk, dtype),
        "wv": init_linear(ks[2], d_model, H * dv, dtype),
        "wo": init_linear(ks[3], H * dv, d_model, dtype),
        "g_proj": init_linear(ks[4], d_model, H * dv, dtype),
        "g_norm": jnp.ones((H * dv,), jnp.float32),
    }
    if kind in ("kda", "gdn", "mamba2"):
        p["a_proj"] = init_linear(ks[5], d_model, H, dtype)
        p["A_log"] = jnp.zeros((H,), jnp.float32)            # exp(0)=1 rate
        p["dt_bias"] = jnp.zeros((H,), jnp.float32)
    if kind in ("kda", "gdn"):
        p["b_proj"] = init_linear(ks[6], d_model, H, dtype)
    if kind == "gla":
        p["a_proj"] = init_linear(ks[5], d_model, H, dtype)
    if kind == "mlstm":
        p["i_proj"] = init_linear(ks[5], d_model, H, dtype)
        p["f_proj"] = init_linear(ks[6], d_model, H, dtype)
    if kind == "mamba2":
        p["D_skip"] = jnp.zeros((H,), jnp.float32)
    if spec.conv_kernel:
        C = H * (2 * dk + dv)
        p["conv_w"] = jax.random.normal(ks[7], (spec.conv_kernel, C), dtype) \
            * (spec.conv_kernel ** -0.5)
    return p


# ---------------------------------------------------------------------------
# shared q/k/v path (projection + causal conv + activation)
# ---------------------------------------------------------------------------


def _qkv(p, x, spec: LinearSpec, conv_state=None, lengths=None):
    H, dk, dv = spec.heads, spec.key_dim, spec.value_dim
    q = x @ p["wq"]["w"]
    k = x @ p["wk"]["w"]
    v = x @ p["wv"]["w"]
    new_conv = None
    if spec.conv_kernel:
        qkv = jnp.concatenate([q, k, v], axis=-1)
        if lengths is not None:
            # zero padded positions so conv taps at valid positions only
            # ever read real inputs (or zeros past the end)
            S = qkv.shape[1]
            mask = jnp.arange(S)[None, :] < lengths[:, None]
            qkv = jnp.where(mask[..., None], qkv, 0)
        qkv, new_conv = causal_conv1d(qkv, p["conv_w"], conv_state,
                                      lengths=lengths)
        qkv = jax.nn.silu(qkv)
        q = qkv[..., :H * dk]
        k = qkv[..., H * dk:2 * H * dk]
        v = qkv[..., 2 * H * dk:]
    return _heads(q, H, dk), _heads(k, H, dk), _heads(v, H, dv), new_conv


def _gates_full(p, x, spec: LinearSpec):
    """Per-token per-head (log_a, beta) for the full-sequence path."""
    kind = spec.kind
    if kind in ("kda", "gdn", "mamba2"):
        dt = jax.nn.softplus(x @ p["a_proj"]["w"]
                             + p["dt_bias"].astype(x.dtype))
        log_a = -jnp.exp(p["A_log"].astype(jnp.float32)) \
            * dt.astype(jnp.float32)                         # (B,S,H) <= 0
    elif kind == "gla":
        log_a = jax.nn.log_sigmoid(
            (x @ p["a_proj"]["w"]).astype(jnp.float32) + 4.0)
    elif kind == "mlstm":
        log_a = jax.nn.log_sigmoid((x @ p["f_proj"]["w"]).astype(jnp.float32)
                                   + 4.0)
    else:
        raise ValueError(kind)
    beta = None
    if kind in ("kda", "gdn"):
        beta = jax.nn.sigmoid((x @ p["b_proj"]["w"]).astype(jnp.float32))
    return log_a.transpose(0, 2, 1), \
        (beta.transpose(0, 2, 1) if beta is not None else None)  # (B,H,S)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def linear_forward(p, x, spec: LinearSpec, *, initial_state=None,
                   conv_state=None, lengths=None, use_kernels=True):
    """Returns (y, cache = {"state": (B,H,dk,dv) f32 [, "conv"]}).

    ``lengths`` (B,): valid token counts for right-padded batches (bucketed
    prefill).  Padded positions are made state-neutral — decay forced to 1
    and key/beta to 0, so the recurrent update degenerates to identity — and
    the conv window is gathered at ``lengths``; the returned state is then
    EXACTLY the state after the request's real tokens, independent of how
    much bucket padding follows.  ``lengths=None`` (train / unpadded
    prefill) is byte-identical to the old path.
    """
    B, S, _ = x.shape
    kind = spec.kind
    if kind == "slstm":
        return _slstm_forward(p, x, spec, initial_state=initial_state,
                              lengths=lengths)

    q, k, v, new_conv = _qkv(p, x, spec, conv_state, lengths=lengths)
    log_a, beta = _gates_full(p, x, spec)
    # padded-position neutralization (decay -> 1, k/beta -> 0) happens inside
    # ops.gla/ops.delta: fused in-VMEM on the kernel path, identical
    # jnp.where masking on the ref path. Safe to mask after the kind
    # transforms below because each maps 0 -> 0 (_l2norm(0) = 0, gain * 0
    # = 0), so transform-then-mask == mask-then-transform.

    if kind in ("kda", "gdn"):
        k = _l2norm(k)
        q = _l2norm(q)
        o, state = ops.delta(q, k, v, log_a, beta, initial_state,
                             lengths=lengths, use_kernel=use_kernels)
    elif kind == "mlstm":
        i_gate = jax.nn.sigmoid((x @ p["i_proj"]["w"]).astype(jnp.float32))
        k = (k.astype(jnp.float32)
             * i_gate.transpose(0, 2, 1)[..., None]).astype(k.dtype)
        k = k * (spec.key_dim ** -0.5)
        ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
        v_aug = jnp.concatenate([v, ones], axis=-1)
        o_aug, state = ops.gla(q, k, v_aug, log_a, initial_state,
                               lengths=lengths, use_kernel=use_kernels)
        num, den = o_aug[..., :-1], o_aug[..., -1:]
        o = (num.astype(jnp.float32)
             / jnp.maximum(jnp.abs(den.astype(jnp.float32)), 1.0)
             ).astype(v.dtype)
    else:  # gla / mamba2
        if kind == "mamba2":
            k = k * (spec.key_dim ** -0.5)
        o, state = ops.gla(q, k, v, log_a, initial_state,
                           lengths=lengths, use_kernel=use_kernels)
        if kind == "mamba2":
            o = o + p["D_skip"].astype(jnp.float32).reshape(1, -1, 1, 1) \
                * v.astype(jnp.float32)

    o = _per_head_norm(o.astype(x.dtype), p["g_norm"])
    g = jax.nn.silu(x @ p["g_proj"]["w"])
    y = (_unheads(o) * g) @ p["wo"]["w"]
    cache = {"state": state}
    if spec.conv_kernel:
        cache["conv"] = new_conv
    return y, cache


# ---------------------------------------------------------------------------
# single-token decode
# ---------------------------------------------------------------------------


def linear_decode(p, x, spec: LinearSpec, cache, *, use_kernels=True):
    """x: (B,1,d). cache: {"state" [, "conv"] ...}. Returns (y, cache)."""
    if spec.kind == "slstm":
        return _slstm_decode(p, x, spec, cache)
    B = x.shape[0]
    q, k, v, new_conv = _qkv(p, x, spec, cache.get("conv"))
    log_a, beta = _gates_full(p, x, spec)
    q1, k1, v1 = q[:, :, 0], k[:, :, 0], v[:, :, 0]
    la1 = log_a[:, :, 0]
    kind = spec.kind
    state = cache["state"]
    if kind in ("kda", "gdn"):
        k1 = _l2norm(k1)
        q1 = _l2norm(q1)
        o, state = ops.delta_step(q1, k1, v1, la1, beta[:, :, 0], state)
    elif kind == "mlstm":
        i_gate = jax.nn.sigmoid(
            (x @ p["i_proj"]["w"]).astype(jnp.float32))[:, 0]  # (B,H)
        k1 = (k1.astype(jnp.float32) * i_gate[..., None]).astype(k1.dtype)
        k1 = k1 * (spec.key_dim ** -0.5)
        ones = jnp.ones(v1.shape[:-1] + (1,), v1.dtype)
        o_aug, state = ops.gla_step(q1, k1, jnp.concatenate([v1, ones], -1),
                                    la1, state)
        num, den = o_aug[..., :-1], o_aug[..., -1:]
        o = (num.astype(jnp.float32)
             / jnp.maximum(jnp.abs(den.astype(jnp.float32)), 1.0)
             ).astype(v1.dtype)
    else:
        if kind == "mamba2":
            k1 = k1 * (spec.key_dim ** -0.5)
        o, state = ops.gla_step(q1, k1, v1, la1, state)
        if kind == "mamba2":
            o = o + p["D_skip"].astype(jnp.float32).reshape(1, -1, 1) \
                * v1.astype(jnp.float32)

    o = _per_head_norm(o[:, :, None].astype(x.dtype), p["g_norm"])[:, :, 0]
    g = jax.nn.silu(x[:, 0] @ p["g_proj"]["w"])
    y = ((o.reshape(B, -1) * g) @ p["wo"]["w"])[:, None]
    new_cache = {"state": state}
    if spec.conv_kernel:
        new_cache["conv"] = new_conv
    return y, new_cache


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, recurrent gate feedback -> sequential scan)
# ---------------------------------------------------------------------------


def _slstm_gates(p, x_t, h_prev, spec: LinearSpec):
    """x_t: (B, d); h_prev: (B, H, dv) -> four gates (B, H, dv)."""
    H, dv = spec.heads, spec.value_dim
    gx = x_t @ p["w_gates"]["w"]                             # (B, 4*H*dv)
    gh = jnp.einsum("bhv,hvu->bhu", h_prev.astype(p["r_gates"].dtype),
                    p["r_gates"])                            # (B,H,4*dv)
    g = (gx.reshape(-1, H, 4 * dv) + gh).astype(jnp.float32) \
        + p["b_gates"].reshape(H, 4 * dv)
    i, f, z, o = jnp.split(g, 4, axis=-1)
    return i, f, z, o


def _slstm_step(p, spec, x_t, state):
    c, n, m, h = state
    i_t, f_t, z_t, o_t = _slstm_gates(p, x_t, h, spec)
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c = f_p * c + i_p * jnp.tanh(z_t)
    n = f_p * n + i_p
    h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h)


def slstm_init_state(B, spec: LinearSpec):
    H, dv = spec.heads, spec.value_dim
    z = jnp.zeros((B, H, dv), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}


UNROLL = False


def _slstm_forward(p, x, spec: LinearSpec, *, initial_state=None,
                   lengths=None):
    B, S, d = x.shape
    if initial_state is None:
        initial_state = slstm_init_state(B, spec)
    st0 = (initial_state["c"], initial_state["n"], initial_state["m"],
           initial_state["h"])

    if lengths is not None:
        # right-padded batch: hold the state at padded positions so the
        # final state is the state after each row's real tokens
        mask = jnp.arange(S)[:, None] < lengths[None, :]     # (S,B)

        def step(state, inp):
            x_t, m_t = inp
            new = _slstm_step(p, spec, x_t, state)
            state = tuple(jnp.where(m_t[:, None, None], nw, old)
                          for nw, old in zip(new, state))
            return state, state[3]

        (c, n, m, h), hs = jax.lax.scan(step, st0,
                                        (x.transpose(1, 0, 2), mask),
                                        unroll=True if UNROLL else 1)
        hs = hs.transpose(1, 0, 2, 3)                        # (B,S,H,dv)
        o = rms_norm(hs.reshape(B, S, -1).astype(x.dtype), p["g_norm"])
        y = o @ p["wo"]["w"]
        return y, {"state": {"c": c, "n": n, "m": m, "h": h}}

    def step(state, x_t):
        state = _slstm_step(p, spec, x_t, state)
        return state, state[3]

    (c, n, m, h), hs = jax.lax.scan(step, st0, x.transpose(1, 0, 2),
                                    unroll=True if UNROLL else 1)
    hs = hs.transpose(1, 0, 2, 3)                            # (B,S,H,dv)
    o = rms_norm(hs.reshape(B, S, -1).astype(x.dtype), p["g_norm"])
    y = o @ p["wo"]["w"]
    return y, {"state": {"c": c, "n": n, "m": m, "h": h}}


def _slstm_decode(p, x, spec: LinearSpec, cache):
    B = x.shape[0]
    s = cache["state"]
    st = _slstm_step(p, spec, x[:, 0], (s["c"], s["n"], s["m"], s["h"]))
    c, n, m, h = st
    o = rms_norm(h.reshape(B, -1).astype(x.dtype), p["g_norm"])
    y = (o @ p["wo"]["w"])[:, None]
    return y, {"state": {"c": c, "n": n, "m": m, "h": h}}

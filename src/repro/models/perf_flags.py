"""Performance-iteration flags (EXPERIMENTS.md §Perf).

Module-level so the dry-run / cost-probe launchers can flip variants without
threading knobs through every layer. Defaults = paper-faithful baseline.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field


@dataclass
class PerfFlags:
    # shard MoE dispatch buffers (token/slot dim over "data") — fixes the
    # replicated (E*C, d) gather buffers that dominate prefill/train memory
    shard_moe_tokens: bool = False
    # cap on token count for exact dropless MoE; larger prefills fall back
    # to capacity dispatch (cf from the spec) — bounds the ragged gather
    moe_dropless_max_tokens: int = 1 << 62
    # activation sharding hint at block boundaries (sequence over "model")
    sequence_parallel: bool = False
    # pin (batch->data, heads->model) 2-D sharding at attention entry —
    # GSPMD otherwise sometimes drops the batch dim when heads shard
    shard_attention: bool = False
    # scan MoE over token chunks of this size (0 = off): bounds the
    # (chunk*k, d) dispatch/gather buffers that GSPMD cannot shard (gather
    # across all token shards) — chunked-prefill-style FFN execution
    moe_chunk_tokens: int = 0


FLAGS = PerfFlags()

VARIANTS = {
    "baseline": PerfFlags(),
    # iteration 1: shard MoE dispatch + gate dropless to decode-size batches
    "moe_shard": PerfFlags(shard_moe_tokens=True,
                moe_chunk_tokens=16384,
                           moe_dropless_max_tokens=32768,
                           shard_attention=True),
    # iteration 2 (decode): moe_shard + no FSDP (set via dryrun --perf-variant
    # plumbing: fsdp handled in the launcher, flags here for model-side)
    "no_fsdp": PerfFlags(shard_moe_tokens=True,
                moe_chunk_tokens=16384,
                         moe_dropless_max_tokens=32768,
                         shard_attention=True),
    # iteration 3: + sequence-parallel activations
    "seqpar": PerfFlags(shard_moe_tokens=True,
                moe_chunk_tokens=16384,
                        moe_dropless_max_tokens=32768,
                        shard_attention=True,
                        sequence_parallel=True),
}


@contextlib.contextmanager
def use_variant(name: str):
    """Mutates the FLAGS singleton in place — modules import the object
    itself (``from ... import FLAGS``), so rebinding would not propagate."""
    import dataclasses as _dc
    old = _dc.replace(FLAGS)
    for f in _dc.fields(PerfFlags):
        setattr(FLAGS, f.name, getattr(VARIANTS[name], f.name))
    try:
        yield FLAGS
    finally:
        for f in _dc.fields(PerfFlags):
            setattr(FLAGS, f.name, getattr(old, f.name))


def shard_hint(x, *spec):
    """with_sharding_constraint that no-ops outside a mesh context."""
    import jax
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x

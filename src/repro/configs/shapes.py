"""Assigned input-shape set. Every (arch x shape) cell is well-defined here.

``train_*`` lower ``train_step``; ``prefill_*`` lower the prefill forward;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token with a KV cache
of ``seq_len``). ``long_500k`` is only lowered for sub-quadratic archs
(``ModelConfig.is_sub_quadratic``), per the assignment.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    sub_quadratic_only: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1,
                           sub_quadratic_only=True),
}


def cells(configs):
    """Yield every runnable (arch_name, shape_name) cell, applying skips."""
    for name, cfg in configs.items():
        for sname, shape in SHAPES.items():
            if shape.sub_quadratic_only and not cfg.runs_long_context:
                continue
            yield name, sname

"""SeamlessM4T-medium: enc-dec, 12L+12L, d=1024, 16H MHA(kv=16), d_ff=4096.

[arXiv:2308.11596; hf]. Multimodal enc-dec; per the assignment the audio
frontend is a STUB: ``input_specs()`` provides precomputed frame embeddings
(B, src_len, d_model) consumed directly by the transformer encoder. The
decoder has self-attention (cached) + cross-attention over encoder output.

PrfaaS mapping: the encoder plays the "prefill" role (compute-dense, produces
the cross-attention K/V = this arch's 'KVCache'), the decoder the "decode"
role — the paper's P/D split falls on the enc/dec boundary.
"""
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                ModelConfig)


def build() -> ModelConfig:
    self_attn = AttentionSpec(kind="full", q_heads=16, kv_heads=16,
                              head_dim=64, rope=False)
    cross_attn = AttentionSpec(kind="full", q_heads=16, kv_heads=16,
                               head_dim=64, rope=False, is_cross=True)
    ffn = FFNSpec(kind="dense", d_ff=4096, activation="gelu")
    enc_block = BlockSpec(mixer=self_attn, ffn=ffn)
    dec_block = BlockSpec(mixer=self_attn, ffn=ffn, cross=cross_attn)
    return ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        d_model=1024,
        vocab_size=256206,
        groups=(GroupSpec(blocks=(dec_block,), repeats=12),),
        encoder_groups=(GroupSpec(blocks=(enc_block,), repeats=12),),
        encoder_input_dim=1024,
        max_seq_len=8192,
        source="arXiv:2308.11596",
        notes="enc-dec; audio frontend stubbed as precomputed frame embeds.",
    )

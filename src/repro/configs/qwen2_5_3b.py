"""Qwen2.5-3B: 36L, d=2048, 16H GQA(kv=2), d_ff=11008, vocab 151936, QKV bias.

[hf:Qwen/Qwen2.5 family; hf].
"""
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                ModelConfig)


def build() -> ModelConfig:
    attn = AttentionSpec(kind="full", q_heads=16, kv_heads=2, head_dim=128,
                         qkv_bias=True, rope=True, rope_theta=1_000_000.0)
    ffn = FFNSpec(kind="dense", d_ff=11008, activation="swiglu")
    block = BlockSpec(mixer=attn, ffn=ffn)
    return ModelConfig(
        name="qwen2.5-3b",
        family="dense",
        d_model=2048,
        vocab_size=151936,
        groups=(GroupSpec(blocks=(block,), repeats=36),),
        tie_embeddings=True,
        max_seq_len=32768,
        source="hf:Qwen/Qwen2.5-3B",
        notes="GQA kv=2 with QKV bias; tied embeddings.",
    )

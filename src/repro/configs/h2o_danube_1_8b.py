"""H2O-Danube-1.8B: 24L, d=2560, 32H GQA(kv=8), d_ff=6912, vocab 32000, SWA.

[arXiv:2401.16818; hf]. Llama+Mistral mix with sliding-window attention.
"""
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                ModelConfig)


def build() -> ModelConfig:
    attn = AttentionSpec(kind="swa", q_heads=32, kv_heads=8, head_dim=80,
                         window=4096, rope=True)
    ffn = FFNSpec(kind="dense", d_ff=6912, activation="swiglu")
    block = BlockSpec(mixer=attn, ffn=ffn)
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        d_model=2560,
        vocab_size=32000,
        groups=(GroupSpec(blocks=(block,), repeats=24),),
        max_seq_len=16384,
        source="arXiv:2401.16818",
        notes="SWA window 4096; head_dim 80 (d_model/q_heads).",
    )

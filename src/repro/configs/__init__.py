"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from repro.configs import (granite_20b, h2o_danube_1_8b, kimi_linear_1t,
                           llama4_scout, mistral_nemo_12b, mixtral_8x22b,
                           phi3_vision_4_2b, qwen2_5_3b, seamless_m4t_medium,
                           xlstm_350m, zamba2_1_2b)
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                LinearSpec, ModelConfig, reduce_for_smoke)
from repro.configs.shapes import SHAPES, ShapeSpec, cells

# The 10 assigned architectures (dry-run + roofline grid) + the paper's own.
ARCH_BUILDERS = {
    "mixtral-8x22b": mixtral_8x22b.build,
    "llama4-scout-17b-a16e": llama4_scout.build,
    "granite-20b": granite_20b.build,
    "qwen2.5-3b": qwen2_5_3b.build,
    "mistral-nemo-12b": mistral_nemo_12b.build,
    "h2o-danube-1.8b": h2o_danube_1_8b.build,
    "phi-3-vision-4.2b": phi3_vision_4_2b.build,
    "seamless-m4t-medium": seamless_m4t_medium.build,
    "zamba2-1.2b": zamba2_1_2b.build,
    "xlstm-350m": xlstm_350m.build,
    # the paper's case-study model (not part of the assigned 40-cell grid,
    # but first-class: it drives the Table 5/6 reproduction)
    "kimi-linear-1t": kimi_linear_1t.build,
}

ASSIGNED_ARCHS = [k for k in ARCH_BUILDERS if k != "kimi-linear-1t"]

_cache = {}


def get_config(name: str) -> ModelConfig:
    if name not in _cache:
        if name not in ARCH_BUILDERS:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_BUILDERS)}")
        _cache[name] = ARCH_BUILDERS[name]()
    return _cache[name]


def get_smoke_config(name: str) -> ModelConfig:
    return reduce_for_smoke(get_config(name))


def all_configs(assigned_only: bool = True):
    names = ASSIGNED_ARCHS if assigned_only else list(ARCH_BUILDERS)
    return {n: get_config(n) for n in names}


__all__ = [
    "ARCH_BUILDERS", "ASSIGNED_ARCHS", "SHAPES", "ShapeSpec", "cells",
    "get_config", "get_smoke_config", "all_configs",
    "ModelConfig", "AttentionSpec", "LinearSpec", "FFNSpec", "BlockSpec",
    "GroupSpec", "reduce_for_smoke",
]

"""Approximate public configs for the paper's Table 1/3 comparison models.

These are *profile* configs: used by the Φ_kv / bandwidth benchmarks for
S_kv and FLOP accounting, not as assigned dry-run architectures. Dims are
taken from public releases / tech reports where published, else approximated
from stated totals; each entry notes its provenance.
"""
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                LinearSpec, ModelConfig)


def kimi_linear_48b() -> ModelConfig:
    """Kimi Linear 48B-A3B [arXiv:2510.26692]: KDA:MLA 3:1."""
    kda = LinearSpec(kind="kda", heads=32, key_dim=128, value_dim=128)
    mla = AttentionSpec(kind="mla", q_heads=32, kv_heads=32, head_dim=128,
                        mla_kv_rank=512, mla_rope_dim=64)
    moe = FFNSpec(kind="moe", d_ff=1408, activation="swiglu",
                  num_experts=256, top_k=8, shared_experts=1)
    return ModelConfig(
        name="kimi-linear-48b", family="hybrid", d_model=4096,
        vocab_size=163840,
        groups=(GroupSpec(blocks=(BlockSpec(kda, moe), BlockSpec(kda, moe),
                                  BlockSpec(kda, moe), BlockSpec(mla, moe)),
                          repeats=12),),
        source="arXiv:2510.26692")


def mimo_v2_flash() -> ModelConfig:
    """MiMo-V2-Flash 309B [arXiv:2601.02780]: SWA:GQA 5:1 MoE."""
    swa = AttentionSpec(kind="swa", q_heads=48, kv_heads=8, head_dim=128,
                        window=4096)
    gqa = AttentionSpec(kind="full", q_heads=48, kv_heads=8, head_dim=128)
    moe = FFNSpec(kind="moe", d_ff=2048, activation="swiglu",
                  num_experts=256, top_k=8, shared_experts=1)
    return ModelConfig(
        name="mimo-v2-flash", family="hybrid", d_model=6144,
        vocab_size=151936,
        groups=(GroupSpec(blocks=(BlockSpec(swa, moe),) * 5 +
                                 (BlockSpec(gqa, moe),),
                          repeats=8),),
        source="arXiv:2601.02780")


def qwen3_5_397b() -> ModelConfig:
    """Qwen3.5-397B [qwen.ai blog]: GDN:GQA 3:1 MoE."""
    gdn = LinearSpec(kind="gdn", heads=32, key_dim=128, value_dim=128)
    gqa = AttentionSpec(kind="full", q_heads=64, kv_heads=4, head_dim=128)
    moe = FFNSpec(kind="moe", d_ff=2560, activation="swiglu",
                  num_experts=384, top_k=10, shared_experts=1)
    return ModelConfig(
        name="qwen3.5-397b", family="hybrid", d_model=6144,
        vocab_size=151936,
        groups=(GroupSpec(blocks=(BlockSpec(gdn, moe), BlockSpec(gdn, moe),
                                  BlockSpec(gdn, moe), BlockSpec(gqa, moe)),
                          repeats=15),),
        source="qwen.ai blog (Qwen3.5)")


def ring_2_5_1t() -> ModelConfig:
    """Ring-2.5-1T [github:inclusionAI/Ring-V2.5]: Lightning:MLA 7:1 MoE."""
    lightning = LinearSpec(kind="gla", heads=48, key_dim=128, value_dim=128)
    mla = AttentionSpec(kind="mla", q_heads=64, kv_heads=64, head_dim=128,
                        mla_kv_rank=512, mla_rope_dim=64)
    moe = FFNSpec(kind="moe", d_ff=2048, activation="swiglu",
                  num_experts=384, top_k=8, shared_experts=1)
    return ModelConfig(
        name="ring-2.5-1t", family="hybrid", d_model=7168,
        vocab_size=157184,
        groups=(GroupSpec(blocks=(BlockSpec(lightning, moe),) * 7 +
                                 (BlockSpec(mla, moe),),
                          repeats=8),),
        source="github:inclusionAI/Ring-V2.5")


def minimax_m2_5() -> ModelConfig:
    """MiniMax-M2.5 229B [minimax.io]: dense full GQA (the paper's 'dense' foil)."""
    gqa = AttentionSpec(kind="full", q_heads=48, kv_heads=8, head_dim=128)
    moe = FFNSpec(kind="moe", d_ff=2560, activation="swiglu",
                  num_experts=256, top_k=8, shared_experts=1)
    return ModelConfig(
        name="minimax-m2.5", family="moe", d_model=6144,
        vocab_size=200064,
        groups=(GroupSpec(blocks=(BlockSpec(gqa, moe),), repeats=62),),
        source="minimax.io (M2.5)")


def qwen3_235b() -> ModelConfig:
    """Qwen3-235B-A22B [arXiv:2505.09388]: 94L GQA kv=4 MoE."""
    gqa = AttentionSpec(kind="full", q_heads=64, kv_heads=4, head_dim=128)
    moe = FFNSpec(kind="moe", d_ff=1536, activation="swiglu",
                  num_experts=128, top_k=8)
    return ModelConfig(
        name="qwen3-235b", family="moe", d_model=4096,
        vocab_size=151936,
        groups=(GroupSpec(blocks=(BlockSpec(gqa, moe),), repeats=94),),
        source="arXiv:2505.09388")


PROFILE_MODELS = {
    "kimi-linear-48b": kimi_linear_48b,
    "mimo-v2-flash": mimo_v2_flash,
    "qwen3.5-397b": qwen3_5_397b,
    "ring-2.5-1t": ring_2_5_1t,
    "minimax-m2.5": minimax_m2_5,
    "qwen3-235b": qwen3_235b,
}

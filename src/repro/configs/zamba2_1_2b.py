"""Zamba2-1.2B: 38L, d=2048, Mamba2 backbone + shared full-attn blocks.

[arXiv:2411.15242; hf]. ssm_state=64. 32 Mamba2 layers with a *shared*
(parameter-tied) attention+FFN block invoked 6 times, interleaved every 6
layers — expressed here as 6 repeats of (5 mamba2 + 1 shared attn) plus a
2-layer mamba2 tail. Shared attn: 32H MHA (kv=32), head_dim 64.
"""
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                LinearSpec, ModelConfig)


def build() -> ModelConfig:
    # Mamba2: expand=2 -> inner 4096 = 32 heads x 128 value dim; state N=64.
    mamba = LinearSpec(kind="mamba2", heads=32, key_dim=64, value_dim=128,
                       conv_kernel=4)
    attn = AttentionSpec(kind="full", q_heads=32, kv_heads=32, head_dim=64,
                         rope=True)
    no_ffn = FFNSpec(kind="none")
    ffn = FFNSpec(kind="dense", d_ff=8192, activation="swiglu")
    m_block = BlockSpec(mixer=mamba, ffn=no_ffn)
    shared_attn = BlockSpec(mixer=attn, ffn=ffn, shared=True)
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        d_model=2048,
        vocab_size=32000,
        groups=(
            GroupSpec(blocks=(m_block, m_block, m_block, m_block, m_block,
                              shared_attn), repeats=6),
            GroupSpec(blocks=(m_block,), repeats=2),
        ),
        max_seq_len=1_048_576,
        source="arXiv:2411.15242",
        notes="Mamba2 + shared attn blocks (params tied across 6 invocations).",
    )

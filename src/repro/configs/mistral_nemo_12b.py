"""Mistral-Nemo-12B: 40L, d=5120, 32H GQA(kv=8), head_dim=128, d_ff=14336.

[hf:mistralai/Mistral-Nemo-Base-2407; hf]. 128k context; q_heads*head_dim
(4096) deliberately != d_model (5120), matching the released config.
"""
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                ModelConfig)


def build() -> ModelConfig:
    attn = AttentionSpec(kind="full", q_heads=32, kv_heads=8, head_dim=128,
                         rope=True, rope_theta=1_000_000.0)
    ffn = FFNSpec(kind="dense", d_ff=14336, activation="swiglu")
    block = BlockSpec(mixer=attn, ffn=ffn)
    return ModelConfig(
        name="mistral-nemo-12b",
        family="dense",
        d_model=5120,
        vocab_size=131072,
        groups=(GroupSpec(blocks=(block,), repeats=40),),
        max_seq_len=131072,
        source="hf:mistralai/Mistral-Nemo-Base-2407",
        notes="128k ctx; head_dim 128 (q_heads*head_dim != d_model).",
    )

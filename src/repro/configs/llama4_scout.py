"""Llama4-Scout-17B-A16E: 48L, d=5120, 40H GQA(kv=8), d_ff=8192, 16e top-1.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]. MoE with a shared expert
and top-1 routing; full GQA attention (no window) -> long_500k skipped.
"""
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                ModelConfig)


def build() -> ModelConfig:
    attn = AttentionSpec(kind="full", q_heads=40, kv_heads=8, head_dim=128,
                         rope=True, rope_theta=500_000.0)
    ffn = FFNSpec(kind="moe", d_ff=8192, activation="swiglu",
                  num_experts=16, top_k=1, shared_experts=1)
    block = BlockSpec(mixer=attn, ffn=ffn)
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        d_model=5120,
        vocab_size=202048,
        groups=(GroupSpec(blocks=(block,), repeats=48),),
        max_seq_len=131072,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
        notes="16 routed experts top-1 + 1 shared; early-fusion text backbone.",
    )

"""Mixtral-8x22B: 56L, d=6144, 48H GQA(kv=8), d_ff=16384, 8 experts top-2, SWA.

[arXiv:2401.04088; hf]. Sliding-window attention (Mistral lineage, w=4096)
bounds the KV cache, which is what makes this MoE arch PrfaaS-friendly.
"""
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                ModelConfig)


def build() -> ModelConfig:
    attn = AttentionSpec(kind="swa", q_heads=48, kv_heads=8, head_dim=128,
                         window=4096, rope=True, rope_theta=1_000_000.0)
    ffn = FFNSpec(kind="moe", d_ff=16384, activation="swiglu",
                  num_experts=8, top_k=2)
    block = BlockSpec(mixer=attn, ffn=ffn)
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        d_model=6144,
        vocab_size=32768,
        groups=(GroupSpec(blocks=(block,), repeats=56),),
        max_seq_len=65536,
        source="arXiv:2401.04088",
        notes="8 experts top-2; SWA window 4096 bounds per-layer KV.",
    )

"""Phi-3-Vision-4.2B: 32L, d=3072, 32H MHA(kv=32), d_ff=8192, vocab 32064.

[hf:microsoft/Phi-3-vision-128k-instruct; hf]. phi3-mini text backbone +
CLIP frontend. Per the assignment, the modality frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings (B, 576, d_model)
that the model prepends to the token stream.
"""
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                ModelConfig)


def build() -> ModelConfig:
    attn = AttentionSpec(kind="full", q_heads=32, kv_heads=32, head_dim=96,
                         rope=True)
    ffn = FFNSpec(kind="dense", d_ff=8192, activation="swiglu")
    block = BlockSpec(mixer=attn, ffn=ffn)
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        d_model=3072,
        vocab_size=32064,
        groups=(GroupSpec(blocks=(block,), repeats=32),),
        num_image_patches=576,
        max_seq_len=131072,
        source="hf:microsoft/Phi-3-vision-128k-instruct",
        notes="MHA backbone; CLIP patch embeds are a precomputed stub input.",
    )

"""Model/config dataclasses shared by every architecture.

A model is a stack of *groups*; each group is a repeated sequence of
``BlockSpec``s (the repeat unit).  ``lax.scan`` runs over the repeats of a
group with stacked parameters, which keeps HLO size and compile time bounded
for 50+ layer models while still expressing hybrid interleaves
(e.g. [KDA, KDA, KDA, MLA] x 16).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Mixer specs (the sequence-mixing half of a block)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionSpec:
    """Full (quadratic) attention: MHA / GQA / MQA / SWA / MLA."""

    kind: str = "full"          # "full" | "swa" | "mla"
    q_heads: int = 8
    kv_heads: int = 8
    head_dim: int = 128
    window: int = 0             # >0 => sliding-window attention
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    # MLA-only fields (DeepSeek-V2 style latent compression).
    mla_kv_rank: int = 512      # latent c_kv dim (cached)
    mla_rope_dim: int = 64      # decoupled rope key dim (cached)
    mla_q_rank: int = 0         # 0 => full-rank q projection
    is_cross: bool = False      # encoder-decoder cross attention

    @property
    def is_sub_quadratic(self) -> bool:
        return self.window > 0

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """Per-token per-layer KVCache bytes (the paper's S_kv contribution)."""
        if self.kind == "mla":
            return (self.mla_kv_rank + self.mla_rope_dim) * dtype_bytes
        return 2 * self.kv_heads * self.head_dim * dtype_bytes

    def kv_cache_tokens(self, seq_len: int) -> int:
        """Number of cached token slots (SWA bounds this by the window)."""
        if self.kind == "swa" and self.window > 0:
            return min(seq_len, self.window)
        return seq_len


@dataclass(frozen=True)
class LinearSpec:
    """Bounded-state sequence mixers: KDA / GDN / GLA / Mamba2 / mLSTM / sLSTM."""

    kind: str = "gla"           # "kda" | "gdn" | "gla" | "mamba2" | "mlstm" | "slstm"
    heads: int = 8
    key_dim: int = 128          # per-head key/state dim
    value_dim: int = 128        # per-head value dim
    conv_kernel: int = 4        # short depthwise conv on q/k/v paths (0 = off)
    state_dtype_bytes: int = 4  # recurrent state kept in fp32

    @property
    def is_sub_quadratic(self) -> bool:
        return True

    def state_bytes(self) -> int:
        """Fixed per-request recurrent-state bytes (length independent)."""
        if self.kind == "slstm":
            # scalar-memory cells: (c, n, h, m) per head-dim unit
            return 4 * self.heads * self.value_dim * self.state_dtype_bytes
        s = self.heads * self.key_dim * self.value_dim * self.state_dtype_bytes
        if self.kind in ("mlstm",):
            # + normalizer n (heads, key_dim) and max-state m (heads,)
            s += self.heads * (self.key_dim + 1) * self.state_dtype_bytes
        if self.conv_kernel:
            s += self.conv_kernel * self.heads * (self.key_dim * 2 + self.value_dim) * 2
        return s


# ---------------------------------------------------------------------------
# FFN specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FFNSpec:
    kind: str = "dense"         # "dense" | "moe" | "none"
    d_ff: int = 0
    activation: str = "swiglu"  # "swiglu" | "gelu" | "geglu"
    # MoE fields
    num_experts: int = 0
    top_k: int = 0
    shared_experts: int = 0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class BlockSpec:
    mixer: object               # AttentionSpec | LinearSpec
    ffn: FFNSpec
    shared: bool = False        # zamba-style: parameters shared across repeats
    cross: Optional[AttentionSpec] = None  # enc-dec decoder cross-attention


@dataclass(frozen=True)
class GroupSpec:
    """``repeats`` x ``blocks`` with stacked params scanned over repeats."""

    blocks: Tuple[BlockSpec, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.blocks) * self.repeats


# ---------------------------------------------------------------------------
# Whole-model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # "dense" | "moe" | "vlm" | "audio" | "hybrid" | "ssm"
    d_model: int
    vocab_size: int
    groups: Tuple[GroupSpec, ...]
    # encoder (enc-dec only); None for decoder-only LMs
    encoder_groups: Optional[Tuple[GroupSpec, ...]] = None
    encoder_input_dim: int = 0  # >0: continuous frontend features (audio stub)
    num_image_patches: int = 0  # >0: VLM patch-embedding stub prepended
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 131072
    dtype: str = "bfloat16"
    # Reference/bookkeeping
    source: str = ""
    notes: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def n_layers(self) -> int:
        return sum(g.n_layers for g in self.groups)

    def iter_blocks(self):
        """Yield (group_idx, repeat_idx, block_idx, BlockSpec) in stack order."""
        for gi, g in enumerate(self.groups):
            for r in range(g.repeats):
                for bi, b in enumerate(g.blocks):
                    yield gi, r, bi, b

    def full_attn_layers(self) -> int:
        return sum(1 for *_, b in self.iter_blocks()
                   if isinstance(b.mixer, AttentionSpec))

    def linear_layers(self) -> int:
        return sum(1 for *_, b in self.iter_blocks()
                   if isinstance(b.mixer, LinearSpec))

    @property
    def is_sub_quadratic(self) -> bool:
        """True iff no unbounded full-attention layer exists."""
        for *_, b in self.iter_blocks():
            m = b.mixer
            if isinstance(m, AttentionSpec) and not m.is_sub_quadratic:
                return False
        return True

    @property
    def runs_long_context(self) -> bool:
        """long_500k eligibility: SSM/hybrid/linear-attn/SWA archs run it;
        pure full-attention archs skip (per assignment)."""
        return self.is_sub_quadratic or self.family in ("hybrid", "ssm")

    # -- parameter counting (used for 6ND model flops & memory estimates) ---
    def _block_params(self, b: BlockSpec) -> int:
        d = self.d_model
        n = 0
        m = b.mixer
        if isinstance(m, AttentionSpec):
            if m.kind == "mla":
                qd = m.q_heads * m.head_dim
                n += d * (m.mla_q_rank or qd)
                if m.mla_q_rank:
                    n += m.mla_q_rank * qd
                n += d * (m.mla_kv_rank + m.mla_rope_dim)
                n += m.mla_kv_rank * (m.kv_heads * m.head_dim * 2)
                n += qd * d  # o_proj
            else:
                n += d * m.q_heads * m.head_dim          # q
                n += 2 * d * m.kv_heads * m.head_dim     # k, v
                n += m.q_heads * m.head_dim * d          # o
                if m.qkv_bias:
                    n += (m.q_heads + 2 * m.kv_heads) * m.head_dim
        else:
            h, dk, dv = m.heads, m.key_dim, m.value_dim
            n += d * h * (2 * dk + dv)                   # q,k,v projections
            n += h * dv * d                              # o
            n += d * h * 2                               # gates (decay, beta/out-gate)
            if m.conv_kernel:
                n += m.conv_kernel * h * (2 * dk + dv)
            if m.kind == "slstm":
                n = d * 4 * h * dv * 2 + 4 * h * dv      # i,f,z,o x (W, R) + bias
        if b.cross is not None:
            c = b.cross
            n += d * c.q_heads * c.head_dim + 2 * d * c.kv_heads * c.head_dim
            n += c.q_heads * c.head_dim * d
        f = b.ffn
        if f.kind == "dense":
            mult = 3 if f.activation in ("swiglu", "geglu") else 2
            n += mult * d * f.d_ff
        elif f.kind == "moe":
            mult = 3 if f.activation in ("swiglu", "geglu") else 2
            n += f.num_experts * mult * d * f.d_ff
            n += d * f.num_experts                        # router
            n += f.shared_experts * mult * d * f.d_ff
        n += 2 * d  # two RMSNorm scales
        return n

    def param_count(self) -> int:
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for *_, b in self.iter_blocks():
            n += self._block_params(b)
        if self.encoder_groups:
            for g in self.encoder_groups:
                for _ in range(g.repeats):
                    for b in g.blocks:
                        n += self._block_params(b)
            if self.encoder_input_dim:
                n += self.encoder_input_dim * self.d_model
        n += self.d_model  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for *_, b in self.iter_blocks():
            f = b.ffn
            if f.kind == "moe":
                dense_b = dataclasses.replace(
                    b, ffn=FFNSpec(kind="dense", d_ff=f.d_ff * (f.top_k + f.shared_experts),
                                   activation=f.activation))
                n += self._block_params(dense_b)
            else:
                n += self._block_params(b)
        if self.encoder_groups:
            for g in self.encoder_groups:
                for _ in range(g.repeats):
                    for b in g.blocks:
                        n += self._block_params(b)
        n += self.d_model
        return n

    # -- KVCache accounting (paper Eq. 1 numerator) --------------------------
    def kv_cache_bytes(self, seq_len: int, dtype_bytes: int = 2) -> int:
        """Total per-request KVCache+state bytes at context ``seq_len``."""
        total = 0
        blocks = list(self.iter_blocks())
        if self.encoder_groups is not None:
            # decoder self-attn caches + cross-attn K/V over encoder output
            for g in self.encoder_groups:
                pass  # encoder itself holds no serving-time cache
        for *_, b in blocks:
            m = b.mixer
            if isinstance(m, AttentionSpec):
                total += m.kv_bytes_per_token(dtype_bytes) * m.kv_cache_tokens(seq_len)
            else:
                total += m.state_bytes()
            if b.cross is not None:
                c = b.cross
                total += c.kv_bytes_per_token(dtype_bytes) * seq_len
        return total


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests: few layers, narrow dims."""

    def _shrink_mixer(m):
        if isinstance(m, AttentionSpec):
            q = max(2, min(4, m.q_heads))
            kv = 1 if m.kv_heads == 1 else max(1, min(2, m.kv_heads))
            if m.kv_heads == m.q_heads:
                kv = q
            return dataclasses.replace(
                m, q_heads=q, kv_heads=kv, head_dim=16,
                window=min(m.window, 64) if m.window else 0,
                mla_kv_rank=32 if m.kind == "mla" else m.mla_kv_rank,
                mla_rope_dim=16 if m.kind == "mla" else m.mla_rope_dim,
                mla_q_rank=0)
        return dataclasses.replace(m, heads=2, key_dim=16, value_dim=16,
                                   conv_kernel=min(m.conv_kernel, 4))

    def _shrink_ffn(f):
        if f.kind == "none":
            return f
        return dataclasses.replace(
            f, d_ff=64,
            num_experts=min(f.num_experts, 4) if f.kind == "moe" else 0,
            top_k=min(f.top_k, 2) if f.kind == "moe" else 0,
            shared_experts=min(f.shared_experts, 1))

    def _shrink_groups(groups):
        out = []
        for g in groups:
            blocks = tuple(
                dataclasses.replace(b, mixer=_shrink_mixer(b.mixer),
                                    ffn=_shrink_ffn(b.ffn),
                                    cross=_shrink_mixer(b.cross) if b.cross else None)
                for b in g.blocks)
            out.append(GroupSpec(blocks=blocks, repeats=min(g.repeats, 2)))
        return tuple(out)

    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=64,
        vocab_size=256,
        groups=_shrink_groups(cfg.groups),
        encoder_groups=_shrink_groups(cfg.encoder_groups) if cfg.encoder_groups else None,
        encoder_input_dim=64 if cfg.encoder_input_dim else 0,
        num_image_patches=8 if cfg.num_image_patches else 0,
        max_seq_len=512,
        dtype="float32",
    )

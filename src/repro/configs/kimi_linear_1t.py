"""The paper's case-study model: internal 1T hybrid following Kimi Linear.

Proxy reconstruction (the internal model is unpublished): interleaved
KDA:MLA at 3:1 [arXiv:2510.26692], 64 layers = 16 x (3 KDA + 1 MLA),
d=7168, MoE FFN sized to ~1T total params.

Calibrated so S_kv(l) matches the paper's Table 5 within ~1%:
  - MLA layers cache (kv_rank 472 + rope 64) = 536 dims/token/layer * 2B
    * 16 layers = 16.75 KiB/token   (paper: ~16.7 KiB/token slope)
  - KDA fixed state: 56 heads x 128 x 128 fp32 = 3.67 MiB/layer * 48 layers
    = 176 MiB + conv tail            (paper: ~174 MiB intercept)
"""
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                LinearSpec, ModelConfig)


def build() -> ModelConfig:
    kda = LinearSpec(kind="kda", heads=56, key_dim=128, value_dim=128,
                     conv_kernel=4)
    mla = AttentionSpec(kind="mla", q_heads=64, kv_heads=64, head_dim=128,
                        mla_kv_rank=472, mla_rope_dim=64, mla_q_rank=1536,
                        rope=True)
    moe = FFNSpec(kind="moe", d_ff=2048, activation="swiglu",
                  num_experts=352, top_k=8, shared_experts=1)
    kda_block = BlockSpec(mixer=kda, ffn=moe)
    mla_block = BlockSpec(mixer=mla, ffn=moe)
    return ModelConfig(
        name="kimi-linear-1t",
        family="hybrid",
        d_model=7168,
        vocab_size=163840,
        groups=(GroupSpec(blocks=(kda_block, kda_block, kda_block, mla_block),
                          repeats=16),),
        max_seq_len=1_048_576,
        source="arXiv:2510.26692 (architecture); paper §4 (scale)",
        notes="paper case-study proxy; S_kv(l) calibrated to paper Table 5.",
    )

"""xLSTM-350M: 24L, d=1024, 4H, alternating mLSTM / sLSTM blocks, d_ff=0.

[arXiv:2405.04517; unverified]. Blocks carry their own up/down projections
(pre-up-projection xLSTM style), so there is no separate FFN (d_ff=0 ->
FFNSpec 'none'). mLSTM is a matrix-memory gated linear attention (bounded
state), sLSTM a scalar-memory recurrent cell — both O(1) state, so every
shape incl. long_500k runs.
"""
from repro.configs.base import (BlockSpec, FFNSpec, GroupSpec, LinearSpec,
                                ModelConfig)


def build() -> ModelConfig:
    mlstm = LinearSpec(kind="mlstm", heads=4, key_dim=256, value_dim=256,
                       conv_kernel=4)
    slstm = LinearSpec(kind="slstm", heads=4, key_dim=256, value_dim=256,
                       conv_kernel=4)
    no_ffn = FFNSpec(kind="none")
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        d_model=1024,
        vocab_size=50304,
        groups=(GroupSpec(blocks=(BlockSpec(mixer=mlstm, ffn=no_ffn),
                                  BlockSpec(mixer=slstm, ffn=no_ffn)),
                          repeats=12),),
        tie_embeddings=True,
        max_seq_len=1_048_576,
        source="arXiv:2405.04517",
        notes="sLSTM+mLSTM 1:1 interleave; blocks embed their own FFN paths.",
    )

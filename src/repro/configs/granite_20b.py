"""Granite-20B (code): 52L, d=6144, 48H MQA(kv=1), d_ff=24576, vocab 49152.

[arXiv:2405.04324; hf]. MQA already shrinks KV 48x vs MHA; dense FFN.
"""
from repro.configs.base import (AttentionSpec, BlockSpec, FFNSpec, GroupSpec,
                                ModelConfig)


def build() -> ModelConfig:
    attn = AttentionSpec(kind="full", q_heads=48, kv_heads=1, head_dim=128,
                         rope=True)
    ffn = FFNSpec(kind="dense", d_ff=24576, activation="gelu")
    block = BlockSpec(mixer=attn, ffn=ffn)
    return ModelConfig(
        name="granite-20b",
        family="dense",
        d_model=6144,
        vocab_size=49152,
        groups=(GroupSpec(blocks=(block,), repeats=52),),
        max_seq_len=8192,
        source="arXiv:2405.04324",
        notes="llama-arch code model; MQA kv=1.",
    )

"""Chunked gated linear attention for TPU (Pallas).

Covers Mamba2/SSD (scalar per-head decay), Lightning/simple linear attention
(decay = 1), GLA, and mLSTM (via the caller augmenting v with a normalizer
column). Recurrence:

    S_t = a_t * S_{t-1} + k_t v_t^T ,   o_t = q_t S_t ,   a_t = exp(log_a_t)

TPU-native chunking: the chunk axis is a sequential grid dimension; the
(dk x dv) fp32 state is carried in VMEM scratch. All decay factors are
expressed as exp(differences of log-decay cumsums) with non-positive
exponents, so every scaling factor is <= 1 (numerically safe for strong
decay — no 1/gamma anywhere).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gla_chunk_step(q, k, v, la, S, chunk):
    """One chunk of the recurrence: returns (o, new state), all fp32."""
    csum = jnp.cumsum(la)                               # inclusive
    gamma = jnp.exp(csum)[:, None]                      # (C, 1), <= 1

    # intra-chunk: A[t,s] = (q_t . k_s) * exp(csum_t - csum_s), s <= t
    diff = csum[:, None] - csum[None, :]                # <= 0 on lower tri
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    incl = col <= row
    decay = jnp.where(incl, jnp.exp(jnp.where(incl, diff, 0.0)), 0.0)
    A = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * decay
    o = jax.lax.dot(A, v) + jax.lax.dot(q * gamma, S)

    # state update: S <- gamma_C * S + sum_s (gamma_C / gamma_s) k_s v_s^T
    g_c = jnp.exp(csum[-1])
    kscale = jnp.exp(csum[-1] - csum)[:, None]          # <= 1
    S = g_c * S + jax.lax.dot_general(
        k * kscale, v, (((0,), (0,)), ((), ())))
    return o, S


def _gla_kernel(q_ref, k_ref, v_ref, la_ref, s0_ref, o_ref, sT_ref, state,
                *, chunk, num_chunks):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)                    # (C, dk)
    k = k_ref[0].astype(jnp.float32)                    # (C, dk)
    v = v_ref[0].astype(jnp.float32)                    # (C, dv)
    la = la_ref[0].astype(jnp.float32)                  # (C,)

    o, S = _gla_chunk_step(q, k, v, la, state[...], chunk)
    state[...] = S
    o_ref[0] = o.astype(o_ref.dtype)

    @pl.when(c == num_chunks - 1)
    def _finish():
        sT_ref[0] = state[...]


def _gla_fused_kernel(q_ref, k_ref, v_ref, la_ref, len_ref, s0_ref, o_ref,
                      sT_ref, state, *, chunk, num_chunks):
    """Fused-masking variant: rows at positions >= the row's valid length
    are neutralized in-VMEM (k -> 0: no state write; log_a -> 0: no decay)
    so the caller skips the full-tensor masking passes over k/log_a."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    pos = c * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    valid = pos < len_ref[0, 0]                         # (C, 1)

    q = q_ref[0].astype(jnp.float32)
    k = jnp.where(valid, k_ref[0].astype(jnp.float32), 0.0)
    v = v_ref[0].astype(jnp.float32)
    la = jnp.where(valid[:, 0], la_ref[0].astype(jnp.float32), 0.0)

    o, S = _gla_chunk_step(q, k, v, la, state[...], chunk)
    state[...] = S
    o_ref[0] = o.astype(o_ref.dtype)

    @pl.when(c == num_chunks - 1)
    def _finish():
        sT_ref[0] = state[...]


def gla_chunked(q, k, v, log_a, initial_state=None, *, chunk: int = 64,
                interpret: bool = False):
    """q,k: (B,H,S,dk); v: (B,H,S,dv); log_a: (B,H,S) (<=0).

    Returns (o: (B,H,S,dv), final_state: (B,H,dk,dv) float32).
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, dk, dv), jnp.float32)

    chunk = min(chunk, max(S, 8))
    pad = (-S) % chunk
    if pad:
        # padded tokens: k = 0 (no state write), log_a = 0 (no decay)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))
    Sp = S + pad
    nc = Sp // chunk

    qr = q.reshape(B * H, Sp, dk)
    kr = k.reshape(B * H, Sp, dk)
    vr = v.reshape(B * H, Sp, dv)
    lar = log_a.reshape(B * H, Sp)
    s0 = initial_state.reshape(B * H, dk, dv)

    kernel = functools.partial(_gla_kernel, chunk=chunk, num_chunks=nc)
    o, sT = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1, dk, dv), lambda h, c: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sp, dv), q.dtype),
            jax.ShapeDtypeStruct((B * H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, lar, s0)
    o = o.reshape(B, H, Sp, dv)[:, :, :S]
    return o, sT.reshape(B, H, dk, dv)


def gla_chunked_fused(q, k, v, log_a, lengths, initial_state=None, *,
                      chunk: int = 64, interpret: bool = False):
    """``gla_chunked`` with per-row valid ``lengths: (B,)`` applied inside
    the kernel instead of by full-tensor ``jnp.where`` passes on the host
    program (the serving prefill path's padded-bucket masking).

    Returns (o: (B,H,S,dv), final_state: (B,H,dk,dv) float32). Output rows
    at positions >= lengths[b] are unspecified (the engine discards them).
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, dk, dv), jnp.float32)

    chunk = min(chunk, max(S, 8))
    pad = (-S) % chunk
    if pad:
        # padded rows land at pos >= S >= lengths -> masked by the kernel
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))
    Sp = S + pad
    nc = Sp // chunk

    qr = q.reshape(B * H, Sp, dk)
    kr = k.reshape(B * H, Sp, dk)
    vr = v.reshape(B * H, Sp, dv)
    lar = log_a.reshape(B * H, Sp)
    lens = jnp.broadcast_to(lengths.astype(jnp.int32)[:, None],
                            (B, H)).reshape(B * H, 1)
    s0 = initial_state.reshape(B * H, dk, dv)

    kernel = functools.partial(_gla_fused_kernel, chunk=chunk, num_chunks=nc)
    o, sT = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1, 1), lambda h, c: (h, 0)),
            pl.BlockSpec((1, dk, dv), lambda h, c: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sp, dv), q.dtype),
            jax.ShapeDtypeStruct((B * H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, lar, lens, s0)
    o = o.reshape(B, H, Sp, dv)[:, :, :S]
    return o, sT.reshape(B, H, dk, dv)

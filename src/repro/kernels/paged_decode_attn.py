"""Paged flash-decode for TPU (Pallas): block-table KV gather.

Same running-softmax core as ``decode_attn.py``, but the KV lives in a
shared page pool ``(Hkv, P, T, D)`` instead of per-request dense buffers:
logical page ``j`` of request ``b`` is physical page ``tables[b, j]``. The
table and per-request lengths ride in as scalar-prefetch operands
(``PrefetchScalarGridSpec``) so the page indirection happens in the index
map — each grid step DMAs exactly one (T x D) KV tile straight from its
pooled page, no gather materialization. Pages at positions >= length may be
sink/garbage pages; the length mask keeps them out of the softmax.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _paged_decode_kernel(len_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale, window, page_tokens,
                         num_pages, num_q_heads):
    h = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[h // num_q_heads]
    k_start = j * page_tokens
    live = k_start < length
    if window > 0:
        live &= (k_start + page_tokens) > (length - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale         # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (T, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (1, T)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < length
        if window > 0:
            mask &= kpos >= (length - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        safe_m = jnp.where(m_new == NEG_INF, 0.0, m_new)
        corr = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - safe_m))
        p = jnp.where(s == NEG_INF, 0.0, jnp.exp(s - safe_m))
        l_ref[...] = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot(p, v)

    @pl.when(j == num_pages - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, tables, lengths, *,
                           window: int = 0, scale: float | None = None,
                           interpret: bool = False):
    """q: (B, Hq, D); pages: (Hkv, P, T, D); tables: (B, N) int32 ->
    (B, Hq, Dv)."""
    B, Hq, D = q.shape
    Hkv, P, T, _ = k_pages.shape
    Dv = v_pages.shape[-1]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    N = tables.shape[1]
    assert tables.shape == (B, N) and N >= 1
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    qr = q.reshape(B * Hq, 1, D)
    lens = lengths.astype(jnp.int32).reshape(B)
    tbl = tables.astype(jnp.int32)

    def kv_map(h, j, lens_ref, tbl_ref):
        return ((h % Hq) // group, tbl_ref[h // Hq, j], 0, 0)

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, window=window, page_tokens=T,
        num_pages=N, num_q_heads=Hq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * Hq, N),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda h, j, lens_ref, tbl_ref: (h, 0, 0)),
            pl.BlockSpec((1, 1, T, D), kv_map),
            pl.BlockSpec((1, 1, T, Dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, Dv),
                               lambda h, j, lens_ref, tbl_ref: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, Dv), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, 1, Dv), q.dtype),
        interpret=interpret,
    )(lens, tbl, qr, k_pages, v_pages)
    return out.reshape(B, Hq, Dv)

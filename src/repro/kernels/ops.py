"""Public kernel ops: jit-friendly dispatch wrappers.

Each op:
  * runs the Pallas kernel on TPU, or in ``interpret=True`` mode on CPU
    (the kernel body executes in Python — bit-accurate vs the TPU lowering
    semantics, used by the test suite);
  * is differentiable via ``jax.custom_vjp`` whose backward pass is the VJP
    of the pure-jnp oracle with recomputation (flash-attention-style: store
    only the inputs, recompute the forward in the backward). Gradients are
    therefore oracle-exact while the forward stays on the kernel.
  * can be forced onto the oracle with ``use_kernel=False`` (or globally via
    ``repro.kernels.ops.FORCE_REF`` for debugging).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attn as _decode
from repro.kernels import paged_decode_attn as _paged_decode
from repro.kernels import paged_prefill_attn as _paged_prefill
from repro.kernels import delta as _delta
from repro.kernels import flash_attn as _flash
from repro.kernels import gla as _gla
from repro.kernels import quantize as _quant
from repro.kernels import ref

FORCE_REF = False

# lowerable memory-efficient paths (used when the TPU kernel is unavailable
# -- CPU tests and the dry-run -- and as the kernels' backward recompute)
from repro.models import chunked_attention as chk

# below this many KV tokens the plain quadratic oracle is cheapest
SMALL_SEQ = 1024


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _use_kernel(flag):
    if FORCE_REF:
        return False
    return flag


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _flash_vjp(causal, window, scale, q_offset, block_q, block_k, interpret):
    @jax.custom_vjp
    def op(q, k, v):
        return _flash.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, block_q=block_q, block_k=block_k,
            interpret=interpret)

    def fwd(q, k, v):
        return op(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        # memory-safe recompute backward (flash-style)
        _, vjp = jax.vjp(
            lambda q, k, v: _attention_jnp(
                q, k, v, causal=causal, window=window, scale=scale,
                q_offset=q_offset),
            q, k, v)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


def _attention_jnp(q, k, v, *, causal=True, window=0, scale=None,
                   q_offset=0):
    """Shape-adaptive lowerable path: banded (SWA) / checkpointed-MEA
    (long full attention) / quadratic oracle (short)."""
    from repro.models.perf_flags import FLAGS, shard_hint
    if FLAGS.shard_attention:
        q = shard_hint(q, ("pod", "data"), "model", None, None)
        k = shard_hint(k, ("pod", "data"),
                       "model" if k.shape[1] % 16 == 0 else None, None, None)
        v = shard_hint(v, ("pod", "data"),
                       "model" if v.shape[1] % 16 == 0 else None, None, None)
    Sk = k.shape[2]
    if Sk <= SMALL_SEQ:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       scale=scale, q_offset=q_offset)
    if (window > 0 and causal and q.shape[2] == Sk
            and Sk >= 2 * window):
        return chk.swa_banded(q, k, v, window=window, scale=scale)
    return chk.mea_attention(q, k, v, causal=causal, window=window,
                             scale=scale, q_offset=q_offset)


def attention(q, k, v, *, causal=True, window=0, scale=None, q_offset=0,
              block_q=128, block_k=128, use_kernel=True):
    """Full attention (GQA/MQA/MHA/SWA). q:(B,Hq,S,D) k,v:(B,Hkv,S,D)."""
    if not _use_kernel(use_kernel) or _on_cpu_lowering(k.shape[2]):
        return _attention_jnp(q, k, v, causal=causal, window=window,
                              scale=scale, q_offset=q_offset)
    op = _flash_vjp(causal, window, scale, q_offset, block_q, block_k,
                    _on_cpu())
    return op(q, k, v)


# tests set this to exercise the ops->Pallas dispatch on CPU explicitly
FORCE_KERNEL_ON_CPU = False


def _on_cpu_lowering(seq: int) -> bool:
    """On CPU the jnp paths are used for ALL model lowering: interpret-mode
    Pallas executes the grid as a Python-semantics loop whose HLO cost
    profile is meaningless (and seq-dependent dispatch would make the cost
    probes measure different programs at different probe points). The
    kernels are TPU-target; on CPU they are validated by the dedicated
    kernel tests (interpret=True) and via FORCE_KERNEL_ON_CPU."""
    return _on_cpu() and not FORCE_KERNEL_ON_CPU


# ---------------------------------------------------------------------------
# gated linear attention (Mamba2 / GLA / Lightning / mLSTM)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _gla_vjp(chunk, interpret):
    @jax.custom_vjp
    def op(q, k, v, log_a, s0):
        return _gla.gla_chunked(q, k, v, log_a, s0, chunk=chunk,
                                interpret=interpret)

    def fwd(q, k, v, log_a, s0):
        return op(q, k, v, log_a, s0), (q, k, v, log_a, s0)

    def bwd(res, g):
        q, k, v, log_a, s0 = res
        _, vjp = jax.vjp(lambda *a: chk.gla_chunked_jnp(*a), q, k, v, log_a,
                         s0)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


def _mask_padded(lengths, S, log_a, k, beta=None):
    """Padded-row neutralization for right-padded bucket batches: decay -> 1
    (log_a = 0), key/beta -> 0 past each row's valid length — EXACTLY the
    masking the fused kernels apply in-VMEM, so both dispatch targets of a
    ``lengths=`` call compute the same state."""
    mask = jnp.arange(S)[None, :] < lengths[:, None]         # (B, S)
    log_a = jnp.where(mask[:, None, :], log_a, 0.0)
    k = jnp.where(mask[:, None, :, None], k, jnp.zeros((), k.dtype))
    if beta is None:
        return log_a, k
    return log_a, k, jnp.where(mask[:, None, :], beta, 0.0)


def gla(q, k, v, log_a, initial_state=None, *, lengths=None, chunk=64,
        use_kernel=True):
    """Gated linear attention. Returns (o, final_state).

    ``lengths`` (B,): valid token counts for right-padded batches.  The
    kernel path fuses the padded-row masking (decay -> 1, k -> 0) into the
    chunked-state kernel; the jnp path applies the identical ``jnp.where``
    masking before the chunked scan."""
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, dk, dv), jnp.float32)
    if lengths is None:
        if not _use_kernel(use_kernel) or _on_cpu_lowering(S):
            return chk.gla_chunked_jnp(q, k, v, log_a, initial_state,
                                       chunk=chunk)
        return _gla_vjp(chunk, _on_cpu())(q, k, v, log_a, initial_state)
    lengths = jnp.asarray(lengths, jnp.int32)
    if not _use_kernel(use_kernel) or _on_cpu_lowering(S):
        log_a, k = _mask_padded(lengths, S, log_a, k)
        return chk.gla_chunked_jnp(q, k, v, log_a, initial_state, chunk=chunk)
    interpret = _on_cpu()

    @jax.custom_vjp
    def op(q, k, v, log_a, s0):
        return _gla.gla_chunked_fused(q, k, v, log_a, lengths, s0,
                                      chunk=chunk, interpret=interpret)

    def fwd(q, k, v, log_a, s0):
        return op(q, k, v, log_a, s0), (q, k, v, log_a, s0)

    def bwd(res, g):
        q, k, v, log_a, s0 = res

        def oracle(q, k, v, log_a, s0):
            la, km = _mask_padded(lengths, S, log_a, k)
            return chk.gla_chunked_jnp(q, km, v, la, s0, chunk=chunk)

        _, vjp = jax.vjp(oracle, q, k, v, log_a, s0)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op(q, k, v, log_a, initial_state)


# ---------------------------------------------------------------------------
# (gated) delta rule (DeltaNet / GDN / KDA)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _delta_vjp(chunk, interpret):
    @jax.custom_vjp
    def op(q, k, v, log_a, beta, s0):
        return _delta.delta_chunked(q, k, v, log_a, beta, s0, chunk=chunk,
                                    interpret=interpret)

    def fwd(q, k, v, log_a, beta, s0):
        return op(q, k, v, log_a, beta, s0), (q, k, v, log_a, beta, s0)

    def bwd(res, g):
        q, k, v, log_a, beta, s0 = res
        _, vjp = jax.vjp(lambda *a: chk.delta_chunked_jnp(*a), q, k, v,
                         log_a, beta, s0)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


def delta(q, k, v, log_a, beta, initial_state=None, *, lengths=None,
          chunk=64, use_kernel=True):
    """Gated delta rule. Returns (o, final_state).

    ``lengths`` as in :func:`gla`: the kernel path fuses the padded-row
    masking (decay -> 1, k/beta -> 0) into the chunked-state kernel."""
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, dk, dv), jnp.float32)
    if lengths is None:
        if not _use_kernel(use_kernel) or _on_cpu_lowering(S):
            return chk.delta_chunked_jnp(q, k, v, log_a, beta, initial_state,
                                         chunk=chunk)
        return _delta_vjp(chunk, _on_cpu())(q, k, v, log_a, beta,
                                            initial_state)
    lengths = jnp.asarray(lengths, jnp.int32)
    if not _use_kernel(use_kernel) or _on_cpu_lowering(S):
        log_a, k, beta = _mask_padded(lengths, S, log_a, k, beta)
        return chk.delta_chunked_jnp(q, k, v, log_a, beta, initial_state,
                                     chunk=chunk)
    interpret = _on_cpu()

    @jax.custom_vjp
    def op(q, k, v, log_a, beta, s0):
        return _delta.delta_chunked_fused(q, k, v, log_a, beta, lengths, s0,
                                          chunk=chunk, interpret=interpret)

    def fwd(q, k, v, log_a, beta, s0):
        return op(q, k, v, log_a, beta, s0), (q, k, v, log_a, beta, s0)

    def bwd(res, g):
        q, k, v, log_a, beta, s0 = res

        def oracle(q, k, v, log_a, beta, s0):
            la, km, b = _mask_padded(lengths, S, log_a, k, beta)
            return chk.delta_chunked_jnp(q, km, v, la, b, s0, chunk=chunk)

        _, vjp = jax.vjp(oracle, q, k, v, log_a, beta, s0)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op(q, k, v, log_a, beta, initial_state)


# ---------------------------------------------------------------------------
# decode attention (no grad path needed — serving only)
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, lengths, *, window=0, scale=None,
                     block_k=512, use_kernel=True):
    if not _use_kernel(use_kernel) or _on_cpu_lowering(k_cache.shape[2]):
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths,
                                        window=window, scale=scale)
    return _decode.decode_attention(q, k_cache, v_cache, lengths,
                                    window=window, scale=scale,
                                    block_k=block_k, interpret=_on_cpu())


def verify_attention(q, k_cache, v_cache, lengths, *, scale=None,
                     use_kernel=True):
    """Speculative-verify attention over Q candidate positions in one call.

    q: (B, Hq, Q, D); position j attends over ``min(lengths + j, S)`` keys
    (``lengths`` = context + 1, the first position's key count).  The ref
    path batches all Q positions through ONE masked pass over the KV cache
    (the hot-path win: Q× fewer attention ops per layer); the kernel path
    unrolls Q calls of the flash decode kernel so accelerator numerics stay
    bit-identical to the plain one-token decode dispatch."""
    if not _use_kernel(use_kernel) or _on_cpu_lowering(k_cache.shape[2]):
        return ref.verify_attention_ref(q, k_cache, v_cache, lengths,
                                        scale=scale)
    S = k_cache.shape[2]
    outs = [_decode.decode_attention(q[:, :, j], k_cache, v_cache,
                                     jnp.minimum(lengths + j, S),
                                     scale=scale, interpret=_on_cpu())
            for j in range(q.shape[2])]
    return jnp.stack(outs, axis=2)


def paged_decode_attention(q, k_pages, v_pages, tables, lengths, *, window=0,
                           scale=None, use_kernel=True):
    """Block-table flash-decode: KV gathered from a shared page pool.

    q: (B, Hq, D); pages: (Hkv, P, T, D); tables: (B, N) int32."""
    if not _use_kernel(use_kernel) or _on_cpu_lowering(
            tables.shape[1] * k_pages.shape[2]):
        return ref.paged_decode_attention_ref(q, k_pages, v_pages, tables,
                                              lengths, window=window,
                                              scale=scale)
    return _paged_decode.paged_decode_attention(
        q, k_pages, v_pages, tables, lengths, window=window, scale=scale,
        interpret=_on_cpu())


def paged_prefill_attention(q, k_pages, v_pages, tables, k_suf, v_suf, *,
                            scale=None, use_kernel=True):
    """Chunked-prefill flash over block-table pages plus dense suffix rows.

    q: (B, Hq, C, D) suffix-chunk queries; pages: (Hkv, P, T, D) shared
    pool; tables: (B, N) int32 covering prior positions [0, N*T);
    k_suf/v_suf: (B, Hkv, Ssuf, D) dense suffix keys whose last C rows are
    the chunk's own (causally masked)."""
    total = tables.shape[1] * k_pages.shape[2] + k_suf.shape[2]
    if not _use_kernel(use_kernel) or _on_cpu_lowering(total):
        return ref.paged_prefill_attention_ref(q, k_pages, v_pages, tables,
                                               k_suf, v_suf, scale=scale)
    return _paged_prefill.paged_prefill_attention(
        q, k_pages, v_pages, tables, k_suf, v_suf, scale=scale,
        interpret=_on_cpu())


def quantize_wire(x, *, use_kernel=True):
    """Per-tensor symmetric int8 wire quantization of a float32 leaf.

    Returns (q: int8, scale: float32 scalar), byte-identical between the
    fused Pallas pass and the jnp ref (same max/round/clip chain)."""
    if not _use_kernel(use_kernel) or _on_cpu_lowering(x.size):
        return ref.quantize_int8_ref(x)
    return _quant.quantize_int8_fused(x, interpret=_on_cpu())


# single-step recurrent updates are trivially jnp (no kernel needed)
gla_step = ref.gla_step_ref
delta_step = ref.delta_step_ref

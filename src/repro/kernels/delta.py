"""Chunked (gated) delta rule for TPU (Pallas): DeltaNet / GDN / KDA.

Recurrence (scalar per-head decay a_t, write strength beta_t, keys
L2-normalized by the caller):

    S_t = a_t (I - beta_t k_t k_t^T) S_{t-1} + beta_t k_t v_t^T
    o_t = q_t S_t

Chunked via the WY representation. The per-chunk unit-lower-triangular system
(I + diag(beta) A) U = diag(beta) (V - K~ S0) is solved with the *Neumann
product* factorization: for N strictly lower triangular (nilpotent, N^C = 0),

    (I + N)^{-1} = prod_{i=0}^{log2(C)-1} (I + (-N)^{2^i})

i.e. log2(C) dense (C x C) matmuls on the MXU — a TPU-native substitute for
the warp-level forward substitution used by GPU implementations (see
DESIGN.md §3). All decay factors are exp(non-positive log-gamma differences),
so every scale is <= 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _neumann_unit_lower_inverse(n, chunk):
    """Inverse of (I + n) for strictly-lower-triangular n, via log2(C) matmuls."""
    eye = jnp.eye(chunk, dtype=jnp.float32)
    m = -n
    r = eye + m
    steps = max(1, (chunk - 1).bit_length())
    for _ in range(steps - 1):
        m = jax.lax.dot(m, m)
        r = r + jax.lax.dot(r, m)
    return r


def _delta_chunk_step(q, k, v, la, beta, S, chunk):
    """One WY-representation chunk of the recurrence: (o, new state), fp32."""
    csum = jnp.cumsum(la)
    gamma = jnp.exp(csum)[:, None]                      # (C,1) <= 1

    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = col < row
    incl = col <= row
    diff = csum[:, None] - csum[None, :]
    decay_strict = jnp.where(strict, jnp.exp(jnp.where(strict, diff, 0.0)), 0.0)
    decay_incl = jnp.where(incl, jnp.exp(jnp.where(incl, diff, 0.0)), 0.0)

    kkt = jax.lax.dot_general(k, k, (((1,), (1,)), ((), ())))   # (C, C)
    n = beta * (kkt * decay_strict)                     # diag(beta) A, strictly lower
    tinv = _neumann_unit_lower_inverse(n, chunk)        # (I + N)^-1

    rhs = beta * (v - jax.lax.dot(k * gamma, S))        # (C, dv)
    u = jax.lax.dot(tinv, rhs)                          # (C, dv)

    qkt = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    o = jax.lax.dot(q * gamma, S) + jax.lax.dot(qkt * decay_incl, u)

    g_c = jnp.exp(csum[-1])
    kscale = jnp.exp(csum[-1] - csum)[:, None]
    S = g_c * S + jax.lax.dot_general(
        k * kscale, u, (((0,), (0,)), ((), ())))
    return o, S


def _delta_kernel(q_ref, k_ref, v_ref, la_ref, b_ref, s0_ref, o_ref, sT_ref,
                  state, *, chunk, num_chunks):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)                    # (C, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)                    # (C, dv)
    la = la_ref[0].astype(jnp.float32)                  # (C,)
    beta = b_ref[0].astype(jnp.float32)[:, None]        # (C, 1)

    o, S = _delta_chunk_step(q, k, v, la, beta, state[...], chunk)
    state[...] = S
    o_ref[0] = o.astype(o_ref.dtype)

    @pl.when(c == num_chunks - 1)
    def _finish():
        sT_ref[0] = state[...]


def _delta_fused_kernel(q_ref, k_ref, v_ref, la_ref, b_ref, len_ref, s0_ref,
                        o_ref, sT_ref, state, *, chunk, num_chunks):
    """Fused-masking variant: rows past the row's valid length are
    neutralized in-VMEM (beta -> 0: no write, log_a -> 0: no decay, k -> 0)
    so the caller skips the full-tensor masking passes."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    pos = c * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    valid = pos < len_ref[0, 0]                         # (C, 1)

    q = q_ref[0].astype(jnp.float32)
    k = jnp.where(valid, k_ref[0].astype(jnp.float32), 0.0)
    v = v_ref[0].astype(jnp.float32)
    la = jnp.where(valid[:, 0], la_ref[0].astype(jnp.float32), 0.0)
    beta = jnp.where(valid, b_ref[0].astype(jnp.float32)[:, None], 0.0)

    o, S = _delta_chunk_step(q, k, v, la, beta, state[...], chunk)
    state[...] = S
    o_ref[0] = o.astype(o_ref.dtype)

    @pl.when(c == num_chunks - 1)
    def _finish():
        sT_ref[0] = state[...]


def delta_chunked(q, k, v, log_a, beta, initial_state=None, *,
                  chunk: int = 64, interpret: bool = False):
    """q,k: (B,H,S,dk); v: (B,H,S,dv); log_a, beta: (B,H,S).

    Returns (o: (B,H,S,dv), final_state: (B,H,dk,dv) float32).
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, dk, dv), jnp.float32)

    chunk = min(chunk, max(S, 8))
    pad = (-S) % chunk
    if pad:
        # padded tokens: beta = 0 and log_a = 0 -> state passes through
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))
        beta = jnp.pad(beta, ((0, 0), (0, 0), (0, pad)))
    Sp = S + pad
    nc = Sp // chunk

    qr = q.reshape(B * H, Sp, dk)
    kr = k.reshape(B * H, Sp, dk)
    vr = v.reshape(B * H, Sp, dv)
    lar = log_a.reshape(B * H, Sp)
    br = beta.reshape(B * H, Sp)
    s0 = initial_state.reshape(B * H, dk, dv)

    kernel = functools.partial(_delta_kernel, chunk=chunk, num_chunks=nc)
    o, sT = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1, dk, dv), lambda h, c: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sp, dv), q.dtype),
            jax.ShapeDtypeStruct((B * H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, lar, br, s0)
    o = o.reshape(B, H, Sp, dv)[:, :, :S]
    return o, sT.reshape(B, H, dk, dv)


def delta_chunked_fused(q, k, v, log_a, beta, lengths, initial_state=None, *,
                        chunk: int = 64, interpret: bool = False):
    """``delta_chunked`` with per-row valid ``lengths: (B,)`` applied inside
    the kernel instead of by full-tensor ``jnp.where`` passes (the serving
    prefill path's padded-bucket masking).

    Returns (o: (B,H,S,dv), final_state: (B,H,dk,dv) float32). Output rows
    at positions >= lengths[b] are unspecified (the engine discards them).
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, dk, dv), jnp.float32)

    chunk = min(chunk, max(S, 8))
    pad = (-S) % chunk
    if pad:
        # padded rows land at pos >= S >= lengths -> masked by the kernel
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))
        beta = jnp.pad(beta, ((0, 0), (0, 0), (0, pad)))
    Sp = S + pad
    nc = Sp // chunk

    qr = q.reshape(B * H, Sp, dk)
    kr = k.reshape(B * H, Sp, dk)
    vr = v.reshape(B * H, Sp, dv)
    lar = log_a.reshape(B * H, Sp)
    br = beta.reshape(B * H, Sp)
    lens = jnp.broadcast_to(lengths.astype(jnp.int32)[:, None],
                            (B, H)).reshape(B * H, 1)
    s0 = initial_state.reshape(B * H, dk, dv)

    kernel = functools.partial(_delta_fused_kernel, chunk=chunk,
                               num_chunks=nc)
    o, sT = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1, 1), lambda h, c: (h, 0)),
            pl.BlockSpec((1, dk, dv), lambda h, c: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sp, dv), q.dtype),
            jax.ShapeDtypeStruct((B * H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, lar, br, lens, s0)
    o = o.reshape(B, H, Sp, dv)[:, :, :S]
    return o, sT.reshape(B, H, dk, dv)

"""Paged flash-prefill for TPU (Pallas): suffix chunks over block tables.

A prefix-hit suffix prefill attends each chunk's queries over (a) the
device-resident cached prefix — pool pages ``(Hkv, P, T, D)`` addressed
through the request's block table — and (b) the dense suffix keys
accumulated so far (whose last C rows are the chunk's own, causally
masked). The pre-kernel path gathered the table's pages into a dense
``(B, c, Hkv, D)`` prior operand first; here the table rides in as a
scalar-prefetch operand so each grid step DMAs one (T x D) KV tile
straight from its pooled page — the cached prefix is never materialized
outside the pool.

Grid ``(B * Hq, N + 1)``: steps j < N stream the N prior pages (all fully
visible — every prior position precedes every query), step j == N streams
the dense suffix with the causal mask and normalizes. Same running-softmax
core as ``paged_decode_attn.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _accum(s, v, acc_ref, m_ref, l_ref):
    """Streaming-softmax accumulation of scores s: (C, L) against v: (L, Dv)."""
    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    safe_m = jnp.where(m_new == NEG_INF, 0.0, m_new)
    corr = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - safe_m))
    p = jnp.where(s == NEG_INF, 0.0, jnp.exp(s - safe_m))
    l_ref[...] = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = corr * acc_ref[...] + jax.lax.dot(p, v)


def _paged_prefill_kernel(tbl_ref, q_ref, kp_ref, vp_ref, ks_ref, vs_ref,
                          o_ref, acc_ref, m_ref, l_ref, *, scale, num_pages,
                          chunk_q, suffix_len):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale              # (C, D)

    @pl.when(j < num_pages)
    def _page():
        k = kp_ref[0, 0].astype(jnp.float32)                 # (T, D)
        v = vp_ref[0, 0].astype(jnp.float32)
        # prior pages: every position < every query position -> no mask
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        _accum(s, v, acc_ref, m_ref, l_ref)

    @pl.when(j == num_pages)
    def _suffix():
        k = ks_ref[0, 0].astype(jnp.float32)                 # (Ssuf, D)
        v = vs_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        # query row i sits at suffix position (suffix_len - chunk_q) + i
        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(col <= (suffix_len - chunk_q) + row, s, NEG_INF)
        _accum(s, v, acc_ref, m_ref, l_ref)

        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_prefill_attention(q, k_pages, v_pages, tables, k_suf, v_suf, *,
                            scale: float | None = None,
                            interpret: bool = False):
    """q: (B, Hq, C, D); pages: (Hkv, P, T, D); tables: (B, N) int32;
    k_suf/v_suf: (B, Hkv, Ssuf, D). Returns (B, Hq, C, Dv)."""
    B, Hq, C, D = q.shape
    Hkv, P, T, _ = k_pages.shape
    Dv = v_pages.shape[-1]
    Ssuf = k_suf.shape[2]
    assert Hq % Hkv == 0
    assert Ssuf >= C, (Ssuf, C)
    group = Hq // Hkv
    N = tables.shape[1]
    assert tables.shape == (B, N) and N >= 1
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    qr = q.reshape(B * Hq, 1, C, D)
    tbl = tables.astype(jnp.int32)

    def page_map(h, j, tbl_ref):
        # j == N (the suffix step) clamps to a dummy page; pl.when skips it
        return ((h % Hq) // group, tbl_ref[h // Hq, jnp.minimum(j, N - 1)],
                0, 0)

    def suf_map(h, j, tbl_ref):
        return (h // Hq, (h % Hq) // group, 0, 0)

    kernel = functools.partial(
        _paged_prefill_kernel, scale=scale, num_pages=N, chunk_q=C,
        suffix_len=Ssuf)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hq, N + 1),
        in_specs=[
            pl.BlockSpec((1, 1, C, D), lambda h, j, tbl_ref: (h, 0, 0, 0)),
            pl.BlockSpec((1, 1, T, D), page_map),
            pl.BlockSpec((1, 1, T, Dv), page_map),
            pl.BlockSpec((1, 1, Ssuf, D), suf_map),
            pl.BlockSpec((1, 1, Ssuf, Dv), suf_map),
        ],
        out_specs=pl.BlockSpec((1, 1, C, Dv),
                               lambda h, j, tbl_ref: (h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, Dv), jnp.float32),
            pltpu.VMEM((C, 1), jnp.float32),
            pltpu.VMEM((C, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, 1, C, Dv), q.dtype),
        interpret=interpret,
    )(tbl, qr, k_pages, v_pages, k_suf, v_suf)
    return out.reshape(B, Hq, C, Dv)

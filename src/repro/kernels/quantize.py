"""Fused int8 quantize-on-write for the KV wire format (Pallas).

``distributed.collectives.quantize_int8`` is a per-tensor symmetric int8
encode: absmax reduction, scale = max(absmax, 1e-30)/127, round/clip. As a
plain jnp chain on the admission path it is a separate multi-op pass over
every wire-eligible cache leaf (abs, global max, divide, round, clip, cast
— each materializing an intermediate). This kernel fuses the whole encode
into one tiled pass: a two-phase sequential grid first reduces the absmax
into VMEM scratch, then encodes each tile against the shared scale, so the
leaf is read twice and written once (int8) with no fp32 intermediates in
HBM.

The math is kept operation-for-operation identical to ``quantize_int8``
(same max/round/clip primitives in the same order), so the produced wire
pytree is byte-identical to the unfused path — pinned by
``tests/test_kernels_quantize.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# rows per grid step of the flattened (rows, LANE) view of the leaf
_TILE_ROWS = 256
_LANE = 128


def _quantize_kernel(x_ref, q_ref, scale_ref, amax, *, num_tiles):
    """Grid (2, num_tiles): phase 0 reduces |x| into ``amax`` scratch,
    phase 1 encodes every tile against the finished per-tensor scale."""
    phase = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((phase == 0) & (j == 0))
    def _zero():
        amax[0, 0] = jnp.float32(0.0)

    x = x_ref[...].astype(jnp.float32)

    @pl.when(phase == 0)
    def _reduce():
        amax[0, 0] = jnp.maximum(amax[0, 0], jnp.max(jnp.abs(x)))

    @pl.when(phase == 1)
    def _encode():
        # multiply by the f32 reciprocal instead of dividing: XLA rewrites
        # constant divisions to reciprocal multiplies under jit, so an
        # explicit multiply is the only form that is bit-stable between
        # this (jitted) kernel and the eager jnp ref
        scale = jnp.maximum(amax[0, 0], 1e-30) * (1.0 / 127.0)
        q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127
                              ).astype(jnp.int8)

        @pl.when(j == 0)
        def _emit_scale():
            scale_ref[0, 0] = scale


def quantize_int8_fused(x, *, interpret: bool = False):
    """Per-tensor symmetric int8 quantization as one fused Pallas pass.

    Returns (q: int8, scale: float32 scalar) — byte-identical to
    ``distributed.collectives.quantize_int8(x)``.
    """
    shape = x.shape
    n = x.size
    rows = -(-n // _LANE)
    tiles = -(-rows // _TILE_ROWS)
    pad = tiles * _TILE_ROWS * _LANE - n
    flat = jnp.pad(x.reshape(-1), (0, pad))  # zeros never win the absmax
    xr = flat.reshape(tiles * _TILE_ROWS, _LANE)

    kernel = functools.partial(_quantize_kernel, num_tiles=tiles)
    q, scale = pl.pallas_call(
        kernel,
        grid=(2, tiles),
        in_specs=[pl.BlockSpec((_TILE_ROWS, _LANE), lambda p, j: (j, 0))],
        out_specs=[
            pl.BlockSpec((_TILE_ROWS, _LANE), lambda p, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda p, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xr.shape, jnp.int8),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(xr)
    q = q.reshape(-1)[:n].reshape(shape)
    return q, scale[0, 0]

"""Flash-decode for TPU (Pallas): one query token vs a long KV cache.

Decode is memory-bandwidth bound: the kernel streams (block_k x D) KV tiles
HBM->VMEM once, with running-softmax statistics in VMEM scratch. Per-request
cache lengths arrive in SMEM ((1,1) int32 blocks); sliding-window archs mask
keys below ``length - window`` so SWA decode touches O(window) bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, scale, window, block_k, num_kv_blocks):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0, 0]
    k_start = j * block_k
    live = k_start < length
    if window > 0:
        live &= (k_start + block_k) > (length - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale         # (1, D)
        k = k_ref[0].astype(jnp.float32)                 # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (1, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < length
        if window > 0:
            mask &= kpos >= (length - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        safe_m = jnp.where(m_new == NEG_INF, 0.0, m_new)
        corr = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - safe_m))
        p = jnp.where(s == NEG_INF, 0.0, jnp.exp(s - safe_m))
        l_ref[...] = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot(p, v)

    @pl.when(j == num_kv_blocks - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, window: int = 0,
                     scale: float | None = None, block_k: int = 512,
                     interpret: bool = False):
    """q: (B, Hq, D); caches: (B, Hkv, S, D); lengths: (B,) -> (B, Hq, D)."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    Dv = v_cache.shape[-1]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    block_k = min(block_k, max(S, 8))
    pk = (-S) % block_k
    kp = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k_cache
    vp = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v_cache
    Sp = S + pk
    nk = Sp // block_k

    qr = q.reshape(B * Hq, 1, D)
    kr = kp.reshape(B * Hkv, Sp, D)
    vr = vp.reshape(B * Hkv, Sp, Dv)
    lens = lengths.astype(jnp.int32).reshape(B, 1)

    def kv_map(h, j):
        return ((h // Hq) * Hkv + (h % Hq) // group, j, 0)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               block_k=block_k, num_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda h, j: (h // Hq, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, D), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, Dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, Dv), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, 1, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, Dv), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(B, Hq, Dv)

"""Flash attention for TPU (Pallas): causal / GQA / sliding-window prefill.

TPU-native design: the KV axis is the innermost *sequential* grid dimension,
so the running-softmax statistics (m, l) and the output accumulator live in
VMEM scratch across KV steps — the MXU sees (block_q x D) @ (D x block_k)
and (block_q x block_k) @ (block_k x D) matmuls with hardware-aligned tiles.
Fully-masked KV blocks are skipped with ``pl.when`` (causal + window
block-level bounds), which is where SWA's linear cost comes from.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, kv_valid, q_offset,
                  block_q, block_k, num_kv_blocks):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i = pl.program_id(1)
    q_start = i * block_q + q_offset          # global position of q row 0
    k_start = j * block_k

    # Block-level skip: block is live unless fully masked.
    live = k_start < kv_valid
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window > 0:
        live &= (k_start + block_k) > (q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0].astype(jnp.float32)                # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_valid
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked-so-far rows (m == -inf)
        safe_m = jnp.where(m_new == NEG_INF, 0.0, m_new)
        corr = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - safe_m))
        p = jnp.where(s == NEG_INF, 0.0, jnp.exp(s - safe_m))
        l_ref[...] = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot(p, v)

    @pl.when(j == num_kv_blocks - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    Dv = v.shape[-1]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if q_offset == 0 and causal and Sq != Sk:
        q_offset = Sk - Sq

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v

    Sqp, Skp = Sq + pq, Sk + pk
    qr = qp.reshape(B * Hq, Sqp, D)
    kr = kp.reshape(B * Hkv, Skp, D)
    vr = vp.reshape(B * Hkv, Skp, Dv)
    nq, nk = Sqp // block_q, Skp // block_k

    def q_map(h, i, j):
        return (h, i, 0)

    def kv_map(h, i, j):
        return ((h // Hq) * Hkv + (h % Hq) // group, j, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        kv_valid=Sk, q_offset=q_offset, block_q=block_q, block_k=block_k,
        num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, Dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sqp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dv), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, Hq, Sqp, Dv)
    return out[:, :, :Sq] if pq else out

from repro.kernels import ops, ref
from repro.kernels.ops import (attention, decode_attention, delta,
                               delta_step, gla, gla_step)

__all__ = ["ops", "ref", "attention", "decode_attention", "delta",
           "delta_step", "gla", "gla_step"]

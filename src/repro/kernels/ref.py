"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

All oracles compute in float32 internally and cast back to the input dtype,
matching the kernels' accumulation strategy. They are also the differentiable
path: ``ops.py`` wires each kernel's backward pass to the VJP of its oracle
(recompute-based), so training gradients are oracle-exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# Full attention (MHA / GQA / MQA / SWA): the T_prefill hot loop
# ---------------------------------------------------------------------------


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None, q_offset: int = 0):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D). Returns (B, Hq, Sq, D).

    ``q_offset``: global position of q row 0 minus position of k row 0
    (used when continuing from a cached prefix; 0 for plain self-attention).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    dtype = q.dtype
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows produce NaN -> zero them (padded rows only)
    probs = jnp.where(jnp.any(mask, -1)[None, None, :, None], probs, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vf).astype(dtype)


# ---------------------------------------------------------------------------
# Gated linear attention (Mamba2 / GLA / Lightning / mLSTM): bounded state
# ---------------------------------------------------------------------------


def gla_ref(q, k, v, log_a, initial_state=None):
    """Sequential oracle for S_t = a_t * S_{t-1} + k_t v_t^T ; o_t = q_t S_t.

    q, k: (B, H, S, dk); v: (B, H, S, dv); log_a: (B, H, S) with log a <= 0.
    Returns (o: (B, H, S, dv) in q.dtype, final_state: (B, H, dk, dv) f32).
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    dtype = q.dtype
    if initial_state is None:
        initial_state = jnp.zeros((B, H, dk, dv), jnp.float32)

    def per_head(q_h, k_h, v_h, a_h, s0):
        def step(S, inp):
            qt, kt, vt, at = inp
            S = jnp.exp(at) * S + jnp.outer(kt, vt)
            return S, qt @ S

        return jax.lax.scan(step, s0, (q_h.astype(jnp.float32),
                                       k_h.astype(jnp.float32),
                                       v_h.astype(jnp.float32),
                                       a_h.astype(jnp.float32)))

    fn = jax.vmap(jax.vmap(per_head))
    final, o = fn(q, k, v, log_a, initial_state)
    return o.astype(dtype), final


# ---------------------------------------------------------------------------
# (Gated) delta rule (DeltaNet / GDN / KDA): the paper's case-study mixer
# ---------------------------------------------------------------------------


def delta_ref(q, k, v, log_a, beta, initial_state=None):
    """Sequential oracle for the gated delta rule:

        S_t = a_t (I - beta_t k_t k_t^T) S_{t-1} + beta_t k_t v_t^T
        o_t = q_t S_t

    q, k: (B, H, S, dk); v: (B, H, S, dv); log_a, beta: (B, H, S).
    ``log_a = 0`` recovers plain DeltaNet. Keys are expected L2-normalized by
    the caller (required for the delta operator to be a contraction).
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    dtype = q.dtype
    if initial_state is None:
        initial_state = jnp.zeros((B, H, dk, dv), jnp.float32)

    def per_head(q_h, k_h, v_h, a_h, b_h, s0):
        def step(S, inp):
            qt, kt, vt, at, bt = inp
            S = jnp.exp(at) * (S - bt * jnp.outer(kt, kt @ S))
            S = S + bt * jnp.outer(kt, vt)
            return S, qt @ S

        return jax.lax.scan(step, s0, (q_h.astype(jnp.float32),
                                       k_h.astype(jnp.float32),
                                       v_h.astype(jnp.float32),
                                       a_h.astype(jnp.float32),
                                       b_h.astype(jnp.float32)))

    fn = jax.vmap(jax.vmap(per_head))
    final, o = fn(q, k, v, log_a, beta, initial_state)
    return o.astype(dtype), final


# ---------------------------------------------------------------------------
# Decode attention (flash-decode): one new token vs a long KV cache
# ---------------------------------------------------------------------------


def decode_attention_ref(q, k_cache, v_cache, lengths, *, window: int = 0,
                         scale: float | None = None):
    """q: (B, Hq, D); k_cache, v_cache: (B, Hkv, S, D); lengths: (B,) int32.

    Valid keys for request b are positions [max(0, L_b - window), L_b) where
    L_b = lengths[b] (the cache already contains the current token's K/V).
    Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    dtype = q.dtype
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k_cache.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v_cache.astype(jnp.float32), group, axis=1)
    scores = jnp.einsum("bhd,bhkd->bhk", qf, kf)
    from repro.models.perf_flags import FLAGS, shard_hint
    if FLAGS.shard_attention:
        # keep decode scores batch-sharded: without this, GSPMD computes
        # the (B, Hq, S) scores batch-replicated and all-reduces 16x more
        # bytes than necessary when the cache head_dim is sharded
        scores = shard_hint(scores, ("pod", "data"), None, None)
    kpos = jnp.arange(S)[None, :]
    mask = kpos < lengths[:, None]
    if window > 0:
        mask &= kpos >= (lengths[:, None] - window)
    scores = jnp.where(mask[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.any(mask, -1)[:, None, None], probs, 0.0)
    return jnp.einsum("bhk,bhkd->bhd", probs, vf).astype(dtype)


def verify_attention_ref(q, k_cache, v_cache, lengths, *,
                         scale: float | None = None):
    """Speculative-verify attention: q: (B, Hq, Q, D) — Q candidate
    positions per request; k_cache, v_cache: (B, Hkv, S, D).

    Position j of request b attends over ``min(lengths[b] + j, S)`` keys
    (the caller passes ``lengths`` as the FIRST position's key count, i.e.
    context + 1).  ONE masked pass over the KV cache scores every position
    — the f32 upcast/GQA-repeat of the cache AND the two GEMM sweeps over
    it are shared across all Q positions, which is the whole perf win over
    q sequential decode steps (each of which re-reads the cache).

    Numerics contract: float-equivalent, not bitwise, to per-position
    ``decode_attention_ref`` calls — the (B,H,Q,S)-shaped GEMMs may tile
    (and thus reassociate the d/k summations) differently from the
    (B,H,S)-shaped single-token ones.  Masking is content-independent
    (rows >= the per-position length get NEG_INF before softmax, so the
    future rows a verify pass pre-writes contribute exactly 0); the
    speculative contract enforced by the engine tests is greedy TOKEN
    identity (argmax), which survives ulp-level reassociation.
    Returns (B, Hq, Q, D).
    """
    B, Hq, Q, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    dtype = q.dtype
    # Grouped contractions on the UN-repeated cache: the GQA head-group is
    # a batch dim of the dot, not a contraction dim, so skipping the
    # materialized ``jnp.repeat`` halves the GEMM input traffic without
    # changing any summation.
    qf = q.astype(jnp.float32) * scale
    qf = qf.reshape(B, Hkv, group, Q, D)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    scores = jnp.einsum("bngqd,bnkd->bngqk", qf, kf)     # (B,Hkv,G,Q,S)
    from repro.models.perf_flags import FLAGS, shard_hint
    if FLAGS.shard_attention:
        scores = shard_hint(scores, ("pod", "data"), None, None, None, None)
    kpos = jnp.arange(S)[None, None, :]
    eff = jnp.minimum(lengths[:, None] + jnp.arange(Q)[None, :], S)
    mask = kpos < eff[:, :, None]                        # (B, Q, S)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.any(mask, -1)[:, None, None, :, None], probs, 0.0)
    o = jnp.einsum("bngqk,bnkd->bngqd", probs, vf)
    return o.reshape(B, Hq, Q, D).astype(dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, tables, lengths, *,
                               window: int = 0, scale: float | None = None):
    """Paged flash-decode oracle: gather pages through the block table, then
    run the dense decode oracle over the gathered cache.

    q: (B, Hq, D); k_pages, v_pages: (Hkv, P, T, D) page pools; tables:
    (B, N) int32 physical page ids (logical page j of request b lives at
    ``tables[b, j]``); lengths: (B,) int32. Positions >= lengths[b] may point
    at garbage/sink pages — the length mask guarantees they never contribute.
    Returns (B, Hq, D), bit-identical to ``decode_attention_ref`` on the
    equivalent dense cache.
    """
    Hkv = k_pages.shape[0]
    B, N = tables.shape
    T, D = k_pages.shape[2], k_pages.shape[3]
    Dv = v_pages.shape[3]
    kg = jnp.transpose(k_pages[:, tables], (1, 0, 2, 3, 4)).reshape(
        B, Hkv, N * T, D)
    vg = jnp.transpose(v_pages[:, tables], (1, 0, 2, 3, 4)).reshape(
        B, Hkv, N * T, Dv)
    return decode_attention_ref(q, kg, vg, lengths, window=window, scale=scale)


def paged_prefill_attention_ref(q, k_pages, v_pages, tables, k_suf, v_suf, *,
                                scale: float | None = None):
    """Paged-prefill oracle: gather the prior pages through the block table,
    concatenate the dense suffix, and run the dense flash oracle.

    q: (B, Hq, C, D) — the current suffix chunk's queries; k_pages, v_pages:
    (Hkv, P, T, D) page pools; tables: (B, N) int32; k_suf, v_suf:
    (B, Hkv, Ssuf, D) — all suffix keys/values seen so far (the last C rows
    are the chunk's own, causally masked). Prior pages are fully visible.
    Returns (B, Hq, C, Dv), bit-identical to ``flash_attention_ref`` over the
    equivalent dense [prior | suffix] cache.
    """
    Hkv = k_pages.shape[0]
    B, N = tables.shape
    T, D = k_pages.shape[2], k_pages.shape[3]
    Dv = v_pages.shape[3]
    C = q.shape[2]
    Ssuf = k_suf.shape[2]
    kg = jnp.transpose(k_pages[:, tables], (1, 0, 2, 3, 4)).reshape(
        B, Hkv, N * T, D)
    vg = jnp.transpose(v_pages[:, tables], (1, 0, 2, 3, 4)).reshape(
        B, Hkv, N * T, Dv)
    k_full = jnp.concatenate([kg, k_suf], axis=2)
    v_full = jnp.concatenate([vg, v_suf], axis=2)
    # q row 0 sits at global position N*T + (Ssuf - C)
    return flash_attention_ref(q, k_full, v_full, causal=True,
                               scale=scale, q_offset=N * T + Ssuf - C)


# ---------------------------------------------------------------------------
# Wire quantization (cross-DC KV transfer): per-tensor symmetric int8
# ---------------------------------------------------------------------------


def quantize_int8_ref(x):
    """Per-tensor symmetric int8 encode — the unfused oracle for
    ``kernels.quantize.quantize_int8_fused`` and the exact math of
    ``distributed.collectives.quantize_int8`` (byte-identity is pinned)."""
    absmax = jnp.max(jnp.abs(x))
    # reciprocal multiply, not division: jit rewrites constant divisions to
    # reciprocal multiplies, so this form is the one that stays bit-stable
    # between eager oracle calls and the jitted/interpreted kernel
    scale = jnp.maximum(absmax, 1e-30) * (1.0 / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Single-step recurrent updates (decode path for linear mixers)
# ---------------------------------------------------------------------------


def gla_step_ref(q, k, v, log_a, state):
    """One decode step. q,k: (B,H,dk); v: (B,H,dv); log_a: (B,H); state f32."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    state = a * state + jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32),
                                   v.astype(jnp.float32))
    o = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), state)
    return o.astype(q.dtype), state


def delta_step_ref(q, k, v, log_a, beta, state):
    """One gated-delta decode step (shapes as gla_step_ref + beta: (B,H))."""
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    b = beta.astype(jnp.float32)[..., None, None]
    kS = jnp.einsum("bhk,bhkv->bhv", kf, state)
    state = a * (state - b * jnp.einsum("bhk,bhv->bhkv", kf, kS))
    state = state + b * jnp.einsum("bhk,bhv->bhkv", kf, vf)
    o = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), state)
    return o.astype(q.dtype), state

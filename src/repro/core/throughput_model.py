"""The paper's analytical throughput model (§3.4, Eqs. 1-8) + grid search.

Roles: PrfaaS prefill (N_prfaas instances), PD-P (N_p), PD-D (N_d).
A fraction p = P(L > t) of requests offload to PrfaaS; Eq. 6 gives

    Lambda_max = min(Theta_prfaas / p, Theta_pdp / (1-p), Theta_pdd)

with Theta_prfaas bandwidth-clipped by B_out (Eq. 3). ``grid_search``
solves the two decision variables (t, N_p/N_d) exactly as §3.4.2/§4.2.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.hardware import Profile
from repro.core.workload import Workload


def kv_throughput(profile: Profile, l: float) -> float:
    """Eq. 1: Φ_kv(l) = S_kv(l) / T_prefill(l), bytes/s."""
    return profile.s_kv(int(l)) / profile.t_prefill(int(l))


def egress_bandwidth(n_gpus: int, gpus_per_instance: int, profile: Profile,
                     l_avg: float) -> float:
    """Eq. 2: minimum egress bandwidth of an N-GPU prefill cluster, bytes/s."""
    return (n_gpus / gpus_per_instance) * kv_throughput(profile, l_avg)


@dataclass(frozen=True)
class SystemConfig:
    n_prfaas: int                 # PrfaaS prefill instances
    n_p: int                      # PD prefill instances (total over clusters)
    n_d: int                      # PD decode instances (total over clusters)
    b_out: float                  # PrfaaS egress bandwidth (bytes/s)
    threshold: float              # routing threshold t (tokens); inf => no offload
    # beyond-paper: int8 KV quantization on the inter-DC wire (KIVI/CacheGen
    # family, paper §5) — divides S_kv on the link, raising the bandwidth-
    # bound Θ_prfaas ceiling. NOT a free parameter: set it to the MEASURED
    # quantized/raw byte ratio of a real prefill cache
    # (``models.kvcache.wire_compression_ratio`` /
    # ``CrossDCDeployment.measured_compression``). 1.0 = off
    # (paper-faithful); the simulator charges the same ratio per flow.
    kv_wire_compression: float = 1.0
    # multi-cluster deployments: per-PD-cluster instance counts (must sum to
    # n_p / n_d).  None = one PD cluster holding everything (paper baseline).
    n_p_clusters: Optional[tuple] = None
    n_d_clusters: Optional[tuple] = None

    def __post_init__(self):
        for name, per, total in (("n_p_clusters", self.n_p_clusters, self.n_p),
                                 ("n_d_clusters", self.n_d_clusters, self.n_d)):
            if per is not None and sum(per) != total:
                raise ValueError(f"{name} {per} must sum to {total}")
        if (self.n_p_clusters is None) != (self.n_d_clusters is None):
            raise ValueError("set both n_p_clusters and n_d_clusters or neither")
        if (self.n_p_clusters is not None
                and len(self.n_p_clusters) != len(self.n_d_clusters)):
            raise ValueError("per-cluster tuples must have equal length")

    @property
    def num_pd_clusters(self) -> int:
        return len(self.n_p_clusters) if self.n_p_clusters is not None else 1

    def per_cluster(self, k: Optional[int] = None):
        """(n_p, n_d) per PD cluster.  Without explicit tuples the totals are
        split evenly over ``k`` clusters, remainder to earlier ones."""
        if self.n_p_clusters is not None:
            return list(zip(self.n_p_clusters, self.n_d_clusters))
        k = 1 if k is None else k
        return list(zip(split_even(self.n_p, k), split_even(self.n_d, k)))


def split_even(total: int, k: int):
    """Deterministic even split of ``total`` over ``k`` buckets."""
    base, rem = divmod(total, k)
    return [base + (1 if i < rem else 0) for i in range(k)]


@dataclass
class ThroughputModel:
    prfaas_profile: Optional[Profile]   # None => no PrfaaS cluster
    pd_profile: Profile
    workload: Workload

    # -- stage throughputs (req/s) ------------------------------------------
    def theta_prfaas(self, sc: SystemConfig) -> float:
        """Eq. 3: min(compute rate, egress rate) with layer-wise pipelining."""
        if sc.n_prfaas == 0 or self.prfaas_profile is None:
            return 0.0
        if self.workload.lengths.p_gt(sc.threshold) <= 0.0:
            return math.inf
        l_long = self.workload.lengths.mean_above(sc.threshold)
        compute = sc.n_prfaas / self.prfaas_profile.t_prefill(int(l_long))
        wire_bytes = self.prfaas_profile.s_kv(int(l_long)) \
            / max(sc.kv_wire_compression, 1e-9)
        egress = sc.b_out / wire_bytes
        return min(compute, egress)

    def theta_pdp(self, sc: SystemConfig) -> float:
        """Eq. 4 (RDMA intra-cluster: compute bound only)."""
        if sc.n_p == 0:
            return 0.0
        frac_long = self.workload.lengths.p_gt(sc.threshold)
        if sc.n_prfaas == 0 or frac_long >= 1.0:
            l_short = self.workload.lengths.mean()
        elif frac_long <= 0.0:
            l_short = self.workload.lengths.mean()
        else:
            l_short = self.workload.lengths.mean_below(sc.threshold)
        return sc.n_p / self.pd_profile.t_prefill(int(l_short))

    def theta_pdd(self, sc: SystemConfig) -> float:
        """Eq. 5: N_d * BS_max / (T_decode * L_out)."""
        w = self.workload
        return sc.n_d * w.bs_max / (w.t_decode * w.output_len)

    # -- Eq. 6 ----------------------------------------------------------------
    def lambda_max(self, sc: SystemConfig,
                   pd_shares: Optional[list] = None,
                   thresholds: Optional[list] = None) -> float:
        """Eq. 6, generalized to per-PD-cluster instance counts: with
        regional traffic shares s_c, cluster c must sustain s_c of the
        global rate with its own N_p,c / N_d,c, so each per-cluster stage
        throughput is divided by its share.  The single-cluster case
        (``n_p_clusters is None``) is the paper's original min().

        ``thresholds`` (per-region, multi-cluster only) models the
        regionalized short-term loop: region c offloads with its OWN
        t_c, so p_c = P(L > t_c) and the PrfaaS cluster serves the traffic
        mixture — compute constraint sum_c s_c p_c T_prefill(l_long,c)
        <= N_prfaas / Lambda, egress constraint sum_c s_c p_c S_kv(l_long,c)
        <= B_out / Lambda — while each region's PD-P stage is evaluated at
        its own conditional short-length mean.  ``thresholds=None`` uses
        ``sc.threshold`` everywhere (identical to the uniform case).

        (A request short-circuits to 0 via theta_pdp == 0 when n_p == 0 and
        p < 1 — the old explicit ``return 0.0`` branch was unreachable.)"""
        terms = []
        if sc.n_p_clusters is None:
            if thresholds is not None:
                raise ValueError("per-region thresholds require per-cluster "
                                 "instance counts (n_p_clusters)")
            p = self.workload.lengths.p_gt(sc.threshold) if sc.n_prfaas \
                else 0.0
            if p > 0:
                terms.append(self.theta_prfaas(sc) / p)
            terms.append(self.theta_pdd(sc))
            if p < 1:
                terms.append(self.theta_pdp(sc) / (1.0 - p))
            return min(terms)
        k = sc.num_pd_clusters
        if pd_shares is None:
            shares = [1.0 / k] * k
        else:
            if len(pd_shares) != k or min(pd_shares) < 0 \
                    or sum(pd_shares) <= 0:
                raise ValueError(f"pd_shares {pd_shares} invalid for "
                                 f"{k} PD clusters")
            shares = [s / sum(pd_shares) for s in pd_shares]
        if thresholds is None:
            ts = [sc.threshold] * k
        else:
            if len(thresholds) != k:
                raise ValueError(f"thresholds {thresholds} invalid for "
                                 f"{k} PD clusters")
            ts = list(thresholds)
        lengths = self.workload.lengths
        # PrfaaS serves the cross-region mixture of long requests: one
        # aggregate compute and one aggregate egress constraint.
        if sc.n_prfaas:
            time_per_req = 0.0      # E[s_c p_c T_prefill(l_long,c)]
            bytes_per_req = 0.0     # E[s_c p_c S_kv(l_long,c)] on the wire
            for s, t in zip(shares, ts):
                p_c = lengths.p_gt(t)
                if s <= 0 or p_c <= 0:
                    continue
                if self.prfaas_profile is None:
                    # offloading configured with no PrfaaS profile: the
                    # offloaded fraction has nowhere to run (theta == 0)
                    return 0.0
                l_long = int(lengths.mean_above(t))
                time_per_req += s * p_c * self.prfaas_profile.t_prefill(l_long)
                bytes_per_req += s * p_c * self.prfaas_profile.s_kv(l_long) \
                    / max(sc.kv_wire_compression, 1e-9)
            if time_per_req > 0:
                terms.append(sc.n_prfaas / time_per_req)
                terms.append(sc.b_out / bytes_per_req)
        pdd_unit = self.theta_pdd(
            SystemConfig(sc.n_prfaas, 1, 1, sc.b_out, sc.threshold))
        for (n_p_c, n_d_c), s, t in zip(sc.per_cluster(), shares, ts):
            if s <= 0:
                continue
            terms.append(n_d_c * pdd_unit / s)
            p_c = lengths.p_gt(t) if sc.n_prfaas else 0.0
            if p_c < 1:
                l_short = lengths.mean() if sc.n_prfaas == 0 \
                    else lengths.mean_below(t)
                pdp_c = n_p_c / self.pd_profile.t_prefill(int(l_short))
                terms.append(pdp_c / ((1.0 - p_c) * s))
        return min(terms)

    def egress_load(self, sc: SystemConfig, rate: Optional[float] = None) -> float:
        """Average egress bytes/s at offered rate (default: Λ_max)."""
        if sc.n_prfaas == 0:
            return 0.0
        rate = self.lambda_max(sc) if rate is None else rate
        p = self.workload.lengths.p_gt(sc.threshold)
        l_long = self.workload.lengths.mean_above(sc.threshold)
        return rate * p * self.prfaas_profile.s_kv(int(l_long)) \
            / max(sc.kv_wire_compression, 1e-9)

    # -- §3.4.2: grid search over (t, N_p/N_d) --------------------------------
    def grid_search(self, n_prfaas: int, n_pd_total: int, b_out: float,
                    thresholds=None, kv_wire_compression: float = 1.0):
        """Exhaustive 2-D search maximizing Λ_max (paper Fig. 5).

        Returns (best SystemConfig, Λ_max, search trace).
        """
        lo = math.log(max(self.workload.lengths.lo, 256))
        hi = math.log(self.workload.lengths.hi)
        if thresholds is None:
            thresholds = [math.exp(lo + (hi - lo) * i / 400)
                          for i in range(401)]
        if n_prfaas == 0:
            thresholds = [math.inf]
        # The per-threshold workload moments (p_gt, conditional means, and
        # the resulting per-instance stage rates) are independent of the
        # N_p/N_d split, so hoist them out of the inner loop: O(T + T*N)
        # cheap arithmetic instead of O(T*N) erf/interp evaluations.
        decode_unit = self.theta_pdd(
            SystemConfig(n_prfaas, 0, 1, b_out, 0.0))
        per_t = []
        for t in thresholds:
            sc1 = SystemConfig(n_prfaas, 1, 1, b_out, t,
                               kv_wire_compression=kv_wire_compression)
            p = self.workload.lengths.p_gt(t) if n_prfaas else 0.0
            per_t.append((t, p, self.theta_prfaas(sc1),
                          self.theta_pdp(sc1)))
        best, best_rate, trace = None, -1.0, []
        for n_p in range(0 if n_prfaas else 1, n_pd_total):
            n_d = n_pd_total - n_p
            th_pdd = n_d * decode_unit
            for t, p, th_prfaas, th_pdp_unit in per_t:
                rate = th_pdd
                if p > 0:
                    rate = min(rate, th_prfaas / p)
                if p < 1:
                    rate = min(rate, n_p * th_pdp_unit / (1.0 - p))
                trace.append((n_p, n_d, t, rate))
                if rate > best_rate:
                    best_rate = rate
                    best = SystemConfig(
                        n_prfaas, n_p, n_d, b_out, t,
                        kv_wire_compression=kv_wire_compression)
        return best, best_rate, trace

    # -- §3.4.2 optimality residuals (Eqs. 7-8), for tests/analysis ----------
    def balance_residuals(self, sc: SystemConfig):
        p = self.workload.lengths.p_gt(sc.threshold)
        eq7 = None
        if 0 < p < 1:
            eq7 = (self.theta_prfaas(sc) / p) - (self.theta_pdp(sc) / (1 - p))
        eq8 = (self.theta_prfaas(sc) + self.theta_pdp(sc)) - self.theta_pdd(sc)
        return eq7, eq8

"""Global KVCache manager (paper §3.2): cross-cluster cache metadata.

Maintains one HybridPrefixCache per cluster, computes per-cluster prefix
matches for routing, selects cache-affine nodes, and performs hotspot
rebalancing / opportunistic cross-cluster cache transfer when bandwidth is
abundant (§3.4.3 "bandwidth is abundant" branch).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.blockpool import BlockPool
from repro.core.prefix_cache import HybridPrefixCache, token_block_hashes


@dataclass
class MatchInfo:
    cluster: str
    matched_tokens: int


class GlobalKVManager:
    def __init__(self):
        self.clusters: Dict[str, HybridPrefixCache] = {}
        self.node_affinity: Dict[str, int] = {}   # cluster -> node count
        self.rebalanced = 0
        self.cross_transfers = 0

    def register_cluster(self, name: str, cache: HybridPrefixCache,
                         nodes: int = 1):
        self.clusters[name] = cache
        self.node_affinity[name] = nodes

    # ------------------------------------------------------------- matching
    def match_all(self, tokens: Sequence[int],
                  names: Optional[Sequence[str]] = None) -> Dict[str, int]:
        """Paper: 'computes prefix-match information for every cluster'.

        ``names`` optionally restricts the match to reachable clusters (the
        live deployment filters by link topology)."""
        if names is None:
            names = self.clusters.keys()
        return {name: self.clusters[name].match(tokens)
                for name in names if name in self.clusters}

    def best_match(self, tokens: Sequence[int]) -> MatchInfo:
        matches = self.match_all(tokens)
        best = max(matches.items(), key=lambda kv: kv[1])
        return MatchInfo(cluster=best[0], matched_tokens=best[1])

    # ----------------------------------------------------- affinity routing
    def affine_node(self, cluster: str, tokens: Sequence[int],
                    block_tokens: int = 64) -> int:
        """Cache-affine node within a cluster: consistent hash of the first
        prefix block so same-prefix requests co-locate."""
        n = self.node_affinity.get(cluster, 1)
        hashes = token_block_hashes(tokens[:block_tokens], block_tokens)
        key = hashes[0] if hashes else hash(tuple(tokens[:8]))
        return key % max(1, n)

    # ------------------------------------------------------------ lifecycle
    def record_prefill(self, cluster: str, tokens: Sequence[int]) -> int:
        return self.clusters[cluster].insert(tokens)

    def rebalance(self, tokens: Sequence[int], src: str, dst: str) -> bool:
        """Replicate a hot prefix into another cluster (cache rebalancing /
        cross-cluster cache transfer). Returns True if dst now caches it."""
        if self.clusters[src].match(tokens) == 0:
            return False
        inserted = self.clusters[dst].insert(tokens)
        if inserted:
            self.cross_transfers += 1
        return inserted > 0

    def stats(self) -> dict:
        return {name: {"hit_rate": c.hit_rate(),
                       "pool_util": c.pool.utilization(),
                       "evicted": c.pool.stats["evicted"],
                       "pool": {**c.pool.stats,
                                "resident": c.pool.resident,
                                "used_blocks": c.pool.used_blocks,
                                "num_blocks": c.pool.num_blocks}}
                for name, c in self.clusters.items()}

"""Hardware model: chip classes, instance profiles, T_prefill / S_kv sources.

Three profile kinds feed the throughput model (paper Eq. 1):
  * ``PaperProfile`` — the paper's measured Table 5 for the internal 1T
    hybrid on an 8xH200 instance, with log-log (power-law) interpolation.
    This is the *faithful-reproduction* input: feeding it into our
    throughput model must reproduce Table 6 (validated in benchmarks).
  * ``AnalyticProfile`` — derived from any ``ModelConfig`` + chip spec via a
    FLOPs/bytes roofline with an MFU(l) saturation curve; used for the
    assigned architectures where no measured profile exists.
  * ``CalibratedProfile`` — the same roofline, but the chip peak and the
    MFU(l) curve are MEASURED on this machine by the kernel sweep in
    ``benchmarks.kernel_bench`` and fitted by ``analysis.calibrate``:
    routing thresholds and simulated service times then derive from the
    hardware the engines actually run on, not a named chip's datasheet.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Tuple

import numpy as np

from repro.configs.base import AttentionSpec, ModelConfig

MIB = 2 ** 20


@dataclass(frozen=True)
class ChipSpec:
    name: str
    flops_bf16: float          # peak FLOP/s
    hbm_bw: float              # bytes/s
    hbm_bytes: float

    def prefill_time(self, flops: float, bytes_moved: float,
                     mfu: float = 0.5, chips: int = 1) -> float:
        return max(flops / (chips * self.flops_bf16 * mfu),
                   bytes_moved / (chips * self.hbm_bw * 0.8))


CHIPS = {
    "h200": ChipSpec("h200", 989e12, 4.8e12, 141e9),
    "h20": ChipSpec("h20", 148e12, 4.0e12, 96e9),
    "tpu-v5e": ChipSpec("tpu-v5e", 197e12, 819e9, 16e9),
    "tpu-v5p": ChipSpec("tpu-v5p", 459e12, 2.77e12, 95e9),
}


def _loglog_interp(xs, ys, x):
    """Piecewise power-law interpolation (extrapolates end slopes)."""
    lx = [math.log(v) for v in xs]
    ly = [math.log(v) for v in ys]
    q = math.log(x)
    if q <= lx[0]:
        i = 0
    elif q >= lx[-1]:
        i = len(lx) - 2
    else:
        i = max(j for j in range(len(lx) - 1) if lx[j] <= q)
    slope = (ly[i + 1] - ly[i]) / (lx[i + 1] - lx[i])
    return math.exp(ly[i] + slope * (q - lx[i]))


def _loglog_interp_vec(xs, ys, x: np.ndarray) -> np.ndarray:
    """Vectorized ``_loglog_interp``: same piecewise power law (including
    end-slope extrapolation) over an array of query lengths.  Agrees with
    the scalar version to float rounding — the vectorized simulator engine
    must charge the same bytes/service times as the event engine."""
    lx = np.log(np.asarray(xs, np.float64))
    ly = np.log(np.asarray(ys, np.float64))
    q = np.log(np.maximum(np.asarray(x, np.float64), 1e-300))
    # segment index per query, clipped so end segments extrapolate
    i = np.clip(np.searchsorted(lx, q, side="right") - 1, 0, len(lx) - 2)
    slope = (ly[i + 1] - ly[i]) / (lx[i + 1] - lx[i])
    return np.exp(ly[i] + slope * (q - lx[i]))


class Profile:
    """Per-instance profile: S_kv(l) bytes, T_prefill(l) seconds."""

    def s_kv(self, l: int) -> float:
        raise NotImplementedError

    def t_prefill(self, l: int) -> float:
        raise NotImplementedError

    def kv_throughput(self, l: int) -> float:
        """Paper Eq. 1: Φ_kv(l) in bytes/s."""
        return self.s_kv(l) / self.t_prefill(l)

    # -- batched evaluation (vectorized simulator engine) -------------------
    # Subclasses with closed-form curves override these with pure-numpy
    # versions; the fallback loops over the scalar methods so ANY profile
    # stays usable from the SoA fast path (just without the speedup).
    def s_kv_vec(self, lens: np.ndarray) -> np.ndarray:
        return np.array([self.s_kv(int(l)) for l in np.asarray(lens).ravel()],
                        np.float64).reshape(np.shape(lens))

    def t_prefill_vec(self, lens: np.ndarray) -> np.ndarray:
        return np.array([self.t_prefill(int(l))
                         for l in np.asarray(lens).ravel()],
                        np.float64).reshape(np.shape(lens))


# Paper Table 5 (1T hybrid model, 8xH200, in-house vLLM).
PAPER_TABLE5_LENS = (1024, 8192, 32768, 131072)
PAPER_TABLE5_SKV_MIB = (190.8, 308.9, 701.3, 2316.3)
PAPER_TABLE5_TPREFILL = (0.44, 0.72, 1.84, 7.40)


class PaperProfile(Profile):
    """The paper's measured Table 5 with power-law interpolation.

    ``slowdown(l)`` maps the 8xH200 profile onto other hardware; the H20
    factor is calibrated from the paper's own Table 6 operating points
    (T_H20(10.2K)=1.83s, T_H20(27.3K)=4.27s -> kappa(l) ~= 2.19*(l/10222)^0.188).
    """

    def __init__(self, slowdown_base: float = 1.0, slowdown_exp: float = 0.0,
                 slowdown_ref_len: float = 10222.0):
        self.slowdown_base = slowdown_base
        self.slowdown_exp = slowdown_exp
        self.slowdown_ref_len = slowdown_ref_len

    def s_kv(self, l: int) -> float:
        return _loglog_interp(PAPER_TABLE5_LENS,
                              [v * MIB for v in PAPER_TABLE5_SKV_MIB], l)

    def t_prefill(self, l: int) -> float:
        base = _loglog_interp(PAPER_TABLE5_LENS, PAPER_TABLE5_TPREFILL, l)
        kappa = self.slowdown_base * (l / self.slowdown_ref_len) ** self.slowdown_exp
        return base * kappa

    def s_kv_vec(self, lens: np.ndarray) -> np.ndarray:
        return _loglog_interp_vec(
            PAPER_TABLE5_LENS, [v * MIB for v in PAPER_TABLE5_SKV_MIB], lens)

    def t_prefill_vec(self, lens: np.ndarray) -> np.ndarray:
        l = np.asarray(lens, np.float64)
        base = _loglog_interp_vec(PAPER_TABLE5_LENS, PAPER_TABLE5_TPREFILL, l)
        kappa = self.slowdown_base * (
            l / self.slowdown_ref_len) ** self.slowdown_exp
        return base * kappa


def paper_h200_profile() -> PaperProfile:
    return PaperProfile()


def paper_h20_profile() -> PaperProfile:
    # calibrated vs Table 6 (see module docstring)
    return PaperProfile(slowdown_base=2.187, slowdown_exp=0.1876,
                        slowdown_ref_len=10222.0)


class AnalyticProfile(Profile):
    """Roofline-derived profile for an arbitrary ModelConfig.

    T_prefill(l) = max(compute, HBM) with a length-dependent MFU saturation
    curve mfu(l) = mfu_max * l / (l + l_half): short prefills are launch/
    memory-bound (low utilization), long prefills approach peak — matching
    the shape of the paper's Figure 2 / Table 5.
    """

    def __init__(self, cfg: ModelConfig, chip: ChipSpec, chips_per_instance: int,
                 mfu_max: float = 0.55, l_half: float = 2048.0,
                 kv_dtype_bytes: int = 2):
        self.cfg = cfg
        self.chip = chip
        self.chips = chips_per_instance
        self.mfu_max = mfu_max
        self.l_half = l_half
        self.kv_dtype_bytes = kv_dtype_bytes

    def s_kv(self, l: int) -> float:
        return float(self.cfg.kv_cache_bytes(l, self.kv_dtype_bytes))

    def mfu(self, l: float) -> float:
        """Length-dependent MFU saturation curve (overridable: measured)."""
        return self.mfu_max * l / (l + self.l_half)

    def prefill_flops(self, l: int) -> float:
        """2*N_active*l matmul + attention quadratic terms."""
        cfg = self.cfg
        f = 2.0 * cfg.active_param_count() * l
        for *_, b in cfg.iter_blocks():
            m = b.mixer
            if isinstance(m, AttentionSpec):
                eff = min(l, m.window) if m.window else l
                # q@k^T + p@v over causal half
                f += 2.0 * 2.0 * m.q_heads * m.head_dim * l * eff / 2.0
            else:                            # linear mixer: chunked scan
                f += 2.0 * 2.0 * m.heads * m.key_dim * m.value_dim * l
        return f

    def prefill_bytes(self, l: int) -> float:
        cfg = self.cfg
        w = cfg.active_param_count() * 2.0   # weights once (big-batch amortized)
        act = 12.0 * l * cfg.d_model * cfg.n_layers * 2.0
        return w + act

    def t_prefill(self, l: int) -> float:
        mfu = self.mfu(l)
        t_c = self.prefill_flops(l) / (self.chips * self.chip.flops_bf16 * mfu)
        t_m = self.prefill_bytes(l) / (self.chips * self.chip.hbm_bw * 0.8)
        return max(t_c, t_m)


@dataclass(frozen=True)
class Calibration:
    """Measured-machine kernel calibration (see ``analysis.calibrate``).

    ``points`` are (prefill_length, measured_mfu) pairs from the kernel
    sweep; ``mfu_max``/``l_half`` are the fitted saturation-curve params
    used outside the measured range.  ``peak_flops``/``mem_bw`` are this
    machine's measured peaks (the "chip" the MFU is relative to).
    """
    peak_flops: float
    mem_bw: float
    mfu_max: float
    l_half: float
    points: Tuple[Tuple[float, float], ...] = field(default_factory=tuple)
    source: str = "kernel_bench"


class CalibratedProfile(AnalyticProfile):
    """AnalyticProfile whose chip peak and MFU(l) come from measured
    kernels: log-log interpolation over the measured MFU points inside the
    sweep range, the fitted saturation curve outside it.

    Flow: ``benchmarks.kernel_bench`` (sweep -> BENCH_kernel.json) ->
    ``analysis.calibrate.load_calibration`` -> ``CalibratedProfile`` ->
    Router / ``PrfaasSimulator`` service times.
    """

    def __init__(self, cfg: ModelConfig, calibration: Calibration,
                 chips_per_instance: int = 1, kv_dtype_bytes: int = 2):
        chip = ChipSpec(f"measured:{calibration.source}",
                        calibration.peak_flops, calibration.mem_bw, 0.0)
        super().__init__(cfg, chip, chips_per_instance,
                         mfu_max=calibration.mfu_max,
                         l_half=calibration.l_half,
                         kv_dtype_bytes=kv_dtype_bytes)
        self.calibration = calibration

    def mfu(self, l: float) -> float:
        pts = self.calibration.points
        if len(pts) >= 2 and pts[0][0] <= l <= pts[-1][0]:
            return _loglog_interp([p[0] for p in pts],
                                  [max(p[1], 1e-9) for p in pts], l)
        return super().mfu(l)

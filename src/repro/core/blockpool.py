"""Unified KV block pool (paper §3.2, Fig. 4).

The pool is the allocation authority for KV blocks. Full-attn/MLA/SWA
KVCache groups allocate fixed-size *device pages* from it when the paged
layout is on (``DeploymentConfig(paged_kv=True)``); linear-state groups
allocate metadata blocks for their request-level snapshots. With the paged
layout off the pool still runs the same lifecycle purely as a byte-accounting
twin of the dense buffers. Blocks are ref-counted and carry a category:

  * prefix-cache blocks — reusable across requests once fully populated;
    evictable LRU when free space runs out;
  * transfer-cache blocks — tail KVCache produced for PD-disaggregated
    transfer; discarded as soon as the transfer completes (never reused).
"""
from __future__ import annotations

import itertools
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

PREFIX = "prefix"
TRANSFER = "transfer"


@dataclass
class Block:
    block_id: int
    category: str = PREFIX
    ref_count: int = 0
    populated: bool = False      # prefix blocks reusable only when full
    key: Optional[int] = None    # content hash (prefix chain)


class BlockPool:
    """Ref-counted block pool with LRU eviction of unreferenced prefix blocks."""

    def __init__(self, num_blocks: int, block_tokens: int = 64,
                 block_bytes: int = 0):
        self.num_blocks = num_blocks
        self.block_tokens = block_tokens
        self.block_bytes = block_bytes
        self._free = list(range(num_blocks - 1, -1, -1))
        self._blocks = {}
        # unreferenced-but-cached prefix blocks, LRU order
        self._lru: OrderedDict[int, None] = OrderedDict()
        self.stats = {"allocated": 0, "evicted": 0, "freed": 0,
                      "alloc_fail": 0}

    # ------------------------------------------------------------- queries
    @property
    def free_blocks(self) -> int:
        return len(self._free) + len(self._lru)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - self.free_blocks

    @property
    def resident(self) -> int:
        """Blocks with live metadata: ref-held or cached (LRU). Conservation
        invariant: ``allocated == freed + evicted + resident``."""
        return len(self._blocks)

    def utilization(self) -> float:
        return self.used_blocks / max(1, self.num_blocks)

    def get(self, block_id: int) -> Block:
        return self._blocks[block_id]

    # ----------------------------------------------------------- lifecycle
    def allocate(self, n: int, category: str = PREFIX):
        """Allocate n blocks (evicting LRU prefix blocks if needed).

        Returns list of block ids, or None if pool cannot satisfy.
        """
        if n > self.free_blocks:
            self.stats["alloc_fail"] += 1
            return None
        out = []
        for _ in range(n):
            if not self._free:
                self._evict_one()
            bid = self._free.pop()
            self._blocks[bid] = Block(bid, category=category, ref_count=1)
            out.append(bid)
        self.stats["allocated"] += n
        return out

    def _evict_one(self):
        bid, _ = self._lru.popitem(last=False)
        self._blocks.pop(bid, None)
        self._free.append(bid)
        self.stats["evicted"] += 1

    def retain(self, block_ids):
        for bid in block_ids:
            b = self._blocks[bid]
            if b.ref_count == 0:
                self._lru.pop(bid, None)
            b.ref_count += 1

    def release(self, block_ids):
        """Drop a reference. Transfer blocks free immediately at rc=0;
        prefix blocks stay cached (LRU) if populated, else free."""
        for bid in block_ids:
            b = self._blocks.get(bid)
            if b is None:
                continue
            b.ref_count -= 1
            assert b.ref_count >= 0, f"double free of block {bid}"
            if b.ref_count == 0:
                if b.category == TRANSFER or not b.populated:
                    self._blocks.pop(bid)
                    self._free.append(bid)
                    self.stats["freed"] += 1
                else:
                    self._lru[bid] = None   # cached, evictable

    def touch(self, block_ids):
        """LRU refresh for cached blocks on a prefix hit."""
        for bid in block_ids:
            if bid in self._lru:
                self._lru.move_to_end(bid)

    def mark_populated(self, block_ids, keys=None):
        for i, bid in enumerate(block_ids):
            b = self._blocks[bid]
            b.populated = True
            if keys is not None:
                b.key = keys[i]

    # ------------------------------------------------------------ invariants
    def check_invariants(self):
        ref = sum(1 for b in self._blocks.values() if b.ref_count > 0)
        cached = len(self._lru)
        free = len(self._free)
        assert ref + cached + free == self.num_blocks, \
            (ref, cached, free, self.num_blocks)
        assert all(self._blocks[b].ref_count == 0 for b in self._lru)
        return True

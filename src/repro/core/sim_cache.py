"""Metadata-only hybrid prefix cache for the cluster simulator.

Same resumable-prefix semantics as ``prefix_cache.HybridPrefixCache`` —
block-level full-attn chain matching plus request-level linear snapshots
valid only at their exact block-aligned length, sharing one LRU-evicted
block budget (paper §3.2) — exploiting a structural fact of the simulated
workload: block hashes are per-session chains, and different sessions never
share a prefix.  A chain is therefore fully described by its covered block
count plus the snapshot boundaries inserted so far, making match/insert
O(1) per *request* instead of O(blocks):

  * match(chain, n)  = largest snapshot boundary <= min(coverage, n)
    — identical to walking the per-block hash chain and then looking for
    the longest linear snapshot at or below the covered boundary;
  * eviction is LRU over whole chains.  In the real ``BlockPool`` a chain's
    blocks sit contiguously in LRU order and evicting a chain's first block
    already zeroes its matchable prefix, so whole-chain eviction yields the
    same observable hit statistics.

The live serving path (``serving.deployment``) keeps the real
``HybridPrefixCache``/``BlockPool``, which track actual KV bytes and
arbitrary cross-request block sharing.
"""
from __future__ import annotations

from bisect import bisect_right, insort
from collections import OrderedDict
from typing import List


class _PoolStats:
    """Duck-typed stand-in for ``BlockPool`` telemetry consumed by
    ``GlobalKVManager.stats`` (utilization / eviction counters)."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self.used = 0
        self.stats = {"allocated": 0, "evicted": 0, "freed": 0,
                      "alloc_fail": 0}

    def utilization(self) -> float:
        return self.used / max(1, self.num_blocks)

    @property
    def used_blocks(self) -> int:
        return self.used

    @property
    def resident(self) -> int:
        # the simulator's pool twin has no ref-counts: every used block is
        # resident (allocated == freed + evicted + resident holds by
        # construction)
        return self.used


class SimPrefixCache:
    """Drop-in for ``HybridPrefixCache`` inside ``PrfaasSimulator``: exposes
    ``match`` / ``insert`` keyed by (chain id, block count) with the same
    observable semantics, plus ``hit_rate`` / ``pool`` telemetry."""

    def __init__(self, num_blocks: int, block_tokens: int):
        self.block_tokens = block_tokens
        self.pool = _PoolStats(num_blocks)
        # chain id -> ascending snapshot boundaries (block counts); the last
        # entry is the chain's covered prefix length.  OrderedDict = LRU.
        self._chains: "OrderedDict[int, List[int]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0

    # ----------------------------------------------------------------- match
    def match(self, chain: int, n_blocks: int) -> int:
        """Longest resumable cached prefix (tokens) of an ``n_blocks``-block
        request on ``chain``: full-attn blocks cover [0, b) AND a linear
        snapshot exists at a boundary <= b."""
        if n_blocks <= 0:
            return 0
        snaps = self._chains.get(chain)
        if snaps is None:
            self.misses += 1
            return 0
        covered = min(snaps[-1], n_blocks)
        i = bisect_right(snaps, covered)
        matched = snaps[i - 1] * self.block_tokens if i else 0
        self._chains.move_to_end(chain)
        if matched:
            self.hits += 1
            self.hit_tokens += matched
        else:
            self.misses += 1
        return matched

    # ---------------------------------------------------------------- insert
    def insert(self, chain: int, n_blocks: int) -> int:
        """Record the KV/state produced by a completed prefill: the chain's
        missing full-attn blocks plus one linear snapshot at ``n_blocks``.
        Each snapshot costs one extra pool block (request-level state)."""
        if n_blocks <= 0:
            return 0
        pool = self.pool
        if n_blocks + 1 > pool.num_blocks:
            pool.stats["alloc_fail"] += 1
            return 0
        snaps = self._chains.get(chain)
        added = 0
        if snaps is None:
            self._chains[chain] = [n_blocks]
            added = n_blocks + 1
        else:
            if n_blocks > snaps[-1]:
                added = n_blocks - snaps[-1] + 1
                snaps.append(n_blocks)
            elif n_blocks not in snaps:
                added = 1                         # snapshot only; blocks cached
                insort(snaps, n_blocks)
            self._chains.move_to_end(chain)
        pool.used += added
        pool.stats["allocated"] += added
        if pool.used > pool.num_blocks:
            self._evict_over()
        return n_blocks * self.block_tokens

    def _evict_over(self):
        """LRU whole-chain eviction; the insertee sits at the MRU end and is
        never evicted (len > 1 guard)."""
        pool, chains = self.pool, self._chains
        evicted = 0
        while pool.used > pool.num_blocks and len(chains) > 1:
            _, snaps = chains.popitem(last=False)
            freed = snaps[-1] + len(snaps)
            pool.used -= freed
            evicted += freed
        pool.stats["evicted"] += evicted

    # ------------------------------------------------------------- telemetry
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

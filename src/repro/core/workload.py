"""Workload model: truncated log-normal request lengths (paper §4.1) plus
the trace-driven workload layer for the vectorized simulator.

All conditional moments needed by the throughput model — p(t) = P(L > t),
l_long(t) = E[L | L > t], l_short(t) = E[L | L <= t] — are computed in closed
form from the truncated log-normal (no scipy; erf from math).

Traces (``Trace``) are structure-of-arrays arrival schedules — (arrival_s,
total_len, session, home) columns — replayable through either simulator
engine: ``PrfaasSimulator.inject_trace(trace.to_entries())`` for the exact
event engine, or directly (no per-request Python objects) for
``SimConfig(engine="vector")``.  ``Trace.save``/``Trace.load`` round-trip
through ``.npz`` with a JSON metadata blob, so recorded production traces
and generated scenario traces share one format.  Three generator families
cover the production shapes the paper's claims are about:

  * ``diurnal_trace``       — nonhomogeneous Poisson with a sinusoidal
                              day/night cycle, phase-shifted per region by
                              its time-zone offset (peaks do not align);
  * ``flash_crowd_trace``   — baseline Poisson plus exponentially decaying
                              rate spikes at flash onset times;
  * ``conversation_trace``  — multi-turn conversation trees: session starts
                              from any arrival process, geometric turn
                              counts, exponential think-time gaps between
                              turns, per-turn context growth (the agentic
                              prefix-cache workload), optional roaming.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import numpy as np


def _phi(z: float) -> float:
    """Standard normal CDF."""
    return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))


def mmpp_rate(base_rate: float, burst_factor: float, period_s: float,
              t: float) -> float:
    """Square-wave 2-state MMPP modulation of a Poisson rate, preserving the
    mean rate for ANY burst_factor bf >= 1:

      * bf <= 2: 50% duty cycle with phase rates (bf, 2-bf) x base
        -> mean = (bf + (2-bf))/2 = 1 x base
      * bf  > 2: the low phase would go negative, so instead the duty cycle
        shrinks to 1/bf with a silent low phase
        -> mean = (1/bf)*bf + (1-1/bf)*0 = 1 x base

    (The seed clamped the low phase at max(0, 2-bf) with a fixed 50% duty,
    which inflated the offered load to bf/2 x base for bf > 2.)
    """
    bf = burst_factor
    if bf <= 1.0 or period_s <= 0.0:
        return base_rate
    if bf <= 2.0:
        duty, low = 0.5, 2.0 - bf
    else:
        duty, low = 1.0 / bf, 0.0
    phase_high = (t % period_s) < duty * period_s
    return base_rate * (bf if phase_high else low)


@dataclass(frozen=True)
class LogNormalLengths:
    mu: float = 9.90
    sigma: float = 1.00
    lo: float = 128.0
    hi: float = 131072.0

    # -- closed-form moments -------------------------------------------------
    def _z(self, x: float) -> float:
        return (math.log(x) - self.mu) / self.sigma

    @property
    def _norm(self) -> float:
        return _phi(self._z(self.hi)) - _phi(self._z(self.lo))

    def p_gt(self, t: float) -> float:
        """P(L > t) under truncation."""
        t = min(max(t, self.lo), self.hi)
        return (_phi(self._z(self.hi)) - _phi(self._z(t))) / self._norm

    def _partial_mean(self, a: float, b: float) -> float:
        """E[L ; a < L <= b] (unnormalized partial expectation)."""
        m = math.exp(self.mu + 0.5 * self.sigma ** 2)
        return m * (_phi(self._z(b) - self.sigma)
                    - _phi(self._z(a) - self.sigma)) / self._norm

    def mean(self) -> float:
        return self._partial_mean(self.lo, self.hi)

    def mean_above(self, t: float) -> float:
        """E[L | L > t]."""
        t = min(max(t, self.lo), self.hi)
        p = self.p_gt(t)
        if p <= 0:
            return self.hi
        return self._partial_mean(t, self.hi) / p

    def mean_below(self, t: float) -> float:
        """E[L | L <= t]."""
        t = min(max(t, self.lo), self.hi)
        p = 1.0 - self.p_gt(t)
        if p <= 0:
            return self.lo
        return self._partial_mean(self.lo, t) / p

    # -- sampling ------------------------------------------------------------
    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """True truncation (rejection), matching the analytic moments —
        clipping would put a point mass at the bounds."""
        out = np.empty(n, np.float64)
        filled = 0
        while filled < n:
            x = rng.lognormal(self.mu, self.sigma, size=max(n - filled, 64))
            x = x[(x >= self.lo) & (x <= self.hi)]
            take = min(len(x), n - filled)
            out[filled:filled + take] = x[:take]
            filled += take
        return out.astype(np.int64)


@dataclass(frozen=True)
class Workload:
    """Full serving workload (paper §4.1 defaults)."""

    lengths: LogNormalLengths = LogNormalLengths()
    output_len: int = 1024
    decode_tps_slo: float = 40.0          # tokens/s per stream (SLO)
    bs_max: int = 20                      # decode slots per instance
    # request arrival burstiness (MMPP 2-state modulation of Poisson rate)
    burst_factor: float = 1.0             # 1.0 = plain Poisson
    burst_period_s: float = 60.0
    # prefix caching behaviour (agentic multi-turn sessions)
    session_prob: float = 0.0             # P(request continues a session)
    session_growth: float = 4096.0        # mean new tokens per turn

    @property
    def t_decode(self) -> float:
        return 1.0 / self.decode_tps_slo


# ---------------------------------------------------------------------------
# trace-driven workload layer
# ---------------------------------------------------------------------------
@dataclass
class Trace:
    """Structure-of-arrays arrival trace (sorted by arrival time).

    Columns: ``arrival`` (float64 seconds), ``total_len`` (int64 tokens),
    ``session`` (int64, dense ids from 0), ``home`` (int32 index into
    ``home_names``).  ``meta`` carries generator provenance (family,
    parameters, seed) for the scenario engine's artifacts.
    """

    arrival: np.ndarray
    total_len: np.ndarray
    session: np.ndarray
    home: np.ndarray
    home_names: Tuple[str, ...] = ("pd",)
    meta: dict = field(default_factory=dict)

    def __post_init__(self):
        self.arrival = np.asarray(self.arrival, np.float64)
        self.total_len = np.asarray(self.total_len, np.int64)
        self.session = np.asarray(self.session, np.int64)
        self.home = np.asarray(self.home, np.int32)
        n = len(self.arrival)
        if not (len(self.total_len) == len(self.session)
                == len(self.home) == n):
            raise ValueError("trace columns must have equal length")
        if n and np.any(np.diff(self.arrival) < 0):
            raise ValueError("trace must be sorted by arrival time")
        if n and (self.home.min() < 0
                  or self.home.max() >= len(self.home_names)):
            raise ValueError("home index out of range of home_names")

    def __len__(self) -> int:
        return len(self.arrival)

    @property
    def n_sessions(self) -> int:
        return int(self.session.max()) + 1 if len(self) else 0

    def to_entries(self):
        """(arrival, total_len, session, home_name) tuples for
        ``PrfaasSimulator.inject_trace`` — the event-engine replay path."""
        names = self.home_names
        return [(float(a), int(l), int(s), names[h])
                for a, l, s, h in zip(self.arrival, self.total_len,
                                      self.session, self.home)]

    # ------------------------------------------------------------------ io
    def save(self, path: str):
        """Write the ``.npz`` trace file (columns + JSON meta blob)."""
        np.savez_compressed(
            path, arrival=self.arrival, total_len=self.total_len,
            session=self.session, home=self.home,
            meta=np.frombuffer(json.dumps(
                {"home_names": list(self.home_names), **self.meta}
            ).encode(), dtype=np.uint8))

    @classmethod
    def load(cls, path: str) -> "Trace":
        with np.load(path) as z:
            meta = json.loads(bytes(z["meta"]).decode())
            names = tuple(meta.pop("home_names"))
            return cls(z["arrival"], z["total_len"], z["session"], z["home"],
                       home_names=names, meta=meta)


def _thin_poisson(rate_grid: np.ndarray, grid_dt: float, sim_time: float,
                  rng: np.random.Generator) -> np.ndarray:
    """Nonhomogeneous Poisson arrival times on [0, sim_time) by thinning a
    piecewise-constant rate (vectorized: exponential gaps + cumsum, then one
    acceptance pass — no per-arrival Python loop)."""
    lam_max = float(rate_grid.max(initial=0.0))
    if lam_max <= 0.0 or sim_time <= 0.0:
        return np.empty(0, np.float64)
    out = []
    t0 = 0.0
    # draw in chunks until the candidate stream crosses the horizon
    chunk = max(1024, int(lam_max * sim_time * 1.2))
    while t0 < sim_time:
        gaps = rng.exponential(1.0 / lam_max, size=chunk)
        t = t0 + np.cumsum(gaps)
        u = rng.random(chunk)
        keep = t < sim_time
        lam = rate_grid[np.minimum((t[keep] / grid_dt).astype(np.int64),
                                   len(rate_grid) - 1)]
        out.append(t[keep][u[keep] * lam_max < lam])
        if not keep.all():
            break
        t0 = float(t[-1])
        chunk = max(1024, chunk // 4)
    return np.concatenate(out) if out else np.empty(0, np.float64)


def _sample_homes(n: int, shares: Optional[Sequence[float]], k: int,
                  rng: np.random.Generator) -> np.ndarray:
    if k == 1:
        return np.zeros(n, np.int32)
    p = (np.full(k, 1.0 / k) if shares is None
         else np.asarray(shares, np.float64) / np.sum(shares))
    return rng.choice(k, size=n, p=p).astype(np.int32)


def diurnal_trace(mean_rate: float, sim_time: float, seed: int = 0,
                  home_names: Sequence[str] = ("pd",),
                  shares: Optional[Sequence[float]] = None,
                  tz_offsets_s: Optional[Sequence[float]] = None,
                  day_s: float = 86_400.0, depth: float = 0.6,
                  lengths: LogNormalLengths = LogNormalLengths(),
                  grid_dt: float = 10.0) -> Trace:
    """Diurnal cycle with regional time-zone offsets: each region r draws a
    nonhomogeneous Poisson stream at

        lam_r(t) = mean_rate * share_r * (1 + depth * sin(2pi (t+tz_r)/day))

    so regional peaks are phase-shifted (the paper's cross-datacenter
    premise: one region's off-peak prefill capacity can serve another's
    peak).  Every request is its own single-turn session; compose with
    ``conversation_trace`` for multi-turn sessions."""
    k = len(home_names)
    shares_v = ([1.0 / k] * k if shares is None
                else [s / sum(shares) for s in shares])
    tz = list(tz_offsets_s) if tz_offsets_s is not None else [0.0] * k
    if len(tz) != k or len(shares_v) != k:
        raise ValueError("shares/tz_offsets_s must match home_names")
    rng = np.random.default_rng(seed)
    grid_t = np.arange(0.0, sim_time + grid_dt, grid_dt)
    per_region = []
    for r in range(k):
        rate = mean_rate * shares_v[r] * (
            1.0 + depth * np.sin(2.0 * np.pi * (grid_t + tz[r]) / day_s))
        times = _thin_poisson(np.maximum(rate, 0.0), grid_dt, sim_time, rng)
        per_region.append((times, np.full(len(times), r, np.int32)))
    arrival = np.concatenate([t for t, _ in per_region])
    home = np.concatenate([h for _, h in per_region])
    order = np.argsort(arrival, kind="stable")
    arrival, home = arrival[order], home[order]
    n = len(arrival)
    return Trace(arrival, lengths.sample(rng, n), np.arange(n, dtype=np.int64),
                 home, tuple(home_names),
                 meta={"family": "diurnal", "mean_rate": mean_rate,
                       "sim_time": sim_time, "seed": seed, "depth": depth,
                       "day_s": day_s, "tz_offsets_s": tz})


def flash_crowd_trace(base_rate: float, sim_time: float, seed: int = 0,
                      home_names: Sequence[str] = ("pd",),
                      shares: Optional[Sequence[float]] = None,
                      flash_times: Optional[Sequence[float]] = None,
                      flash_amp: float = 4.0, flash_decay_s: float = 60.0,
                      lengths: LogNormalLengths = LogNormalLengths(),
                      grid_dt: float = 1.0) -> Trace:
    """Baseline Poisson plus flash crowds: at each onset time the global
    rate jumps by ``flash_amp x base_rate`` and decays exponentially
    (``flash_decay_s``) — the viral-moment / breaking-news shape that
    stresses admission and the short-term routing loop."""
    rng = np.random.default_rng(seed)
    if flash_times is None:
        # a couple of onsets per run by default, clear of the warmup edge
        n_flash = max(1, int(sim_time / 600.0))
        flash_times = np.sort(rng.uniform(0.2 * sim_time, 0.9 * sim_time,
                                          size=n_flash))
    grid_t = np.arange(0.0, sim_time + grid_dt, grid_dt)
    rate = np.full_like(grid_t, base_rate)
    for tf in np.asarray(flash_times, np.float64):
        dt = grid_t - tf
        rate += np.where(dt >= 0.0,
                         base_rate * flash_amp * np.exp(-dt / flash_decay_s),
                         0.0)
    arrival = _thin_poisson(rate, grid_dt, sim_time, rng)
    n = len(arrival)
    return Trace(arrival, lengths.sample(rng, n), np.arange(n, dtype=np.int64),
                 _sample_homes(n, shares, len(home_names), rng),
                 tuple(home_names),
                 meta={"family": "flash_crowd", "base_rate": base_rate,
                       "sim_time": sim_time, "seed": seed,
                       "flash_times": [float(t) for t in flash_times],
                       "flash_amp": flash_amp,
                       "flash_decay_s": flash_decay_s})


def conversation_trace(session_starts: np.ndarray, sim_time: float,
                       seed: int = 0,
                       home_names: Sequence[str] = ("pd",),
                       shares: Optional[Sequence[float]] = None,
                       turns_mean: float = 4.0,
                       think_mean_s: float = 30.0,
                       growth_mean: float = 4096.0,
                       roam_prob: float = 0.0,
                       lengths: LogNormalLengths = LogNormalLengths()
                       ) -> Trace:
    """Multi-turn conversation trees with think-time gaps: each session
    start spawns a geometric number of turns (mean ``turns_mean``); turn
    j+1 arrives an Exp(``think_mean_s``) gap after turn j and grows the
    context by Exp(``growth_mean``)+1 tokens (capped at ``lengths.hi``),
    reusing the session's cached prefix — the workload where prefix-cache
    dynamics dominate.  ``roam_prob`` re-homes individual turns (session
    roaming: the cached prefix stays behind, forcing cross-region copies).

    ``session_starts`` is any sorted arrival-time array — e.g.
    ``diurnal_trace(...).arrival`` to put conversation trees on a diurnal
    cycle."""
    starts = np.asarray(session_starts, np.float64)
    n_sess = len(starts)
    rng = np.random.default_rng(seed)
    if n_sess == 0:
        return Trace(np.empty(0), np.empty(0, np.int64),
                     np.empty(0, np.int64), np.empty(0, np.int32),
                     tuple(home_names), meta={"family": "conversation"})
    turns = rng.geometric(min(1.0, 1.0 / max(turns_mean, 1.0)), size=n_sess)
    total = int(turns.sum())
    sess = np.repeat(np.arange(n_sess, dtype=np.int64), turns)
    # segmented cumsum helper: within-session running sums over flat draws
    offsets = np.concatenate(([0], np.cumsum(turns)[:-1]))

    def _seg_cumsum(flat: np.ndarray) -> np.ndarray:
        cs = np.cumsum(flat)
        base = np.repeat(cs[offsets] - flat[offsets], turns)
        return cs - base

    # think-time gaps (turn 0 gap = 0: it IS the session start)
    gaps = rng.exponential(think_mean_s, size=total)
    gaps[offsets] = 0.0
    arrival = np.repeat(starts, turns) + _seg_cumsum(gaps)
    # context growth per turn on top of the first-turn length
    first_len = lengths.sample(rng, n_sess).astype(np.float64)
    grow = rng.exponential(growth_mean, size=total) + 1.0
    grow[offsets] = 0.0
    total_len = np.minimum(np.repeat(first_len, turns) + _seg_cumsum(grow),
                           lengths.hi).astype(np.int64)
    # homes: per session, with optional per-turn roaming
    k = len(home_names)
    home = np.repeat(_sample_homes(n_sess, shares, k, rng), turns)
    if roam_prob > 0.0 and k > 1:
        roam = rng.random(total) < roam_prob
        roam[offsets] = False
        idx = np.flatnonzero(roam)
        if len(idx):
            # redraw uniformly over the OTHER regions
            shift = rng.integers(1, k, size=len(idx)).astype(np.int32)
            home[idx] = (home[idx] + shift) % k
    keep = arrival < sim_time
    order = np.argsort(arrival[keep], kind="stable")
    return Trace(arrival[keep][order], total_len[keep][order],
                 sess[keep][order], home[keep][order], tuple(home_names),
                 meta={"family": "conversation", "sim_time": sim_time,
                       "seed": seed, "turns_mean": turns_mean,
                       "think_mean_s": think_mean_s,
                       "growth_mean": growth_mean, "roam_prob": roam_prob})

"""Workload model: truncated log-normal request lengths (paper §4.1).

All conditional moments needed by the throughput model — p(t) = P(L > t),
l_long(t) = E[L | L > t], l_short(t) = E[L | L <= t] — are computed in closed
form from the truncated log-normal (no scipy; erf from math).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


def _phi(z: float) -> float:
    """Standard normal CDF."""
    return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))


def mmpp_rate(base_rate: float, burst_factor: float, period_s: float,
              t: float) -> float:
    """Square-wave 2-state MMPP modulation of a Poisson rate, preserving the
    mean rate for ANY burst_factor bf >= 1:

      * bf <= 2: 50% duty cycle with phase rates (bf, 2-bf) x base
        -> mean = (bf + (2-bf))/2 = 1 x base
      * bf  > 2: the low phase would go negative, so instead the duty cycle
        shrinks to 1/bf with a silent low phase
        -> mean = (1/bf)*bf + (1-1/bf)*0 = 1 x base

    (The seed clamped the low phase at max(0, 2-bf) with a fixed 50% duty,
    which inflated the offered load to bf/2 x base for bf > 2.)
    """
    bf = burst_factor
    if bf <= 1.0 or period_s <= 0.0:
        return base_rate
    if bf <= 2.0:
        duty, low = 0.5, 2.0 - bf
    else:
        duty, low = 1.0 / bf, 0.0
    phase_high = (t % period_s) < duty * period_s
    return base_rate * (bf if phase_high else low)


@dataclass(frozen=True)
class LogNormalLengths:
    mu: float = 9.90
    sigma: float = 1.00
    lo: float = 128.0
    hi: float = 131072.0

    # -- closed-form moments -------------------------------------------------
    def _z(self, x: float) -> float:
        return (math.log(x) - self.mu) / self.sigma

    @property
    def _norm(self) -> float:
        return _phi(self._z(self.hi)) - _phi(self._z(self.lo))

    def p_gt(self, t: float) -> float:
        """P(L > t) under truncation."""
        t = min(max(t, self.lo), self.hi)
        return (_phi(self._z(self.hi)) - _phi(self._z(t))) / self._norm

    def _partial_mean(self, a: float, b: float) -> float:
        """E[L ; a < L <= b] (unnormalized partial expectation)."""
        m = math.exp(self.mu + 0.5 * self.sigma ** 2)
        return m * (_phi(self._z(b) - self.sigma)
                    - _phi(self._z(a) - self.sigma)) / self._norm

    def mean(self) -> float:
        return self._partial_mean(self.lo, self.hi)

    def mean_above(self, t: float) -> float:
        """E[L | L > t]."""
        t = min(max(t, self.lo), self.hi)
        p = self.p_gt(t)
        if p <= 0:
            return self.hi
        return self._partial_mean(t, self.hi) / p

    def mean_below(self, t: float) -> float:
        """E[L | L <= t]."""
        t = min(max(t, self.lo), self.hi)
        p = 1.0 - self.p_gt(t)
        if p <= 0:
            return self.lo
        return self._partial_mean(self.lo, t) / p

    # -- sampling ------------------------------------------------------------
    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """True truncation (rejection), matching the analytic moments —
        clipping would put a point mass at the bounds."""
        out = np.empty(n, np.float64)
        filled = 0
        while filled < n:
            x = rng.lognormal(self.mu, self.sigma, size=max(n - filled, 64))
            x = x[(x >= self.lo) & (x <= self.hi)]
            take = min(len(x), n - filled)
            out[filled:filled + take] = x[:take]
            filled += take
        return out.astype(np.int64)


@dataclass(frozen=True)
class Workload:
    """Full serving workload (paper §4.1 defaults)."""

    lengths: LogNormalLengths = LogNormalLengths()
    output_len: int = 1024
    decode_tps_slo: float = 40.0          # tokens/s per stream (SLO)
    bs_max: int = 20                      # decode slots per instance
    # request arrival burstiness (MMPP 2-state modulation of Poisson rate)
    burst_factor: float = 1.0             # 1.0 = plain Poisson
    burst_period_s: float = 60.0
    # prefix caching behaviour (agentic multi-turn sessions)
    session_prob: float = 0.0             # P(request continues a session)
    session_growth: float = 4096.0        # mean new tokens per turn

    @property
    def t_decode(self) -> float:
        return 1.0 / self.decode_tps_slo

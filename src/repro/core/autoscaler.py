"""Long-term scheduling: traffic-driven allocation re-optimization
(paper §3.4.3, long-term loop).

Monitors stage utilization / queue depth over minutes, detects persistent
producer/consumer imbalance (Theta_prfaas + Theta_pdp vs Theta_pdd, Eq. 8)
and converts PD nodes between prefill and decode roles; after each
conversion the routing threshold t is re-optimized (Eq. 7).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.router import Router
from repro.core.throughput_model import SystemConfig, ThroughputModel


@dataclass
class StageTelemetry:
    prefill_queue: int = 0
    decode_queue: int = 0
    prefill_util: float = 0.0
    decode_util: float = 0.0


@dataclass
class AutoscalerConfig:
    period_s: float = 300.0          # re-evaluation period
    imbalance_ratio: float = 1.25    # hysteresis on producer/consumer ratio
    min_p: int = 1
    min_d: int = 1


class Autoscaler:
    def __init__(self, model: ThroughputModel, router: Router,
                 system: SystemConfig,
                 cfg: Optional[AutoscalerConfig] = None):
        self.model = model
        self.router = router
        self.system = system
        # fresh config per autoscaler (a default argument would be a single
        # mutable instance shared by every Autoscaler in the process)
        self.cfg = AutoscalerConfig() if cfg is None else cfg
        self._last_eval = 0.0
        self.conversions: List[tuple] = []

    def maybe_rebalance(self, now: float, tel: StageTelemetry) -> Optional[SystemConfig]:
        if now - self._last_eval < self.cfg.period_s:
            return None
        self._last_eval = now
        sc = self.system
        producer = self.model.theta_prfaas(sc) + self.model.theta_pdp(sc)
        consumer = self.model.theta_pdd(sc)
        new_p, new_d = sc.n_p, sc.n_d
        # queue evidence + model evidence must agree (avoid flapping)
        if (producer > consumer * self.cfg.imbalance_ratio
                and tel.decode_queue > tel.prefill_queue
                and sc.n_p > self.cfg.min_p):
            new_p, new_d = sc.n_p - 1, sc.n_d + 1          # P -> D
        elif (consumer > producer * self.cfg.imbalance_ratio
                and tel.prefill_queue > tel.decode_queue
                and sc.n_d > self.cfg.min_d):
            new_p, new_d = sc.n_p + 1, sc.n_d - 1          # D -> P
        if (new_p, new_d) == (sc.n_p, sc.n_d):
            return None
        self.system = SystemConfig(sc.n_prfaas, new_p, new_d, sc.b_out,
                                   self.router.threshold)
        self.router.reoptimize(sc.n_prfaas, new_p, new_d, sc.b_out)
        self.conversions.append((now, new_p, new_d))
        return self.system

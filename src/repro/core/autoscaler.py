"""Long-term scheduling: traffic-driven allocation re-optimization
(paper §3.4.3, long-term loop).

Monitors stage utilization / queue depth over minutes, detects persistent
producer/consumer imbalance (Theta_prfaas + Theta_pdp vs Theta_pdd, Eq. 8)
and converts PD nodes between prefill and decode roles; after each
conversion the routing threshold t is re-optimized (Eq. 7).

Regionalized control (multi-cluster deployments): each PD cluster runs its
OWN Autoscaler over its region-local ``SystemConfig`` (that region's
N_p,c / N_d,c, and the shared PrfaaS cluster's instances/egress scaled by
the region's traffic share — region c consumes s_c of the offloaded-KV
stream) with ``home`` set, so conversions and the threshold re-anchor
apply to one region only — the simulator instantiates one per PD cluster
and feeds it per-region ``StageTelemetry``.  The single-cluster case is
one autoscaler over the whole fleet, exactly the paper's loop.

Session-aware producer estimate: ``StageTelemetry.cache_hit_frac`` is the
fraction of prefill tokens served from the regional prefix cache (fed from
``SimPrefixCache`` match telemetry via the router's decisions).  Cached
tokens consume no prefill compute, so the effective producer throughput is
``theta / (1 - frac)`` — a region with hot agentic sessions needs fewer
prefill instances than raw queue depths alone would suggest.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.router import Router
from repro.core.throughput_model import SystemConfig, ThroughputModel


@dataclass
class StageTelemetry:
    prefill_queue: int = 0
    decode_queue: int = 0
    prefill_util: float = 0.0
    decode_util: float = 0.0
    # fraction of prefill tokens served from the prefix cache (long-term
    # loop's session-awareness; 0 = cold cache, matches pre-session model)
    cache_hit_frac: float = 0.0
    # CUMULATIVE routed-token counters (preferred over cache_hit_frac when
    # provided): the autoscaler diffs them against its previous evaluation,
    # so the producer boost tracks the hit rate over the last period
    # instead of a stale lifetime average
    cached_tokens: int = 0
    routed_tokens: int = 0


@dataclass
class AutoscalerConfig:
    period_s: float = 300.0          # re-evaluation period
    imbalance_ratio: float = 1.25    # hysteresis on producer/consumer ratio
    min_p: int = 1
    min_d: int = 1
    cache_frac_cap: float = 0.9      # bound the producer boost from cache hits


class Autoscaler:
    def __init__(self, model: ThroughputModel, router: Router,
                 system: SystemConfig,
                 cfg: Optional[AutoscalerConfig] = None,
                 home: Optional[str] = None):
        self.model = model
        self.router = router
        self.system = system
        self.home = home                 # PD cluster governed (None = global)
        # fresh config per autoscaler (a default argument would be a single
        # mutable instance shared by every Autoscaler in the process)
        self.cfg = AutoscalerConfig() if cfg is None else cfg
        self._last_eval = 0.0
        self._cache_snap = (0, 0)        # (cached, routed) at last eval
        # allocation before the first conversion, so cost accounting can
        # time-integrate the piecewise-constant (n_p, n_d) trajectory
        self.initial = (system.n_p, system.n_d)
        self.conversions: List[tuple] = []

    def _window_cache_frac(self, tel: StageTelemetry) -> float:
        """Cache-hit token fraction over the window since the previous
        evaluation (from the cumulative counters); falls back to the
        directly supplied ``cache_hit_frac`` when no tokens were routed
        in the window (or no counters are fed)."""
        d_cached = tel.cached_tokens - self._cache_snap[0]
        d_routed = tel.routed_tokens - self._cache_snap[1]
        self._cache_snap = (tel.cached_tokens, tel.routed_tokens)
        if d_routed > 0:
            return d_cached / d_routed
        return tel.cache_hit_frac

    def maybe_rebalance(self, now: float, tel: StageTelemetry) -> Optional[SystemConfig]:
        if now - self._last_eval < self.cfg.period_s:
            return None
        self._last_eval = now
        sc = self.system
        producer = self.model.theta_prfaas(sc) + self.model.theta_pdp(sc)
        # cached prefix tokens cost no prefill compute: the hit fraction
        # observed over the LAST period scales the effective producer rate
        # (session-aware loop)
        frac = min(max(self._window_cache_frac(tel), 0.0),
                   self.cfg.cache_frac_cap)
        producer /= (1.0 - frac)
        consumer = self.model.theta_pdd(sc)
        new_p, new_d = sc.n_p, sc.n_d
        # queue evidence + model evidence must agree (avoid flapping)
        if (producer > consumer * self.cfg.imbalance_ratio
                and tel.decode_queue > tel.prefill_queue
                and sc.n_p > self.cfg.min_p):
            new_p, new_d = sc.n_p - 1, sc.n_d + 1          # P -> D
        elif (consumer > producer * self.cfg.imbalance_ratio
                and tel.prefill_queue > tel.decode_queue
                and sc.n_d > self.cfg.min_d):
            new_p, new_d = sc.n_p + 1, sc.n_d - 1          # D -> P
        if (new_p, new_d) == (sc.n_p, sc.n_d):
            return None
        threshold = (self.router.threshold if self.home is None
                     else self.router.threshold_for(self.home))
        self.system = SystemConfig(sc.n_prfaas, new_p, new_d, sc.b_out,
                                   threshold)
        self.router.reoptimize(sc.n_prfaas, new_p, new_d, sc.b_out,
                               home=self.home)
        self.conversions.append((now, new_p, new_d))
        return self.system

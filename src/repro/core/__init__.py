# The paper's primary contribution: cross-datacenter PrfaaS-PD serving —
# hybrid prefix cache pool, global KV manager, bandwidth/cache-aware
# dual-timescale scheduling, throughput model (Eqs. 1-8), link transfer
# engine, and the cross-DC cluster simulator.
from repro.core.autoscaler import Autoscaler, AutoscalerConfig, StageTelemetry
from repro.core.blockpool import PREFIX, TRANSFER, Block, BlockPool
from repro.core.hardware import (CHIPS, AnalyticProfile, Calibration,
                                 CalibratedProfile, ChipSpec, PaperProfile,
                                 Profile, paper_h20_profile,
                                 paper_h200_profile)
from repro.core.kv_manager import GlobalKVManager, MatchInfo
from repro.core.prefix_cache import (FullAttnGroup, HybridPrefixCache,
                                     LinearStateGroup, token_block_hashes)
from repro.core.router import (PD, PRFAAS, Router, RouterConfig,
                               RoutingDecision)
from repro.core.simulator import (EventPool, PrfaasSimulator, Request,
                                  SimConfig)
from repro.core.throughput_model import (SystemConfig, ThroughputModel,
                                         egress_bandwidth, kv_throughput,
                                         split_even)
from repro.core.transfer import (Flow, Link, LinkTopology, layerwise_release,
                                 star_pairs)
from repro.core.workload import (LogNormalLengths, Trace, Workload,
                                 conversation_trace, diurnal_trace,
                                 flash_crowd_trace, mmpp_rate)

__all__ = [
    "Autoscaler", "AutoscalerConfig", "StageTelemetry",
    "Block", "BlockPool", "PREFIX", "TRANSFER",
    "CHIPS", "ChipSpec", "Profile", "PaperProfile", "AnalyticProfile",
    "Calibration", "CalibratedProfile",
    "paper_h200_profile", "paper_h20_profile",
    "GlobalKVManager", "MatchInfo",
    "FullAttnGroup", "HybridPrefixCache", "LinearStateGroup",
    "token_block_hashes",
    "Router", "RouterConfig", "RoutingDecision", "PD", "PRFAAS",
    "EventPool", "PrfaasSimulator", "Request", "SimConfig",
    "SystemConfig", "ThroughputModel", "egress_bandwidth", "kv_throughput",
    "split_even",
    "Flow", "Link", "LinkTopology", "layerwise_release", "star_pairs",
    "LogNormalLengths", "Trace", "Workload", "mmpp_rate",
    "diurnal_trace", "flash_crowd_trace", "conversation_trace",
]

"""Vectorized structure-of-arrays fast path for ``PrfaasSimulator``
(``SimConfig(engine="vector")``).

The exact event engine (``simulator._run_event``) processes one event at a
time; at production scale (1e6+ requests over hours of simulated time) the
Python event loop dominates.  This engine batches homogeneous events into
numpy SoA state and advances the world in fixed epochs of
``SimConfig.vector_dt`` seconds (default: ``control_dt``):

  * **arrivals** — all arrivals in an epoch are matched against the prefix
    caches and routed in one vectorized pass that mirrors
    ``Router.route``'s decision table exactly (regime split, best-cache
    scan in registration order, tie-prefers-target cache source, the
    ``n_prfaas==0`` / ``n_p==0`` overrides).  Congestion signals and
    per-home thresholds are frozen at epoch start — the event engine only
    updates them on the ``control_dt`` grid anyway.
  * **prefill pools** — an exact FIFO-c server pool over a finish-time
    heap (``heapreplace`` per job): start times are exact, not epoch
    quantized.  Without autoscaling the pool is drained eagerly at
    routing time; with autoscaling jobs start lazily per epoch so queue
    telemetry and capacity resizes happen on the control grid.
  * **links** — each fair-share pair link becomes a per-epoch fluid
    recurrence: layer-wise release ramps are pre-scattered into per-epoch
    rate-difference/lump arrays (``np.add.at``) and each epoch moves
    ``min(capacity, backlog + released)`` bytes.  Completions follow
    processor-sharing virtual time: V advances by ``sent / active_flows``
    per epoch and a flow finishes when V reaches ``V(ramp_end)`` plus its
    bytes left unserved at the ramp end (read off the aggregate S/R
    trajectories over the flow's own ramp window).  Uncongested links are
    exact — completion == ramp end; under congestion small flows overtake
    large backlogs exactly as max-min fair sharing does, with flow counts
    frozen per epoch.  OU bandwidth fluctuation is precomputed per link
    with the event engine's exact RNG stream (``seed + 7919*i``, one
    ``standard_normal`` per ``fluct_dt``).
  * **decode** — without autoscaling decode feeds back into nothing, so
    slot contention is solved in one closed-form post-pass: sort ready
    times per home and solve the FIFO-c recurrence
    ``start_i = max(r_i, start_{i-c} + s)`` per residue class with a
    ``np.maximum.accumulate`` (service is constant per run).  With
    autoscaling a per-epoch heap pool keeps queue telemetry exact.
  * **caches** — a vectorized twin of ``SimPrefixCache`` holds per-cluster
    per-session block coverage / snapshot counts as arrays.  Because a
    session's request lengths are non-decreasing, the longest resumable
    prefix is always the full covered coverage, so ``match`` is one
    gather; LRU is an append-only (session, stamp) log with stale-entry
    skipping, giving the same whole-chain eviction order.

Equivalence contract: held to the same 5% band as the tick engine on
throughput / TTFT mean / TTFT P90 / offload fraction / egress
(``tests/test_sim_event_engine.py``), with known quantizations: control
and insert timing rounded to the epoch grid, flow completion order under
sustained congestion, and single-epoch LRU interleavings.  The event
engine stays the default; the golden trace never runs through this path.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.core.autoscaler import StageTelemetry
from repro.core.router import PRFAAS

_EPS_B = 1e-6


class _VecPool:
    """Exact FIFO server pool over a finish-time heap (``EventPool`` twin).

    ``extend`` enqueues jobs in submission order; ``process(until)`` starts
    every queued job whose exact start time (max of its ready time and the
    earliest server-free time) is <= ``until``.  Capacity decrease pops the
    earliest finish time — exactly ``EventPool.set_capacity``'s semantics,
    where the first finisher's slot disappears because ``release`` checks
    ``busy < capacity`` after decrementing."""

    def __init__(self, capacity: int, n_homes: int = 1):
        self.capacity = max(int(capacity), 0)
        self.heap: List[float] = [0.0] * self.capacity
        self.q: deque = deque()                 # (ready, service, idx, home)
        self.home_pending = np.zeros(max(n_homes, 1), np.int64)

    def extend(self, ready, service, idx, homes):
        self.q.extend(zip(ready.tolist(), service.tolist(),
                          idx.tolist(), homes.tolist()))
        np.add.at(self.home_pending, homes, 1)

    def process(self, until: float):
        starts: List[float] = []
        dones: List[float] = []
        idxs: List[int] = []
        h, q = self.heap, self.q
        while q and h:
            r, s, i, hm = q[0]
            st = r if r >= h[0] else h[0]
            if st > until:
                break
            heapq.heapreplace(h, st + s)
            q.popleft()
            self.home_pending[hm] -= 1
            starts.append(st)
            dones.append(st + s)
            idxs.append(i)
        return (np.array(idxs, np.int64), np.array(starts, np.float64),
                np.array(dones, np.float64))

    def set_capacity(self, cap: int, now: float):
        cap = max(int(cap), 0)
        while self.capacity > cap and self.heap:
            heapq.heappop(self.heap)
            self.capacity -= 1
        while self.capacity < cap:
            heapq.heappush(self.heap, now)
            self.capacity += 1

    def pending(self) -> int:
        return len(self.q)


def _fifo_lanes(ready_sorted: np.ndarray, c: int, s: float) -> np.ndarray:
    """Closed-form FIFO-c start times for constant service ``s``: request i
    (in ready order) is served by the server that finished request i-c, so
    ``start_i = max(r_i, start_{i-c} + s)`` — solved per residue class as
    ``max.accumulate(r_j - j*s) + j*s``."""
    n = len(ready_sorted)
    start = np.empty(n, np.float64)
    if c <= 0:
        start.fill(np.inf)
        return start
    for j in range(min(c, n)):
        lane = ready_sorted[j::c]
        m = np.arange(len(lane), dtype=np.float64)
        start[j::c] = np.maximum.accumulate(lane - m * s) + m * s
    return start


class _VecCache:
    """Vectorized ``SimPrefixCache`` twin over all clusters at once.

    Per (cluster, session): covered blocks, snapshot count, and the stamp
    of the latest LRU touch.  Request lengths are non-decreasing within a
    session, so a match is always the full coverage (one gather) and an
    insert only ever grows coverage by ``n - old + 1`` blocks (+1 = the
    new linear snapshot).  LRU eviction replays an append-only
    (session, stamp) log, skipping entries whose stamp is stale — the
    surviving order is exactly the OrderedDict move-to-end order."""

    def __init__(self, n_clusters: int, n_sessions: int, num_blocks: int,
                 block_tokens: int):
        self.C, self.bt = n_clusters, block_tokens
        self.num_blocks = num_blocks
        self.blocks = np.zeros((n_clusters, n_sessions), np.int64)
        self.snaps = np.zeros((n_clusters, n_sessions), np.int32)
        self.pos = np.full((n_clusters, n_sessions), -1, np.int64)
        self.used = [0] * n_clusters
        self.chains = [0] * n_clusters
        self.hits = [0] * n_clusters
        self.misses = [0] * n_clusters
        self.hit_tokens = [0] * n_clusters
        self.allocated = [0] * n_clusters
        self.evicted = [0] * n_clusters
        self.alloc_fail = [0] * n_clusters
        self._pend = [([], []) for _ in range(n_clusters)]  # sid/stamp arrays
        self._flat = [(np.empty(0, np.int64), np.empty(0, np.int64), 0)
                      for _ in range(n_clusters)]
        self._ctr = 0

    def touch(self, c: int, sids: np.ndarray):
        n = len(sids)
        if n == 0:
            return
        stamps = np.arange(self._ctr, self._ctr + n, dtype=np.int64)
        self._ctr += n
        self.pos[c, sids] = stamps
        self._pend[c][0].append(np.asarray(sids, np.int64))
        self._pend[c][1].append(stamps)

    def insert_batch(self, c: int, sids: np.ndarray, nblks: np.ndarray):
        pos = nblks > 0                          # insert(n<=0) is a no-op
        sids, nblks = sids[pos], nblks[pos]
        if len(sids) == 0:
            return
        if np.unique(sids).size < sids.size:
            # same session twice in one epoch batch: fall back to exact
            # sequential semantics (each insert sees the previous one)
            for s, n in zip(sids.tolist(), nblks.tolist()):
                self._insert_one(c, s, n)
            return
        fail = nblks + 1 > self.num_blocks
        self.alloc_fail[c] += int(fail.sum())
        sids, nblks = sids[~fail], nblks[~fail]
        if len(sids) == 0:
            return
        old = self.blocks[c, sids]
        grow = nblks > old
        delta = np.where(grow, nblks - old + 1, 0)
        self.chains[c] += int((grow & (old == 0)).sum())
        gs = sids[grow]
        self.blocks[c, gs] = nblks[grow]
        self.snaps[c, gs] += 1
        tot = int(delta.sum())
        self.used[c] += tot
        self.allocated[c] += tot
        self.touch(c, sids)                      # insert == MRU touch
        if self.used[c] > self.num_blocks:
            self._evict_over(c)

    def _insert_one(self, c: int, sid: int, n: int):
        if n + 1 > self.num_blocks:
            self.alloc_fail[c] += 1
            return
        old = int(self.blocks[c, sid])
        if n > old:
            delta = n - old + 1
            if old == 0:
                self.chains[c] += 1
            self.blocks[c, sid] = n
            self.snaps[c, sid] += 1
            self.used[c] += delta
            self.allocated[c] += delta
        self.touch(c, np.array([sid], np.int64))
        if self.used[c] > self.num_blocks:
            self._evict_over(c)

    def _pop_lru(self, c: int) -> Optional[int]:
        sid_f, st_f, head = self._flat[c]
        while True:
            if head >= len(sid_f):
                pend = self._pend[c]
                if not pend[0]:
                    self._flat[c] = (sid_f, st_f, head)
                    return None
                sid_f = np.concatenate(pend[0])
                st_f = np.concatenate(pend[1])
                pend[0].clear()
                pend[1].clear()
                head = 0
            s, st = int(sid_f[head]), int(st_f[head])
            head += 1
            if self.pos[c, s] == st and self.blocks[c, s] > 0:
                self._flat[c] = (sid_f, st_f, head)
                return s

    def _evict_over(self, c: int):
        ev = 0
        while self.used[c] > self.num_blocks and self.chains[c] > 1:
            s = self._pop_lru(c)
            if s is None:
                break
            freed = int(self.blocks[c, s]) + int(self.snaps[c, s])
            self.used[c] -= freed
            ev += freed
            self.blocks[c, s] = 0
            self.snaps[c, s] = 0
            self.pos[c, s] = -1
            self.chains[c] -= 1
        self.evicted[c] += ev

    def stats(self, names: List[str]) -> dict:
        out = {}
        for c, name in enumerate(names):
            tot = self.hits[c] + self.misses[c]
            out[name] = {
                "hit_rate": self.hits[c] / tot if tot else 0.0,
                "pool_util": self.used[c] / max(1, self.num_blocks),
                "evicted": self.evicted[c],
                "pool": {"allocated": self.allocated[c],
                         "evicted": self.evicted[c], "freed": 0,
                         "alloc_fail": self.alloc_fail[c],
                         "resident": self.used[c],
                         "used_blocks": self.used[c],
                         "num_blocks": self.num_blocks}}
        return out


class _VecLink:
    """One pair link as a per-epoch fluid recurrence (see module doc)."""

    def __init__(self, capacity_bps: float, cap_bytes_per_epoch: np.ndarray,
                 n_ep: int):
        self.capacity_bps = capacity_bps
        self.capB = cap_bytes_per_epoch         # byte capacity per epoch
        # release accounting: running-ramp rate diffs + partial-epoch bytes,
        # split into paced ramp segments vs instantaneous lumps (the split
        # feeds the water-filling V-rate: greedy lump/backlogged flows soak
        # up whatever pacing leaves unused)
        self.rate_diff = np.zeros(n_ep + 2, np.float64)
        self.extra_p = np.zeros(n_ep + 1, np.float64)
        self.extra_l = np.zeros(n_ep + 1, np.float64)
        self.rate = 0.0
        self.backlog = 0.0
        self.R = 0.0                            # total released
        self.S = 0.0                            # total sent
        self.submitted = 0.0                    # conservation: bytes charged
        self.n_flows = 0
        self.n_done = 0
        # processor-sharing virtual time: V advances by sent/active per
        # epoch, so a flow's fair-share service is V(now) - V(join).  S/R
        # histories at epoch starts let late ramp-end marks reconstruct the
        # aggregate served fraction over their own ramp window.
        self.V = 0.0
        self.act = 0                            # flows joined - completed
        self.join = np.zeros(n_ep + 1, np.int64)
        self.S_hist = np.zeros(n_ep + 2, np.float64)
        self.R_hist = np.zeros(n_ep + 2, np.float64)
        # waiting completions: a flow finishes at the EARLIER of its
        # virtual-time crossing (fair-share order) and its sent-byte
        # crossing (total-drain order) — each is exact in the regime the
        # other mis-ranks
        self.wait_V = np.empty(0, np.float64)
        self.wait_S = np.empty(0, np.float64)
        self.wait_re = np.empty(0, np.float64)
        self.wait_req = np.empty(0, np.int64)
        # telemetry (event-engine formulas)
        self.util_ewma = 0.0
        self.busy_time = 0.0
        self.drops_w = 0.0
        self.drops_total = 0.0
        self.sent_at_warmup = 0.0


class _VectorEngine:
    def __init__(self, sim):
        self.sim = sim                          # the PrfaasSimulator
        cfg = sim.sim
        raw = cfg.vector_dt if getattr(cfg, "vector_dt", 0.0) > 0 \
            else max(cfg.control_dt, 1e-3)
        # snap the epoch length onto the control grid (divisor below it,
        # multiple above it) so control/telemetry sampling happens at the
        # same instants as the event engine's control events — an epoch
        # boundary drifting past the control tick skews the util_ewma the
        # router sees and flips regime decisions near the boundary
        cd = cfg.control_dt
        if cd > 0:
            if raw <= cd:
                self.dt = cd / max(1, round(cd / raw))
            else:
                self.dt = cd * max(1, round(raw / cd))
        else:
            self.dt = raw
        self.T = cfg.sim_time
        self.n_ep = max(1, int(math.ceil(self.T / self.dt - 1e-12)))
        self.edges = np.minimum(np.arange(self.n_ep + 1) * self.dt, self.T)
        self.names = [PRFAAS] + sim._pd_names   # cluster index space
        self.k = len(sim._pd_names)
        self.eager = not cfg.autoscale

    # ------------------------------------------------------------- helpers
    def _ep(self, t: float) -> int:
        return min(int(t / self.dt), self.n_ep - 1)

    def _ep_arr(self, t: np.ndarray) -> np.ndarray:
        return np.minimum((t / self.dt).astype(np.int64), self.n_ep - 1)

    # -------------------------------------------------------------- traces
    def _load_trace(self):
        sim = self.sim
        soa = getattr(sim, "_soa_trace", None)
        if soa is not None:
            self.reqs = None
            self.arrival = np.asarray(soa.arrival, np.float64)
            self.total = np.asarray(soa.total_len, np.int64)
            self.sess = np.asarray(soa.session, np.int64)
            hmap = {}
            for i, n in enumerate(soa.home_names):
                if n not in sim._pd_names:
                    raise ValueError(f"trace home {n!r} not in simulator "
                                     f"clusters {sim._pd_names}")
                hmap[i] = sim._pd_names.index(n)
            lut = np.array([hmap[i] for i in range(len(soa.home_names))],
                           np.int64)
            self.home = lut[np.asarray(soa.home, np.int64)]
        else:
            reqs = sim._generate_arrivals()
            self.reqs = reqs
            self.arrival = np.array([r.arrival for r in reqs], np.float64)
            self.total = np.array([r.total_len for r in reqs], np.int64)
            self.sess = np.array([r.session for r in reqs], np.int64)
            pidx = {n: i for i, n in enumerate(sim._pd_names)}
            self.home = np.array([pidx[r.home] for r in reqs], np.int64)
        self.N = len(self.arrival)
        self.n_sess = int(self.sess.max()) + 1 if self.N else 1

    # --------------------------------------------------------------- links
    def _build_links(self):
        sim = self.sim
        self.link_keys = list(sim.topology.links.keys())
        self.links: List[_VecLink] = []
        nidx = {n: i for i, n in enumerate(self.names)}
        self.pair_link = np.full((len(self.names), len(self.names)), -1,
                                 np.int64)
        for li, (a, b) in enumerate(self.link_keys):
            real = sim.topology.links[(a, b)]
            capB = self._cap_bytes(real)
            self.links.append(_VecLink(real.capacity_bps, capB, self.n_ep))
            ia, ib = nidx[a], nidx[b]
            self.pair_link[ia, ib] = self.pair_link[ib, ia] = li
        # star link index per home (PrfaaS <-> pd), for regime signals
        self.star = np.array(
            [self.pair_link[0, 1 + h] for h in range(self.k)], np.int64)

    def _cap_bytes(self, real) -> np.ndarray:
        """Per-epoch byte capacity with the event engine's exact OU draw
        sequence: one ``standard_normal`` per ``fluct_dt`` boundary from
        ``default_rng(seed + 7919*i)`` (the link's own generator seed)."""
        cap = real.capacity_bps / 8.0
        if real.fluctuation <= 0:
            return cap * np.diff(self.edges)
        fdt = real.fluct_dt
        n_f = int(math.floor(self.T / fdt + 1e-9))
        # the real Link objects are never advanced in vector mode, so its
        # generator is ours to consume — the exact same PCG64 stream the
        # event engine would draw from
        z = real._rng.standard_normal(n_f)
        mult = np.empty(n_f + 2, np.float64)
        mult[0] = 1.0
        m, rev, fl, sq = 1.0, real.revert, real.fluctuation, math.sqrt(fdt)
        for j in range(n_f):
            logm = math.log(m)
            logm += -rev * logm * fdt + fl * sq * z[j]
            m = min(max(math.exp(logm), 0.3), 1.5)
            mult[j + 1] = m
        mult[n_f + 1] = m                        # pad past the horizon
        grid = np.arange(n_f + 3) * fdt
        cum = np.concatenate([[0.0], np.cumsum(mult * fdt)]) * cap
        return np.diff(np.interp(self.edges, grid, cum))

    # ------------------------------------------------------------- routing
    def _route_batch(self, ai: np.ndarray, e: int):
        sim, router = self.sim, self.sim.router
        C = len(self.names)
        h = self.home[ai]
        sid = self.sess[ai]
        L = self.total[ai]
        nblk = L // sim.sim.block_tokens
        thr = np.array([router.threshold_for(n) for n in sim._pd_names])
        star_util = np.array([self.links[s].util_ewma for s in self.star])
        abundant = star_util[h] < router.cfg.util_abundant
        # vectorized cache match: coverage is the full resumable prefix
        # (session lengths are non-decreasing -> n >= coverage always)
        M = np.zeros((len(ai), C), np.int64)
        valid = nblk > 0
        for c in range(C):
            ok = self.reach[h, c] & valid
            blk = self.cache.blocks[c, sid]
            hit = ok & (blk > 0)
            M[:, c] = np.where(hit, blk * sim.sim.block_tokens, 0)
            self.cache.hits[c] += int(hit.sum())
            self.cache.misses[c] += int((ok & ~hit).sum())
            self.cache.hit_tokens[c] += int(M[hit, c].sum())
            self.cache.touch(c, sid[hit])
        home_cl = h + 1
        l_home = M[np.arange(len(ai)), home_cl]
        l_prfaas = M[:, 0]
        t = thr[h]
        # abundant regime: best cache anywhere, first-strictly-greater in
        # registration order, starting from home
        best = home_cl.copy()
        lp = l_home.copy()
        for c in range(C):
            upd = M[:, c] > lp
            best[upd] = c
            lp[upd] = M[upd, c]
        tgt_ab = np.where(L - lp <= t, home_cl, 0)
        m_tgt = M[np.arange(len(ai)), tgt_ab]
        cc_ab = np.where(m_tgt >= lp, tgt_ab, best)
        cross_ab = (cc_ab != tgt_ab) & (lp > 0)
        # scarce regime: home and PrfaaS caches evaluated independently
        local = (L - l_home) <= t
        tgt_sc = np.where(local, home_cl, 0)
        cached_sc = np.where(local, l_home, l_prfaas)
        target = np.where(abundant, tgt_ab, tgt_sc).astype(np.int64)
        cached = np.where(abundant, lp, cached_sc).astype(np.int64)
        cache_cl = np.where(abundant, cc_ab, tgt_sc).astype(np.int64)
        cross = np.where(abundant, cross_ab, False)
        if sim.system.n_prfaas == 0:
            target, cached, cache_cl = home_cl, l_home, home_cl
            cross = np.zeros(len(ai), bool)
        elif sim.system.n_p == 0:
            target = np.zeros(len(ai), np.int64)
            cached, cache_cl = l_prfaas, target
            cross = np.zeros(len(ai), bool)
        incr = L - cached
        # mirror the Router's counters so downstream telemetry/metrics see
        # the same decision stream
        for c in range(C):
            n = int((target == c).sum())
            if n:
                router.decisions[self.names[c]] = \
                    router.decisions.get(self.names[c], 0) + n
        router.cross_transfers += int(cross.sum())
        for hh, name in enumerate(sim._pd_names):
            sel = h == hh
            if sel.any():
                acc = sim._route_tokens[name]
                acc[0] += int(cached[sel].sum())
                acc[1] += int(L[sel].sum())
        # store per-request decision state
        self.target[ai] = target
        self.cached[ai] = cached
        self.cache_cl[ai] = cache_cl
        self.cross[ai] = cross
        # service times + wire bytes
        incr_c = np.maximum(incr, 1).astype(np.float64)
        svc = np.empty(len(ai), np.float64)
        on_hub = target == 0
        if on_hub.any():
            svc[on_hub] = sim.model.prfaas_profile.t_prefill_vec(
                incr_c[on_hub])
        if (~on_hub).any():
            svc[~on_hub] = sim.model.pd_profile.t_prefill_vec(
                incr_c[~on_hub])
        self.service[ai] = svc
        prof = sim._wire_profile()
        if on_hub.any():
            hubL = L[on_hub].astype(np.float64)
            wb = prof.s_kv_vec(hubL)
            ch = cached[on_hub]
            has = ch > 0
            if has.any():
                sub = np.zeros(len(wb))
                sub[has] = prof.s_kv_vec(ch[has].astype(np.float64))
                wb = wb - sub
            self.wire_b[ai[on_hub]] = np.maximum(wb / sim._wire_comp, 1.0)
        xs = cross & (cached > 0)
        self.cross[ai] = xs                      # event guards cached>0 too
        if xs.any():
            self.cross_b[ai[xs]] = np.maximum(
                prof.s_kv_vec(cached[xs].astype(np.float64))
                / sim._wire_comp, 1.0)
        # enqueue into prefill pools (arrival order preserved per pool)
        for c in range(C):
            sel = target == c
            if sel.any():
                self.pools[c].extend(self.arrival[ai[sel]], svc[sel],
                                     ai[sel], h[sel])

    # --------------------------------------------------------- flow starts
    def _handle_starts(self, idx, start, done, e: int):
        if len(idx) == 0:
            return
        self.pf_start[idx] = start
        self.pf_done[idx] = done
        tgt = self.target[idx]
        on_hub = tgt == 0
        nfl = on_hub.astype(np.int32) + self.cross[idx].astype(np.int32)
        self.flows_left[idx] = nfl
        # requests with no link flows: transfer is free, ready at prefill end
        free = nfl == 0
        if free.any():
            self.tr_done[idx[free]] = done[free]
            self._mark_ready(idx[free], done[free], e)
        # main KV flow: PrfaaS -> home star link, linear ramp [start, done]
        if on_hub.any():
            sel = idx[on_hub]
            li = self.star[self.home[sel]]
            self._scatter_flow(li, start[on_hub], done[on_hub],
                               self.wire_b[sel], sel)
        xs = self.cross[idx]
        if xs.any():
            sel = idx[xs]
            li = self.pair_link[self.cache_cl[sel], self.target[sel]]
            st = start[xs]
            self._scatter_flow(li, st, st, self.cross_b[sel], sel)

    def _scatter_flow(self, li, start, end, nbytes, req):
        """Scatter flow release ramps into per-link per-epoch accounting and
        register completion marks at each flow's ramp-end epoch."""
        n_ep, dt = self.n_ep, self.dt
        inside = start <= self.T + 1e-9
        li, start, end = li[inside], start[inside], end[inside]
        nbytes, req = nbytes[inside], req[inside]
        if len(li) == 0:
            return
        e0 = self._ep_arr(start)
        dur = end - start
        ramp = dur > 1e-12
        lump = ~ramp
        e1 = np.where(ramp, self._ep_arr(np.minimum(end, self.T)), e0)
        same = ramp & (self._ep_arr(end) == e0) & (end <= self.T + 1e-9)
        # treat beyond-horizon ramp ends via rate columns only
        over = ramp & (end > self.T + 1e-9)
        for l in np.unique(li):
            L = self.links[l]
            m = li == l
            L.submitted += float(nbytes[m].sum())
            L.n_flows += int(m.sum())
            np.add.at(L.join, e0[m], 1)
            # instantaneous lumps vs single-epoch ramps (paced)
            w = m & lump
            if w.any():
                np.add.at(L.extra_l, e0[w], nbytes[w])
            w = m & same
            if w.any():
                np.add.at(L.extra_p, e0[w], nbytes[w])
            # multi-epoch ramps: partial first, full middle, partial last
            w = m & ramp & ~same
            if w.any():
                rho = nbytes[w] / dur[w]
                a, b = e0[w], e1[w]
                np.add.at(L.extra_p, a,
                          rho * (self.edges[np.minimum(a + 1, n_ep)]
                                 - start[w]))
                np.add.at(L.rate_diff, np.minimum(a + 1, n_ep + 1), rho)
                ov = over[w]
                np.add.at(L.rate_diff,
                          np.where(ov, n_ep + 1, b), -rho)
                tail = ~ov
                if tail.any():
                    np.add.at(L.extra_p, b[tail],
                              rho[tail] * (end[w][tail]
                                           - self.edges[b[tail]]))
        # completion marks at the ramp-end epoch (skip beyond-horizon ends:
        # the event engine never fires those either)
        fin = end <= self.T + 1e-9
        if fin.any():
            ee = self._ep_arr(end[fin])
            for e in np.unique(ee):
                m = ee == e
                self.ramp_q.setdefault(int(e), []).append(
                    (li[fin][m], end[fin][m], req[fin][m],
                     start[fin][m], nbytes[fin][m]))

    def _mark_ready(self, idx, ready, e: int):
        ok = ready <= self.T + 1e-9
        idx, ready = idx[ok], ready[ok]
        if len(idx) == 0:
            return
        self.ready_t[idx] = ready
        # cache insert at ready time, applied at the next epoch boundary
        eb = np.minimum(self._ep_arr(ready) + 1, self.n_ep)
        for b in np.unique(eb):
            m = eb == b
            self.insert_q.setdefault(int(b), []).append(idx[m])
        if not self.eager:
            ed = np.maximum(self._ep_arr(ready), e)
            for b in np.unique(ed):
                m = ed == b
                self.ready_q.setdefault(int(b), []).append(
                    (ready[m], idx[m]))

    def _apply_inserts(self, e: int):
        batch = self.insert_q.pop(e, None)
        if not batch:
            return
        idx = np.concatenate(batch)
        tgt = self.target[idx]
        for c in np.unique(tgt):
            m = tgt == c
            self.cache.insert_batch(
                int(c), self.sess[idx[m]],
                self.total[idx[m]] // self.sim.sim.block_tokens)

    # ------------------------------------------------------------ link epoch
    def _links_epoch(self, e: int):
        t0, t1 = float(self.edges[e]), float(self.edges[e + 1])
        dte = t1 - t0
        if dte <= 0:
            return
        marks = self.ramp_q.pop(e, None)
        if marks:
            ml = np.concatenate([m[0] for m in marks])
            mre = np.concatenate([m[1] for m in marks])
            mreq = np.concatenate([m[2] for m in marks])
            mst = np.concatenate([m[3] for m in marks])
            mby = np.concatenate([m[4] for m in marks])
        done_req: List[np.ndarray] = []
        done_t: List[np.ndarray] = []
        for li, L in enumerate(self.links):
            L.rate += L.rate_diff[e]
            paced = L.rate * dte + L.extra_p[e]
            rel = paced + L.extra_l[e]
            Rprev = L.R
            L.R += rel
            cap = float(L.capB[e])
            sent = min(cap, L.backlog + rel)
            Sprev = L.S
            L.S += sent
            L.backlog += rel - sent
            L.S_hist[e] = Sprev
            L.R_hist[e] = Rprev
            L.S_hist[e + 1] = L.S
            L.R_hist[e + 1] = L.R
            L.act += int(L.join[e])
            act = max(L.act, 1)
            Vprev = L.V
            # water-filling V-rate for greedy (past-ramp-end / lump) flows:
            # they soak up what pacing leaves unused when bandwidth is
            # plentiful, and degrade to an equal 1/active share when not.
            # vinc is the epoch's actual per-waiter service; g is the
            # instantaneous per-waiter drain rate (bytes/s) that maps
            # virtual-time crossings back to wall-clock within the epoch —
            # an idle-link lump completes in B/capacity seconds, not a
            # whole epoch.
            n_new = int((ml == li).sum()) if marks else 0
            n_wait = max(len(L.wait_V) + n_new, 1)
            vinc = max(sent - paced, sent * n_wait / act) / n_wait
            L.V += vinc
            cps = cap / dte
            g = max(cps - paced / dte, cps * n_wait / act) / n_wait
            g = max(g, _EPS_B)
            if L.backlog < _EPS_B:
                L.backlog = 0.0
            util = sent / cap if cap > 0 else 0.0
            a = math.exp(-dte)
            L.util_ewma = util + (L.util_ewma - util) * a
            L.busy_time += dte * util
            congested = util >= 0.999 and L.backlog > _EPS_B
            decay = math.exp(-dte / 30.0)
            add = dte / 0.02 if congested else 0.0
            L.drops_w = L.drops_w * decay + add
            L.drops_total += add
            if self.warm_ep == e:
                frac = (self.warm_t - t0) / dte
                L.sent_at_warmup = Sprev + sent * min(max(frac, 0.0), 1.0)
            # register this epoch's ramp-end marks.  A flow needs virtual
            # time V(ramp_end) + unserved bytes, where the unserved fraction
            # is read off the aggregate S/R trajectories over its own ramp
            # window: exact (completes at ramp_end) when the link kept up,
            # fair-share-ordered when a backlog formed.
            if marks:
                m = ml == li
                if m.any():
                    re = mre[m]
                    rq = mreq[m]
                    a = mst[m]
                    B = mby[m]
                    fr = (re - t0) / dte
                    S_re = Sprev + sent * fr
                    R_re = Rprev + rel * fr
                    ea = self._ep_arr(a)
                    t0a = self.edges[ea]
                    dta = np.maximum(self.edges[ea + 1] - t0a, 1e-12)
                    fra = (a - t0a) / dta
                    Sa = L.S_hist[ea] + (L.S_hist[ea + 1]
                                         - L.S_hist[ea]) * fra
                    Ra = L.R_hist[ea] + (L.R_hist[ea + 1]
                                         - L.R_hist[ea]) * fra
                    den = R_re - Ra
                    frac = np.where(
                        den > _EPS_B,
                        (S_re - Sa) / np.maximum(den, _EPS_B), 0.0)
                    frac = np.clip(frac, 0.0, 1.0)
                    vre = np.minimum(g * (re - t0), vinc)
                    needV = Vprev + vre + B * (1.0 - frac)
                    needS = R_re
                    L.wait_V = np.concatenate([L.wait_V, needV])
                    L.wait_S = np.concatenate([L.wait_S, needS])
                    L.wait_re = np.concatenate([L.wait_re, re])
                    L.wait_req = np.concatenate([L.wait_req, rq])
            if len(L.wait_V):
                doneV = L.wait_V <= L.V + _EPS_B
                doneS = L.wait_S <= L.S + _EPS_B
                dm = doneV | doneS
                pos = int(dm.sum())
                if pos:
                    dre = L.wait_re[dm]
                    rate_s = sent / dte
                    tcV = np.where(doneV[dm],
                                   t0 + (L.wait_V[dm] - Vprev) / g, np.inf)
                    if rate_s > 0:
                        tcS = np.where(doneS[dm],
                                       t0 + (L.wait_S[dm] - Sprev) / rate_s,
                                       np.inf)
                    else:
                        tcS = np.where(doneS[dm], t1, np.inf)
                    tc = np.minimum(tcV, tcS)
                    tc = np.minimum(np.maximum(tc, dre), t1)
                    done_req.append(L.wait_req[dm])
                    done_t.append(tc)
                    L.n_done += pos
                    L.act -= pos
                    keep = ~dm
                    L.wait_V = L.wait_V[keep]
                    L.wait_S = L.wait_S[keep]
                    L.wait_re = L.wait_re[keep]
                    L.wait_req = L.wait_req[keep]
        if done_req:
            dr = np.concatenate(done_req)
            dtm = np.concatenate(done_t)
            np.maximum.at(self.tr_done, dr, dtm)
            np.subtract.at(self.flows_left, dr, 1)
            cand = np.unique(dr)
            fin = cand[self.flows_left[cand] == 0]
            if len(fin):
                ready = np.maximum(self.pf_done[fin], self.tr_done[fin])
                self._mark_ready(fin, ready, e)

    # ------------------------------------------------------------- control
    def _control(self, t1: float):
        sim = self.sim
        for hh, name in enumerate(sim._pd_names):
            row = self.pair_link[1 + hh]
            incident = [self.links[int(li)] for li in row[row >= 0]]
            sig = {"util": max((L.util_ewma for L in incident), default=0.0),
                   "queue_bytes": sum(L.backlog for L in incident),
                   "drops": sum(L.drops_w for L in incident),
                   "drops_total": sum(L.drops_total for L in incident),
                   "inflight": sum(L.act for L in incident)}
            sim.router.observe_congestion(sig, home=name)
        for name in (sim._pd_names if sim.autoscalers else ()):
            hh = sim._pd_names.index(name)
            tel = StageTelemetry(
                prefill_queue=int(self.pools[0].home_pending[hh])
                + self.pools[1 + hh].pending(),
                decode_queue=self.dec_pools[hh].pending(),
                cached_tokens=sim._route_tokens[name][0],
                routed_tokens=sim._route_tokens[name][1])
            new_sys = sim.autoscalers[name].maybe_rebalance(t1, tel)
            if new_sys is not None:
                self.pools[1 + hh].set_capacity(new_sys.n_p, t1)
                self.dec_pools[hh].set_capacity(
                    new_sys.n_d * sim.w.bs_max, t1)

    # ----------------------------------------------------------------- run
    def run(self) -> dict:
        sim = self.sim
        self._load_trace()
        N = self.N
        # decision + execution state (SoA)
        self.target = np.full(N, -1, np.int64)
        self.cached = np.zeros(N, np.int64)
        self.cache_cl = np.full(N, -1, np.int64)
        self.cross = np.zeros(N, bool)
        self.service = np.zeros(N, np.float64)
        self.wire_b = np.zeros(N, np.float64)
        self.cross_b = np.zeros(N, np.float64)
        self.pf_start = np.full(N, -1.0)
        self.pf_done = np.full(N, -1.0)
        self.tr_done = np.full(N, -1.0)
        self.flows_left = np.zeros(N, np.int32)
        self.ready_t = np.full(N, np.inf)
        self.dec_start = np.full(N, -1.0)
        self.ramp_q: Dict[int, list] = {}
        self.insert_q: Dict[int, list] = {}
        self.ready_q: Dict[int, list] = {}
        C = len(self.names)
        self.reach = np.zeros((self.k, C), bool)
        for hh, hname in enumerate(sim._pd_names):
            for c, cname in enumerate(self.names):
                self.reach[hh, c] = sim._match_eligible(hname, cname)
        self.cache = _VecCache(C, self.n_sess, sim.sim.pool_blocks,
                               sim.sim.block_tokens)
        self._build_links()
        self.warm_t = self.T * sim.sim.warmup_frac
        self.warm_ep = self._ep(self.warm_t) if self.warm_t > 0 else -1
        # pools: index 0 = PrfaaS hub, 1+h = regional PD-P
        self.pools = [_VecPool(sim.system.n_prfaas, n_homes=self.k)]
        for name, (n_p_c, _) in zip(sim._pd_names, sim._per_cluster):
            self.pools.append(_VecPool(n_p_c, n_homes=self.k))
        self.dec_pools = [
            _VecPool(n_d_c * sim.w.bs_max)
            for (_, n_d_c) in sim._per_cluster]
        self.decode_time = sim._decode_service_time()
        block_s = sim._block_s
        ctrl_dt = sim.sim.control_dt
        next_ctrl = ctrl_dt if ctrl_dt > 0 else math.inf
        ptr = 0
        for e in range(self.n_ep):
            t1 = float(self.edges[e + 1])
            self._apply_inserts(e)
            hi = int(np.searchsorted(self.arrival, t1, side="left")) \
                if e < self.n_ep - 1 else N
            if hi > ptr:
                self._route_batch(np.arange(ptr, hi, dtype=np.int64), e)
                ptr = hi
            until = math.inf if self.eager else t1
            for c in range(C):
                out = self.pools[c].process(until)
                self._handle_starts(out[0], out[1], out[2], e)
            self._links_epoch(e)
            if not self.eager:
                batch = self.ready_q.pop(e, None)
                if batch:
                    rt = np.concatenate([b[0] for b in batch])
                    ri = np.concatenate([b[1] for b in batch])
                    order = np.argsort(rt, kind="stable")
                    rt, ri = rt[order], ri[order]
                    if block_s > 0:
                        rt = np.ceil((rt - 1e-9) / block_s) * block_s
                    for hh in range(self.k):
                        m = self.home[ri] == hh
                        if m.any():
                            self.dec_pools[hh].extend(
                                rt[m], np.full(int(m.sum()),
                                               self.decode_time),
                                ri[m], np.zeros(int(m.sum()), np.int64))
                for hh in range(self.k):
                    out = self.dec_pools[hh].process(t1)
                    if len(out[0]):
                        self.dec_start[out[0]] = out[1]
            if t1 + 1e-9 >= next_ctrl:
                self._control(t1)
                while next_ctrl <= t1 + 1e-9:
                    next_ctrl += ctrl_dt
        # drain remaining scheduled inserts from the final epoch (cache
        # telemetry parity; routing is over so hit stats are unaffected)
        for e in sorted(self.insert_q):
            self._apply_inserts(e)
        if self.eager:
            self._decode_post_pass(block_s)
        return self._metrics()

    def _decode_post_pass(self, block_s: float):
        """Exact FIFO-c decode solve per home: legal because decode feeds
        back into nothing when autoscaling is off (queue depth is telemetry
        only)."""
        sim = self.sim
        self.dec_queue_end = [0] * self.k
        for hh in range(self.k):
            m = (self.home == hh) & np.isfinite(self.ready_t) \
                & (self.ready_t <= self.T + 1e-9)
            idx = np.where(m)[0]
            if len(idx) == 0:
                continue
            r = self.ready_t[idx]
            order = np.argsort(r, kind="stable")
            idx, r = idx[order], r[order]
            if block_s > 0:
                r = np.ceil((r - 1e-9) / block_s) * block_s
            cap = self.dec_pools[hh].capacity
            start = _fifo_lanes(r, cap, self.decode_time)
            ok = start <= self.T + 1e-9
            self.dec_start[idx[ok]] = start[ok]
            self.dec_queue_end[hh] = int((~ok).sum())

    # -------------------------------------------------------------- metrics
    def _metrics(self) -> dict:
        sim = self.sim
        cfg = sim.sim
        horizon = self.T
        t0 = horizon * cfg.warmup_frac
        window = max(1e-9, horizon - t0)
        started = self.dec_start >= 0
        done_t = np.where(started, self.dec_start + self.decode_time, -1.0)
        first = np.where(started, self.dec_start + sim.w.t_decode, -1.0)
        done = started & (done_t <= horizon) & (self.arrival >= t0)
        ttft = (first - self.arrival)[done & (first > 0)]
        tbt = (done_t - first)[done & (first > 0)] \
            / max(1, sim.w.output_len - 1)
        routed = int((self.target >= 0).sum())
        offload = int((self.target == 0).sum())
        slo = getattr(cfg, "ttft_slo_s", 0.0)

        def _pct(a, q):
            return float(np.percentile(a, q)) if len(a) else float("nan")

        def _slo_stats(tt):
            if slo <= 0:
                return 1.0, len(tt) / window
            good = int((tt <= slo).sum())
            return (good / len(tt) if len(tt) else float("nan"),
                    good / window)

        att, goodput = _slo_stats(ttft)
        if self.eager:
            dec_q = sum(getattr(self, "dec_queue_end", [0] * self.k))
        else:
            dec_q = sum(p.pending() for p in self.dec_pools)
        if self.eager:
            # queued-at-end == jobs whose exact start lies beyond horizon
            pf_q = int(((self.pf_start > self.T + 1e-9)
                        & (self.target >= 0)).sum())
            pf_q += sum(p.pending() for p in self.pools)
        else:
            pf_q = sum(p.pending() for p in self.pools)
        per_cluster = {}
        for hh, name in enumerate(sim._pd_names):
            cm = done & (self.home == hh)
            ct = (first - self.arrival)[cm & (first > 0)]
            c_att, c_good = _slo_stats(ct)
            cached, total = sim._route_tokens[name]
            if self.eager:
                c_pf = int(((self.pf_start > self.T + 1e-9)
                            & (self.target == 1 + hh)).sum()) \
                    + self.pools[1 + hh].pending()
                c_dec = getattr(self, "dec_queue_end", [0] * self.k)[hh]
            else:
                c_pf = self.pools[1 + hh].pending()
                c_dec = self.dec_pools[hh].pending()
            per_cluster[name] = {
                "completed": int(cm.sum()),
                "throughput_rps": int(cm.sum()) / window,
                "ttft_mean": float(ct.mean()) if len(ct) else float("nan"),
                "ttft_p90": _pct(ct, 90),
                "ttft_p99": _pct(ct, 99),
                "slo_attainment": c_att,
                "goodput_rps": c_good,
                "prefill_queue": c_pf,
                "decode_queue": c_dec,
                "threshold": sim.router.threshold_for(name),
                "cache_hit_frac": cached / total if total else 0.0,
                "conversions": len(sim.autoscalers[name].conversions)
                if name in sim.autoscalers else 0,
            }
        thresholds = {name: sim.router.threshold_for(name)
                      for name in sim._pd_names}
        sent_total = sum(L.S for L in self.links)
        egress0 = sum(L.sent_at_warmup for L in self.links) \
            if self.warm_ep >= 0 else 0.0
        links = {}
        for (a, b), L in zip(self.link_keys, self.links):
            links[f"{a}|{b}"] = {
                "sent_bytes": L.S, "capacity_gbps": L.capacity_bps / 1e9,
                "util_ewma": L.util_ewma, "busy_time": L.busy_time,
                "drops_total": L.drops_total, "drops": L.drops_w,
                "inflight": L.act}
        self._stamp_requests(first, done_t)
        return {
            "throughput_rps": int(done.sum()) / window,
            "ttft_mean": float(ttft.mean()) if len(ttft) else float("nan"),
            "ttft_p50": _pct(ttft, 50),
            "ttft_p90": _pct(ttft, 90),
            "ttft_p99": _pct(ttft, 99),
            "ttft_slo_s": slo,
            "slo_attainment": att,
            "goodput_rps": goodput,
            "tbt_mean": float(tbt.mean()) if len(tbt) else float("nan"),
            "tbt_p50": _pct(tbt, 50),
            "tbt_p90": _pct(tbt, 90),
            "tbt_p99": _pct(tbt, 99),
            "tbt_slo_s": getattr(cfg, "tbt_slo_s", 0.0),
            "tbt_attainment": (
                float((tbt <= cfg.tbt_slo_s).mean())
                if getattr(cfg, "tbt_slo_s", 0.0) > 0 and len(tbt) else 1.0),
            "completed": int(done.sum()),
            "offload_frac": offload / max(1, routed),
            "egress_gbps": (sent_total - egress0) * 8 / 1e9 / window,
            "link_util": max(L.util_ewma for L in self.links),
            "router_adjustments": sim.router.adjustments,
            "prefill_queue": pf_q,
            "decode_queue": dec_q,
            "cache": self.cache.stats(self.names),
            "threshold": max(thresholds.values()),
            "thresholds": thresholds,
            "session_evictions": sim.session_evictions,
            "open_sessions": len(sim._open_sessions),
            "clusters": per_cluster,
            "links": links,
            "engine": "vector",
            "n_requests": self.N,
        }

    def _stamp_requests(self, first, done_t):
        """Write results back into the Request objects when the trace came
        from the object path (tests / small runs introspect them); the SoA
        path skips this entirely."""
        if self.reqs is None or len(self.reqs) > 200_000:
            return
        from repro.core.router import RoutingDecision
        for i, r in enumerate(self.reqs):
            if self.target[i] >= 0:
                tname = self.names[self.target[i]]
                r.decision = RoutingDecision(
                    target=tname, cached_tokens=int(self.cached[i]),
                    incremental=max(0, int(self.total[i] - self.cached[i])),
                    cache_cluster=self.names[self.cache_cl[i]]
                    if self.cache_cl[i] >= 0 else tname,
                    cross_cache_transfer=bool(self.cross[i]),
                    home=sim_name(self.sim, int(self.home[i])))
            r.prefill_start = float(self.pf_start[i])
            r.prefill_done = float(self.pf_done[i])
            r.transfer_done = float(self.tr_done[i])
            if self.dec_start[i] >= 0:
                r.decode_start = float(self.dec_start[i])
                r.first_token = float(first[i])
                r.done = float(done_t[i])


def sim_name(sim, h: int) -> str:
    return sim._pd_names[h]


def run_vector(sim) -> dict:
    """Entry point: run ``sim`` through the vectorized engine."""
    eng = _VectorEngine(sim)
    sim._vector_state = eng                  # introspection for tests
    return eng.run()

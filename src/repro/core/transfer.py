"""Cross-datacenter KV transfer engine (paper §3.3): flow-level model of the
commodity-Ethernet inter-cluster link.

Models the three mechanisms the paper combines:
  * layer-wise prefill pipelining — a flow may start while its prefill is
    still computing (release curve = prefill progress), so transfer overlaps
    compute and only the tail is exposed;
  * multi-connection TCP — flows share the link by processor sharing
    (max-min fair); per-flow parallelism is folded into the fair share;
  * congestion monitoring — utilization / queue-depth / drop signals are
    exported each tick for the scheduler (§3.4.3 short-term loop).

Fluid simulation with fixed ticks; bandwidth fluctuation is an OU-like
mean-reverting multiplicative process (bursty links), seedable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclass
class Flow:
    flow_id: int
    total_bytes: float
    # layer-wise pipelining: bytes eligible for the wire at time t
    release: Callable[[float], float]
    on_done: Optional[Callable[[float], None]] = None
    sent: float = 0.0
    start_time: float = 0.0
    done_time: Optional[float] = None

    def backlog(self, now: float) -> float:
        return max(0.0, min(self.release(now), self.total_bytes) - self.sent)


class Link:
    """Fluid fair-share link with fluctuating capacity."""

    def __init__(self, capacity_bps: float, fluctuation: float = 0.0,
                 revert: float = 0.2, seed: int = 0):
        self.capacity_bps = capacity_bps          # bits/s nominal
        self.fluctuation = fluctuation            # rel. std of capacity
        self.revert = revert
        self._mult = 1.0
        self._rng = np.random.default_rng(seed)
        self.flows: Dict[int, Flow] = {}
        self._next_id = 0
        # telemetry for the scheduler
        self.util_ewma = 0.0
        self.queue_bytes = 0.0
        self.drops = 0
        self.sent_bytes = 0.0
        self.busy_time = 0.0

    # -------------------------------------------------------------- control
    def current_capacity(self) -> float:
        """bytes/s after fluctuation."""
        return self.capacity_bps * self._mult / 8.0

    def submit(self, total_bytes: float, now: float,
               release: Optional[Callable[[float], float]] = None,
               on_done: Optional[Callable[[float], None]] = None) -> Flow:
        if release is None:
            release = lambda t: total_bytes          # eager (no pipelining)
        f = Flow(self._next_id, total_bytes, release, on_done,
                 start_time=now)
        self._next_id += 1
        self.flows[f.flow_id] = f
        return f

    # ----------------------------------------------------------------- tick
    def tick(self, now: float, dt: float):
        # capacity fluctuation (mean-reverting log process)
        if self.fluctuation > 0:
            z = self._rng.standard_normal()
            logm = math.log(self._mult)
            logm += -self.revert * logm * dt \
                + self.fluctuation * math.sqrt(dt) * z
            self._mult = min(max(math.exp(logm), 0.3), 1.5)
        cap = self.current_capacity() * dt                   # bytes this tick
        active = [f for f in self.flows.values() if f.backlog(now) > 0]
        total_backlog = sum(f.backlog(now) for f in active)
        sent_this_tick = 0.0
        # processor sharing with redistribution of unused shares
        remaining = cap
        while active and remaining > 1e-9:
            share = remaining / len(active)
            nxt = []
            used = 0.0
            for f in active:
                take = min(f.backlog(now), share)
                f.sent += take
                used += take
                if f.backlog(now) > 0:
                    nxt.append(f)
            remaining -= used
            sent_this_tick += used
            if used <= 1e-12:
                break
            active = nxt
        # completions
        done = [f for f in self.flows.values()
                if f.sent >= f.total_bytes - 1e-6]
        for f in done:
            f.done_time = now + dt
            del self.flows[f.flow_id]
            if f.on_done:
                f.on_done(now + dt)
        # telemetry
        self.sent_bytes += sent_this_tick
        util = sent_this_tick / max(cap, 1e-9)
        self.util_ewma = 0.98 * self.util_ewma + 0.02 * util
        self.queue_bytes = max(0.0, total_backlog - sent_this_tick)
        if util > 0.999 and self.queue_bytes > 0:
            self.drops += 1                                  # congestion signal
        self.busy_time += dt * min(util, 1.0)

    # ------------------------------------------------------------ telemetry
    def congestion_signal(self) -> dict:
        return {"util": self.util_ewma, "queue_bytes": self.queue_bytes,
                "drops": self.drops,
                "inflight": len(self.flows)}


def layerwise_release(prefill_start: float, prefill_time: float,
                      total_bytes: float, n_layers: int = 64):
    """Release curve for layer-wise pipelined prefill: layer i's KV becomes
    wire-eligible when its compute finishes (staircase, ~linear ramp)."""

    def release(t: float) -> float:
        if prefill_time <= 0:
            return total_bytes
        frac = (t - prefill_start) / prefill_time
        steps = math.floor(max(0.0, min(1.0, frac)) * n_layers)
        return total_bytes * steps / n_layers

    return release

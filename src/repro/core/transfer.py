"""Cross-datacenter KV transfer engine (paper §3.3): flow-level model of the
commodity-Ethernet inter-cluster link.

Models the three mechanisms the paper combines:
  * layer-wise prefill pipelining — a flow may start while its prefill is
    still computing (release curve = prefill progress), so transfer overlaps
    compute and only the tail is exposed;
  * multi-connection TCP — flows share the link by processor sharing
    (max-min fair); per-flow parallelism is folded into the fair share;
  * congestion monitoring — utilization / queue-depth / drop signals are
    exported for the scheduler (§3.4.3 short-term loop).

Two integration modes over the same ``Link`` state:
  * ``tick(now, dt)`` — legacy fixed-step fluid draining (kept for the
    apples-to-apples equivalence test against the event engine);
  * ``advance(to)`` / ``next_event()`` — exact discrete-event solver.
    Between structural events the max-min fair allocation is computed by
    progressive filling (water-filling over per-flow release-rate caps),
    all rates are constant, and the next flow drain / ramp end / capacity
    resample time is found analytically — no bytes are drained per tick.
    Bandwidth fluctuation (an OU-like mean-reverting multiplicative
    process) is resampled on a coarse independent schedule (``fluct_dt``)
    so capacity is piecewise constant and the solve stays exact.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

_EPS_T = 1e-9          # time epsilon (s)
_EPS_B = 1e-6          # byte epsilon
_DROP_WINDOW_S = 30.0  # congestion-drop signal decay window


@dataclass
class Flow:
    flow_id: int
    total_bytes: float
    # layer-wise pipelining: bytes eligible for the wire at time t.  Either a
    # callable release curve (tick mode, arbitrary shape) or a linear ramp
    # [start_time, ramp_end] (event mode, exactly solvable).
    release: Optional[Callable[[float], float]] = None
    on_done: Optional[Callable[[float], None]] = None
    sent: float = 0.0
    start_time: float = 0.0
    ramp_end: Optional[float] = None
    done_time: Optional[float] = None

    def eligible(self, t: float) -> float:
        """Bytes allowed on the wire by time t (monotone, <= total).
        Nothing is eligible before the flow's start_time — a flow may be
        submitted ahead of the link clock (e.g. the deployment's virtual
        batches) and must not transfer bytes before it exists."""
        if t < self.start_time:
            return 0.0
        if self.ramp_end is not None:
            dur = self.ramp_end - self.start_time
            if dur <= 0.0:
                return self.total_bytes
            frac = (t - self.start_time) / dur
            return self.total_bytes * min(max(frac, 0.0), 1.0)
        if self.release is not None:
            return max(0.0, min(self.release(t), self.total_bytes))
        return self.total_bytes

    def release_rate(self, t: float) -> float:
        """d(eligible)/dt at time t — nonzero only on a linear ramp."""
        if self.ramp_end is None or t < self.start_time:
            return 0.0
        dur = self.ramp_end - self.start_time
        if dur <= 0.0 or t >= self.ramp_end - _EPS_T:
            return 0.0
        return self.total_bytes / dur

    def backlog(self, now: float) -> float:
        return max(0.0, self.eligible(now) - self.sent)


class Link:
    """Fair-share link with fluctuating capacity (fluid tick or exact event)."""

    def __init__(self, capacity_bps: float, fluctuation: float = 0.0,
                 revert: float = 0.2, seed: int = 0, fluct_dt: float = 0.25):
        self.capacity_bps = capacity_bps          # bits/s nominal
        self.fluctuation = fluctuation            # rel. std of capacity
        self.revert = revert
        self.fluct_dt = fluct_dt                  # event-mode resample period
        self._mult = 1.0
        self._rng = np.random.default_rng(seed)
        self.flows: Dict[int, Flow] = {}
        self._next_id = 0
        # event-mode clock + cached segment solution (rates are piecewise
        # constant between structural events, so absolute drain/completion
        # times are invariant until a flow joins/leaves/resamples)
        self.now = 0.0
        self._fluct_t = 0.0                       # last resample time
        self._seg_valid = False
        self._seg_rates: Dict[int, float] = {}
        self._seg_total = 0.0
        self._seg_backlogged = False
        self._seg_next = math.inf
        self._queue_stale = False
        # telemetry for the scheduler
        self.util_ewma = 0.0
        self.queue_bytes = 0.0
        self.drops_total = 0.0                    # cumulative congested "drops"
        self._drops_w = 0.0                       # windowed (decaying) drops
        self.sent_bytes = 0.0
        self.busy_time = 0.0

    # -------------------------------------------------------------- control
    def current_capacity(self) -> float:
        """bytes/s after fluctuation."""
        return self.capacity_bps * self._mult / 8.0

    @property
    def drops(self) -> float:
        """Windowed congestion-drop signal (decays over ~30 s) — NOT a
        monotonically growing counter; see ``drops_total`` for cumulative."""
        return self._drops_w

    def submit(self, total_bytes: float, now: float,
               release: Optional[Callable[[float], float]] = None,
               on_done: Optional[Callable[[float], None]] = None,
               ramp_end: Optional[float] = None) -> Flow:
        f = Flow(self._next_id, total_bytes, release, on_done,
                 start_time=now, ramp_end=ramp_end)
        self._next_id += 1
        self.flows[f.flow_id] = f
        self._seg_valid = False
        return f

    def _record_drops(self, n: float, dt: float):
        decay = math.exp(-dt / _DROP_WINDOW_S)
        self._drops_w = self._drops_w * decay + n
        self.drops_total += n

    def _fluct_step(self, dt: float):
        """One Euler step of the mean-reverting log-OU capacity multiplier."""
        z = self._rng.standard_normal()
        logm = math.log(self._mult)
        logm += -self.revert * logm * dt + self.fluctuation * math.sqrt(dt) * z
        self._mult = min(max(math.exp(logm), 0.3), 1.5)

    # ----------------------------------------------------------------- tick
    def tick(self, now: float, dt: float):
        """Legacy fixed-step fluid drain (engine="tick")."""
        if self.fluctuation > 0:
            self._fluct_step(dt)
        cap = self.current_capacity() * dt                   # bytes this tick
        active = [f for f in self.flows.values() if f.backlog(now) > 0]
        total_backlog = sum(f.backlog(now) for f in active)
        sent_this_tick = 0.0
        # processor sharing with redistribution of unused shares
        remaining = cap
        while active and remaining > 1e-9:
            share = remaining / len(active)
            nxt = []
            used = 0.0
            for f in active:
                take = min(f.backlog(now), share)
                f.sent += take
                used += take
                if f.backlog(now) > 0:
                    nxt.append(f)
            remaining -= used
            sent_this_tick += used
            if used <= 1e-12:
                break
            active = nxt
        # completions
        done = [f for f in self.flows.values()
                if f.sent >= f.total_bytes - 1e-6]
        for f in done:
            f.done_time = now + dt
            del self.flows[f.flow_id]
            if f.on_done:
                f.on_done(now + dt)
        # telemetry
        self.sent_bytes += sent_this_tick
        util = sent_this_tick / max(cap, 1e-9)
        self.util_ewma = 0.98 * self.util_ewma + 0.02 * util
        self.queue_bytes = max(0.0, total_backlog - sent_this_tick)
        congested = util > 0.999 and self.queue_bytes > 0
        self._record_drops(1.0 if congested else 0.0, dt)
        self.busy_time += dt * min(util, 1.0)
        self.now = now + dt

    # ---------------------------------------------------------- event solve
    def _fair_rates(self, t: float, cap_bps: float) -> Dict[int, float]:
        """Max-min fair rates by progressive filling (water-filling).

        Backlogged flows are greedy (uncapped); flows with no backlog but an
        active release ramp are paced at their release rate (their cap), and
        the unused share is redistributed to the rest.
        """
        entries = []
        for f in self.flows.values():
            backlog = f.eligible(t) - f.sent
            if backlog > _EPS_B:
                entries.append((math.inf, f))
            else:
                rr = f.release_rate(t)
                if rr > 0.0:
                    entries.append((rr, f))
        if not entries:
            return {}
        entries.sort(key=lambda e: e[0])
        rates: Dict[int, float] = {}
        remaining = cap_bps
        n = len(entries)
        for i, (cap, f) in enumerate(entries):
            share = remaining / (n - i)
            r = min(cap, share)
            rates[f.flow_id] = r
            remaining -= r
        return rates

    def _recompute_segment(self):
        """Solve the current fluid segment: fair rates at ``now`` plus the
        absolute time of the next structural change (a flow drains its
        eligible backlog and possibly completes, a release ramp ends, or
        the capacity resamples).  Valid until a flow joins/leaves or the
        structural time is reached."""
        t0 = self.now
        cap = self.current_capacity()
        rates = self._fair_rates(t0, cap)
        self._seg_rates = rates
        self._seg_total = sum(rates.values())
        self._seg_backlogged = False
        t = math.inf
        if self.fluctuation > 0:
            t = self._fluct_t + self.fluct_dt
        for f in self.flows.values():
            if f.start_time > t0 + _EPS_T:
                t = min(t, f.start_time)      # not-yet-started flow joins
                continue
            r = rates.get(f.flow_id, 0.0)
            rr = f.release_rate(t0)
            if f.ramp_end is not None and f.ramp_end > t0 + _EPS_T:
                t = min(t, f.ramp_end)
            backlog = f.eligible(t0) - f.sent
            if backlog > _EPS_B:
                self._seg_backlogged = True
                if r > rr + _EPS_B:
                    t = min(t, t0 + backlog / (r - rr))
        self._seg_next = t
        self._seg_valid = True

    def next_event(self) -> float:
        """Next time the event engine must wake the link (inf when idle)."""
        if not self.flows:
            return math.inf
        if not self._seg_valid:
            self._recompute_segment()
        return self._seg_next

    def _fire_completions(self):
        """Structural boundary at ``now``: invalidate the segment and fire
        on_done for every fully drained flow at the exact current time."""
        self._seg_valid = False
        done = [f for f in self.flows.values()
                if f.sent >= f.total_bytes - _EPS_B]
        for f in done:
            f.done_time = self.now
            del self.flows[f.flow_id]
        for f in done:
            if f.on_done:
                f.on_done(self.now)

    def _process_due_boundary(self):
        """A structural boundary lies within ``_EPS_T`` of the clock — a
        zero-length segment no positive-dt step can cross.  Snap the
        sub-epsilon residual backlogs the fair rates would drain in that
        instant (<= rate x eps bytes, by construction of the drain time)
        and fire completions.  Without this, a drain time landing inside
        the time epsilon livelocks the solver: ``next_event`` re-announces
        the same boundary ~1 ns ahead forever while the residual bytes
        never move."""
        for fid, r in self._seg_rates.items():
            f = self.flows.get(fid)
            if f is None:
                continue
            backlog = f.eligible(self.now) - f.sent
            if 0.0 < backlog <= max(r, 1.0) * (2.0 * _EPS_T):
                take = min(backlog, f.total_bytes - f.sent)
                f.sent += take
                self.sent_bytes += take
        self._fire_completions()

    def advance(self, to: float):
        """Exactly advance the fluid solution from ``self.now`` to ``to``,
        firing flow on_done callbacks at their exact completion times."""
        if not self.flows and self.fluctuation <= 0:
            # idle fast path (telemetry decays toward zero)
            if to <= self.now + _EPS_T:
                return
            self._telemetry_step(to - self.now, 0.0, congested=False)
            self.now = to
            return
        while True:
            if self.fluctuation > 0:
                boundary = self._fluct_t + self.fluct_dt
                if boundary <= self.now + _EPS_T:
                    self._fluct_step(self.fluct_dt)
                    self._fluct_t = boundary
                    self._seg_valid = False
                    continue
            if not self._seg_valid:
                self._recompute_segment()
            if self._seg_next <= self.now + _EPS_T:
                # zero-length segment: resolve it NOW (each pass strictly
                # removes its cause — drained backlog, expired ramp, or
                # started flow — so this cannot cycle)
                self._process_due_boundary()
                continue
            if to <= self.now + _EPS_T:
                break
            t_next = min(to, self._seg_next)
            dt = t_next - self.now
            if self._seg_rates:
                cap = self.current_capacity()
                for fid, r in self._seg_rates.items():
                    f = self.flows.get(fid)
                    if f is not None and r > 0.0:
                        f.sent = min(f.sent + r * dt, f.total_bytes)
                self.sent_bytes += self._seg_total * dt
                util = min(self._seg_total / max(cap, _EPS_B), 1.0)
                self._telemetry_step(
                    dt, util,
                    congested=(util >= 0.999 and self._seg_backlogged))
            else:
                self._telemetry_step(dt, 0.0, congested=False)
            self.now = t_next
            if t_next < self._seg_next - _EPS_T:
                break                 # mid-segment: solution still valid
            # structural boundary: completions fire exactly here
            self._fire_completions()
        self.now = max(self.now, to)
        self._queue_stale = True

    def run_until_idle(self, max_time: float = math.inf) -> float:
        """Drain all flows exactly; returns the time the link went idle."""
        while self.flows:
            t = self.next_event()
            if not math.isfinite(t) or t > max_time:
                break
            self.advance(t)
        return self.now

    def _telemetry_step(self, dt: float, util: float, congested: bool):
        # continuous-time EWMA with ~1 s time constant (the tick engine's
        # 0.98-per-20ms decay) so the router sees comparable signals;
        # congested fluid time converts to "drops" at the tick engine's
        # reference rate of one per 20 ms tick
        a = math.exp(-dt / 1.0)
        self.util_ewma = util + (self.util_ewma - util) * a
        self.busy_time += dt * util
        self._record_drops(dt / 0.02 if congested else 0.0, dt)

    def backlog_bytes(self) -> float:
        """Total bytes still owed by live flows (eligible or not): the
        conservation counterpart of ``sent_bytes`` — at any instant
        ``sent_bytes + backlog_bytes() == total bytes ever submitted``
        (within the solver's byte epsilon)."""
        return sum(f.total_bytes - f.sent for f in self.flows.values())

    # ------------------------------------------------------------ telemetry
    def congestion_signal(self) -> dict:
        if self._queue_stale:
            now = self.now
            self.queue_bytes = sum(f.backlog(now)
                                   for f in self.flows.values())
            self._queue_stale = False
        return {"util": self.util_ewma, "queue_bytes": self.queue_bytes,
                "drops": self._drops_w, "drops_total": self.drops_total,
                "inflight": len(self.flows)}


class LinkTopology:
    """N named clusters with one fair-share ``Link`` per connected unordered
    cluster pair (paper deployment story: one compute-dense PrfaaS cluster
    feeding several regional PD clusters over loosely coupled Ethernet).

    The topology is a thin routing matrix over independent ``Link`` solvers:
    each pair link keeps its own capacity, OU fluctuation process, and
    telemetry, so a congested PrfaaS->region-A link never slows region B.
    Pairs are unordered — a pair link carries both prefill KV egress and
    reverse cross-cache copies, exactly like the original single ``Link``
    (which makes a two-cluster topology bit-for-bit identical to it).

    ``advance``/``tick``/``next_event`` fan out to every member link so both
    simulator engines drive all links with one call; per-destination
    aggregation (``dest_signal``) gives the router the regional congestion
    view, while ``aggregate_signal`` preserves the legacy single-link
    telemetry shape for global control loops.
    """

    def __init__(self, clusters: List[str]):
        self.clusters = list(clusters)
        self._links: Dict[tuple, Link] = {}

    @staticmethod
    def _key(a: str, b: str) -> tuple:
        if a == b:
            raise ValueError(f"no self-link: {a!r}")
        return (a, b) if a < b else (b, a)

    @classmethod
    def build(cls, clusters: List[str], pairs: List[tuple],
              gbps, fluctuation=0.0, seed: int = 0,
              fluct_dt: float = 0.25) -> "LinkTopology":
        """Construct links for ``pairs``.  ``gbps``/``fluctuation`` may be
        scalars (shared) or per-pair sequences aligned with ``pairs``.  Link
        i is seeded ``seed + 7919*i`` so pair 0 of a single-pair topology
        reproduces a bare ``Link(seed=seed)`` exactly and additional links
        get independent fluctuation streams."""
        topo = cls(clusters)
        n = len(pairs)
        gbps_l = list(gbps) if hasattr(gbps, "__len__") else [gbps] * n
        fluct_l = (list(fluctuation) if hasattr(fluctuation, "__len__")
                   else [fluctuation] * n)
        if len(gbps_l) != n or len(fluct_l) != n:
            raise ValueError("per-pair gbps/fluctuation must match pairs")
        for i, (a, b) in enumerate(pairs):
            topo.add_link(a, b, Link(gbps_l[i] * 1e9,
                                     fluctuation=fluct_l[i],
                                     seed=seed + 7919 * i,
                                     fluct_dt=fluct_dt))
        return topo

    # ------------------------------------------------------------- wiring
    def add_link(self, a: str, b: str, link: Link):
        for c in (a, b):
            if c not in self.clusters:
                raise ValueError(f"unknown cluster {c!r}")
        self._links[self._key(a, b)] = link

    def link(self, a: str, b: str) -> Link:
        return self._links[self._key(a, b)]

    def has_link(self, a: str, b: str) -> bool:
        return a != b and self._key(a, b) in self._links

    def cache_reachable(self, home: str, name: str,
                        hub: str = "prfaas") -> bool:
        """Is cluster ``name``'s prefix cache usable by a request whose home
        is ``home``?  The home itself and the ``hub`` (PrfaaS) always are;
        another region only with pair links to BOTH possible prefill
        targets (home and hub) — a star-only topology cannot ship another
        region's cache anywhere useful.  The ONE reachability rule shared
        by the simulator and the live deployment (route agreement in
        ``launch.serve --cross-validate`` depends on it)."""
        if name == home or name == hub:
            return True
        return self.has_link(name, home) and self.has_link(name, hub)

    @property
    def links(self) -> Dict[tuple, Link]:
        return self._links

    # ----------------------------------------------------------- transfer
    def submit(self, a: str, b: str, total_bytes: float, now: float,
               **kw) -> Flow:
        """Charge a KV flow to the (a, b) pair link."""
        return self.link(a, b).submit(total_bytes, now, **kw)

    def advance(self, to: float):
        for link in self._links.values():
            link.advance(to)

    def tick(self, now: float, dt: float):
        for link in self._links.values():
            link.tick(now, dt)

    def next_event(self) -> float:
        return min((l.next_event() for l in self._links.values()),
                   default=math.inf)

    def run_until_idle(self, max_time: float = math.inf) -> float:
        """Drain all links exactly; returns the time the last one idled."""
        t = 0.0
        while True:
            nxt = self.next_event()
            if not math.isfinite(nxt) or nxt > max_time:
                return t
            self.advance(nxt)
            t = nxt

    # ------------------------------------------------------------ telemetry
    @property
    def sent_bytes(self) -> float:
        return sum(l.sent_bytes for l in self._links.values())

    def pair_signal(self, a: str, b: str) -> dict:
        return self.link(a, b).congestion_signal()

    def dest_signal(self, dst: str) -> dict:
        """Aggregate congestion toward ``dst`` over every incident link:
        worst-case util (one saturated ingress stalls that region), summed
        queues/drops/inflight."""
        incident = [l for (a, b), l in self._links.items() if dst in (a, b)]
        return self._aggregate(incident)

    def aggregate_signal(self) -> dict:
        """Topology-wide signal with the single-``Link`` telemetry shape
        (identical to that link's signal for a one-pair topology)."""
        return self._aggregate(list(self._links.values()))

    @staticmethod
    def _aggregate(links: List[Link]) -> dict:
        sigs = [l.congestion_signal() for l in links]
        if not sigs:
            return {"util": 0.0, "queue_bytes": 0.0, "drops": 0.0,
                    "drops_total": 0.0, "inflight": 0}
        return {"util": max(s["util"] for s in sigs),
                "queue_bytes": sum(s["queue_bytes"] for s in sigs),
                "drops": sum(s["drops"] for s in sigs),
                "drops_total": sum(s["drops_total"] for s in sigs),
                "inflight": sum(s["inflight"] for s in sigs)}

    def pair_backlogs(self) -> Dict[str, float]:
        """Per-pair live backlog (bytes still owed by in-flight flows) —
        with ``pair_stats()[pair]["sent_bytes"]`` this conserves the total
        bytes submitted to each pair link."""
        return {f"{a}|{b}": l.backlog_bytes()
                for (a, b), l in self._links.items()}

    def pair_stats(self) -> Dict[str, dict]:
        """Per-pair byte/utilization accounting for metrics and tests."""
        return {f"{a}|{b}": {"sent_bytes": l.sent_bytes,
                             "capacity_gbps": l.capacity_bps / 1e9,
                             "util_ewma": l.util_ewma,
                             "busy_time": l.busy_time,
                             "drops_total": l.drops_total,
                             "drops": l.drops,
                             "inflight": len(l.flows)}
                for (a, b), l in self._links.items()}


def star_pairs(hub: str, leaves: List[str],
               mesh: bool = False) -> List[tuple]:
    """Hub-and-spoke pair list (PrfaaS at the hub, one spoke per PD
    cluster), optionally adding the full leaf-to-leaf mesh so regional
    caches can cross-transfer without transiting the hub."""
    pairs = [(hub, leaf) for leaf in leaves]
    if mesh:
        pairs += [(a, b) for i, a in enumerate(leaves)
                  for b in leaves[i + 1:]]
    return pairs


def layerwise_release(prefill_start: float, prefill_time: float,
                      total_bytes: float, n_layers: int = 64):
    """Release curve for layer-wise pipelined prefill: layer i's KV becomes
    wire-eligible when its compute finishes (staircase, ~linear ramp).

    The event engine instead passes ``ramp_end`` to ``Link.submit`` (the
    fluid n_layers -> inf limit of this staircase), which solves exactly."""

    def release(t: float) -> float:
        if prefill_time <= 0:
            return total_bytes
        frac = (t - prefill_start) / prefill_time
        steps = math.floor(max(0.0, min(1.0, frac)) * n_layers)
        return total_bytes * steps / n_layers

    return release

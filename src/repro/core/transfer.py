"""Cross-datacenter KV transfer engine (paper §3.3): flow-level model of the
commodity-Ethernet inter-cluster link.

Models the three mechanisms the paper combines:
  * layer-wise prefill pipelining — a flow may start while its prefill is
    still computing (release curve = prefill progress), so transfer overlaps
    compute and only the tail is exposed;
  * multi-connection TCP — flows share the link by processor sharing
    (max-min fair); per-flow parallelism is folded into the fair share;
  * congestion monitoring — utilization / queue-depth / drop signals are
    exported for the scheduler (§3.4.3 short-term loop).

Two integration modes over the same ``Link`` state:
  * ``tick(now, dt)`` — legacy fixed-step fluid draining (kept for the
    apples-to-apples equivalence test against the event engine);
  * ``advance(to)`` / ``next_event()`` — exact discrete-event solver.
    Between structural events the max-min fair allocation is computed by
    progressive filling (water-filling over per-flow release-rate caps),
    all rates are constant, and the next flow drain / ramp end / capacity
    resample time is found analytically — no bytes are drained per tick.
    Bandwidth fluctuation (an OU-like mean-reverting multiplicative
    process) is resampled on a coarse independent schedule (``fluct_dt``)
    so capacity is piecewise constant and the solve stays exact.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

_EPS_T = 1e-9          # time epsilon (s)
_EPS_B = 1e-6          # byte epsilon
_DROP_WINDOW_S = 30.0  # congestion-drop signal decay window


@dataclass
class Flow:
    flow_id: int
    total_bytes: float
    # layer-wise pipelining: bytes eligible for the wire at time t.  Either a
    # callable release curve (tick mode, arbitrary shape) or a linear ramp
    # [start_time, ramp_end] (event mode, exactly solvable).
    release: Optional[Callable[[float], float]] = None
    on_done: Optional[Callable[[float], None]] = None
    sent: float = 0.0
    start_time: float = 0.0
    ramp_end: Optional[float] = None
    done_time: Optional[float] = None

    def eligible(self, t: float) -> float:
        """Bytes allowed on the wire by time t (monotone, <= total).
        Nothing is eligible before the flow's start_time — a flow may be
        submitted ahead of the link clock (e.g. the deployment's virtual
        batches) and must not transfer bytes before it exists."""
        if t < self.start_time:
            return 0.0
        if self.ramp_end is not None:
            dur = self.ramp_end - self.start_time
            if dur <= 0.0:
                return self.total_bytes
            frac = (t - self.start_time) / dur
            return self.total_bytes * min(max(frac, 0.0), 1.0)
        if self.release is not None:
            return max(0.0, min(self.release(t), self.total_bytes))
        return self.total_bytes

    def release_rate(self, t: float) -> float:
        """d(eligible)/dt at time t — nonzero only on a linear ramp."""
        if self.ramp_end is None or t < self.start_time:
            return 0.0
        dur = self.ramp_end - self.start_time
        if dur <= 0.0 or t >= self.ramp_end - _EPS_T:
            return 0.0
        return self.total_bytes / dur

    def backlog(self, now: float) -> float:
        return max(0.0, self.eligible(now) - self.sent)


class Link:
    """Fair-share link with fluctuating capacity (fluid tick or exact event)."""

    def __init__(self, capacity_bps: float, fluctuation: float = 0.0,
                 revert: float = 0.2, seed: int = 0, fluct_dt: float = 0.25):
        self.capacity_bps = capacity_bps          # bits/s nominal
        self.fluctuation = fluctuation            # rel. std of capacity
        self.revert = revert
        self.fluct_dt = fluct_dt                  # event-mode resample period
        self._mult = 1.0
        self._rng = np.random.default_rng(seed)
        self.flows: Dict[int, Flow] = {}
        self._next_id = 0
        # event-mode clock + cached segment solution (rates are piecewise
        # constant between structural events, so absolute drain/completion
        # times are invariant until a flow joins/leaves/resamples)
        self.now = 0.0
        self._fluct_t = 0.0                       # last resample time
        self._seg_valid = False
        self._seg_rates: Dict[int, float] = {}
        self._seg_total = 0.0
        self._seg_backlogged = False
        self._seg_next = math.inf
        self._queue_stale = False
        # telemetry for the scheduler
        self.util_ewma = 0.0
        self.queue_bytes = 0.0
        self.drops_total = 0.0                    # cumulative congested "drops"
        self._drops_w = 0.0                       # windowed (decaying) drops
        self.sent_bytes = 0.0
        self.busy_time = 0.0

    # -------------------------------------------------------------- control
    def current_capacity(self) -> float:
        """bytes/s after fluctuation."""
        return self.capacity_bps * self._mult / 8.0

    @property
    def drops(self) -> float:
        """Windowed congestion-drop signal (decays over ~30 s) — NOT a
        monotonically growing counter; see ``drops_total`` for cumulative."""
        return self._drops_w

    def submit(self, total_bytes: float, now: float,
               release: Optional[Callable[[float], float]] = None,
               on_done: Optional[Callable[[float], None]] = None,
               ramp_end: Optional[float] = None) -> Flow:
        f = Flow(self._next_id, total_bytes, release, on_done,
                 start_time=now, ramp_end=ramp_end)
        self._next_id += 1
        self.flows[f.flow_id] = f
        self._seg_valid = False
        return f

    def _record_drops(self, n: float, dt: float):
        decay = math.exp(-dt / _DROP_WINDOW_S)
        self._drops_w = self._drops_w * decay + n
        self.drops_total += n

    def _fluct_step(self, dt: float):
        """One Euler step of the mean-reverting log-OU capacity multiplier."""
        z = self._rng.standard_normal()
        logm = math.log(self._mult)
        logm += -self.revert * logm * dt + self.fluctuation * math.sqrt(dt) * z
        self._mult = min(max(math.exp(logm), 0.3), 1.5)

    # ----------------------------------------------------------------- tick
    def tick(self, now: float, dt: float):
        """Legacy fixed-step fluid drain (engine="tick")."""
        if self.fluctuation > 0:
            self._fluct_step(dt)
        cap = self.current_capacity() * dt                   # bytes this tick
        active = [f for f in self.flows.values() if f.backlog(now) > 0]
        total_backlog = sum(f.backlog(now) for f in active)
        sent_this_tick = 0.0
        # processor sharing with redistribution of unused shares
        remaining = cap
        while active and remaining > 1e-9:
            share = remaining / len(active)
            nxt = []
            used = 0.0
            for f in active:
                take = min(f.backlog(now), share)
                f.sent += take
                used += take
                if f.backlog(now) > 0:
                    nxt.append(f)
            remaining -= used
            sent_this_tick += used
            if used <= 1e-12:
                break
            active = nxt
        # completions
        done = [f for f in self.flows.values()
                if f.sent >= f.total_bytes - 1e-6]
        for f in done:
            f.done_time = now + dt
            del self.flows[f.flow_id]
            if f.on_done:
                f.on_done(now + dt)
        # telemetry
        self.sent_bytes += sent_this_tick
        util = sent_this_tick / max(cap, 1e-9)
        self.util_ewma = 0.98 * self.util_ewma + 0.02 * util
        self.queue_bytes = max(0.0, total_backlog - sent_this_tick)
        congested = util > 0.999 and self.queue_bytes > 0
        self._record_drops(1.0 if congested else 0.0, dt)
        self.busy_time += dt * min(util, 1.0)
        self.now = now + dt

    # ---------------------------------------------------------- event solve
    def _fair_rates(self, t: float, cap_bps: float) -> Dict[int, float]:
        """Max-min fair rates by progressive filling (water-filling).

        Backlogged flows are greedy (uncapped); flows with no backlog but an
        active release ramp are paced at their release rate (their cap), and
        the unused share is redistributed to the rest.
        """
        entries = []
        for f in self.flows.values():
            backlog = f.eligible(t) - f.sent
            if backlog > _EPS_B:
                entries.append((math.inf, f))
            else:
                rr = f.release_rate(t)
                if rr > 0.0:
                    entries.append((rr, f))
        if not entries:
            return {}
        entries.sort(key=lambda e: e[0])
        rates: Dict[int, float] = {}
        remaining = cap_bps
        n = len(entries)
        for i, (cap, f) in enumerate(entries):
            share = remaining / (n - i)
            r = min(cap, share)
            rates[f.flow_id] = r
            remaining -= r
        return rates

    def _recompute_segment(self):
        """Solve the current fluid segment: fair rates at ``now`` plus the
        absolute time of the next structural change (a flow drains its
        eligible backlog and possibly completes, a release ramp ends, or
        the capacity resamples).  Valid until a flow joins/leaves or the
        structural time is reached."""
        t0 = self.now
        cap = self.current_capacity()
        rates = self._fair_rates(t0, cap)
        self._seg_rates = rates
        self._seg_total = sum(rates.values())
        self._seg_backlogged = False
        t = math.inf
        if self.fluctuation > 0:
            t = self._fluct_t + self.fluct_dt
        for f in self.flows.values():
            if f.start_time > t0 + _EPS_T:
                t = min(t, f.start_time)      # not-yet-started flow joins
                continue
            r = rates.get(f.flow_id, 0.0)
            rr = f.release_rate(t0)
            if f.ramp_end is not None and f.ramp_end > t0 + _EPS_T:
                t = min(t, f.ramp_end)
            backlog = f.eligible(t0) - f.sent
            if backlog > _EPS_B:
                self._seg_backlogged = True
                if r > rr + _EPS_B:
                    t = min(t, t0 + backlog / (r - rr))
        self._seg_next = t
        self._seg_valid = True

    def next_event(self) -> float:
        """Next time the event engine must wake the link (inf when idle)."""
        if not self.flows:
            return math.inf
        if not self._seg_valid:
            self._recompute_segment()
        return self._seg_next

    def advance(self, to: float):
        """Exactly advance the fluid solution from ``self.now`` to ``to``,
        firing flow on_done callbacks at their exact completion times."""
        if to <= self.now + _EPS_T:
            return
        if not self.flows and self.fluctuation <= 0:
            # idle fast path (telemetry decays toward zero)
            self._telemetry_step(to - self.now, 0.0, congested=False)
            self.now = to
            return
        while self.now < to - _EPS_T:
            if self.fluctuation > 0:
                boundary = self._fluct_t + self.fluct_dt
                if boundary <= self.now + _EPS_T:
                    self._fluct_step(self.fluct_dt)
                    self._fluct_t = boundary
                    self._seg_valid = False
                    continue
            if not self._seg_valid:
                self._recompute_segment()
            t_next = min(to, max(self._seg_next, self.now + _EPS_T))
            dt = t_next - self.now
            if self._seg_rates:
                cap = self.current_capacity()
                for fid, r in self._seg_rates.items():
                    f = self.flows.get(fid)
                    if f is not None and r > 0.0:
                        f.sent = min(f.sent + r * dt, f.total_bytes)
                self.sent_bytes += self._seg_total * dt
                util = min(self._seg_total / max(cap, _EPS_B), 1.0)
                self._telemetry_step(
                    dt, util,
                    congested=(util >= 0.999 and self._seg_backlogged))
            else:
                self._telemetry_step(dt, 0.0, congested=False)
            self.now = t_next
            if t_next < self._seg_next - _EPS_T:
                break                 # mid-segment: solution still valid
            # structural boundary: completions fire exactly here
            self._seg_valid = False
            done = [f for f in self.flows.values()
                    if f.sent >= f.total_bytes - _EPS_B]
            for f in done:
                f.done_time = self.now
                del self.flows[f.flow_id]
            for f in done:
                if f.on_done:
                    f.on_done(self.now)
        self.now = max(self.now, to)
        self._queue_stale = True

    def run_until_idle(self, max_time: float = math.inf) -> float:
        """Drain all flows exactly; returns the time the link went idle."""
        while self.flows:
            t = self.next_event()
            if not math.isfinite(t) or t > max_time:
                break
            self.advance(t)
        return self.now

    def _telemetry_step(self, dt: float, util: float, congested: bool):
        # continuous-time EWMA with ~1 s time constant (the tick engine's
        # 0.98-per-20ms decay) so the router sees comparable signals;
        # congested fluid time converts to "drops" at the tick engine's
        # reference rate of one per 20 ms tick
        a = math.exp(-dt / 1.0)
        self.util_ewma = util + (self.util_ewma - util) * a
        self.busy_time += dt * util
        self._record_drops(dt / 0.02 if congested else 0.0, dt)

    # ------------------------------------------------------------ telemetry
    def congestion_signal(self) -> dict:
        if self._queue_stale:
            now = self.now
            self.queue_bytes = sum(f.backlog(now)
                                   for f in self.flows.values())
            self._queue_stale = False
        return {"util": self.util_ewma, "queue_bytes": self.queue_bytes,
                "drops": self._drops_w, "drops_total": self.drops_total,
                "inflight": len(self.flows)}


def layerwise_release(prefill_start: float, prefill_time: float,
                      total_bytes: float, n_layers: int = 64):
    """Release curve for layer-wise pipelined prefill: layer i's KV becomes
    wire-eligible when its compute finishes (staircase, ~linear ramp).

    The event engine instead passes ``ramp_end`` to ``Link.submit`` (the
    fluid n_layers -> inf limit of this staircase), which solves exactly."""

    def release(t: float) -> float:
        if prefill_time <= 0:
            return total_bytes
        frac = (t - prefill_start) / prefill_time
        steps = math.floor(max(0.0, min(1.0, frac)) * n_layers)
        return total_bytes * steps / n_layers

    return release

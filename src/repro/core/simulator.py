"""Cross-datacenter PrfaaS-PD cluster simulator (discrete-event core).

Ties every core component together under a realistic workload: bursty
(MMPP-modulated Poisson) arrivals, truncated log-normal lengths, agentic
sessions producing prefix-cache hits, a fluctuating inter-DC Ethernet
topology with layer-wise pipelined KV flows, the dual-timescale scheduler,
and the hybrid prefix cache pools.

Multi-cluster deployments (paper deployment story)
--------------------------------------------------
One compute-dense PrfaaS cluster feeds ``SimConfig.pd_clusters`` regional
PD clusters over a ``transfer.LinkTopology``: a star of independent
per-pair links (plus an optional PD<->PD mesh for cross-region cache
copies), skewed regional traffic shares (``pd_shares``), per-region
prefill/decode pools, and a home-cluster router — each request offloads to
PrfaaS, prefills locally, or reuses the best cache anywhere reachable,
charging the correct pair link.  ``pd_clusters=1`` (the default) is the
paper's two-cluster deployment and reproduces the original single-``Link``
simulator bit-for-bit on the same seed.

Regionalized control plane
--------------------------
Both control loops react to *regional* state rather than one global
signal:

  * short-term (router): every control epoch each home cluster observes
    its OWN aggregated congestion view (``LinkTopology.dest_signal``) and
    adjusts a per-home routing threshold — a congested region raises its
    offload bar alone while quiet regions keep routing normally.
  * long-term (autoscaler): each PD cluster runs its own ``Autoscaler``
    over its region-local (N_p,c, N_d,c), converting P<->D roles from
    per-region queue depths, pool utilizations, and the region's prefix
    cache-hit token fraction (``SimPrefixCache`` telemetry via routing
    decisions) — cached tokens cost no prefill compute, so hot agentic
    regions shed prefill capacity sooner.  Conversions resize only that
    region's pools and re-anchor only that home's threshold.

Session roaming (``SimConfig.roam_prob``)
-----------------------------------------
With probability ``roam_prob`` a continuing session re-arrives at a
DIFFERENT home region (sampled from the other clusters' traffic shares);
the session's cached prefix stays where it was produced, so the router's
best-cache-anywhere regime triggers a cross-region copy charged to the
correct PD<->PD mesh pair link (``pd_mesh_gbps``) — or falls back to a
cold prefill when no mesh link exists.  ``roam_prob=0`` (default) keeps
sessions pinned and the RNG stream identical to the pre-roaming
simulator.  Live sessions are tracked in an explicit bounded window
(``SimConfig.max_open_sessions``): overflowing sessions are evicted
oldest-first and counted (``metrics()["session_evictions"]``), never
silently dropped.

Event model (``SimConfig(engine="event")``, the default)
--------------------------------------------------------
A single priority-queue loop over exact event times — no fixed dt:

  * ARRIVAL       — pre-generated exact MMPP arrival trace (thinning over the
                    piecewise-constant rate, mean-preserving for any
                    burst_factor); routes and submits to a prefill pool.
  * PREFILL_DONE  — frees the prefill server, starts the next queued request,
                    and (with all KV flows drained) admits the request to
                    decode.
  * LINK wake     — every fair-share pair link is solved *exactly* between
                    events by progressive filling (``transfer.Link.advance``):
                    flow completion / layer-release ramp end / OU bandwidth
                    resample times are computed analytically.  KV flows
                    release layer-wise while prefill computes (linear ramp),
                    and cross-cache prefix copies are charged to the
                    owner<->target pair link.
  * DECODE_DONE   — frees a decode slot in the request's home cluster
                    (slot count = N_d,c x BS_max).
  * ADMIT         — (``decode_block_tokens`` > 0) a ready request deferred
                    to the next decode block boundary claims its slot; both
                    engines model the live ``RegionScheduler``'s admit-at-
                    block-boundary cadence, and decode holds slots for
                    whole blocks.  0 (default) = exact-time admission.
  * CONTROL       — every ``control_dt``: the router's short-term congestion
                    loop observes aggregated link telemetry, and the
                    autoscaler's long-term loop may convert P<->D roles
                    (epoch gating is the autoscaler's own ``period_s``).
  * WARMUP        — at t0 = warmup_frac x horizon: snapshots topology
                    sent-bytes so egress is reported over the same
                    measurement window as throughput.

``SimConfig(engine="tick")`` keeps the legacy fixed-step fluid loop (fed the
identical arrival trace) for apples-to-apples equivalence testing; the event
engine reproduces its metrics within a few percent while running one to two
orders of magnitude faster.

Wire compression and live cross-validation
------------------------------------------
``SystemConfig.kv_wire_compression`` (the measured int8 quantized/raw byte
ratio, see ``models.kvcache.wire_compression_ratio``) is applied at FLOW
granularity: every per-request prefill-KV flow and cross-cache copy
carries ``S_kv / ratio`` bytes, so link telemetry, congestion feedback,
and egress metrics all see the compressed stream.  ``inject_trace``
replays an external arrival trace — the live deployment's recorded
arrivals — through the simulator, which is how ``launch.serve
--cross-validate`` checks per-request route agreement between this policy
model and the actual ``serving.CrossDCDeployment`` (both drive the same
``core.router.Router`` over a ``core.transfer.LinkTopology``).

Produces the paper's §4.3 observables: throughput, mean/P90 TTFT, egress
bandwidth (including cross-cache transfer bytes), offload fraction, cache
hit rates, queue depths — globally and per PD cluster.
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.hardware import Profile
from repro.core.kv_manager import GlobalKVManager
from repro.core.sim_cache import SimPrefixCache
from repro.core.router import PD, PRFAAS, Router, RouterConfig, RoutingDecision
from repro.core.autoscaler import Autoscaler, AutoscalerConfig, StageTelemetry
from repro.core.throughput_model import SystemConfig, ThroughputModel
from repro.core.transfer import Link, LinkTopology, star_pairs
from repro.core.workload import Workload, mmpp_rate


@dataclass
class Request:
    rid: int
    arrival: float
    total_len: int
    session: int
    home: str = PD                # regional PD cluster serving this request
    # filled by routing / execution
    decision: Optional[RoutingDecision] = None
    prefill_start: float = -1.0
    prefill_done: float = -1.0
    transfer_done: float = -1.0
    decode_start: float = -1.0
    first_token: float = -1.0
    done: float = -1.0
    flows_pending: int = 0        # in-flight link flows gating decode
    _hashes: Optional[List[int]] = field(default=None, repr=False)

    def block_hashes(self, block_tokens: int) -> List[int]:
        if self._hashes is None:
            n = self.total_len // block_tokens
            # chained-hash stand-in: unique per (session, block index), no
            # per-block tuple allocation (hot path: ~400 blocks/request)
            base = (self.session * 0x9E3779B97F4A7C15) & 0x7FFFFFFFFFFFFFFF
            self._hashes = [(base + i * 0x9E3779B1) & 0x7FFFFFFFFFFFFFFF
                            for i in range(n)]
        return self._hashes


class InstancePool:
    """N identical single-request servers with one FIFO queue (tick engine)."""

    def __init__(self, n: int):
        self.capacity = n
        self.busy: List[float] = []          # end times
        self.queue: deque = deque()          # (req, service_time)
        self.busy_time = 0.0
        self.cap_time = 0.0                  # time-integrated capacity

    def submit(self, req, service_time: float):
        self.queue.append((req, service_time))

    def tick(self, now: float, dt: float, on_start, admit: bool = True):
        self.busy = [t for t in self.busy if t > now]
        if admit:
            while self.queue and len(self.busy) < self.capacity:
                req, st = self.queue.popleft()
                self.busy.append(now + st)
                on_start(req, now, now + st)
        self.busy_time += dt * len(self.busy)
        self.cap_time += dt * max(1, self.capacity)

    def utilization(self, elapsed: float) -> float:
        # capacity is integrated over time (cap_time), so a mid-run resize
        # does not rewrite the history of earlier, differently-sized epochs
        return self.busy_time / max(1e-9, self.cap_time)


class DecodePool(InstancePool):
    """n_d instances x BS_max slots; a request holds a slot for its decode."""


class EventPool:
    """FIFO server pool for the event engine: exact start/finish times, no
    per-tick scans.  ``submit`` returns True when the item starts now;
    otherwise it queues until ``release`` or a capacity increase frees it."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.busy = 0
        self.queue: deque = deque()
        self.busy_time = 0.0
        self.cap_time = 0.0                  # time-integrated capacity
        self._last = 0.0

    def _integrate(self, now: float):
        self.busy_time += (now - self._last) * self.busy
        self.cap_time += (now - self._last) * max(1, self.capacity)
        self._last = now

    def submit(self, item, now: float) -> bool:
        self._integrate(now)
        if self.busy < self.capacity:
            self.busy += 1
            return True
        self.queue.append(item)
        return False

    def release(self, now: float):
        """Free one server; returns the next queued item to start (or None)."""
        self._integrate(now)
        self.busy -= 1
        if self.queue and self.busy < self.capacity:
            self.busy += 1
            return self.queue.popleft()
        return None

    def set_capacity(self, capacity: int, now: float) -> list:
        """Resize; returns queued items that can start immediately."""
        self._integrate(now)
        self.capacity = capacity
        started = []
        while self.queue and self.busy < self.capacity:
            self.busy += 1
            started.append(self.queue.popleft())
        return started

    def utilization(self, elapsed: float) -> float:
        """Busy fraction up to ``elapsed`` (== now; pools start at t=0).
        Integrates pending busy time first so mid-interval reads are
        current.  The denominator is capacity integrated over time, so a
        mid-run ``set_capacity`` changes only the epochs it governs instead
        of retroactively rescaling the whole history."""
        self._integrate(elapsed)
        return self.busy_time / max(1e-9, self.cap_time)


@dataclass
class SimConfig:
    arrival_rate: float                 # req/s offered (global, all regions)
    sim_time: float = 1800.0
    dt: float = 0.02                    # tick engine step
    seed: int = 0
    link_gbps: float = 100.0
    link_fluctuation: float = 0.0
    pool_blocks: int = 200_000          # per-cluster prefix pool blocks
    block_tokens: int = 64
    autoscale: bool = False
    warmup_frac: float = 0.1            # exclude from metrics
    engine: str = "event"               # "event" | "tick" | "vector" (SoA)
    control_dt: float = 0.25            # event engine: telemetry/control loop
    fluct_dt: float = 0.25              # event engine: OU resample period
    # vector engine epoch length (0 -> control_dt).  Larger epochs trade
    # control-loop granularity for speed at million-request scale.
    vector_dt: float = 0.0
    # TTFT SLO for goodput/attainment metrics (0 = off: attainment reports
    # 1.0 and goodput equals throughput, keeping the keys JSON-stable)
    ttft_slo_s: float = 0.0
    # TBT SLO (mean time-between-tokens per request); 0 = off, same
    # JSON-stable convention as ttft_slo_s
    tbt_slo_s: float = 0.0
    # mean accepted DRAFT tokens per verify dispatch from the live
    # speculative decoder (accepted_tokens_per_dispatch - 1).  > 0 scales
    # decode service time by 1/(1+rate); 0 keeps the golden exact path
    spec_accept_rate: float = 0.0
    # -- multi-cluster topology (1 = the paper's two-cluster deployment) ----
    pd_clusters: int = 1                # regional PD clusters fed by PrfaaS
    pd_shares: Optional[Tuple[float, ...]] = None   # regional traffic shares
    pd_link_gbps: Optional[Tuple[float, ...]] = None  # per-region star links
    pd_link_fluct: Optional[Tuple[float, ...]] = None
    pd_mesh_gbps: float = 0.0           # PD<->PD links (0 = star only)
    # -- regionalized control plane -----------------------------------------
    roam_prob: float = 0.0              # P(continuing session switches home)
    max_open_sessions: int = 512        # live-session window (explicit evict)
    # -- continuous-batching fidelity ---------------------------------------
    # > 0: decode admission happens only at block boundaries (every
    # decode_block_tokens * Workload.t_decode seconds), matching the live
    # RegionScheduler's step_block cadence, and decode service time is
    # rounded up to whole blocks.  0 (default) keeps the legacy exact-time
    # admission — byte-identical traces, golden tests untouched.
    decode_block_tokens: int = 0


# event kinds, ordered so ties process deterministically
(_EV_ARRIVAL, _EV_PREFILL_DONE, _EV_DECODE_DONE, _EV_CONTROL, _EV_LINK,
 _EV_WARMUP, _EV_ADMIT) = range(7)


class PrfaasSimulator:
    def __init__(self, model: ThroughputModel, system: SystemConfig,
                 workload: Workload, sim: SimConfig,
                 router_cfg: Optional[RouterConfig] = None):
        self.model = model
        self.system = system
        self.w = workload
        self.sim = sim
        self.rng = np.random.default_rng(sim.seed)

        # -- regional PD clusters, traffic shares, link topology ------------
        k = sim.pd_clusters
        if k < 1:
            raise ValueError("pd_clusters must be >= 1")
        if not 0.0 <= sim.roam_prob <= 1.0:
            raise ValueError(f"roam_prob {sim.roam_prob} not in [0, 1]")
        if sim.max_open_sessions < 1:
            raise ValueError("max_open_sessions must be >= 1")
        self._pd_names = [PD] if k == 1 else [f"pd{i}" for i in range(k)]
        shares = sim.pd_shares if sim.pd_shares is not None \
            else tuple([1.0 / k] * k)
        if len(shares) != k or min(shares) < 0 or sum(shares) <= 0:
            raise ValueError(f"pd_shares {shares} invalid for {k} clusters")
        self._shares = [s / sum(shares) for s in shares]
        if system.n_p_clusters is not None \
                and len(system.n_p_clusters) != k:
            raise ValueError("SystemConfig per-cluster tuples must match "
                             "SimConfig.pd_clusters")
        self._per_cluster = system.per_cluster(k)   # [(n_p, n_d) per region]

        self.router = Router(model, system, router_cfg)
        self.kv = GlobalKVManager()
        self.kv.register_cluster(
            PRFAAS, SimPrefixCache(sim.pool_blocks, sim.block_tokens),
            nodes=max(1, system.n_prfaas))
        for name, (n_p_c, n_d_c) in zip(self._pd_names, self._per_cluster):
            self.kv.register_cluster(
                name, SimPrefixCache(sim.pool_blocks, sim.block_tokens),
                nodes=max(1, n_p_c + n_d_c))
        self.topology = self._build_topology()
        self.prfaas_pool = InstancePool(system.n_prfaas)
        self.pdp_pools: Dict[str, InstancePool] = {
            name: InstancePool(n_p_c)
            for name, (n_p_c, _) in zip(self._pd_names, self._per_cluster)}
        self.decode_pools: Dict[str, InstancePool] = {
            name: DecodePool(n_d_c * workload.bs_max)
            for name, (_, n_d_c) in zip(self._pd_names, self._per_cluster)}
        # per-region long-term loop: one autoscaler per PD cluster, each
        # governing its region-local (n_p_c, n_d_c) and that home's routing
        # threshold.  The shared PrfaaS cluster is scaled by the region's
        # traffic share (region c consumes s_c of the offloaded stream), so
        # the region-local model — imbalance detection AND the post-
        # conversion threshold re-optimization — sees only its slice
        # instead of crediting the full hub to every region.
        self.autoscalers: Dict[str, Autoscaler] = {}
        if sim.autoscale:
            for name, share, (n_p_c, n_d_c) in zip(
                    self._pd_names, self._shares, self._per_cluster):
                n_prfaas_r = max(1, round(share * system.n_prfaas)) \
                    if system.n_prfaas else 0
                region_sc = SystemConfig(n_prfaas_r, n_p_c, n_d_c,
                                         share * system.b_out,
                                         system.threshold)
                self.autoscalers[name] = Autoscaler(
                    model, self.router, region_sc, home=name)

        self.completed: List[Request] = []
        self.all_requests: List[Request] = []
        self._next_rid = 0
        self._next_session = 0
        # (session_id, cur_len, home); window of live sessions with EXPLICIT
        # oldest-first eviction (counted) once max_open_sessions is exceeded
        self._open_sessions: deque = deque()
        self.session_evictions = 0
        # per-home (cached, total) routed token counters -> cache_hit_frac
        # telemetry for the session-aware long-term loop
        self._route_tokens: Dict[str, List[int]] = {
            name: [0, 0] for name in self._pd_names}
        self._egress_t0 = 0.0         # topology sent-bytes at warmup end
        # int8 KV on the wire, at flow granularity: every per-request link
        # flow (prefill KV and cross-cache copies) carries S_kv divided by
        # the measured quantized/raw ratio (SystemConfig.kv_wire_compression,
        # 1.0 = off -> byte-identical to the uncompressed simulator)
        if system.kv_wire_compression < 1.0:
            raise ValueError("kv_wire_compression must be >= 1.0 "
                             f"(got {system.kv_wire_compression})")
        self._wire_comp = system.kv_wire_compression
        # external arrival trace (policy/actual cross-validation): replaces
        # the generated MMPP trace when set via ``inject_trace``
        self._external_trace: Optional[List[Request]] = None
        # SoA trace for the vector engine (``inject_soa_trace``)
        self._soa_trace = None
        # continuous-batching fidelity: decode admission quantized to the
        # live scheduler's step_block cadence (0 = legacy exact-time)
        if sim.decode_block_tokens < 0:
            raise ValueError("decode_block_tokens must be >= 0")
        self._block_s = sim.decode_block_tokens * workload.t_decode

    def _build_topology(self) -> LinkTopology:
        """Star topology PrfaaS->each region (+ optional PD mesh).  The
        single-region star is one pair seeded ``sim.seed`` — identical to
        the original bare ``Link``."""
        sim, k = self.sim, self.sim.pd_clusters
        star = star_pairs(PRFAAS, self._pd_names, mesh=sim.pd_mesh_gbps > 0)
        n_star = k
        gbps = list(sim.pd_link_gbps) if sim.pd_link_gbps is not None \
            else [sim.link_gbps] * n_star
        fluct = list(sim.pd_link_fluct) if sim.pd_link_fluct is not None \
            else [sim.link_fluctuation] * n_star
        if len(gbps) != n_star or len(fluct) != n_star:
            raise ValueError("pd_link_gbps/pd_link_fluct must have one entry "
                             "per PD cluster")
        n_mesh = len(star) - n_star
        gbps += [sim.pd_mesh_gbps] * n_mesh
        fluct += [sim.link_fluctuation] * n_mesh
        return LinkTopology.build([PRFAAS] + self._pd_names, star, gbps,
                                  fluctuation=fluct, seed=sim.seed,
                                  fluct_dt=sim.fluct_dt)

    # ------------------------------------------------- two-cluster aliases
    # The classic deployment has one PD cluster; these aliases keep the
    # original single-cluster attribute API (tests, notebooks) working.
    @property
    def link(self) -> Link:
        return self.topology.link(PRFAAS, self._pd_names[0])

    @property
    def pdp_pool(self):
        return self.pdp_pools[self._pd_names[0]]

    @pdp_pool.setter
    def pdp_pool(self, pool):
        self.pdp_pools[self._pd_names[0]] = pool

    @property
    def decode_pool(self):
        return self.decode_pools[self._pd_names[0]]

    @decode_pool.setter
    def decode_pool(self, pool):
        self.decode_pools[self._pd_names[0]] = pool

    @property
    def autoscaler(self) -> Optional[Autoscaler]:
        """First region's autoscaler (the single-cluster autoscaler in the
        classic deployment); None when autoscaling is off."""
        if not self.autoscalers:
            return None
        return self.autoscalers[self._pd_names[0]]

    # ------------------------------------------------------------- arrivals
    def _arrival_rate(self, now: float) -> float:
        return mmpp_rate(self.sim.arrival_rate, self.w.burst_factor,
                         self.w.burst_period_s, now)

    def _sample_home(self, exclude: Optional[str] = None) -> str:
        """Regional origin of a new session, skewed by pd_shares.  The
        single-cluster case draws nothing, keeping the RNG stream (and thus
        the whole trajectory) identical to the pre-topology simulator.
        ``exclude`` (session roaming) renormalizes the shares over the
        OTHER regions so a roaming session always changes home."""
        if len(self._pd_names) == 1:
            return self._pd_names[0]
        if exclude is None:
            i = int(self.rng.choice(len(self._pd_names), p=self._shares))
            return self._pd_names[i]
        names = [n for n in self._pd_names if n != exclude]
        w = [s for n, s in zip(self._pd_names, self._shares) if n != exclude]
        tot = sum(w)
        p = [x / tot for x in w] if tot > 0 else None   # uniform fallback
        return names[int(self.rng.choice(len(names), p=p))]

    def _new_request(self, now: float) -> Request:
        if (self._open_sessions
                and self.rng.random() < self.w.session_prob):
            i = self.rng.integers(len(self._open_sessions))
            sid, cur, home = self._open_sessions[i]
            grow = int(self.rng.exponential(self.w.session_growth)) + 1
            total = min(cur + grow, int(self.w.lengths.hi))
            # session roaming: the user re-appears in a different region;
            # the cached prefix stays at the old home, so the router's
            # best-cache-anywhere regime charges a cross-region mesh copy.
            # Guarded draws keep the roam_prob=0 RNG stream untouched.
            if (self.sim.roam_prob > 0 and len(self._pd_names) > 1
                    and self.rng.random() < self.sim.roam_prob):
                home = self._sample_home(exclude=home)
            self._open_sessions[i] = (sid, total, home)
        else:
            sid = self._next_session
            self._next_session += 1
            total = int(self.w.lengths.sample(self.rng, 1)[0])
            home = self._sample_home()
            self._open_sessions.append((sid, total, home))
            # explicit live-session window: evict oldest-first and COUNT it
            # (a deque(maxlen=...) dropped live sessions silently, invisibly
            # skewing session_prob reuse under high arrival rates)
            while len(self._open_sessions) > self.sim.max_open_sessions:
                self._open_sessions.popleft()
                self.session_evictions += 1
        r = Request(self._next_rid, now, total, sid, home=home)
        self._next_rid += 1
        self.all_requests.append(r)
        return r

    def inject_trace(self, entries) -> List[Request]:
        """Replay an EXTERNAL arrival trace instead of generating one —
        the policy/actual cross-validation path (``launch.serve
        --cross-validate``): the live deployment's recorded arrivals
        ``(arrival_s, total_len, session_id, home)`` are replayed through
        the simulator so per-request routing decisions can be compared.
        Entries must be sorted by arrival time; homes must name existing
        PD clusters.  Returns the created simulator ``Request``s (in trace
        order, matching the live run's request order)."""
        reqs: List[Request] = []
        prev = -math.inf
        for arrival, total_len, session, home in entries:
            if home not in self._pd_names:
                raise ValueError(f"unknown home cluster {home!r}; "
                                 f"expected one of {self._pd_names}")
            if arrival < prev:
                raise ValueError("trace entries must be sorted by arrival")
            prev = arrival
            reqs.append(Request(self._next_rid, float(arrival),
                                int(total_len), int(session), home=home))
            self._next_rid += 1
        self._external_trace = reqs
        return reqs

    def inject_soa_trace(self, trace):
        """Feed a ``workload.Trace`` (SoA columns) directly to the vector
        engine — no per-request Python objects are materialized, which is
        what makes 1e6+ request runs single-digit seconds.  Other engines
        replay it through ``inject_trace`` (object path)."""
        if self.sim.engine == "vector":
            self._soa_trace = trace
            return None
        return self.inject_trace(trace.to_entries())

    def _generate_arrivals(self) -> List[Request]:
        """Exact MMPP arrival trace via thinning over the piecewise-constant
        rate — both engines consume the identical trace, so equivalence
        differences come from time discretization only.  An injected
        external trace (``inject_trace``) takes precedence."""
        if self._external_trace is not None:
            self.all_requests.extend(self._external_trace)
            return list(self._external_trace)
        sim, w = self.sim, self.w
        out: List[Request] = []
        lam_max = sim.arrival_rate * max(w.burst_factor, 1.0)
        if lam_max <= 0:
            return out
        t = 0.0
        while True:
            t += self.rng.exponential(1.0 / lam_max)
            if t >= sim.sim_time:
                return out
            lam = self._arrival_rate(t)
            if lam < lam_max and self.rng.random() * lam_max > lam:
                continue                             # thinned
            out.append(self._new_request(t))

    # ---------------------------------------------------- shared byte model
    def _wire_profile(self) -> Profile:
        return self.model.prfaas_profile or self.model.pd_profile

    def _prefill_wire_bytes(self, req: Request) -> float:
        """KV bytes for a PrfaaS-prefilled request crossing the link (the
        already-cached prefix need not be resent), after int8 wire
        compression (``SystemConfig.kv_wire_compression``)."""
        prof = self._wire_profile()
        nbytes = prof.s_kv(req.total_len)
        if req.decision.cached_tokens:
            nbytes -= prof.s_kv(req.decision.cached_tokens)
        return max(nbytes / self._wire_comp, 1.0)

    def _cross_cache_bytes(self, decision: RoutingDecision) -> float:
        """Cached-prefix KV bytes copied between clusters when the router
        reuses the best cache anywhere (abundant-bandwidth regime) — also
        compressed on the wire."""
        return max(self._wire_profile().s_kv(decision.cached_tokens)
                   / self._wire_comp, 1.0)

    def _match_eligible(self, home: str, name: str) -> bool:
        """Shared reachability rule: ``LinkTopology.cache_reachable``."""
        return self.topology.cache_reachable(home, name, hub=PRFAAS)

    def _prefill_pool(self, cluster: str):
        return self.prfaas_pool if cluster == PRFAAS \
            else self.pdp_pools[cluster]

    # -------------------------------------------- decode block granularity
    def _block_boundary(self, t: float) -> float:
        """Next decode block boundary at or after ``t`` (t itself when it
        lies on one, or always when block granularity is off)."""
        if self._block_s <= 0:
            return t
        return math.ceil((t - 1e-9) / self._block_s) * self._block_s

    def _decode_service_time(self) -> float:
        """Per-request decode slot hold time; with block granularity on,
        the slot is held for whole blocks (output_len rounded up)."""
        n = self.w.output_len
        b = self.sim.decode_block_tokens
        if b > 0:
            n = -(-n // b) * b
        t = n * self.w.t_decode
        # speculative decode emits (1 + accept_rate) tokens per dispatch on
        # average; the guard keeps rate = 0 byte-identical to the pre-spec
        # golden path
        if self.sim.spec_accept_rate > 0:
            t /= 1.0 + self.sim.spec_accept_rate
        return t

    def _route(self, req: Request) -> Tuple[str, float]:
        n_blocks = req.total_len // self.sim.block_tokens
        matches = {name: c.match(req.session, n_blocks)
                   for name, c in self.kv.clusters.items()
                   if self._match_eligible(req.home, name)}
        decision = self.router.route(
            req.total_len, matches,
            self.topology.pair_signal(PRFAAS, req.home), home=req.home)
        req.decision = decision
        acc = self._route_tokens[req.home]
        acc[0] += decision.cached_tokens
        acc[1] += req.total_len
        incr = max(decision.incremental, 1)
        if decision.target == PRFAAS:
            return PRFAAS, self.model.prfaas_profile.t_prefill(incr)
        return decision.target, self.model.pd_profile.t_prefill(incr)

    # ------------------------------------------------ regional control plane
    def _observe_regions(self):
        """Short-term loop: each home adjusts its OWN routing threshold from
        its own aggregated link view (``dest_signal``).  For one PD cluster
        the regional view IS the single pair link, reproducing the legacy
        global loop exactly."""
        for name in self._pd_names:
            self.router.observe_congestion(self.topology.dest_signal(name),
                                           home=name)

    def _region_telemetry(self, name: str,
                          util_now: Optional[float] = None) -> StageTelemetry:
        """Per-region long-term telemetry: the region's own prefill/decode
        queues (requests queued at PrfaaS attributed by home), pool
        utilizations (event engine), and the home's cumulative routed/
        cached token counters (prefix-cache telemetry; the autoscaler
        windows them over its own evaluation period)."""
        pq = sum(1 for item in self.prfaas_pool.queue
                 if item[0].home == name)
        pq += len(self.pdp_pools[name].queue)
        cached, total = self._route_tokens[name]
        tel = StageTelemetry(
            prefill_queue=pq,
            decode_queue=len(self.decode_pools[name].queue),
            # cumulative counters: the autoscaler windows them per period
            cached_tokens=cached, routed_tokens=total)
        if util_now is not None:
            tel.prefill_util = self.pdp_pools[name].utilization(
                max(util_now, 1e-9))
            tel.decode_util = self.decode_pools[name].utilization(
                max(util_now, 1e-9))
        return tel

    # ----------------------------------------------------------------- run
    def run(self) -> dict:
        if self.sim.engine == "tick":
            return self._run_tick()
        if self.sim.engine == "vector":
            from repro.core.vector_engine import run_vector
            return run_vector(self)
        if self.sim.engine != "event":
            raise ValueError(f"unknown engine {self.sim.engine!r}; "
                             "expected 'event', 'tick', or 'vector'")
        return self._run_event()

    # ---------------------------------------------------------- tick engine
    def _route_and_submit_tick(self, req: Request, now: float):
        cluster, st = self._route(req)
        self._prefill_pool(cluster).submit(req, st)

    def _submit_request_flows(self, req: Request, cluster: str, now: float,
                              done: float, on_all_done=None):
        """Submit this request's link flows (main KV + cross-cache copy) to
        the correct pair links and wire their completion into the request's
        readiness state.  ``on_all_done(req, tc)`` fires when the LAST flow
        drains, at its exact completion time (event engine decode
        admission)."""
        req.flows_pending = 0

        def on_flow_done(tc: float, _req=req):
            _req.flows_pending -= 1
            _req.transfer_done = max(_req.transfer_done, tc)
            if _req.flows_pending == 0 and on_all_done is not None:
                on_all_done(_req, tc)

        if cluster == PRFAAS:
            # layer-wise pipelined KV flow to the request's home region:
            # releases linearly while prefill computes (the fluid limit of
            # the per-layer staircase)
            self.topology.submit(PRFAAS, req.home,
                                 self._prefill_wire_bytes(req), now,
                                 ramp_end=done, on_done=on_flow_done)
            req.flows_pending += 1
        if req.decision.cross_cache_transfer and req.decision.cached_tokens:
            # cached prefix lives in another cluster: the copy is already
            # materialized, so it is wire-eligible immediately (eager),
            # charged to the owner<->target pair link
            self.topology.submit(req.decision.cache_cluster,
                                 req.decision.target,
                                 self._cross_cache_bytes(req.decision), now,
                                 ramp_end=now, on_done=on_flow_done)
            req.flows_pending += 1
        if req.flows_pending == 0:
            req.transfer_done = done      # intra-cluster RDMA: free

    def _on_prefill_start(self, cluster: str):
        def cb(req: Request, now: float, done: float):
            req.prefill_start = now
            req.prefill_done = done
            self._inflight.append(req)
            self._submit_request_flows(req, cluster, now, done)
        return cb

    def _on_decode_start(self, req: Request, now: float, done: float):
        req.decode_start = now
        req.first_token = now + self.w.t_decode
        req.done = done

    def _run_tick(self) -> dict:
        sim, w = self.sim, self.w
        trace = self._generate_arrivals()
        idx = 0
        now = 0.0
        self._inflight: List[Request] = []
        decode_time = self._decode_service_time()
        t0 = sim.sim_time * sim.warmup_frac
        egress_snapped = False
        steps = int(sim.sim_time / sim.dt)
        for step in range(steps):
            now = step * sim.dt
            if not egress_snapped and now >= t0:
                # warmup ends: egress measured over the same window as
                # throughput (sent-bytes so far cover [0, now))
                self._egress_t0 = self.topology.sent_bytes
                egress_snapped = True
            # process arrivals at the first tick AT or AFTER their exact
            # arrival time, so prefill never starts before the request exists
            while idx < len(trace) and trace[idx].arrival <= now:
                self._route_and_submit_tick(trace[idx], now)
                idx += 1
            self.prfaas_pool.tick(now, sim.dt, self._on_prefill_start(PRFAAS))
            for name, pool in self.pdp_pools.items():
                pool.tick(now, sim.dt, self._on_prefill_start(name))
            self.topology.tick(now, sim.dt)
            # decode block granularity: only ticks whose interval crosses a
            # block boundary admit into decode slots (all ticks when off)
            at_boundary = (self._block_s <= 0 or math.floor(
                (now + 1e-9) / self._block_s) != math.floor(
                (now - sim.dt + 1e-9) / self._block_s) or step == 0)
            # prefill+transfer complete -> decode queue (+cache insert)
            still = []
            for req in self._inflight:
                ready = (req.prefill_done <= now and req.flows_pending == 0
                         and 0 <= req.transfer_done <= now)
                if ready:
                    cluster = req.decision.target
                    self.kv.clusters[cluster].insert(
                        req.session, req.total_len // sim.block_tokens)
                    self.decode_pools[req.home].submit(req, decode_time)
                else:
                    still.append(req)
            self._inflight = still
            for pool in self.decode_pools.values():
                pool.tick(now, sim.dt, self._on_decode_start,
                          admit=at_boundary)
            self._observe_regions()
            for name in (self._pd_names if self.autoscalers else ()):
                new_sys = self.autoscalers[name].maybe_rebalance(
                    now, self._region_telemetry(name))
                if new_sys is not None:
                    self.pdp_pools[name].capacity = new_sys.n_p
                    self.decode_pools[name].capacity = new_sys.n_d * w.bs_max
        return self.metrics()

    # --------------------------------------------------------- event engine
    def _push(self, t: float, kind: int, payload=None):
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def _wake_link(self, now: float):
        nxt = self.topology.next_event()
        if not math.isfinite(nxt) or nxt > self.sim.sim_time:
            return
        nxt = max(nxt, now + 1e-9)
        if nxt < self._link_wake - 1e-9:
            self._link_wake = nxt
            self._push(nxt, _EV_LINK)

    def _start_prefill(self, req: Request, st: float, cluster: str,
                       now: float):
        req.prefill_start = now
        done = now + st
        req.prefill_done = done
        self._submit_request_flows(req, cluster, now, done,
                                   on_all_done=self._flows_done)
        self._push(done, _EV_PREFILL_DONE, (req, cluster))

    def _flows_done(self, req: Request, tc: float):
        """All link flows drained at tc.  Only admit to decode if prefill is
        also finished by then — otherwise the PREFILL_DONE event handles it
        (never call pools with a timestamp in their future)."""
        if req.prefill_done <= tc + 1e-9:
            self._maybe_ready(req, tc)

    def _maybe_ready(self, req: Request, t: float):
        """Prefill finished and every link flow drained -> decode admission
        (exact time) in the home cluster, inserting the produced KV into the
        target cluster's prefix cache."""
        if req.rid in self._ready_seen:
            return
        if req.flows_pending > 0 or req.prefill_done > t + 1e-9:
            return
        self._ready_seen.add(req.rid)
        self.kv.clusters[req.decision.target].insert(
            req.session, req.total_len // self.sim.block_tokens)
        self._admit_decode(req, t)

    def _admit_decode(self, req: Request, t: float):
        """Hand a ready request to its home decode pool — at the exact
        ready time by default, or deferred to the next block boundary when
        ``decode_block_tokens`` models the live scheduler's admit-at-
        boundary cadence."""
        tb = self._block_boundary(t)
        if tb > t + 1e-12:
            self._push(tb, _EV_ADMIT, req)
            return
        if self.decode_pools[req.home].submit(req, tb):
            self._start_decode(req, tb)

    def _start_decode(self, req: Request, now: float):
        req.decode_start = now
        req.first_token = now + self.w.t_decode
        req.done = now + self._decode_time
        self._push(req.done, _EV_DECODE_DONE, req)

    def _ev_arrival(self, req: Request, now: float):
        cluster, st = self._route(req)
        if self._prefill_pool(cluster).submit((req, st), now):
            self._start_prefill(req, st, cluster, now)

    def _ev_control(self, now: float):
        self._observe_regions()
        for name in (self._pd_names if self.autoscalers else ()):
            new_sys = self.autoscalers[name].maybe_rebalance(
                now, self._region_telemetry(name, util_now=now))
            if new_sys is None:
                continue
            # resize ONLY this region's pools; freed capacity starts queued
            # work at the exact conversion time
            for req, st in self.pdp_pools[name].set_capacity(
                    new_sys.n_p, now):
                self._start_prefill(req, st, name, now)
            for req in self.decode_pools[name].set_capacity(
                    new_sys.n_d * self.w.bs_max, now):
                self._start_decode(req, self._block_boundary(now))
        nxt = now + self.sim.control_dt
        if nxt <= self.sim.sim_time:
            self._push(nxt, _EV_CONTROL)

    def _run_event(self) -> dict:
        sim, w = self.sim, self.w
        self.prfaas_pool = EventPool(self.system.n_prfaas)
        self.pdp_pools = {
            name: EventPool(n_p_c)
            for name, (n_p_c, _) in zip(self._pd_names, self._per_cluster)}
        self.decode_pools = {
            name: EventPool(n_d_c * w.bs_max)
            for name, (_, n_d_c) in zip(self._pd_names, self._per_cluster)}
        self._decode_time = self._decode_service_time()
        self._heap: List[tuple] = []
        self._seq = itertools.count()
        self._link_wake = math.inf
        self._ready_seen: set = set()
        for req in self._generate_arrivals():
            self._push(req.arrival, _EV_ARRIVAL, req)
        self._push(sim.sim_time * sim.warmup_frac, _EV_WARMUP)
        if sim.control_dt > 0:
            self._push(sim.control_dt, _EV_CONTROL)
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if t > sim.sim_time:
                break
            # solve every link exactly up to this event; flow completions
            # fire at their exact times and may admit requests to decode
            self.topology.advance(t)
            if kind == _EV_LINK and t >= self._link_wake - 1e-9:
                self._link_wake = math.inf
            if kind == _EV_ARRIVAL:
                self._ev_arrival(payload, t)
            elif kind == _EV_PREFILL_DONE:
                req, cluster = payload
                nxt = self._prefill_pool(cluster).release(t)
                if nxt is not None:
                    self._start_prefill(nxt[0], nxt[1], cluster, t)
                self._maybe_ready(req, t)
            elif kind == _EV_DECODE_DONE:
                nxt = self.decode_pools[payload.home].release(t)
                if nxt is not None:
                    # a freed slot refills at the next block boundary (==
                    # t when block granularity is off: done times already
                    # lie on the admitting request's block grid)
                    self._start_decode(nxt, self._block_boundary(t))
            elif kind == _EV_ADMIT:
                if self.decode_pools[payload.home].submit(payload, t):
                    self._start_decode(payload, t)
            elif kind == _EV_CONTROL:
                self._ev_control(t)
            elif kind == _EV_WARMUP:
                self._egress_t0 = self.topology.sent_bytes
            self._wake_link(t)
        self.topology.advance(sim.sim_time)
        return self.metrics()

    # -------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        sim = self.sim
        horizon = sim.sim_time
        t0 = horizon * sim.warmup_frac
        # only requests whose decode actually finishes inside the horizon
        # count as completions — both engines stamp ``done`` when decode
        # STARTS (with its future end time), so an unfiltered list inflates
        # throughput near saturation with work the horizon never absorbed
        done = [r for r in self.all_requests
                if 0 <= r.done <= horizon and r.arrival >= t0]
        ttft = np.array([r.first_token - r.arrival for r in done
                         if r.first_token > 0])
        # mean time-between-tokens per request: decode span over the
        # output_len - 1 inter-token gaps (speculation shrinks the span)
        tbt = np.array([(r.done - r.first_token)
                        / max(1, self.w.output_len - 1)
                        for r in done if r.first_token > 0])
        window = max(1e-9, horizon - t0)
        thr = len(done) / window
        offload = sum(1 for r in self.all_requests
                      if r.decision and r.decision.target == PRFAAS)
        routed = sum(1 for r in self.all_requests if r.decision)

        def _pct(a, q):
            return float(np.percentile(a, q)) if len(a) else float("nan")

        slo = self.sim.ttft_slo_s

        def _slo_stats(tt):
            """(attainment, goodput under the TTFT SLO).  SLO off (0) keeps
            the keys JSON-stable: everything attains, goodput == thr."""
            if slo <= 0:
                return 1.0, len(tt) / window
            good = int((tt <= slo).sum())
            return (good / len(tt) if len(tt) else float("nan"),
                    good / window)

        per_cluster = {}
        for name in self._pd_names:
            c_done = [r for r in done if r.home == name]
            c_ttft = np.array([r.first_token - r.arrival for r in c_done
                               if r.first_token > 0])
            cached, total = self._route_tokens[name]
            c_att, c_good = _slo_stats(c_ttft)
            per_cluster[name] = {
                "completed": len(c_done),
                "throughput_rps": len(c_done) / window,
                "ttft_mean": float(c_ttft.mean()) if len(c_ttft)
                else float("nan"),
                "ttft_p90": _pct(c_ttft, 90),
                "ttft_p99": _pct(c_ttft, 99),
                "slo_attainment": c_att,
                "goodput_rps": c_good,
                "prefill_queue": len(self.pdp_pools[name].queue),
                "decode_queue": len(self.decode_pools[name].queue),
                "threshold": self.router.threshold_for(name),
                "cache_hit_frac": cached / total if total else 0.0,
                "conversions": len(self.autoscalers[name].conversions)
                if name in self.autoscalers else 0,
            }
        thresholds = {name: self.router.threshold_for(name)
                      for name in self._pd_names}
        att, goodput = _slo_stats(ttft)
        return {
            "throughput_rps": thr,
            "ttft_mean": float(ttft.mean()) if len(ttft) else float("nan"),
            "ttft_p50": _pct(ttft, 50),
            "ttft_p90": _pct(ttft, 90),
            "ttft_p99": _pct(ttft, 99),
            "ttft_slo_s": slo,
            "slo_attainment": att,
            "goodput_rps": goodput,
            "tbt_mean": float(tbt.mean()) if len(tbt) else float("nan"),
            "tbt_p50": _pct(tbt, 50),
            "tbt_p90": _pct(tbt, 90),
            "tbt_p99": _pct(tbt, 99),
            "tbt_slo_s": self.sim.tbt_slo_s,
            "tbt_attainment": (float((tbt <= self.sim.tbt_slo_s).mean())
                               if self.sim.tbt_slo_s > 0 and len(tbt)
                               else 1.0),
            "completed": len(done),
            "offload_frac": offload / max(1, routed),
            # same measurement window as throughput: bytes sent after the
            # warmup snapshot, averaged over horizon - t0
            "egress_gbps": (self.topology.sent_bytes - self._egress_t0)
            * 8 / 1e9 / window,
            "link_util": max(l.util_ewma
                             for l in self.topology.links.values()),
            "router_adjustments": self.router.adjustments,
            "prefill_queue": len(self.prfaas_pool.queue)
            + sum(len(p.queue) for p in self.pdp_pools.values()),
            "decode_queue": sum(len(p.queue)
                                for p in self.decode_pools.values()),
            "cache": self.kv.stats(),
            # max over homes == the legacy global value for one PD cluster
            "threshold": max(thresholds.values()),
            "thresholds": thresholds,
            "session_evictions": self.session_evictions,
            "open_sessions": len(self._open_sessions),
            "clusters": per_cluster,
            "links": self.topology.pair_stats(),
        }

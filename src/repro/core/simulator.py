"""Cross-datacenter PrfaaS-PD cluster simulator (discrete-event core).

Ties every core component together under a realistic workload: bursty
(MMPP-modulated Poisson) arrivals, truncated log-normal lengths, agentic
sessions producing prefix-cache hits, a fluctuating inter-DC Ethernet link
with layer-wise pipelined KV flows, the dual-timescale scheduler, and the
hybrid prefix cache pools.

Event model (``SimConfig(engine="event")``, the default)
--------------------------------------------------------
A single priority-queue loop over exact event times — no fixed dt:

  * ARRIVAL       — pre-generated exact MMPP arrival trace (thinning over the
                    piecewise-constant rate, mean-preserving for any
                    burst_factor); routes and submits to a prefill pool.
  * PREFILL_DONE  — frees the prefill server, starts the next queued request,
                    and (with all KV flows drained) admits the request to
                    decode.
  * LINK wake     — the fair-share link is solved *exactly* between events by
                    progressive filling (``transfer.Link.advance``): flow
                    completion / layer-release ramp end / OU bandwidth
                    resample times are computed analytically.  KV flows
                    release layer-wise while prefill computes (linear ramp),
                    and cross-cache prefix copies are charged to the link.
  * DECODE_DONE   — frees a decode slot (slot count = N_d x BS_max).
  * CONTROL       — every ``control_dt``: the router's short-term congestion
                    loop observes link telemetry, and the autoscaler's
                    long-term loop may convert P<->D roles (epoch gating is
                    the autoscaler's own ``period_s``).

``SimConfig(engine="tick")`` keeps the legacy fixed-step fluid loop (fed the
identical arrival trace) for apples-to-apples equivalence testing; the event
engine reproduces its metrics within a few percent while running one to two
orders of magnitude faster.

Produces the paper's §4.3 observables: throughput, mean/P90 TTFT, egress
bandwidth (including cross-cache transfer bytes), offload fraction, cache
hit rates, queue depths.
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.hardware import Profile
from repro.core.kv_manager import GlobalKVManager
from repro.core.sim_cache import SimPrefixCache
from repro.core.router import PD, PRFAAS, Router, RouterConfig, RoutingDecision
from repro.core.autoscaler import Autoscaler, AutoscalerConfig, StageTelemetry
from repro.core.throughput_model import SystemConfig, ThroughputModel
from repro.core.transfer import Link
from repro.core.workload import Workload, mmpp_rate


@dataclass
class Request:
    rid: int
    arrival: float
    total_len: int
    session: int
    # filled by routing / execution
    decision: Optional[RoutingDecision] = None
    prefill_start: float = -1.0
    prefill_done: float = -1.0
    transfer_done: float = -1.0
    decode_start: float = -1.0
    first_token: float = -1.0
    done: float = -1.0
    flows_pending: int = 0        # in-flight link flows gating decode
    _hashes: Optional[List[int]] = field(default=None, repr=False)

    def block_hashes(self, block_tokens: int) -> List[int]:
        if self._hashes is None:
            n = self.total_len // block_tokens
            # chained-hash stand-in: unique per (session, block index), no
            # per-block tuple allocation (hot path: ~400 blocks/request)
            base = (self.session * 0x9E3779B97F4A7C15) & 0x7FFFFFFFFFFFFFFF
            self._hashes = [(base + i * 0x9E3779B1) & 0x7FFFFFFFFFFFFFFF
                            for i in range(n)]
        return self._hashes


class InstancePool:
    """N identical single-request servers with one FIFO queue (tick engine)."""

    def __init__(self, n: int):
        self.capacity = n
        self.busy: List[float] = []          # end times
        self.queue: List[tuple] = []         # (req, service_time)
        self.busy_time = 0.0

    def submit(self, req, service_time: float):
        self.queue.append((req, service_time))

    def tick(self, now: float, dt: float, on_start):
        self.busy = [t for t in self.busy if t > now]
        while self.queue and len(self.busy) < self.capacity:
            req, st = self.queue.pop(0)
            self.busy.append(now + st)
            on_start(req, now, now + st)
        self.busy_time += dt * len(self.busy)

    def utilization(self, elapsed: float) -> float:
        return self.busy_time / max(1e-9, elapsed * max(1, self.capacity))


class DecodePool(InstancePool):
    """n_d instances x BS_max slots; a request holds a slot for its decode."""


class EventPool:
    """FIFO server pool for the event engine: exact start/finish times, no
    per-tick scans.  ``submit`` returns True when the item starts now;
    otherwise it queues until ``release`` or a capacity increase frees it."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.busy = 0
        self.queue: deque = deque()
        self.busy_time = 0.0
        self._last = 0.0

    def _integrate(self, now: float):
        self.busy_time += (now - self._last) * self.busy
        self._last = now

    def submit(self, item, now: float) -> bool:
        self._integrate(now)
        if self.busy < self.capacity:
            self.busy += 1
            return True
        self.queue.append(item)
        return False

    def release(self, now: float):
        """Free one server; returns the next queued item to start (or None)."""
        self._integrate(now)
        self.busy -= 1
        if self.queue and self.busy < self.capacity:
            self.busy += 1
            return self.queue.popleft()
        return None

    def set_capacity(self, capacity: int, now: float) -> list:
        """Resize; returns queued items that can start immediately."""
        self._integrate(now)
        self.capacity = capacity
        started = []
        while self.queue and self.busy < self.capacity:
            self.busy += 1
            started.append(self.queue.popleft())
        return started

    def utilization(self, elapsed: float) -> float:
        """Busy fraction up to ``elapsed`` (== now; pools start at t=0).
        Integrates pending busy time first so mid-interval reads are
        current."""
        self._integrate(elapsed)
        return self.busy_time / max(1e-9, elapsed * max(1, self.capacity))


@dataclass
class SimConfig:
    arrival_rate: float                 # req/s offered
    sim_time: float = 1800.0
    dt: float = 0.02                    # tick engine step
    seed: int = 0
    link_gbps: float = 100.0
    link_fluctuation: float = 0.0
    pool_blocks: int = 200_000          # per-cluster prefix pool blocks
    block_tokens: int = 64
    autoscale: bool = False
    warmup_frac: float = 0.1            # exclude from metrics
    engine: str = "event"               # "event" (exact) | "tick" (legacy)
    control_dt: float = 0.25            # event engine: telemetry/control loop
    fluct_dt: float = 0.25              # event engine: OU resample period


# event kinds, ordered so ties process deterministically
_EV_ARRIVAL, _EV_PREFILL_DONE, _EV_DECODE_DONE, _EV_CONTROL, _EV_LINK = \
    range(5)


class PrfaasSimulator:
    def __init__(self, model: ThroughputModel, system: SystemConfig,
                 workload: Workload, sim: SimConfig,
                 router_cfg: RouterConfig = RouterConfig()):
        self.model = model
        self.system = system
        self.w = workload
        self.sim = sim
        self.rng = np.random.default_rng(sim.seed)

        self.router = Router(model, system, router_cfg)
        self.kv = GlobalKVManager()
        for name in (PRFAAS, PD):
            self.kv.register_cluster(
                name, SimPrefixCache(sim.pool_blocks, sim.block_tokens))
        self.link = Link(sim.link_gbps * 1e9,
                         fluctuation=sim.link_fluctuation, seed=sim.seed,
                         fluct_dt=sim.fluct_dt)
        self.prfaas_pool = InstancePool(system.n_prfaas)
        self.pdp_pool = InstancePool(system.n_p)
        self.decode_pool = DecodePool(system.n_d * workload.bs_max)
        self.autoscaler = Autoscaler(model, self.router, system) \
            if sim.autoscale else None

        self.completed: List[Request] = []
        self.all_requests: List[Request] = []
        self._next_rid = 0
        self._next_session = 0
        self._open_sessions: List[tuple] = []   # (session_id, cur_len)

    # ------------------------------------------------------------- arrivals
    def _arrival_rate(self, now: float) -> float:
        return mmpp_rate(self.sim.arrival_rate, self.w.burst_factor,
                         self.w.burst_period_s, now)

    def _new_request(self, now: float) -> Request:
        if (self._open_sessions
                and self.rng.random() < self.w.session_prob):
            i = self.rng.integers(len(self._open_sessions))
            sid, cur = self._open_sessions[i]
            grow = int(self.rng.exponential(self.w.session_growth)) + 1
            total = min(cur + grow, int(self.w.lengths.hi))
            self._open_sessions[i] = (sid, total)
        else:
            sid = self._next_session
            self._next_session += 1
            total = int(self.w.lengths.sample(self.rng, 1)[0])
            self._open_sessions.append((sid, total))
            if len(self._open_sessions) > 512:
                self._open_sessions.pop(0)
        r = Request(self._next_rid, now, total, sid)
        self._next_rid += 1
        self.all_requests.append(r)
        return r

    def _generate_arrivals(self) -> List[Request]:
        """Exact MMPP arrival trace via thinning over the piecewise-constant
        rate — both engines consume the identical trace, so equivalence
        differences come from time discretization only."""
        sim, w = self.sim, self.w
        out: List[Request] = []
        lam_max = sim.arrival_rate * max(w.burst_factor, 1.0)
        if lam_max <= 0:
            return out
        t = 0.0
        while True:
            t += self.rng.exponential(1.0 / lam_max)
            if t >= sim.sim_time:
                return out
            lam = self._arrival_rate(t)
            if lam < lam_max and self.rng.random() * lam_max > lam:
                continue                             # thinned
            out.append(self._new_request(t))

    # ---------------------------------------------------- shared byte model
    def _wire_profile(self) -> Profile:
        return self.model.prfaas_profile or self.model.pd_profile

    def _prefill_wire_bytes(self, req: Request) -> float:
        """KV bytes for a PrfaaS-prefilled request crossing the link (the
        already-cached prefix need not be resent)."""
        prof = self._wire_profile()
        nbytes = prof.s_kv(req.total_len)
        if req.decision.cached_tokens:
            nbytes -= prof.s_kv(req.decision.cached_tokens)
        return max(nbytes, 1.0)

    def _cross_cache_bytes(self, decision: RoutingDecision) -> float:
        """Cached-prefix KV bytes copied between clusters when the router
        reuses the best cache anywhere (abundant-bandwidth regime)."""
        return max(self._wire_profile().s_kv(decision.cached_tokens), 1.0)

    def _route(self, req: Request) -> Tuple[str, float]:
        n_blocks = req.total_len // self.sim.block_tokens
        matches = {name: c.match(req.session, n_blocks)
                   for name, c in self.kv.clusters.items()}
        decision = self.router.route(req.total_len, matches,
                                     self.link.congestion_signal())
        req.decision = decision
        incr = max(decision.incremental, 1)
        if decision.target == PRFAAS:
            return PRFAAS, self.model.prfaas_profile.t_prefill(incr)
        return PD, self.model.pd_profile.t_prefill(incr)

    # ----------------------------------------------------------------- run
    def run(self) -> dict:
        if self.sim.engine == "tick":
            return self._run_tick()
        if self.sim.engine != "event":
            raise ValueError(f"unknown engine {self.sim.engine!r}; "
                             "expected 'event' or 'tick'")
        return self._run_event()

    # ---------------------------------------------------------- tick engine
    def _route_and_submit_tick(self, req: Request, now: float):
        cluster, st = self._route(req)
        pool = self.prfaas_pool if cluster == PRFAAS else self.pdp_pool
        pool.submit(req, st)

    def _submit_request_flows(self, req: Request, cluster: str, now: float,
                              done: float, on_all_done=None):
        """Submit this request's link flows (main KV + cross-cache copy) and
        wire their completion into the request's readiness state.
        ``on_all_done(req, tc)`` fires when the LAST flow drains, at its
        exact completion time (event engine decode admission)."""
        req.flows_pending = 0

        def on_flow_done(tc: float, _req=req):
            _req.flows_pending -= 1
            _req.transfer_done = max(_req.transfer_done, tc)
            if _req.flows_pending == 0 and on_all_done is not None:
                on_all_done(_req, tc)

        if cluster == PRFAAS:
            # layer-wise pipelined KV flow: releases linearly while prefill
            # computes (the fluid limit of the per-layer staircase)
            self.link.submit(self._prefill_wire_bytes(req), now,
                             ramp_end=done, on_done=on_flow_done)
            req.flows_pending += 1
        if req.decision.cross_cache_transfer and req.decision.cached_tokens:
            # cached prefix lives in the other cluster: the copy is already
            # materialized, so it is wire-eligible immediately (eager)
            self.link.submit(self._cross_cache_bytes(req.decision), now,
                             ramp_end=now, on_done=on_flow_done)
            req.flows_pending += 1
        if req.flows_pending == 0:
            req.transfer_done = done      # intra-cluster RDMA: free

    def _on_prefill_start(self, cluster: str):
        def cb(req: Request, now: float, done: float):
            req.prefill_start = now
            req.prefill_done = done
            self._inflight.append(req)
            self._submit_request_flows(req, cluster, now, done)
        return cb

    def _on_decode_start(self, req: Request, now: float, done: float):
        req.decode_start = now
        req.first_token = now + self.w.t_decode
        req.done = done

    def _run_tick(self) -> dict:
        sim, w = self.sim, self.w
        trace = self._generate_arrivals()
        idx = 0
        now = 0.0
        self._inflight: List[Request] = []
        decode_time = w.output_len * w.t_decode
        steps = int(sim.sim_time / sim.dt)
        for step in range(steps):
            now = step * sim.dt
            # process arrivals at the first tick AT or AFTER their exact
            # arrival time, so prefill never starts before the request exists
            while idx < len(trace) and trace[idx].arrival <= now:
                self._route_and_submit_tick(trace[idx], now)
                idx += 1
            self.prfaas_pool.tick(now, sim.dt, self._on_prefill_start(PRFAAS))
            self.pdp_pool.tick(now, sim.dt, self._on_prefill_start(PD))
            self.link.tick(now, sim.dt)
            # prefill+transfer complete -> decode queue (+cache insert)
            still = []
            for req in self._inflight:
                ready = (req.prefill_done <= now and req.flows_pending == 0
                         and 0 <= req.transfer_done <= now)
                if ready:
                    cluster = req.decision.target
                    self.kv.clusters[cluster].insert(
                        req.session, req.total_len // sim.block_tokens)
                    self.decode_pool.submit(req, decode_time)
                else:
                    still.append(req)
            self._inflight = still
            self.decode_pool.tick(now, sim.dt, self._on_decode_start)
            self.router.observe_congestion(self.link.congestion_signal())
            if self.autoscaler is not None:
                tel = StageTelemetry(
                    prefill_queue=len(self.prfaas_pool.queue)
                    + len(self.pdp_pool.queue),
                    decode_queue=len(self.decode_pool.queue))
                new_sys = self.autoscaler.maybe_rebalance(now, tel)
                if new_sys is not None:
                    self.pdp_pool.capacity = new_sys.n_p
                    self.decode_pool.capacity = new_sys.n_d * w.bs_max
        return self.metrics()

    # --------------------------------------------------------- event engine
    def _push(self, t: float, kind: int, payload=None):
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def _wake_link(self, now: float):
        nxt = self.link.next_event()
        if not math.isfinite(nxt) or nxt > self.sim.sim_time:
            return
        nxt = max(nxt, now + 1e-9)
        if nxt < self._link_wake - 1e-9:
            self._link_wake = nxt
            self._push(nxt, _EV_LINK)

    def _start_prefill(self, req: Request, st: float, cluster: str,
                       now: float):
        req.prefill_start = now
        done = now + st
        req.prefill_done = done
        self._submit_request_flows(req, cluster, now, done,
                                   on_all_done=self._flows_done)
        self._push(done, _EV_PREFILL_DONE, (req, cluster))

    def _flows_done(self, req: Request, tc: float):
        """All link flows drained at tc.  Only admit to decode if prefill is
        also finished by then — otherwise the PREFILL_DONE event handles it
        (never call pools with a timestamp in their future)."""
        if req.prefill_done <= tc + 1e-9:
            self._maybe_ready(req, tc)

    def _maybe_ready(self, req: Request, t: float):
        """Prefill finished and every link flow drained -> decode admission
        (exact time), inserting the produced KV into the prefix cache."""
        if req.rid in self._ready_seen:
            return
        if req.flows_pending > 0 or req.prefill_done > t + 1e-9:
            return
        self._ready_seen.add(req.rid)
        self.kv.clusters[req.decision.target].insert(
            req.session, req.total_len // self.sim.block_tokens)
        if self.decode_pool.submit(req, t):
            self._start_decode(req, t)

    def _start_decode(self, req: Request, now: float):
        req.decode_start = now
        req.first_token = now + self.w.t_decode
        req.done = now + self._decode_time
        self._push(req.done, _EV_DECODE_DONE, req)

    def _ev_arrival(self, req: Request, now: float):
        cluster, st = self._route(req)
        pool = self.prfaas_pool if cluster == PRFAAS else self.pdp_pool
        if pool.submit((req, st), now):
            self._start_prefill(req, st, cluster, now)

    def _ev_control(self, now: float):
        self.router.observe_congestion(self.link.congestion_signal())
        if self.autoscaler is not None:
            tel = StageTelemetry(
                prefill_queue=len(self.prfaas_pool.queue)
                + len(self.pdp_pool.queue),
                decode_queue=len(self.decode_pool.queue),
                prefill_util=self.pdp_pool.utilization(max(now, 1e-9)),
                decode_util=self.decode_pool.utilization(max(now, 1e-9)))
            new_sys = self.autoscaler.maybe_rebalance(now, tel)
            if new_sys is not None:
                for req, st in self.pdp_pool.set_capacity(new_sys.n_p, now):
                    self._start_prefill(req, st, PD, now)
                for req in self.decode_pool.set_capacity(
                        new_sys.n_d * self.w.bs_max, now):
                    self._start_decode(req, now)
        nxt = now + self.sim.control_dt
        if nxt <= self.sim.sim_time:
            self._push(nxt, _EV_CONTROL)

    def _run_event(self) -> dict:
        sim, w = self.sim, self.w
        self.prfaas_pool = EventPool(self.system.n_prfaas)
        self.pdp_pool = EventPool(self.system.n_p)
        self.decode_pool = EventPool(self.system.n_d * w.bs_max)
        self._decode_time = w.output_len * w.t_decode
        self._heap: List[tuple] = []
        self._seq = itertools.count()
        self._link_wake = math.inf
        self._ready_seen: set = set()
        for req in self._generate_arrivals():
            self._push(req.arrival, _EV_ARRIVAL, req)
        if sim.control_dt > 0:
            self._push(sim.control_dt, _EV_CONTROL)
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if t > sim.sim_time:
                break
            # solve the link exactly up to this event; flow completions fire
            # at their exact times and may admit requests to decode
            self.link.advance(t)
            if kind == _EV_LINK and t >= self._link_wake - 1e-9:
                self._link_wake = math.inf
            if kind == _EV_ARRIVAL:
                self._ev_arrival(payload, t)
            elif kind == _EV_PREFILL_DONE:
                req, cluster = payload
                pool = self.prfaas_pool if cluster == PRFAAS else self.pdp_pool
                nxt = pool.release(t)
                if nxt is not None:
                    self._start_prefill(nxt[0], nxt[1], cluster, t)
                self._maybe_ready(req, t)
            elif kind == _EV_DECODE_DONE:
                nxt = self.decode_pool.release(t)
                if nxt is not None:
                    self._start_decode(nxt, t)
            elif kind == _EV_CONTROL:
                self._ev_control(t)
            self._wake_link(t)
        self.link.advance(sim.sim_time)
        return self.metrics()

    # -------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        sim = self.sim
        horizon = sim.sim_time
        t0 = horizon * sim.warmup_frac
        done = [r for r in self.all_requests if r.done >= 0 and r.arrival >= t0]
        ttft = np.array([r.first_token - r.arrival for r in done
                         if r.first_token > 0])
        thr = len(done) / max(1e-9, horizon - t0)
        offload = sum(1 for r in self.all_requests
                      if r.decision and r.decision.target == PRFAAS)
        routed = sum(1 for r in self.all_requests if r.decision)
        return {
            "throughput_rps": thr,
            "ttft_mean": float(ttft.mean()) if len(ttft) else float("nan"),
            "ttft_p50": float(np.percentile(ttft, 50)) if len(ttft) else float("nan"),
            "ttft_p90": float(np.percentile(ttft, 90)) if len(ttft) else float("nan"),
            "ttft_p99": float(np.percentile(ttft, 99)) if len(ttft) else float("nan"),
            "completed": len(done),
            "offload_frac": offload / max(1, routed),
            "egress_gbps": self.link.sent_bytes * 8 / 1e9 / max(1e-9, horizon),
            "link_util": self.link.util_ewma,
            "router_adjustments": self.router.adjustments,
            "prefill_queue": len(self.prfaas_pool.queue) + len(self.pdp_pool.queue),
            "decode_queue": len(self.decode_pool.queue),
            "cache": self.kv.stats(),
            "threshold": self.router.threshold,
        }

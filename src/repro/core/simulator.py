"""Cross-datacenter PrfaaS-PD cluster simulator (fluid/discrete-event).

Ties every core component together under a realistic workload: bursty
(MMPP-modulated Poisson) arrivals, truncated log-normal lengths, agentic
sessions producing prefix-cache hits, a fluctuating inter-DC Ethernet link
with layer-wise pipelined KV flows, the dual-timescale scheduler, and the
hybrid prefix cache pools.

Produces the paper's §4.3 observables: throughput, mean/P90 TTFT, egress
bandwidth, offload fraction, cache hit rates, queue depths.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.blockpool import BlockPool
from repro.core.hardware import Profile
from repro.core.kv_manager import GlobalKVManager
from repro.core.prefix_cache import HybridPrefixCache
from repro.core.router import PD, PRFAAS, Router, RouterConfig, RoutingDecision
from repro.core.autoscaler import Autoscaler, AutoscalerConfig, StageTelemetry
from repro.core.throughput_model import SystemConfig, ThroughputModel
from repro.core.transfer import Link, layerwise_release
from repro.core.workload import Workload


@dataclass
class Request:
    rid: int
    arrival: float
    total_len: int
    session: int
    # filled by routing / execution
    decision: Optional[RoutingDecision] = None
    prefill_start: float = -1.0
    prefill_done: float = -1.0
    transfer_done: float = -1.0
    decode_start: float = -1.0
    first_token: float = -1.0
    done: float = -1.0

    def block_hashes(self, block_tokens: int) -> List[int]:
        n = self.total_len // block_tokens
        sid = self.session
        return [hash((sid, i)) & 0x7FFFFFFFFFFFFFFF for i in range(n)]


class InstancePool:
    """N identical single-request servers with one FIFO queue."""

    def __init__(self, n: int):
        self.capacity = n
        self.busy: List[float] = []          # end times
        self.queue: List[tuple] = []         # (req, service_time)
        self.busy_time = 0.0

    def submit(self, req, service_time: float):
        self.queue.append((req, service_time))

    def tick(self, now: float, dt: float, on_start):
        self.busy = [t for t in self.busy if t > now]
        while self.queue and len(self.busy) < self.capacity:
            req, st = self.queue.pop(0)
            self.busy.append(now + st)
            on_start(req, now, now + st)
        self.busy_time += dt * len(self.busy)

    def utilization(self, elapsed: float) -> float:
        return self.busy_time / max(1e-9, elapsed * max(1, self.capacity))


class DecodePool:
    """n_d instances x BS_max slots; a request holds a slot for its decode."""

    def __init__(self, slots: int):
        self.capacity = slots
        self.busy: List[float] = []
        self.queue: List[tuple] = []
        self.busy_time = 0.0

    def submit(self, req, service_time: float):
        self.queue.append((req, service_time))

    def tick(self, now: float, dt: float, on_start):
        self.busy = [t for t in self.busy if t > now]
        while self.queue and len(self.busy) < self.capacity:
            req, st = self.queue.pop(0)
            self.busy.append(now + st)
            on_start(req, now, now + st)
        self.busy_time += dt * len(self.busy)


@dataclass
class SimConfig:
    arrival_rate: float                 # req/s offered
    sim_time: float = 1800.0
    dt: float = 0.02
    seed: int = 0
    link_gbps: float = 100.0
    link_fluctuation: float = 0.0
    pool_blocks: int = 200_000          # per-cluster prefix pool blocks
    block_tokens: int = 64
    autoscale: bool = False
    warmup_frac: float = 0.1            # exclude from metrics


class PrfaasSimulator:
    def __init__(self, model: ThroughputModel, system: SystemConfig,
                 workload: Workload, sim: SimConfig,
                 router_cfg: RouterConfig = RouterConfig()):
        self.model = model
        self.system = system
        self.w = workload
        self.sim = sim
        self.rng = np.random.default_rng(sim.seed)

        self.router = Router(model, system, router_cfg)
        self.kv = GlobalKVManager()
        for name in (PRFAAS, PD):
            pool = BlockPool(sim.pool_blocks, sim.block_tokens,
                             block_bytes=1 << 20)
            self.kv.register_cluster(
                name, HybridPrefixCache(pool, 0, 1 << 20))
        self.link = Link(sim.link_gbps * 1e9,
                         fluctuation=sim.link_fluctuation, seed=sim.seed)
        self.prfaas_pool = InstancePool(system.n_prfaas)
        self.pdp_pool = InstancePool(system.n_p)
        self.decode_pool = DecodePool(system.n_d * workload.bs_max)
        self.autoscaler = Autoscaler(model, self.router, system) \
            if sim.autoscale else None

        self.completed: List[Request] = []
        self.all_requests: List[Request] = []
        self._next_rid = 0
        self._next_session = 0
        self._open_sessions: List[tuple] = []   # (session_id, cur_len)

    # ------------------------------------------------------------- arrivals
    def _arrival_rate(self, now: float) -> float:
        bf = self.w.burst_factor
        if bf <= 1.0:
            return self.sim.arrival_rate
        # square-wave MMPP: alternate high/low phases, mean preserved
        phase = (now % self.w.burst_period_s) < self.w.burst_period_s / 2
        return self.sim.arrival_rate * (bf if phase else max(0.0, 2.0 - bf))

    def _spawn_arrivals(self, now: float, dt: float) -> List[Request]:
        lam = self._arrival_rate(now) * dt
        n = self.rng.poisson(lam)
        out = []
        for _ in range(n):
            if (self._open_sessions
                    and self.rng.random() < self.w.session_prob):
                i = self.rng.integers(len(self._open_sessions))
                sid, cur = self._open_sessions[i]
                grow = int(self.rng.exponential(self.w.session_growth)) + 1
                total = min(cur + grow, int(self.w.lengths.hi))
                self._open_sessions[i] = (sid, total)
            else:
                sid = self._next_session
                self._next_session += 1
                total = int(self.w.lengths.sample(self.rng, 1)[0])
                self._open_sessions.append((sid, total))
                if len(self._open_sessions) > 512:
                    self._open_sessions.pop(0)
            r = Request(self._next_rid, now, total, sid)
            self._next_rid += 1
            out.append(r)
            self.all_requests.append(r)
        return out

    # ------------------------------------------------------------ execution
    def _route_and_submit(self, req: Request, now: float):
        hashes = req.block_hashes(self.sim.block_tokens)
        matches = {name: c.match_hashes(hashes)
                   for name, c in self.kv.clusters.items()}
        decision = self.router.route(req.total_len, matches,
                                     self.link.congestion_signal())
        req.decision = decision
        incr = max(decision.incremental, 1)
        if decision.target == PRFAAS:
            st = self.model.prfaas_profile.t_prefill(incr)
            self.prfaas_pool.submit(req, st)
        else:
            st = self.model.pd_profile.t_prefill(incr)
            self.pdp_pool.submit(req, st)

    def _on_prefill_start(self, cluster: str):
        def cb(req: Request, now: float, done: float):
            req.prefill_start = now
            req.prefill_done = done
            self._inflight.append(req)
            if cluster == PRFAAS:
                incr = max(req.decision.incremental, 1)
                nbytes = self.model.prfaas_profile.s_kv(req.total_len) \
                    - (self.model.prfaas_profile.s_kv(req.decision.cached_tokens)
                       if req.decision.cached_tokens else 0.0)
                nbytes = max(nbytes, 1.0)
                rel = layerwise_release(now, done - now, nbytes)

                def on_done(t, _req=req):
                    _req.transfer_done = t

                self.link.submit(nbytes, now, release=rel, on_done=on_done)
            else:
                req.transfer_done = done      # intra-cluster RDMA: free
        return cb

    def _on_decode_start(self, req: Request, now: float, done: float):
        req.decode_start = now
        req.first_token = now + self.w.t_decode
        req.done = done

    # ----------------------------------------------------------------- run
    def run(self) -> dict:
        sim, w = self.sim, self.w
        now = 0.0
        self._inflight: List[Request] = []
        decode_time = w.output_len * w.t_decode
        steps = int(sim.sim_time / sim.dt)
        for step in range(steps):
            now = step * sim.dt
            for req in self._spawn_arrivals(now, sim.dt):
                self._route_and_submit(req, now)
            self.prfaas_pool.tick(now, sim.dt, self._on_prefill_start(PRFAAS))
            self.pdp_pool.tick(now, sim.dt, self._on_prefill_start(PD))
            self.link.tick(now, sim.dt)
            # prefill+transfer complete -> decode queue (+cache insert)
            still = []
            for req in self._inflight:
                ready = (req.prefill_done <= now
                         and 0 <= req.transfer_done <= now)
                if ready:
                    cluster = req.decision.target
                    self.kv.clusters[cluster].insert_hashes(
                        req.block_hashes(sim.block_tokens))
                    self.decode_pool.submit(req, decode_time)
                else:
                    still.append(req)
            self._inflight = still
            self.decode_pool.tick(now, sim.dt, self._on_decode_start)
            self.router.observe_congestion(self.link.congestion_signal())
            if self.autoscaler is not None:
                tel = StageTelemetry(
                    prefill_queue=len(self.prfaas_pool.queue)
                    + len(self.pdp_pool.queue),
                    decode_queue=len(self.decode_pool.queue))
                new_sys = self.autoscaler.maybe_rebalance(now, tel)
                if new_sys is not None:
                    self.pdp_pool.capacity = new_sys.n_p
                    self.decode_pool.capacity = new_sys.n_d * w.bs_max
        return self.metrics()

    # -------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        sim = self.sim
        horizon = sim.sim_time
        t0 = horizon * sim.warmup_frac
        done = [r for r in self.all_requests if r.done >= 0 and r.arrival >= t0]
        ttft = np.array([r.first_token - r.arrival for r in done
                         if r.first_token > 0])
        thr = len(done) / max(1e-9, horizon - t0)
        offload = sum(1 for r in self.all_requests
                      if r.decision and r.decision.target == PRFAAS)
        routed = sum(1 for r in self.all_requests if r.decision)
        return {
            "throughput_rps": thr,
            "ttft_mean": float(ttft.mean()) if len(ttft) else float("nan"),
            "ttft_p50": float(np.percentile(ttft, 50)) if len(ttft) else float("nan"),
            "ttft_p90": float(np.percentile(ttft, 90)) if len(ttft) else float("nan"),
            "ttft_p99": float(np.percentile(ttft, 99)) if len(ttft) else float("nan"),
            "completed": len(done),
            "offload_frac": offload / max(1, routed),
            "egress_gbps": self.link.sent_bytes * 8 / 1e9 / max(1e-9, horizon),
            "link_util": self.link.util_ewma,
            "router_adjustments": self.router.adjustments,
            "prefill_queue": len(self.prfaas_pool.queue) + len(self.pdp_pool.queue),
            "decode_queue": len(self.decode_pool.queue),
            "cache": self.kv.stats(),
            "threshold": self.router.threshold,
        }

"""Short-term scheduling: bandwidth- and cache-aware request routing
(paper §3.4.3, short-term loop).

Each request originates at a *home* PD cluster (its region).  Decision per
request (incremental uncached length l after prefix matching):
  * l > t      -> PrfaaS cluster (remote long-context prefill)
  * l <= t     -> home PD-P (local prefill)
with the paper's two cache-aware regimes:
  * bandwidth SCARCE  -> evaluate home and PrfaaS prefixes independently:
       if l_total - l_home <= t : prefill locally (use home's own cache)
       else                     : offload (use PrfaaS's own cache)
  * bandwidth ABUNDANT -> use the best cache anywhere across ALL clusters
       l_prefix = max over clusters; route on l_total - l_prefix and
       cross-transfer the cache when the owning cluster differs from the
       prefill target (the caller charges the owner<->target pair link).

The caller passes only the cluster matches reachable from ``home`` over the
link topology, so an unlinked region's cache is never chosen.  The classic
two-cluster deployment is ``home == PD`` with matches {PRFAAS, PD} and
reproduces the original decision table exactly.

This Router is the ONE routing policy in the repo: both the discrete-event
``core.simulator.PrfaasSimulator`` and the live JAX
``serving.CrossDCDeployment`` instantiate it over a
``transfer.LinkTopology`` — which is what makes ``launch.serve
--cross-validate`` (replaying a live run's arrivals through the simulator)
a meaningful policy/actual check.

The threshold t is re-derived from the live profile whenever the congestion
monitor triggers (egress utilization / queue depth), which is the paper's
"short-term routing adjustment".  The threshold is a *per-home vector*:
``observe_congestion(signal, home=...)`` adjusts only that home cluster's t
from its own regional congestion signal (``LinkTopology.dest_signal``), so
a congested region raises its offload bar alone while quiet regions keep
routing normally.  Calling without ``home`` keeps the legacy single global
threshold (two-cluster deployments, direct Router use).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.throughput_model import SystemConfig, ThroughputModel

PRFAAS = "prfaas"
PD = "pd"


@dataclass
class RoutingDecision:
    """One routing verdict.

    ``cross_cache_transfer`` is True when the reused prefix lives in a
    DIFFERENT cluster than ``target`` (abundant-bandwidth regime only: the
    router picks the best cache anywhere, and the cached-prefix KV must be
    copied across the inter-DC link before prefill can reuse it).  The
    simulator charges those ``S_kv(cached_tokens)`` bytes to the link as an
    eager flow — the copy is already materialized, unlike the layer-wise
    pipelined KV of the prefill itself — and decode admission waits for it.
    """

    target: str                  # "prfaas" | a PD cluster name
    cached_tokens: int           # reused prefix at the chosen cluster
    incremental: int             # tokens actually prefilled
    cache_cluster: str           # where the reused prefix lives
    cross_cache_transfer: bool = False
    home: str = PD               # the request's regional PD cluster


@dataclass
class RouterConfig:
    util_high: float = 0.90      # egress-utilization trigger
    queue_high_bytes: float = 2e9
    util_abundant: float = 0.50  # below this, bandwidth is "abundant"
    threshold_boost: float = 1.35  # raise t when congested
    min_threshold: float = 512.0


class Router:
    def __init__(self, model: ThroughputModel, system: SystemConfig,
                 cfg: Optional[RouterConfig] = None):
        self.model = model
        self.system = system
        # a fresh config per router: a dataclass default argument would be
        # one shared mutable instance across every Router in the process
        self.cfg = RouterConfig() if cfg is None else cfg
        self.threshold = system.threshold
        self.base_threshold = system.threshold
        # per-home threshold vector (short-term loop, regionalized): a home
        # without an entry falls back to the global ``threshold`` above
        self._home_t: Dict[str, float] = {}
        self._home_base: Dict[str, float] = {}
        self.adjustments = 0
        self.decisions = {PRFAAS: 0, PD: 0}
        self.cross_transfers = 0

    # ----------------------------------------------------- congestion loop
    def threshold_for(self, home: str) -> float:
        """Current routing threshold for requests originating at ``home``."""
        return self._home_t.get(home, self.threshold)

    @property
    def thresholds(self) -> Dict[str, float]:
        """Per-home threshold vector (homes seen by the congestion loop)."""
        return dict(self._home_t)

    def observe_congestion(self, signal: dict, home: Optional[str] = None):
        """Short-term adjustment: raise t near the bandwidth ceiling (longer
        requests => lower per-request KV throughput), relax it when clear.
        With ``home`` given, only that home cluster's threshold moves — the
        signal should then be that region's own congestion view."""
        congested = (signal.get("util", 0.0) > self.cfg.util_high
                     or signal.get("queue_bytes", 0.0) > self.cfg.queue_high_bytes)
        if home is None:
            t, base = self.threshold, self.base_threshold
        else:
            t = self._home_t.get(home, self.threshold)
            base = self._home_base.get(home, self.base_threshold)
        if congested:
            t = min(t * self.cfg.threshold_boost,
                    self.model.workload.lengths.hi)
            self.adjustments += 1
        elif t > base:
            t = max(base, t / self.cfg.threshold_boost)
        if home is None:
            self.threshold = t
        else:
            self._home_t[home] = t

    def reoptimize(self, n_prfaas: int, n_p: int, n_d: int, b_out: float,
                   home: Optional[str] = None):
        """Re-derive t for new instance counts (called by the autoscaler).
        With ``home`` given (per-region autoscaling), only that home's base
        threshold is re-anchored."""
        best, _, _ = self.model.grid_search(n_prfaas, n_p + n_d, b_out)
        if best is not None:
            # keep the searched split only for t; N allocation is the
            # autoscaler's decision
            if home is None:
                self.base_threshold = best.threshold
                self.threshold = best.threshold
            else:
                self._home_base[home] = best.threshold
                self._home_t[home] = best.threshold

    # --------------------------------------------------------------- route
    def route(self, l_total: int, matches: Dict[str, int],
              bandwidth_signal: Optional[dict] = None,
              home: str = PD) -> RoutingDecision:
        """Route one request originating at ``home``.  ``matches`` maps every
        reachable cluster (home, PrfaaS, and — bandwidth permitting — other
        regions) to its matched prefix tokens; ``bandwidth_signal`` is the
        home<->PrfaaS pair telemetry, which decides the regime."""
        l_home = matches.get(home, 0)
        l_prfaas = matches.get(PRFAAS, 0)
        signal = bandwidth_signal or {}
        abundant = signal.get("util", 0.0) < self.cfg.util_abundant
        t = self.threshold_for(home)

        if abundant:
            # compute is scarce: use the best cache across all clusters
            # (prefer home on ties, then dict order = registration order)
            best_cluster, l_prefix = home, l_home
            for name, m in matches.items():
                if m > l_prefix:
                    best_cluster, l_prefix = name, m
            incr = l_total - l_prefix
            target = home if incr <= t else PRFAAS
            # prefer the target's own cache on ties (no copy needed)
            cache_cluster = (target if matches.get(target, 0) >= l_prefix
                             else best_cluster)
            cross = cache_cluster != target and l_prefix > 0
            cached = l_prefix
        else:
            # bandwidth is scarce: evaluate home and PrfaaS independently
            if l_total - l_home <= t:
                target, cached, cache_cluster, cross = home, l_home, home, False
            else:
                target, cached, cache_cluster, cross = \
                    PRFAAS, l_prfaas, PRFAAS, False
            incr = l_total - cached

        if self.system.n_prfaas == 0:
            target, cached, cache_cluster, cross = home, l_home, home, False
            incr = l_total - cached
        elif self.system.n_p == 0:          # naive hetero: no local prefill
            target, cached, cache_cluster, cross = PRFAAS, l_prfaas, PRFAAS, False
            incr = l_total - cached
        self.decisions[target] = self.decisions.get(target, 0) + 1
        if cross:
            self.cross_transfers += 1
        return RoutingDecision(target=target, cached_tokens=cached,
                               incremental=max(0, incr),
                               cache_cluster=cache_cluster,
                               cross_cache_transfer=cross, home=home)

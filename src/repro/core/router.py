"""Short-term scheduling: bandwidth- and cache-aware request routing
(paper §3.4.3, short-term loop).

Decision per request (incremental uncached length l after prefix matching):
  * l > t      -> PrfaaS cluster (remote long-context prefill)
  * l <= t     -> local PD-P
with the paper's two cache-aware regimes:
  * bandwidth SCARCE  -> evaluate each cluster's prefix independently:
       if l_total - l_pd <= t : prefill locally (use PD's own cache)
       else                   : offload (use PrfaaS's own cache)
  * bandwidth ABUNDANT -> use the best cache anywhere
       l_prefix = max(l_prfaas, l_pd); route on l_total - l_prefix and
       cross-transfer the cache if the owning cluster differs.

The threshold t is re-derived from the live profile whenever the congestion
monitor triggers (egress utilization / queue depth), which is the paper's
"short-term routing adjustment".
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.throughput_model import SystemConfig, ThroughputModel

PRFAAS = "prfaas"
PD = "pd"


@dataclass
class RoutingDecision:
    """One routing verdict.

    ``cross_cache_transfer`` is True when the reused prefix lives in a
    DIFFERENT cluster than ``target`` (abundant-bandwidth regime only: the
    router picks the best cache anywhere, and the cached-prefix KV must be
    copied across the inter-DC link before prefill can reuse it).  The
    simulator charges those ``S_kv(cached_tokens)`` bytes to the link as an
    eager flow — the copy is already materialized, unlike the layer-wise
    pipelined KV of the prefill itself — and decode admission waits for it.
    """

    target: str                  # "prfaas" | "pd"
    cached_tokens: int           # reused prefix at the chosen cluster
    incremental: int             # tokens actually prefilled
    cache_cluster: str           # where the reused prefix lives
    cross_cache_transfer: bool = False


@dataclass
class RouterConfig:
    util_high: float = 0.90      # egress-utilization trigger
    queue_high_bytes: float = 2e9
    util_abundant: float = 0.50  # below this, bandwidth is "abundant"
    threshold_boost: float = 1.35  # raise t when congested
    min_threshold: float = 512.0


class Router:
    def __init__(self, model: ThroughputModel, system: SystemConfig,
                 cfg: RouterConfig = RouterConfig()):
        self.model = model
        self.system = system
        self.cfg = cfg
        self.threshold = system.threshold
        self.base_threshold = system.threshold
        self.adjustments = 0
        self.decisions = {PRFAAS: 0, PD: 0}
        self.cross_transfers = 0

    # ----------------------------------------------------- congestion loop
    def observe_congestion(self, signal: dict):
        """Short-term adjustment: raise t near the bandwidth ceiling (longer
        requests => lower per-request KV throughput), relax it when clear."""
        congested = (signal.get("util", 0.0) > self.cfg.util_high
                     or signal.get("queue_bytes", 0.0) > self.cfg.queue_high_bytes)
        if congested:
            self.threshold = min(self.threshold * self.cfg.threshold_boost,
                                 self.model.workload.lengths.hi)
            self.adjustments += 1
        elif self.threshold > self.base_threshold:
            self.threshold = max(self.base_threshold,
                                 self.threshold / self.cfg.threshold_boost)

    def reoptimize(self, n_prfaas: int, n_p: int, n_d: int, b_out: float):
        """Re-derive t for new instance counts (called by the autoscaler)."""
        best, _, _ = self.model.grid_search(n_prfaas, n_p + n_d, b_out)
        if best is not None:
            # keep the searched split only for t; N allocation is the
            # autoscaler's decision
            self.base_threshold = best.threshold
            self.threshold = best.threshold

    # --------------------------------------------------------------- route
    def route(self, l_total: int, matches: Dict[str, int],
              bandwidth_signal: Optional[dict] = None) -> RoutingDecision:
        l_pd = matches.get(PD, 0)
        l_prfaas = matches.get(PRFAAS, 0)
        signal = bandwidth_signal or {}
        abundant = signal.get("util", 0.0) < self.cfg.util_abundant
        t = self.threshold

        if abundant:
            # compute is scarce: use the best cache across all clusters
            l_prefix = max(l_prfaas, l_pd)
            incr = l_total - l_prefix
            if incr <= t:
                target, cache_cluster = PD, (PD if l_pd >= l_prfaas else PRFAAS)
            else:
                target, cache_cluster = PRFAAS, (PRFAAS if l_prfaas >= l_pd
                                                 else PD)
            cross = cache_cluster != target and l_prefix > 0
            cached = l_prefix
        else:
            # bandwidth is scarce: evaluate clusters independently
            if l_total - l_pd <= t:
                target, cached, cache_cluster, cross = PD, l_pd, PD, False
            else:
                target, cached, cache_cluster, cross = \
                    PRFAAS, l_prfaas, PRFAAS, False
            incr = l_total - cached

        if self.system.n_prfaas == 0:
            target, cached, cache_cluster, cross = PD, l_pd, PD, False
            incr = l_total - cached
        elif self.system.n_p == 0:          # naive hetero: no local prefill
            target, cached, cache_cluster, cross = PRFAAS, l_prfaas, PRFAAS, False
            incr = l_total - cached
        self.decisions[target] += 1
        if cross:
            self.cross_transfers += 1
        return RoutingDecision(target=target, cached_tokens=cached,
                               incremental=max(0, incr),
                               cache_cluster=cache_cluster,
                               cross_cache_transfer=cross)
